"""AOT export: lower every L2/L1 entry point to HLO text + metadata.

Run once at build time (`make artifacts`); the rust coordinator then
runs self-contained with Python never on the hot path. Emits into
artifacts/:

  model-<preset>.hlo.txt    train_step: (*params, x, y) -> (loss, *grads)
  eval-<preset>.hlo.txt     eval_step:  (*params, x, y) -> (loss, n_top1, n_top5)
  layout-<preset>.json      per-slot name/shape/group/offset (ParamMeta)
  params-<preset>.bin       f32-LE initial parameters, wire order
  kernel-compress_error-d<D>.hlo.txt   eps(K) curve (L1 kernel standalone)
  kernel-ef21_apply-d<D>.hlo.txt       fused EF21 update (standalone)
  manifest.json             index of all of the above

Usage: python -m compile.aot --out-dir ../artifacts [--presets tiny,small,e2e]
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .hlo import lower_to_text
from .kernels.ef21_apply import ef21_apply
from .kernels.topk_error import topk_error_curve

KERNEL_DIMS = (4096,)
SEED = 21  # the paper's random seed (§4.2)


def export_model(preset: str, out: pathlib.Path, with_params: bool) -> dict:
    cfg = M.PRESETS[preset]
    args = M.example_args(cfg)

    train_txt = lower_to_text(M.make_train_step(cfg), *args)
    (out / f"model-{preset}.hlo.txt").write_text(train_txt)

    eval_txt = lower_to_text(M.make_eval_step(cfg), *args)
    (out / f"eval-{preset}.hlo.txt").write_text(eval_txt)

    metas = M.param_meta(cfg)
    layout = {
        "preset": preset,
        "batch": cfg.batch,
        "seq": cfg.seq,
        "d_in": cfg.d_in,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_blocks": cfg.n_blocks,
        "d_ff": cfg.d_ff,
        "n_classes": cfg.n_classes,
        "n_params": M.n_params(cfg),
        "n_groups": cfg.n_blocks + 2,
        "params": [
            {
                "name": m.name,
                "shape": list(m.shape),
                "group": m.group,
                "offset": m.offset,
                "size": m.size,
            }
            for m in metas
        ],
    }
    (out / f"layout-{preset}.json").write_text(json.dumps(layout, indent=1))

    entry = {
        "train_hlo": f"model-{preset}.hlo.txt",
        "eval_hlo": f"eval-{preset}.hlo.txt",
        "layout": f"layout-{preset}.json",
        "n_params": layout["n_params"],
    }
    if with_params:
        params = M.init_params(cfg, jax.random.PRNGKey(SEED))
        flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
        flat.astype("<f4").tofile(out / f"params-{preset}.bin")
        entry["params"] = f"params-{preset}.bin"
    return entry


def export_kernels(out: pathlib.Path) -> dict:
    kernels = {}
    for d in KERNEL_DIMS:
        u = jax.ShapeDtypeStruct((d,), jnp.float32)
        txt = lower_to_text(topk_error_curve, u)
        name = f"kernel-compress_error-d{d}.hlo.txt"
        (out / name).write_text(txt)
        kernels[f"compress_error_d{d}"] = {"hlo": name, "d": d}

        txt = lower_to_text(ef21_apply, u, u, u)
        name = f"kernel-ef21_apply-d{d}.hlo.txt"
        (out / name).write_text(txt)
        kernels[f"ef21_apply_d{d}"] = {"hlo": name, "d": d}
    return kernels


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small,e2e")
    ap.add_argument("--big", action="store_true",
                    help="also export the ~100M-param preset (compile-only)")
    a = ap.parse_args()

    out = pathlib.Path(a.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    presets = [p.strip() for p in a.presets.split(",") if p.strip()]
    if a.big and "big" not in presets:
        presets.append("big")

    manifest = {"seed": SEED, "models": {}, "kernels": {}}
    for preset in presets:
        # 'big' is a footprint study: HLO text is shape-parameterized and
        # stays small, but a params.bin would be ~400 MB — skip it.
        with_params = preset != "big"
        manifest["models"][preset] = export_model(preset, out, with_params)
        print(f"exported model preset '{preset}' "
              f"({manifest['models'][preset]['n_params']} params)")
    manifest["kernels"] = export_kernels(out)
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
