"""Lower jitted JAX functions to HLO *text* — the rust interchange format.

HLO text (not serialized HloModuleProto) is mandatory here: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

We lower with return_tuple=True, so every executable returns one tuple
the rust side unwraps with `Literal::to_tuple()`.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text, via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_text(fn, *example_args) -> str:
    """jit + lower fn at the example shapes and return HLO text."""
    return to_hlo_text(jax.jit(fn).lower(*example_args))
