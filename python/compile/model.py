"""L2: the deep-model workload — a transformer classifier in JAX.

This stands in for the paper's ResNet18/CIFAR10 (DESIGN.md §3): Kimad is
model-agnostic; what it needs from the workload is a *per-layer gradient
structure* with heterogeneous layer sizes. The model below is a standard
pre-norm transformer encoder over patch tokens with a mean-pool + linear
head; its FFN matmuls run through the L1 Pallas kernel
(`kernels.fused_linear`), so the kernel lowers into the same HLO module.

Exported entry points (lowered once by aot.py, executed from Rust):

  train_step(params..., x, y) -> (loss, grad_0, ..., grad_{P-1})
  eval_step(params..., x, y)  -> (loss, top1_count, top5_count)

Parameters travel as a *flat list of arrays* (not a dict) so the Rust
runtime can address them positionally; `param_meta` describes each slot
(name, shape, byte offset, Kimad+ layer group).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer preset. All shapes are static (baked into the HLO)."""

    name: str
    batch: int
    seq: int
    d_in: int
    d_model: int
    n_heads: int
    n_blocks: int
    d_ff: int
    n_classes: int = 10
    # Pallas tile sizes for the FFN kernel (clamped to dims inside).
    bm: int = 128
    bn: int = 128

    def __post_init__(self) -> None:
        if self.d_model % self.n_heads != 0:
            raise ValueError("d_model must be divisible by n_heads")


PRESETS = {
    # Fast unit-test preset: a couple of ms per step under pytest.
    "tiny": ModelConfig("tiny", batch=8, seq=4, d_in=8, d_model=16, n_heads=2,
                        n_blocks=1, d_ff=32),
    # Mid-size preset used by rust integration tests.
    "small": ModelConfig("small", batch=32, seq=8, d_in=16, d_model=32,
                         n_heads=4, n_blocks=2, d_ff=64),
    # The end-to-end training preset (examples/deep_train.rs): ~0.9M params.
    "e2e": ModelConfig("e2e", batch=64, seq=16, d_in=32, d_model=128,
                       n_heads=4, n_blocks=4, d_ff=512),
    # ~100M-parameter footprint-study preset: exported compile-only (the
    # HLO is shape-parameterized so its text stays small); DESIGN.md §8.
    "big": ModelConfig("big", batch=8, seq=32, d_in=64, d_model=1024,
                       n_heads=16, n_blocks=8, d_ff=4096),
}


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamMeta:
    name: str
    shape: Tuple[int, ...]
    group: int  # Kimad+ "layer" id (embed=0, block i = i+1, head = last)
    offset: int  # element offset into the flat f32 vector
    size: int


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], int]]:
    """(name, shape, group) for every parameter slot, in wire order."""
    specs: List[Tuple[str, Tuple[int, ...], int]] = [
        ("embed/w", (cfg.d_in, cfg.d_model), 0),
        ("embed/b", (cfg.d_model,), 0),
        ("embed/pos", (cfg.seq, cfg.d_model), 0),
    ]
    for i in range(cfg.n_blocks):
        g = i + 1
        p = f"block{i}"
        specs += [
            (f"{p}/ln1/g", (cfg.d_model,), g),
            (f"{p}/ln1/b", (cfg.d_model,), g),
            (f"{p}/attn/wqkv", (cfg.d_model, 3 * cfg.d_model), g),
            (f"{p}/attn/bqkv", (3 * cfg.d_model,), g),
            (f"{p}/attn/wo", (cfg.d_model, cfg.d_model), g),
            (f"{p}/attn/bo", (cfg.d_model,), g),
            (f"{p}/ln2/g", (cfg.d_model,), g),
            (f"{p}/ln2/b", (cfg.d_model,), g),
            (f"{p}/ffn/w1", (cfg.d_model, cfg.d_ff), g),
            (f"{p}/ffn/b1", (cfg.d_ff,), g),
            (f"{p}/ffn/w2", (cfg.d_ff, cfg.d_model), g),
            (f"{p}/ffn/b2", (cfg.d_model,), g),
        ]
    gh = cfg.n_blocks + 1
    specs += [
        ("final_ln/g", (cfg.d_model,), gh),
        ("final_ln/b", (cfg.d_model,), gh),
        ("head/w", (cfg.d_model, cfg.n_classes), gh),
        ("head/b", (cfg.n_classes,), gh),
    ]
    return specs


def param_meta(cfg: ModelConfig) -> List[ParamMeta]:
    metas: List[ParamMeta] = []
    off = 0
    for name, shape, group in param_specs(cfg):
        size = 1
        for s in shape:
            size *= s
        metas.append(ParamMeta(name, shape, group, off, size))
        off += size
    return metas


def n_params(cfg: ModelConfig) -> int:
    return sum(m.size for m in param_meta(cfg))


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    """LeCun-normal weights, zero biases, unit LN gains."""
    params: List[jax.Array] = []
    for name, shape, _ in param_specs(cfg):
        key, sub = jax.random.split(key)
        leaf = name.rsplit("/", 1)[-1]
        if leaf in ("b", "bqkv", "bo", "b1", "b2"):
            p = jnp.zeros(shape, jnp.float32)
        elif leaf == "g":
            p = jnp.ones(shape, jnp.float32)
        elif leaf == "pos":
            p = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            p = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
        params.append(p)
    return params


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(h, wqkv, bqkv, wo, bo, n_heads: int):
    bsz, seq, d = h.shape
    hd = d // n_heads
    qkv = jnp.dot(h, wqkv) + bqkv  # [B,S,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [B,S,D] -> [B,H,S,hd]
        return t.reshape(bsz, seq, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(bsz, seq, d)
    return jnp.dot(out, wo) + bo


def forward(cfg: ModelConfig, params: List[jax.Array], x: jax.Array) -> jax.Array:
    """x: [B, S, d_in] -> logits [B, n_classes]."""
    it = iter(params)
    nxt = lambda: next(it)  # noqa: E731

    w_e, b_e, pos = nxt(), nxt(), nxt()
    bsz, seq, d_in = x.shape
    h = fused_linear(
        x.reshape(bsz * seq, d_in), w_e, b_e, "none", cfg.bm, cfg.bn
    ).reshape(bsz, seq, cfg.d_model)
    h = h + pos

    for _ in range(cfg.n_blocks):
        g1, b1 = nxt(), nxt()
        wqkv, bqkv, wo, bo = nxt(), nxt(), nxt(), nxt()
        g2, b2 = nxt(), nxt()
        w1, bf1, w2, bf2 = nxt(), nxt(), nxt(), nxt()

        h = h + _attention(_layernorm(h, g1, b1), wqkv, bqkv, wo, bo, cfg.n_heads)
        hn = _layernorm(h, g2, b2).reshape(bsz * seq, cfg.d_model)
        # FFN hot spot -> L1 Pallas kernel (fused matmul+bias+GELU).
        ff = fused_linear(hn, w1, bf1, "gelu", cfg.bm, cfg.bn)
        ff = fused_linear(ff, w2, bf2, "none", cfg.bm, cfg.bn)
        h = h + ff.reshape(bsz, seq, cfg.d_model)

    gf, bf = nxt(), nxt()
    wh, bh = nxt(), nxt()
    h = _layernorm(h, gf, bf)
    pooled = jnp.mean(h, axis=1)
    return jnp.dot(pooled, wh) + bh


def loss_fn(cfg: ModelConfig, params: List[jax.Array], x: jax.Array,
            y: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; y: int32 [B]."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Exported entry points
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig):
    """train_step(*params, x, y) -> (loss, *per-slot grads)."""

    def train_step(*args):
        params = list(args[:-2])
        x, y = args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, x, y)
        )(params)
        return (loss, *grads)

    return train_step


def make_eval_step(cfg: ModelConfig):
    """eval_step(*params, x, y) -> (loss, top1_count, top5_count)."""

    def eval_step(*args):
        params = list(args[:-2])
        x, y = args[-2], args[-1]
        logits = forward(cfg, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        top1 = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        # Top-5 via rank counting (lax.top_k lowers to a `topk` HLO op
        # that xla_extension 0.5.1's text parser rejects): the true
        # class is in the top k iff fewer than k logits strictly beat it.
        k = min(5, cfg.n_classes)
        true_logit = jnp.take_along_axis(logits, y[:, None], axis=-1)
        rank = jnp.sum((logits > true_logit).astype(jnp.int32), axis=-1)
        top5 = jnp.sum((rank < k).astype(jnp.float32))
        return (jnp.mean(nll), top1, top5)

    return eval_step


def example_args(cfg: ModelConfig):
    """ShapeDtypeStructs matching the exported signature."""
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape, _ in param_specs(cfg)
    ]
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.seq, cfg.d_in), jnp.float32)
    y = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    return (*params, x, y)
