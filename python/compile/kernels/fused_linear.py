"""L1 Pallas kernel: fused tiled linear layer  y = act(x @ W + b).

This is the transformer FFN hot spot. On a real TPU the kernel would be
lowered by Mosaic and the BlockSpec below expresses the HBM->VMEM tiling
schedule (the CUDA paper-equivalent of threadblock + shared-memory
staging): (bm, K) x (K, bn) tiles with fp32 accumulation on the MXU.

`pallas_call` has no autodiff rule, so the layer carries a custom VJP
whose backward pass is *also* built from Pallas kernels:

    gz = dy * act'(z)            (elementwise kernel)
    dx = gz @ W^T                (tiled matmul kernel)
    dW = x^T @ gz                (tiled matmul kernel)
    db = sum_rows(gz)            (XLA reduce)

On this testbed we lower with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); correctness is checked against
``ref.linear_ref`` (and the VJP against autodiff-through-ref) by pytest;
real-TPU performance is *estimated* from the VMEM footprint recorded in
DESIGN.md §8.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles. The second-minor/minor dims of a VMEM tile
# should be multiples of (8, 128) for f32; 128x128 feeds the systolic
# array without re-layout.
DEFAULT_BM = 128
DEFAULT_BN = 128

_ACTIVATIONS = ("none", "relu", "gelu")
_SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)
_GELU_C = 0.044715


def vmem_bytes(bm: int, bn: int, k: int, itemsize: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (x, w, b, y, z tiles)."""
    return itemsize * (bm * k + k * bn + bn + 2 * bm * bn)


def _apply_act(z, activation: str):
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "gelu":
        # tanh-approximate GELU — matches jax.nn.gelu(approximate=True).
        u = _SQRT_2_OVER_PI * (z + _GELU_C * z**3)
        return 0.5 * z * (1.0 + jnp.tanh(u))
    return z


def _act_grad(z, activation: str):
    if activation == "relu":
        return (z > 0.0).astype(z.dtype)
    if activation == "gelu":
        u = _SQRT_2_OVER_PI * (z + _GELU_C * z**3)
        t = jnp.tanh(u)
        du = _SQRT_2_OVER_PI * (1.0 + 3.0 * _GELU_C * z**2)
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * du
    return jnp.ones_like(z)


# --------------------------------------------------------------------------
# Forward kernel: one (bm, bn) output tile, full-K contraction.
# K is kept un-tiled: for the model dims used here (<= 4096) a (bm, K) +
# (K, bn) pair fits comfortably in VMEM (see vmem_bytes()), so a K-loop
# with accumulator carry is not needed.
# --------------------------------------------------------------------------

def _forward_kernel(x_ref, w_ref, b_ref, y_ref, z_ref, *, activation: str):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = acc + b_ref[...]
    z_ref[...] = z.astype(z_ref.dtype)
    y_ref[...] = _apply_act(z, activation).astype(y_ref.dtype)


def _pad2(a, m, n):
    pm, pn = m - a.shape[0], n - a.shape[1]
    return jnp.pad(a, ((0, pm), (0, pn))) if (pm or pn) else a


def _forward(x, w, b, activation: str, bm: int, bn: int):
    """Returns (y, z) with z the pre-activation (saved for the VJP)."""
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    mp, np_ = -(-m // bm) * bm, -(-n // bn) * bn
    xp, wp = _pad2(x, mp, k), _pad2(w, k, np_)
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b

    y, z = pl.pallas_call(
        functools.partial(_forward_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
        ],
        interpret=True,
    )(xp, wp, bp)
    return y[:m, :n], z[:m, :n]


# --------------------------------------------------------------------------
# Backward kernels
# --------------------------------------------------------------------------

def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul(a, b, bm: int, bn: int):
    """Plain tiled matmul (no bias/activation) on the same BlockSpec grid."""
    m, k = a.shape
    _, n = b.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    mp, np_ = -(-m // bm) * bm, -(-n // bn) * bn
    ap, bp = _pad2(a, mp, k), _pad2(b, k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def _act_grad_kernel(z_ref, dy_ref, o_ref, *, activation: str):
    o_ref[...] = (dy_ref[...] * _act_grad(z_ref[...], activation)).astype(
        o_ref.dtype
    )


def _act_grad_apply(z, dy, activation: str, bm: int, bn: int):
    m, n = z.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    mp, np_ = -(-m // bm) * bm, -(-n // bn) * bn
    zp, dyp = _pad2(z, mp, np_), _pad2(dy, mp, np_)
    out = pl.pallas_call(
        functools.partial(_act_grad_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), z.dtype),
        interpret=True,
    )(zp, dyp)
    return out[:m, :n]


# --------------------------------------------------------------------------
# custom_vjp wiring
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _fused_linear(x, w, b, activation: str, bm: int, bn: int):
    y, _ = _forward(x, w, b, activation, bm, bn)
    return y


def _fused_linear_fwd(x, w, b, activation, bm, bn):
    y, z = _forward(x, w, b, activation, bm, bn)
    return y, (x, w, z)


def _fused_linear_bwd(activation, bm, bn, res, dy):
    x, w, z = res
    gz = _act_grad_apply(z, dy, activation, bm, bn)
    dx = _matmul(gz, w.T, bm, bn)
    dw = _matmul(x.T, gz, bm, bn)
    db = jnp.sum(gz, axis=0)
    return dx, dw, db


_fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "none",
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """act(x @ w + b) with x:[M,K], w:[K,N], b:[N] -> [M,N].

    Pads M and N up to tile multiples, runs the Pallas kernel on a
    (ceil(M/bm), ceil(N/bn)) grid, and slices the result back.
    Differentiable via the Pallas-kernel VJP above.
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0] or b.shape != (
        w.shape[1],
    ):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    return _fused_linear(x, w, b, activation, bm, bn)
