"""L1 Pallas kernel: fused EF21 estimator update.

EF21 (Richtarik et al. 2021), as used bidirectionally by Kimad
(Algorithm 3 lines 5/8/14), advances each estimator by the compressed
difference:

    u_hat^{k}  =  u_hat^{k-1}  +  C(u^k - u_hat^{k-1}).

For sparsifying compressors C (TopK/RandK) the compressed difference is
a mask over coordinates, so the update is the fused elementwise

    out = u_hat + mask * (u - u_hat)

done in one pass instead of materializing (u - u_hat), compressing, and
adding (three passes over HBM). Streams (block,) VMEM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _ef21_kernel(u_ref, uhat_ref, mask_ref, o_ref):
    u = u_ref[...]
    uhat = uhat_ref[...]
    m = mask_ref[...]
    o_ref[...] = uhat + m * (u - uhat)


@functools.partial(jax.jit, static_argnames=("block",))
def ef21_apply(
    u: jax.Array, u_hat: jax.Array, mask: jax.Array, block: int = DEFAULT_BLOCK
) -> jax.Array:
    """u_hat + mask * (u - u_hat), elementwise over 1-D vectors."""
    if u.shape != u_hat.shape or u.shape != mask.shape:
        raise ValueError(
            f"shape mismatch: u{u.shape} u_hat{u_hat.shape} mask{mask.shape}"
        )
    (d,) = u.shape
    bs = min(block, max(d, 1))
    dp = -(-d // bs) * bs
    pad = dp - d
    if pad:
        u = jnp.pad(u, (0, pad))
        u_hat = jnp.pad(u_hat, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    out = pl.pallas_call(
        _ef21_kernel,
        grid=(dp // bs,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), u.dtype),
        interpret=True,
    )(u, u_hat, mask)
    return out[:d]
