"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

pytest (python/tests/) asserts allclose(kernel, ref) across
hypothesis-generated shape/dtype/value sweeps. Nothing here is ever
lowered into the shipped artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_ref(x, w, b, activation: str = "none"):
    """Oracle for kernels.fused_linear.fused_linear."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    return y.astype(x.dtype)


def suffix_sum_ref(x):
    """Oracle for kernels.topk_error.suffix_sum."""
    return jnp.cumsum(x[::-1])[::-1]


def topk_error_curve_ref(u):
    """Oracle for kernels.topk_error.topk_error_curve."""
    sq = jnp.sort(u.astype(jnp.float32) ** 2)[::-1]
    suffix = jnp.cumsum(sq[::-1])[::-1]
    return jnp.concatenate([suffix, jnp.zeros((1,), jnp.float32)])


def topk_error_single_ref(u, k: int):
    """|| u - TopK(u) ||^2 by explicit compression (independent oracle)."""
    u = u.astype(jnp.float32)
    d = u.shape[0]
    k = max(0, min(k, d))
    if k == 0:
        return jnp.sum(u**2)
    idx = jnp.argsort(jnp.abs(u))[::-1][:k]
    kept = jnp.zeros_like(u).at[idx].set(u[idx])
    return jnp.sum((u - kept) ** 2)


def ef21_apply_ref(u, u_hat, mask):
    """Oracle for kernels.ef21_apply.ef21_apply."""
    return u_hat + mask * (u - u_hat)
