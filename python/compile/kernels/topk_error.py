"""L1 Pallas kernel: the TopK compression-error curve eps(K).

Kimad+ (Algorithm 4) needs the *Errors matrix*: for every layer and
every candidate compression parameter K, the squared L2 error of
TopK-compressing the layer's accumulated gradient,

    eps(K) = || u - TopK(u) ||^2 = sum of the (d-K) smallest |u_i|^2.

Computing this naively is |C| compress-and-measure passes. Observe that
once the squared magnitudes are sorted descending, the *whole* curve is
one reversed cumulative sum:

    eps(K) = suffix_sum(sorted_sq)[K]   for K = 0..d

so Kimad+ pays one sort (XLA's O(d log d) sort on the VPU; a GPU paper
would radix-select, but Kimad+ needs ALL K anyway so a full sort is the
right TPU-side restructuring — DESIGN.md §Hardware-Adaptation) plus one
linear scan. The scan is the Pallas kernel below, decomposed in the
classic two-pass block-scan shape so the grid is parallel:

  pass 1 (kernel): per-block reversed cumsum + per-block totals
  combine (XLA):   exclusive reversed cumsum over nblocks totals
  pass 2 (kernel): add each block's suffix offset

Both passes stream (block,)-sized VMEM tiles; footprint is O(bs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _block_scan_kernel(x_ref, cum_ref, tot_ref):
    """Within-block reversed (suffix) cumsum and block total."""
    x = x_ref[...]
    rev = jnp.cumsum(x[::-1])[::-1]
    cum_ref[...] = rev
    tot_ref[...] = rev[:1]  # rev[0] == sum of the block


def _add_offset_kernel(cum_ref, off_ref, o_ref):
    o_ref[...] = cum_ref[...] + off_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def suffix_sum(x: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Reversed (suffix) cumulative sum: out[i] = sum_{j >= i} x[j]."""
    (d,) = x.shape
    bs = min(block, max(d, 1))
    dp = -(-d // bs) * bs
    xp = jnp.pad(x, (0, dp - d)) if dp != d else x
    nblocks = dp // bs

    cum, tot = pl.pallas_call(
        _block_scan_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), x.dtype),
            jax.ShapeDtypeStruct((nblocks,), x.dtype),
        ],
        interpret=True,
    )(xp)

    # Exclusive reversed cumsum of block totals -> offset each block must
    # add (the mass of all blocks to its right). O(nblocks), tiny.
    suffix_tot = jnp.cumsum(tot[::-1])[::-1]
    offsets = jnp.concatenate([suffix_tot[1:], jnp.zeros((1,), x.dtype)])
    off_expanded = jnp.repeat(offsets, bs)

    out = pl.pallas_call(
        _add_offset_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=True,
    )(cum, off_expanded)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("block",))
def topk_error_curve(u: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """eps(K) for K = 0..d: squared error of keeping the K largest |u_i|.

    Returns err with err[K] = || u - TopK(u) ||^2, err[d] == 0.
    """
    sq = u.astype(jnp.float32) ** 2
    sorted_desc = jnp.sort(sq)[::-1]
    suffix = suffix_sum(sorted_desc, block=block)
    return jnp.concatenate([suffix, jnp.zeros((1,), jnp.float32)])
