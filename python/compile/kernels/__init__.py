"""L1: Pallas kernels for Kimad's compute hot spots.

- fused_linear: tiled matmul+bias+activation (transformer FFN hot spot)
- topk_error:   the eps(K) compression-error curve Kimad+ feeds its DP
- ef21_apply:   fused EF21 estimator update
- ref:          pure-jnp oracles for all of the above
"""

from . import ef21_apply, fused_linear, ref, topk_error  # noqa: F401
