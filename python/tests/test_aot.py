"""AOT export checks: HLO text well-formedness, layout consistency."""

import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.hlo import lower_to_text
from compile.kernels.topk_error import topk_error_curve

jax.config.update("jax_platform_name", "cpu")


class TestHloText:
    def test_tiny_train_step_lowers(self):
        cfg = M.PRESETS["tiny"]
        txt = lower_to_text(M.make_train_step(cfg), *M.example_args(cfg))
        assert txt.startswith("HloModule")
        # (*params, x, y) inputs and a tuple root.
        assert "ENTRY" in txt
        assert "tuple(" in txt.lower()

    def test_kernel_lowers_without_custom_call(self):
        # interpret=True must lower pallas to plain HLO: a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        u = jax.ShapeDtypeStruct((256,), jnp.float32)
        txt = lower_to_text(topk_error_curve, u)
        assert "custom-call" not in txt or "Sharding" in txt

    def test_param_count_matches_signature(self):
        cfg = M.PRESETS["tiny"]
        txt = lower_to_text(M.make_train_step(cfg), *M.example_args(cfg))
        # Count parameters of the ENTRY computation only (fusion bodies
        # introduce their own local parameter() instructions).
        entry = txt[txt.index("ENTRY"):]
        entry = entry[: entry.index("\n}")]
        n_inputs = entry.count("parameter(")
        assert n_inputs == len(M.param_specs(cfg)) + 2  # params + x + y


class TestExportedArtifacts:
    """Validate on-disk artifacts when they exist (after `make artifacts`)."""

    ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

    @pytest.fixture(autouse=True)
    def _skip_without_artifacts(self):
        if not (self.ART / "manifest.json").exists():
            pytest.skip("artifacts/ not built (run `make artifacts`)")

    def test_manifest_files_exist(self):
        manifest = json.loads((self.ART / "manifest.json").read_text())
        for entry in manifest["models"].values():
            for key in ("train_hlo", "eval_hlo", "layout"):
                assert (self.ART / entry[key]).exists()
        for k in manifest["kernels"].values():
            assert (self.ART / k["hlo"]).exists()

    def test_layout_consistent_with_model(self):
        manifest = json.loads((self.ART / "manifest.json").read_text())
        for preset, entry in manifest["models"].items():
            cfg = M.PRESETS[preset]
            layout = json.loads((self.ART / entry["layout"]).read_text())
            metas = M.param_meta(cfg)
            assert layout["n_params"] == M.n_params(cfg)
            assert len(layout["params"]) == len(metas)
            for got, want in zip(layout["params"], metas):
                assert got["name"] == want.name
                assert tuple(got["shape"]) == want.shape
                assert got["offset"] == want.offset

    def test_params_bin_matches_seeded_init(self):
        manifest = json.loads((self.ART / "manifest.json").read_text())
        for preset, entry in manifest["models"].items():
            if "params" not in entry:
                continue
            cfg = M.PRESETS[preset]
            flat = np.fromfile(self.ART / entry["params"], dtype="<f4")
            assert flat.size == M.n_params(cfg)
            params = M.init_params(cfg, jax.random.PRNGKey(manifest["seed"]))
            want = np.concatenate([np.asarray(p).ravel() for p in params])
            np.testing.assert_allclose(flat, want, rtol=1e-6, atol=1e-7)
