"""L2 model checks: shapes, grad structure, autodiff cross-check, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


def _batch(cfg, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (cfg.batch, cfg.seq, cfg.d_in), jnp.float32)
    y = jax.random.randint(ky, (cfg.batch,), 0, cfg.n_classes)
    return x, y


class TestParamLayout:
    def test_meta_offsets_contiguous(self):
        metas = M.param_meta(CFG)
        off = 0
        for m in metas:
            assert m.offset == off
            assert m.size == int(np.prod(m.shape)) if m.shape else 1
            off += m.size
        assert off == M.n_params(CFG)

    def test_groups_cover_embed_blocks_head(self):
        groups = {m.group for m in M.param_meta(CFG)}
        assert groups == set(range(CFG.n_blocks + 2))

    def test_init_matches_specs(self):
        params = M.init_params(CFG, jax.random.PRNGKey(0))
        specs = M.param_specs(CFG)
        assert len(params) == len(specs)
        for p, (_, shape, _) in zip(params, specs):
            assert p.shape == shape and p.dtype == jnp.float32

    def test_preset_param_counts(self):
        # e2e must be ~1M params, big ~100M (DESIGN.md presets).
        assert 5e5 < M.n_params(M.PRESETS["e2e"]) < 2e6
        assert 0.8e8 < M.n_params(M.PRESETS["big"]) < 1.3e8

    def test_bad_heads_raises(self):
        with pytest.raises(ValueError, match="divisible"):
            M.ModelConfig("bad", 1, 4, 8, 10, 3, 1, 16)


class TestForward:
    def test_logit_shape(self):
        params = M.init_params(CFG, jax.random.PRNGKey(1))
        x, _ = _batch(CFG)
        logits = M.forward(CFG, params, x)
        assert logits.shape == (CFG.batch, CFG.n_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_loss_near_uniform_at_init(self):
        params = M.init_params(CFG, jax.random.PRNGKey(2))
        x, y = _batch(CFG)
        loss = M.loss_fn(CFG, params, x, y)
        assert abs(float(loss) - np.log(CFG.n_classes)) < 1.0

    def test_permutation_equivariance_of_batch(self):
        params = M.init_params(CFG, jax.random.PRNGKey(3))
        x, _ = _batch(CFG)
        logits = M.forward(CFG, params, x)
        perm = jnp.arange(CFG.batch)[::-1]
        logits_p = M.forward(CFG, params, x[perm])
        np.testing.assert_allclose(logits_p, logits[perm], rtol=1e-4, atol=1e-4)


class TestTrainStep:
    def test_signature_and_grad_shapes(self):
        step = jax.jit(M.make_train_step(CFG))
        params = M.init_params(CFG, jax.random.PRNGKey(4))
        x, y = _batch(CFG)
        out = step(*params, x, y)
        assert len(out) == 1 + len(params)
        for g, p in zip(out[1:], params):
            assert g.shape == p.shape

    def test_grads_match_plain_autodiff(self):
        # Cross-check the exported entry point against straight jax.grad
        # of the loss (catches any param-ordering slip in make_train_step).
        params = M.init_params(CFG, jax.random.PRNGKey(5))
        x, y = _batch(CFG, seed=5)
        out = M.make_train_step(CFG)(*params, x, y)
        grads_direct = jax.grad(lambda p: M.loss_fn(CFG, p, x, y))(params)
        for got, want in zip(out[1:], grads_direct):
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_sgd_reduces_loss(self):
        step = jax.jit(M.make_train_step(CFG))
        params = M.init_params(CFG, jax.random.PRNGKey(6))
        x, y = _batch(CFG, seed=6)
        first = None
        for _ in range(30):
            out = step(*params, x, y)
            loss, grads = out[0], out[1:]
            if first is None:
                first = float(loss)
            params = [p - 0.05 * g for p, g in zip(params, grads)]
        assert float(loss) < first - 0.2


class TestEvalStep:
    def test_counts_bounded(self):
        estep = jax.jit(M.make_eval_step(CFG))
        params = M.init_params(CFG, jax.random.PRNGKey(7))
        x, y = _batch(CFG, seed=7)
        loss, top1, top5 = estep(*params, x, y)
        assert 0 <= float(top1) <= float(top5) <= CFG.batch
        assert np.isfinite(float(loss))

    def test_perfect_model_top1(self):
        # Logits forced by a head that copies a one-hot signal: train for
        # a few steps until top1 on the training batch improves.
        step = jax.jit(M.make_train_step(CFG))
        estep = jax.jit(M.make_eval_step(CFG))
        params = M.init_params(CFG, jax.random.PRNGKey(8))
        x, y = _batch(CFG, seed=8)
        _, before, _ = estep(*params, x, y)
        for _ in range(60):
            out = step(*params, x, y)
            params = [p - 0.05 * g for p, g in zip(params, out[1:])]
        _, after, _ = estep(*params, x, y)
        assert float(after) >= float(before)
