"""Kernel-vs-ref allclose — the CORE correctness signal for L1.

hypothesis sweeps shapes/values; every Pallas kernel is checked against
its pure-jnp oracle in compile.kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ef21_apply import ef21_apply
from compile.kernels.fused_linear import fused_linear, vmem_bytes
from compile.kernels.topk_error import suffix_sum, topk_error_curve

jax.config.update("jax_platform_name", "cpu")

HYPO = settings(max_examples=25, deadline=None)


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

class TestFusedLinear:
    @pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
    def test_matches_ref_square(self, activation):
        x, w, b = _rand(0, 32, 16), _rand(1, 16, 24), _rand(2, 24)
        got = fused_linear(x, w, b, activation)
        want = ref.linear_ref(x, w, b, activation)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_tile_exact_multiple(self):
        x, w, b = _rand(3, 256, 64), _rand(4, 64, 128), _rand(5, 128)
        got = fused_linear(x, w, b, "gelu", bm=128, bn=128)
        np.testing.assert_allclose(got, ref.linear_ref(x, w, b, "gelu"),
                                   rtol=1e-5, atol=1e-5)

    def test_ragged_padding(self):
        # M, N deliberately not tile multiples.
        x, w, b = _rand(6, 37, 19), _rand(7, 19, 45), _rand(8, 45)
        got = fused_linear(x, w, b, "relu", bm=16, bn=32)
        np.testing.assert_allclose(got, ref.linear_ref(x, w, b, "relu"),
                                   rtol=1e-5, atol=1e-5)

    def test_single_row_and_col(self):
        x, w, b = _rand(9, 1, 4), _rand(10, 4, 1), _rand(11, 1)
        got = fused_linear(x, w, b)
        np.testing.assert_allclose(got, ref.linear_ref(x, w, b),
                                   rtol=1e-5, atol=1e-5)

    def test_bad_activation_raises(self):
        x, w, b = _rand(0, 4, 4), _rand(1, 4, 4), _rand(2, 4)
        with pytest.raises(ValueError, match="activation"):
            fused_linear(x, w, b, "tanh")

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            fused_linear(_rand(0, 4, 5), _rand(1, 4, 4), _rand(2, 4))

    def test_vmem_estimate_within_budget(self):
        # The default (128,128,K<=4096) tiling must fit a 16 MB VMEM core.
        assert vmem_bytes(128, 128, 4096) <= 16 * 2**20

    @pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
    def test_vjp_matches_autodiff_through_ref(self, activation):
        # The custom VJP (pallas backward kernels) must agree with plain
        # autodiff through the pure-jnp oracle.
        x, w, b = _rand(30, 24, 12), _rand(31, 12, 20), _rand(32, 20)
        t = _rand(33, 24, 20)  # cotangent-shaping target

        def loss_kernel(x, w, b):
            return jnp.sum((fused_linear(x, w, b, activation) - t) ** 2)

        def loss_ref(x, w, b):
            return jnp.sum((ref.linear_ref(x, w, b, activation) - t) ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
        for a, e in zip(gk, gr):
            np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)

    @HYPO
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 40),
        n=st.integers(1, 70),
        act=st.sampled_from(["none", "relu", "gelu"]),
        bm=st.sampled_from([8, 16, 128]),
        bn=st.sampled_from([8, 32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, m, k, n, act, bm, bn, seed):
        kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        b = jax.random.normal(kb, (n,), jnp.float32)
        got = fused_linear(x, w, b, act, bm, bn)
        want = ref.linear_ref(x, w, b, act)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# topk_error / suffix_sum
# ---------------------------------------------------------------------------

class TestSuffixSum:
    def test_small_exact(self):
        x = jnp.array([1.0, 2.0, 3.0, 4.0])
        np.testing.assert_allclose(suffix_sum(x, block=2),
                                   [10.0, 9.0, 7.0, 4.0])

    def test_matches_ref_unaligned(self):
        x = jnp.abs(_rand(12, 1000))
        got = suffix_sum(x, block=512)
        np.testing.assert_allclose(got, ref.suffix_sum_ref(x),
                                   rtol=1e-5, atol=1e-4)

    def test_single_element(self):
        np.testing.assert_allclose(suffix_sum(jnp.array([5.0])), [5.0])

    @HYPO
    @given(d=st.integers(1, 3000), block=st.sampled_from([64, 512, 1024]),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, d, block, seed):
        x = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (d,)))
        got = suffix_sum(x, block=block)
        np.testing.assert_allclose(got, ref.suffix_sum_ref(x),
                                   rtol=1e-4, atol=1e-3)


class TestTopKErrorCurve:
    def test_endpoints(self):
        u = _rand(13, 256)
        err = topk_error_curve(u)
        assert err.shape == (257,)
        np.testing.assert_allclose(err[0], jnp.sum(u**2), rtol=1e-5)
        np.testing.assert_allclose(err[-1], 0.0, atol=1e-6)

    def test_monotone_nonincreasing(self):
        err = np.asarray(topk_error_curve(_rand(14, 777)))
        assert np.all(np.diff(err) <= 1e-4)

    def test_matches_explicit_compression(self):
        u = _rand(15, 128)
        err = topk_error_curve(u)
        for k in (0, 1, 7, 64, 128):
            want = ref.topk_error_single_ref(u, k)
            np.testing.assert_allclose(err[k], want, rtol=1e-4, atol=1e-4)

    @HYPO
    @given(d=st.integers(1, 2000), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_matches_ref(self, d, seed):
        u = jax.random.normal(jax.random.PRNGKey(seed), (d,))
        got = topk_error_curve(u)
        want = ref.topk_error_curve_ref(u)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# ef21_apply
# ---------------------------------------------------------------------------

class TestEf21Apply:
    def test_full_mask_replaces(self):
        u, uh = _rand(16, 100), _rand(17, 100)
        got = ef21_apply(u, uh, jnp.ones(100))
        np.testing.assert_allclose(got, u, rtol=1e-6)

    def test_zero_mask_keeps(self):
        u, uh = _rand(18, 100), _rand(19, 100)
        got = ef21_apply(u, uh, jnp.zeros(100))
        np.testing.assert_allclose(got, uh, rtol=1e-6)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            ef21_apply(_rand(0, 4), _rand(1, 5), _rand(2, 4))

    @HYPO
    @given(d=st.integers(1, 5000), block=st.sampled_from([16, 1024]),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_matches_ref(self, d, block, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        u = jax.random.normal(k1, (d,))
        uh = jax.random.normal(k2, (d,))
        mask = (jax.random.uniform(k3, (d,)) < 0.3).astype(jnp.float32)
        got = ef21_apply(u, uh, mask, block=block)
        np.testing.assert_allclose(got, ref.ef21_apply_ref(u, uh, mask),
                                   rtol=1e-5, atol=1e-5)

    def test_ef21_contracts_toward_gradient(self):
        # One EF21 step with TopK mask must not increase ||u_hat - u||.
        u, uh = _rand(20, 500), _rand(21, 500)
        diff = jnp.abs(u - uh)
        thresh = jnp.sort(diff)[::-1][50]
        mask = (diff >= thresh).astype(jnp.float32)
        new = ef21_apply(u, uh, mask)
        assert jnp.linalg.norm(new - u) <= jnp.linalg.norm(uh - u) + 1e-5
