//! Bench + regeneration for Fig. 9 (compression error: Kimad vs Kimad+
//! vs whole-model optimal) plus micro-benches of the Kimad+ machinery
//! (error curve + knapsack DP — the paper's "non-negligible overhead").

use kimad::kimad::{allocate, knapsack, ErrorCurve, KnapsackParams};
use kimad::reports::{deep, ReportCtx};
use kimad::util::bench::{bench, black_box, time_once};
use kimad::util::rng::Rng;

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    if kimad::runtime::ArtifactStore::open(&ctx.artifacts).is_ok() {
        match time_once("fig9 regeneration (fast)", || deep::fig9(&ctx)) {
            Ok(md) => println!("{md}"),
            Err(e) => println!("fig9 failed: {e:#}"),
        }
    } else {
        println!("fig9: artifacts/ missing — run `make artifacts` first (skipped)");
    }

    // Kimad+ hot path in isolation, at deep-model scale.
    let mut rng = Rng::seed_from_u64(7);
    let grads: Vec<Vec<f32>> = (0..14)
        .map(|i| (0..(1 << (10 + i % 4))).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect();
    bench("error curves (14 layers, 1k-8k coords)", 10, || {
        let curves: Vec<ErrorCurve> =
            grads.iter().map(|g| ErrorCurve::build(black_box(g))).collect();
        black_box(curves);
    });
    let curves: Vec<ErrorCurve> = grads.iter().map(|g| ErrorCurve::build(g)).collect();
    let grid = knapsack::paper_ratio_grid();
    let options = knapsack::topk_options(&curves, &grid, 64);
    let total: u64 = grads.iter().map(|g| g.len() as u64 * 64).sum();
    bench("knapsack DP (14 layers x 50 ratios, D=1000)", 10, || {
        black_box(allocate(
            black_box(&options),
            KnapsackParams { budget_bits: total / 4, discretization: 1000 },
        ));
    });
}
