//! Whole-stack hot-path micro-benchmarks — the §Perf working set
//! (EXPERIMENTS.md): TopK selection, the allocating vs buffer-reuse
//! compress paths (with a counting allocator proving the reuse path is
//! allocation-free), EF21 advance, error curves, knapsack DP, full
//! simulator rounds, and (with artifacts) one PJRT train_step.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use kimad::compress::{Compressed, Compressor, TopK};
use kimad::coordinator::{QuadraticSource, SimConfig, Simulation};
use kimad::ef21::Estimator;
use kimad::kimad::{BudgetParams, CompressPolicy, ErrorCurve};
use kimad::netsim::{Link, NetSim};
use kimad::optim::{LayerwiseSgd, Schedule};
use kimad::quadratic::Quadratic;
use kimad::util::bench::{bench, black_box, fmt_ns};
use kimad::util::rng::Rng;

/// Counts heap allocations so this bench can *prove* the buffer-reuse
/// compress path performs zero per-call allocations once warm.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn main() {
    // --- L3 compressors: TopK selection dominates the per-round cost.
    for d in [100_000usize, 1_000_000, 10_000_000] {
        let u = grad(d, 1);
        let k = d / 100;
        let r = bench(&format!("topk select+compress d={d} k=1%"), 10, || {
            black_box(TopK::new(k).compress(black_box(&u)));
        });
        let mbps = (d as f64 * 4.0) / (r.median_ns() / 1e9) / 1e6;
        println!("    -> {mbps:.0} MB/s effective scan rate");
    }

    // --- Allocating vs buffer-reuse compress (the compress_into path
    // the round loop runs). The counting allocator checks the claim.
    let d = 1_000_000;
    let u = grad(d, 1);
    let c = TopK::new(d / 100);
    let alloc_r = bench("topk compress d=1M (allocating)", 10, || {
        black_box(c.compress(black_box(&u)));
    });
    let mut msg = Compressed::default();
    c.compress_into(&u, &mut msg); // warm buffers + thread-local scratch
    let reuse_r = bench("topk compress_into d=1M (buffer reuse)", 10, || {
        c.compress_into(black_box(&u), &mut msg);
        black_box(&msg);
    });
    let before = ALLOCS.load(Ordering::Relaxed);
    let reps = 100u64;
    for _ in 0..reps {
        c.compress_into(black_box(&u), &mut msg);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    println!(
        "    -> compress_into: {delta} heap allocations over {reps} calls (target 0); \
         {:.2}x faster than the allocating path",
        alloc_r.median_ns() / reuse_r.median_ns()
    );
    assert_eq!(delta, 0, "buffer-reuse compress path must not allocate per call");

    // --- EF21 layer advance (compress + apply), 1M coords: allocating
    // vs reuse form.
    let target = grad(d, 2);
    let layer = kimad::model::Layer { id: 0, name: "l".into(), offset: 0, size: d };
    let mut est = Estimator::zeros(d);
    let mut scratch = Vec::with_capacity(d);
    bench("ef21 compress_advance d=1M k=1%", 10, || {
        black_box(est.compress_advance(&TopK::new(d / 100), &target, &layer, &mut scratch));
    });
    let mut est2 = Estimator::zeros(d);
    let mut msg2 = Compressed::default();
    est2.compress_advance_into(&TopK::new(d / 100), &target, &layer, &mut scratch, &mut msg2);
    bench("ef21 compress_advance_into d=1M k=1%", 10, || {
        est2.compress_advance_into(
            &TopK::new(d / 100),
            &target,
            &layer,
            &mut scratch,
            &mut msg2,
        );
        black_box(&msg2);
    });
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        est2.compress_advance_into(
            &TopK::new(d / 100),
            &target,
            &layer,
            &mut scratch,
            &mut msg2,
        );
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    println!("    -> compress_advance_into: {delta} heap allocations over {reps} calls");
    assert_eq!(delta, 0, "EF21 reuse path must not allocate per call");

    // --- Kimad+ machinery at transformer scale.
    let u = grad(131_072, 3);
    bench("error curve build d=128k", 10, || {
        black_box(ErrorCurve::build(black_box(&u)));
    });

    // --- Whole simulator round throughput (quadratic workload).
    let q = Quadratic::paper_instance(1000);
    let layers = q.layout(10).layers();
    let cfg = SimConfig {
        m: 4,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 1.0 },
        up_policy: CompressPolicy::KimadUniform,
        down_policy: CompressPolicy::KimadUniform,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.01)),
        layers,
        warm_start: true,
        prior_bps: 6400.0,
        round_deadline: Some(1.0),
        budget_safety: 1.0,
        threads: 0,
        mode: kimad::coordinator::ExecMode::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
    };
    let net = NetSim::new(
        (0..4)
            .map(|_| {
                Link::new(
                    Box::new(kimad::bandwidth::SinSquaredTrace::new(6400.0, 0.1, 640.0)),
                    Box::new(kimad::bandwidth::ConstantTrace::new(1e8)),
                )
            })
            .collect(),
    );
    let mut sim = Simulation::new(cfg, net, QuadraticSource::new(q, 0.1), vec![1.0; 1000]);
    let r = bench("simulator round (M=4, d=1000, 10 layers)", 10, || {
        black_box(sim.round().unwrap());
    });
    println!(
        "    -> {:.0} rounds/s",
        1e9 / r.median_ns()
    );

    // --- Kimad+ round (knapsack on the hot path).
    let q2 = Quadratic::paper_instance(1000);
    let layers2 = q2.layout(10).layers();
    let cfg2 = SimConfig {
        m: 1,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 1.0 },
        up_policy: CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] },
        down_policy: CompressPolicy::KimadUniform,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.01)),
        layers: layers2,
        warm_start: true,
        prior_bps: 6400.0,
        round_deadline: Some(1.0),
        budget_safety: 1.0,
        threads: 1,
        mode: kimad::coordinator::ExecMode::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
    };
    let net2 = NetSim::new(vec![Link::new(
        Box::new(kimad::bandwidth::ConstantTrace::new(6400.0)),
        Box::new(kimad::bandwidth::ConstantTrace::new(1e8)),
    )]);
    let mut sim2 = Simulation::new(cfg2, net2, QuadraticSource::new(q2, 0.1), vec![1.0; 1000]);
    bench("simulator round (Kimad+ DP, d=1000)", 10, || {
        black_box(sim2.round().unwrap());
    });

    // --- PJRT train_step (the L2/L1 stack), when artifacts exist.
    if let Ok(store) = kimad::runtime::ArtifactStore::open("artifacts") {
        let rt = kimad::runtime::Runtime::cpu().expect("pjrt cpu");
        for preset in ["small", "e2e"] {
            if store.model(preset).is_err() {
                continue;
            }
            let mut src =
                kimad::runtime::PjrtModelSource::load(&rt, &store, preset, 0.3, 1.0).unwrap();
            let layout = store.layout(preset).unwrap();
            let params = store.initial_params(preset).unwrap();
            let mut out = vec![0.0f32; layout.n_params];
            use kimad::coordinator::GradientSource;
            let t0 = std::time::Instant::now();
            let reps = 5;
            for i in 0..reps {
                black_box(src.update(0, i, &params, &mut out).unwrap());
            }
            let per = t0.elapsed().as_nanos() as f64 / reps as f64;
            println!(
                "pjrt train_step preset={preset} ({} params): {} / step",
                layout.n_params,
                fmt_ns(per)
            );
        }
    } else {
        println!("pjrt train_step: artifacts/ missing (skipped)");
    }
}
