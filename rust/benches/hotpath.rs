//! Whole-stack hot-path micro-benchmarks — the §Perf working set
//! (EXPERIMENTS.md): TopK selection, the allocating vs buffer-reuse
//! compress paths (with a counting allocator proving the reuse path is
//! allocation-free), EF21 advance, error curves, knapsack DP, full
//! simulator rounds, and (with artifacts) one PJRT train_step.
// Wall-clock allowlist file (ARCHITECTURE.md §6): this layer measures
// real time by design; clippy.toml bans the methods elsewhere.
#![allow(clippy::disallowed_methods)]

use std::sync::Arc;

use kimad::bench::{allocs, CountingAlloc};
use kimad::compress::{Compressed, Compressor, TopK};
use kimad::coordinator::{shard, QuadraticSource, ShardPlan, SimConfig, Simulation, WorkerState};
use kimad::ef21::Estimator;
use kimad::kimad::{BudgetParams, CompressPolicy, ErrorCurve};
use kimad::netsim::{Event, EventKind, Link, NetSim};
use kimad::optim::{LayerwiseSgd, Schedule};
use kimad::quadratic::Quadratic;
use kimad::util::bench::{bench, black_box, fmt_ns};
use kimad::util::rng::Rng;

/// The shared counting allocator (kimad::bench::alloc) proves the
/// buffer-reuse compress paths perform zero per-call allocations once
/// warm; installing it is the bench binary's job.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn main() {
    // --- L3 compressors: TopK selection dominates the per-round cost.
    for d in [100_000usize, 1_000_000, 10_000_000] {
        let u = grad(d, 1);
        let k = d / 100;
        let r = bench(&format!("topk select+compress d={d} k=1%"), 10, || {
            black_box(TopK::new(k).compress(black_box(&u)));
        });
        let mbps = (d as f64 * 4.0) / (r.median_ns() / 1e9) / 1e6;
        println!("    -> {mbps:.0} MB/s effective scan rate");
    }

    // --- Allocating vs buffer-reuse compress (the compress_into path
    // the round loop runs). The counting allocator checks the claim.
    let d = 1_000_000;
    let u = grad(d, 1);
    let c = TopK::new(d / 100);
    let alloc_r = bench("topk compress d=1M (allocating)", 10, || {
        black_box(c.compress(black_box(&u)));
    });
    let mut msg = Compressed::default();
    c.compress_into(&u, &mut msg); // warm buffers + thread-local scratch
    let reuse_r = bench("topk compress_into d=1M (buffer reuse)", 10, || {
        c.compress_into(black_box(&u), &mut msg);
        black_box(&msg);
    });
    let before = allocs();
    let reps = 100u64;
    for _ in 0..reps {
        c.compress_into(black_box(&u), &mut msg);
    }
    let delta = allocs() - before;
    println!(
        "    -> compress_into: {delta} heap allocations over {reps} calls (target 0); \
         {:.2}x faster than the allocating path",
        alloc_r.median_ns() / reuse_r.median_ns()
    );
    assert_eq!(delta, 0, "buffer-reuse compress path must not allocate per call");

    // --- EF21 layer advance (compress + apply), 1M coords: allocating
    // vs reuse form.
    let target = grad(d, 2);
    let layer = kimad::model::Layer { id: 0, name: "l".into(), offset: 0, size: d };
    let mut est = Estimator::zeros(d);
    let mut scratch = Vec::with_capacity(d);
    bench("ef21 compress_advance d=1M k=1%", 10, || {
        black_box(est.compress_advance(&TopK::new(d / 100), &target, &layer, &mut scratch));
    });
    let mut est2 = Estimator::zeros(d);
    let mut msg2 = Compressed::default();
    est2.compress_advance_into(&TopK::new(d / 100), &target, &layer, &mut scratch, &mut msg2);
    bench("ef21 compress_advance_into d=1M k=1%", 10, || {
        est2.compress_advance_into(
            &TopK::new(d / 100),
            &target,
            &layer,
            &mut scratch,
            &mut msg2,
        );
        black_box(&msg2);
    });
    let before = allocs();
    for _ in 0..reps {
        est2.compress_advance_into(
            &TopK::new(d / 100),
            &target,
            &layer,
            &mut scratch,
            &mut msg2,
        );
    }
    let delta = allocs() - before;
    println!("    -> compress_advance_into: {delta} heap allocations over {reps} calls");
    assert_eq!(delta, 0, "EF21 reuse path must not allocate per call");

    // --- Sharded vs serialized server aggregation (the semi-sync /
    // async hot path at deep-model scale): Σ w_m û_m over M=8 mirrors
    // of 1M coords across 16 layers, then the bit-identity check.
    let m_workers = 8usize;
    let dim = 1_000_000usize;
    let layers_sh = kimad::model::ModelLayout::synthetic(&[dim / 16; 16]).layers();
    let u_hats: Vec<Estimator> = (0..m_workers)
        .map(|w| {
            let mut e = Estimator::zeros(dim);
            for (i, v) in e.value.iter_mut().enumerate() {
                *v = (((i * 31 + w * 7) % 97) as f32) / 48.0 - 1.0;
            }
            e
        })
        .collect();
    let weights_sh = vec![1.0 / m_workers as f64; m_workers];
    let mut agg = vec![0.0f32; dim];
    let serial_plan = ShardPlan::build(&layers_sh, 1);
    let shards_n = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 16);
    let sharded_plan = ShardPlan::build(&layers_sh, shards_n);
    let r_serial = bench("server aggregate d=1M M=8 (serialized)", 10, || {
        black_box(shard::aggregate(&serial_plan, &weights_sh, &u_hats, &mut agg, false));
    });
    let serial_norm = shard::aggregate(&serial_plan, &weights_sh, &u_hats, &mut agg, false);
    let serial_agg = agg.clone();
    let label = format!("server aggregate d=1M M=8 ({shards_n} shards)");
    let r_sharded = bench(&label, 10, || {
        black_box(shard::aggregate(&sharded_plan, &weights_sh, &u_hats, &mut agg, true));
    });
    let sharded_norm = shard::aggregate(&sharded_plan, &weights_sh, &u_hats, &mut agg, true);
    assert_eq!(
        serial_norm.to_bits(),
        sharded_norm.to_bits(),
        "sharded aggregation must be bit-identical to the serialized path"
    );
    assert_eq!(serial_agg, agg, "sharded agg fill diverged");
    println!(
        "    -> {:.2}x speedup from sharding the aggregation",
        r_serial.median_ns() / r_sharded.median_ns()
    );

    // Alloc guard: the sharded server kernels (batch delivery,
    // aggregate, step) add no per-round heap allocations on the hot
    // path. The serialized fan-out is measured; the parallel fan-out
    // additionally pays one thread scope per batch — the same cost
    // class as the Sync upload batch.
    let opt_sh = LayerwiseSgd::new(Schedule::Constant(0.01));
    let mut x_sh = vec![0.0f32; dim];
    let mut ws: Vec<WorkerState> = (0..2).map(|w| WorkerState::new(w, dim)).collect();
    for (w, wstate) in ws.iter_mut().enumerate() {
        wstate.msgs = layers_sh
            .iter()
            .map(|l| Compressed::Sparse {
                dim: l.size,
                idx: (0..64u32).collect(),
                val: (0..64u32).map(|i| (i as usize + w) as f32 * 0.01).collect(),
            })
            .collect();
    }
    let mut mirrors: Vec<Estimator> = (0..2).map(|_| Estimator::zeros(dim)).collect();
    let batch: Vec<Event> = (0..2usize)
        .map(|w| Event { time: 1.0, worker: w, kind: EventKind::UploadDone, round: 0 })
        .collect();
    let before = allocs();
    for _ in 0..reps {
        shard::deliver_batch(&sharded_plan, &layers_sh, &mut mirrors, &ws, &batch, false);
        shard::aggregate(&sharded_plan, &weights_sh, &u_hats, &mut agg, false);
        shard::step(&sharded_plan, &opt_sh, 3, 1.0, &mut x_sh, &agg, &layers_sh, false);
    }
    let delta = allocs() - before;
    println!("    -> sharded server kernels: {delta} heap allocations over {reps} rounds");
    assert_eq!(delta, 0, "sharded aggregation path must not allocate per round");

    // --- Sharded vs serialized broadcast compression phase (diff,
    // A^compress selection, EF21 compress-advance) at deep-model scale:
    // the PR-4 hot path. KimadUniform under a 1% budget keeps every
    // layer on the sparse TopK path, so the serialized kernel is
    // allocation-free once warm.
    let bsel = kimad::kimad::Selector::new(CompressPolicy::KimadUniform);
    let c_down = (dim as u64 / 100) * kimad::kimad::select::SPARSE_COORD_BITS;
    let xb = grad(dim, 11);
    let mut diff_b = vec![0.0f32; dim];
    let mut hat_serial = Estimator::zeros(dim);
    let mut hat_sharded = Estimator::zeros(dim);
    let mut scr_serial = shard::BroadcastScratch::default();
    let mut scr_sharded = shard::BroadcastScratch::default();
    // Lockstep identity check over a few rounds before benching.
    for round in 0..3 {
        let ba = shard::broadcast(
            &serial_plan,
            &bsel,
            &layers_sh,
            c_down,
            &xb,
            &mut hat_serial,
            &mut diff_b,
            &mut scr_serial,
            false,
        );
        let bb = shard::broadcast(
            &sharded_plan,
            &bsel,
            &layers_sh,
            c_down,
            &xb,
            &mut hat_sharded,
            &mut diff_b,
            &mut scr_sharded,
            true,
        );
        assert_eq!(ba, bb, "round {round}: sharded broadcast wire bits diverged");
        assert_eq!(
            hat_serial.value, hat_sharded.value,
            "round {round}: sharded broadcast x̂ diverged"
        );
    }
    let r_bser = bench("broadcast phase d=1M 16 layers (serialized)", 10, || {
        black_box(shard::broadcast(
            &serial_plan,
            &bsel,
            &layers_sh,
            c_down,
            &xb,
            &mut hat_serial,
            &mut diff_b,
            &mut scr_serial,
            false,
        ));
    });
    let blabel = format!("broadcast phase d=1M 16 layers ({shards_n} shards)");
    let r_bsh = bench(&blabel, 10, || {
        black_box(shard::broadcast(
            &sharded_plan,
            &bsel,
            &layers_sh,
            c_down,
            &xb,
            &mut hat_sharded,
            &mut diff_b,
            &mut scr_sharded,
            true,
        ));
    });
    println!(
        "    -> {:.2}x speedup from sharding the broadcast phase",
        r_bser.median_ns() / r_bsh.median_ns()
    );
    // Alloc guard, extended to the sharded broadcast path: the
    // serialized fan-out through the shard kernel stays allocation-free
    // once warm (the parallel fan-out pays its thread scope per round,
    // the same cost class as the other shard kernels).
    let before = allocs();
    for _ in 0..reps {
        shard::broadcast(
            &serial_plan,
            &bsel,
            &layers_sh,
            c_down,
            &xb,
            &mut hat_serial,
            &mut diff_b,
            &mut scr_serial,
            false,
        );
    }
    let delta = allocs() - before;
    println!("    -> serialized broadcast kernel: {delta} heap allocations over {reps} rounds");
    assert_eq!(delta, 0, "serialized broadcast path must not allocate per round");

    // --- Kimad+ machinery at transformer scale.
    let u = grad(131_072, 3);
    bench("error curve build d=128k", 10, || {
        black_box(ErrorCurve::build(black_box(&u)));
    });

    // --- Whole simulator round throughput (quadratic workload).
    let q = Quadratic::paper_instance(1000);
    let layers = q.layout(10).layers();
    let cfg = SimConfig {
        m: 4,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 1.0 },
        up_policy: CompressPolicy::KimadUniform,
        down_policy: CompressPolicy::KimadUniform,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.01)),
        layers,
        warm_start: true,
        prior_bps: 6400.0,
        round_deadline: Some(1.0),
        budget_safety: 1.0,
        threads: 0,
        mode: kimad::coordinator::ExecMode::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
    };
    let net = NetSim::new(
        (0..4)
            .map(|_| {
                Link::new(
                    Arc::new(kimad::bandwidth::SinSquaredTrace::new(6400.0, 0.1, 640.0)),
                    Arc::new(kimad::bandwidth::ConstantTrace::new(1e8)),
                )
            })
            .collect(),
    );
    let mut sim = Simulation::new(cfg, net, QuadraticSource::new(q, 0.1), vec![1.0; 1000]);
    let r = bench("simulator round (M=4, d=1000, 10 layers)", 10, || {
        black_box(sim.round().unwrap());
    });
    println!("    -> {:.0} rounds/s", 1e9 / r.median_ns());

    // --- Kimad+ round (knapsack on the hot path).
    let q2 = Quadratic::paper_instance(1000);
    let layers2 = q2.layout(10).layers();
    let cfg2 = SimConfig {
        m: 1,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 1.0 },
        up_policy: CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] },
        down_policy: CompressPolicy::KimadUniform,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.01)),
        layers: layers2,
        warm_start: true,
        prior_bps: 6400.0,
        round_deadline: Some(1.0),
        budget_safety: 1.0,
        threads: 1,
        mode: kimad::coordinator::ExecMode::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
    };
    let net2 = NetSim::new(vec![Link::new(
        Arc::new(kimad::bandwidth::ConstantTrace::new(6400.0)),
        Arc::new(kimad::bandwidth::ConstantTrace::new(1e8)),
    )]);
    let mut sim2 = Simulation::new(cfg2, net2, QuadraticSource::new(q2, 0.1), vec![1.0; 1000]);
    bench("simulator round (Kimad+ DP, d=1000)", 10, || {
        black_box(sim2.round().unwrap());
    });

    // --- PJRT train_step (the L2/L1 stack), when artifacts exist.
    if let Ok(store) = kimad::runtime::ArtifactStore::open("artifacts") {
        let rt = kimad::runtime::Runtime::cpu().expect("pjrt cpu");
        for preset in ["small", "e2e"] {
            if store.model(preset).is_err() {
                continue;
            }
            let mut src =
                kimad::runtime::PjrtModelSource::load(&rt, &store, preset, 0.3, 1.0).unwrap();
            let layout = store.layout(preset).unwrap();
            let params = store.initial_params(preset).unwrap();
            let mut out = vec![0.0f32; layout.n_params];
            use kimad::coordinator::GradientSource;
            let t0 = std::time::Instant::now();
            let reps = 5;
            for i in 0..reps {
                black_box(src.update(0, i, &params, &mut out).unwrap());
            }
            let per = t0.elapsed().as_nanos() as f64 / reps as f64;
            println!(
                "pjrt train_step preset={preset} ({} params): {} / step",
                layout.n_params,
                fmt_ns(per)
            );
        }
    } else {
        println!("pjrt train_step: artifacts/ missing (skipped)");
    }
}
