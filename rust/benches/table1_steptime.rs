//! Bench + regeneration for Table 1 (average step time across T_comm,
//! Kimad vs comm-matched EF21). Skips gracefully without artifacts.

use kimad::reports::{deep, ReportCtx};
use kimad::util::bench::time_once;

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    if kimad::runtime::ArtifactStore::open(&ctx.artifacts).is_err() {
        println!("table1: artifacts/ missing — run `make artifacts` first (skipped)");
        return;
    }
    match time_once("table1 regeneration (fast)", || deep::table1(&ctx)) {
        Ok(md) => println!("{md}"),
        Err(e) => println!("table1 failed: {e:#}"),
    }
}
