//! Bench + regeneration for Fig. 7 (communication adaptivity across
//! T_comm; deep model over PJRT). Skips gracefully without artifacts.

use kimad::reports::{deep, ReportCtx};
use kimad::util::bench::time_once;

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    if kimad::runtime::ArtifactStore::open(&ctx.artifacts).is_err() {
        println!("fig7: artifacts/ missing — run `make artifacts` first (skipped)");
        return;
    }
    match time_once("fig7 regeneration (fast)", || deep::fig7(&ctx)) {
        Ok(md) => println!("{md}"),
        Err(e) => println!("fig7 failed: {e:#}"),
    }
}
