//! Bench + regeneration for Figs. 3–6 (synthetic quadratic, four
//! bandwidth regimes; GD vs tuned EF21 vs Kimad).

use kimad::reports::{synthetic, ReportCtx};
use kimad::util::bench::{bench, black_box, time_once};

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    let md = time_once("fig3-6 regeneration (fast grids)", || {
        synthetic::generate_all(&ctx).unwrap()
    });
    println!("{md}");

    // Hot path: one full tuned single-scenario run.
    bench("synthetic run (Kimad, xsmall, 25s horizon)", 10, || {
        black_box(synthetic::run_at(
            synthetic::Scenario::XSmall,
            synthetic::Method::Kimad { t: 1.0 },
            0.05,
            1.0,
            25.0,
        ));
    });
}
