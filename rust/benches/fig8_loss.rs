//! Bench + regeneration for Fig. 8 (loss curve, Kimad vs comm-matched
//! EF21; deep model over PJRT). Skips gracefully without artifacts.

use kimad::reports::{deep, ReportCtx};
use kimad::util::bench::time_once;

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    if kimad::runtime::ArtifactStore::open(&ctx.artifacts).is_err() {
        println!("fig8: artifacts/ missing — run `make artifacts` first (skipped)");
        return;
    }
    match time_once("fig8 regeneration (fast)", || deep::fig8(&ctx)) {
        Ok(md) => println!("{md}"),
        Err(e) => println!("fig8 failed: {e:#}"),
    }
}
