//! Bench + regeneration for Table 2 (Top-5 accuracy across M).
//! Skips gracefully without artifacts.

use kimad::reports::{deep, ReportCtx};
use kimad::util::bench::time_once;

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    if kimad::runtime::ArtifactStore::open(&ctx.artifacts).is_err() {
        println!("table2: artifacts/ missing — run `make artifacts` first (skipped)");
        return;
    }
    match time_once("table2 regeneration (fast)", || deep::table2(&ctx)) {
        Ok(md) => println!("{md}"),
        Err(e) => println!("table2 failed: {e:#}"),
    }
}
