//! Bench + regeneration for Fig. 1 (EC2-like bandwidth traces).
//!
//! Prints the regenerated figure summary, then micro-benchmarks the
//! trace substrate (the hot query of every netsim transfer).

use kimad::bandwidth::BandwidthTrace;
use kimad::reports::{fig1, ReportCtx};
use kimad::util::bench::{bench, black_box, time_once};

fn main() {
    let ctx = ReportCtx::fast();
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    let md = time_once("fig1 regeneration", || fig1::generate(&ctx).unwrap());
    println!("{md}");

    let traces = fig1::ec2_like_traces(21);
    let tr = &traces[0];
    let mut t = 0.0;
    bench("trace::at (OU-noise composite)", 20, || {
        t += 0.37;
        if t > 100.0 {
            t = 0.0;
        }
        black_box(tr.at(black_box(t)));
    });
    bench("trace::integrate 1s window", 20, || {
        black_box(tr.integrate(black_box(10.0), black_box(11.0)));
    });
    bench("trace::transfer_time 1Mbit", 20, || {
        black_box(tr.transfer_time(black_box(5.0), black_box(1e6)));
    });
}
