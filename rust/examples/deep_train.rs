//! End-to-end deep-model training — the full three-layer stack.
//!
//! Loads the AOT-compiled JAX transformer (L2, with Pallas FFN kernels
//! at L1) through PJRT — or, when the PJRT backend is stubbed, runs
//! the native rust transformer (`model::native`) — then trains it for
//! a few hundred rounds with M=4 workers under the paper's §4.2
//! bandwidth regime, with Kimad's bandwidth-adaptive compression on
//! both directions. Logs the loss curve and held-out accuracy — the
//! run recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts   # once (or: kimad gen-artifacts --presets e2e)
//!     cargo run --release --example deep_train [--preset e2e] [--rounds 300]

// Wall-clock allowlist file (ARCHITECTURE.md §6): examples report real
// run time; clippy.toml bans the methods in engine code.
#![allow(clippy::disallowed_methods)]

use kimad::driver::run_experiment;
use kimad::kimad::CompressPolicy;
use kimad::reports::{deep, ReportCtx};
use kimad::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let preset = args.opt_or("preset", "e2e");
    let rounds = args.opt_usize("rounds", 300)? as u64;
    let artifacts = args.opt_or("artifacts", "artifacts");

    let ctx = ReportCtx {
        artifacts: artifacts.clone(),
        out_dir: "reports".into(),
        fast: preset == "small",
    };
    let mut cfg = deep::base_config(&ctx, CompressPolicy::KimadUniform, 1.0, 4);
    cfg.name = format!("deep_train-{preset}");
    cfg.rounds = rounds;

    eprintln!(
        "training preset '{preset}' for {rounds} rounds, M=4, Kimad uniform policy..."
    );
    let t0 = std::time::Instant::now();
    let res = run_experiment(&cfg, Some(&artifacts), 8)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("round | vtime(s) | loss    | up Mbit (w0)");
    let stride = (res.records.len() / 20).max(1);
    for r in res.records.iter().step_by(stride) {
        println!(
            "{:>5} | {:>8.1} | {:.4} | {:.3}",
            r.step,
            r.t_end(),
            r.loss,
            r.workers[0].up_bits as f64 / 1e6
        );
    }
    let first = res.records.first().unwrap().loss;
    let last = res.records.last().unwrap().loss;
    println!(
        "\nloss {first:.4} -> {last:.4} over {} rounds ({:.1} virtual s)",
        res.records.len(),
        res.total_time
    );
    println!("mean step time {:.2}s", res.mean_step_time());
    if let Some(e) = res.eval {
        println!(
            "held-out eval: loss={:.4} top1={:.1}% top5={:.1}% (n={})",
            e.loss,
            e.top1 * 100.0,
            e.top5 * 100.0,
            e.n
        );
    }
    println!("wall-clock: {wall:.1}s ({:.1} rounds/s real time)", res.records.len() as f64 / wall);
    Ok(())
}
