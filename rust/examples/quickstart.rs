//! Quickstart: the smallest end-to-end Kimad run.
//!
//! Simulates 60 rounds of bandwidth-adaptive compressed training on the
//! paper's d=30 quadratic (§4.1), one worker, sin² bandwidth — then
//! prints the loss trajectory and per-round communication sizes.
//!
//!     cargo run --release --example quickstart

use kimad::bandwidth::TraceSpec;
use kimad::config::{ExperimentConfig, OptimizerSpec, WorkloadSpec};
use kimad::driver::run_experiment;
use kimad::kimad::{BudgetParams, CompressPolicy};

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        m: 1,
        participation: 1.0,
        cohorts: 0,
        workload: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.2 },
        budget: BudgetParams::PerDirection { t_comm: 0.8 },
        up_policy: CompressPolicy::KimadUniform,
        down_policy: CompressPolicy::KimadUniform,
        optimizer: OptimizerSpec { gamma: 0.03, layer_weights: vec![] },
        // bits/s: one sparse coordinate is 64 bits, so this link fits
        // roughly 2..9 of the 30 coordinates per 0.8 s window.
        uplink: TraceSpec::SinSquared { eta: 576.0, theta: 0.1, delta: 192.0, phase: 0.0 },
        downlink: TraceSpec::Constant { bps: 1e9 },
        alpha: 1.0,
        rounds: 120,
        prior_bps: 0.0,
        warm_start: true,
        single_layer: false,
        budget_safety: 1.0,
        threads: 0,
        shards: 0,
        thread_cap: 0,
        mode: kimad::config::ExecModeSpec::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
        transport: kimad::config::TransportSpec::Inproc,
        seed: 21,
    };

    let res = run_experiment(&cfg, None, 0)?;
    println!("round |   time | up bits | f(x)");
    for r in res.records.iter().step_by(5) {
        println!(
            "{:>5} | {:>5.1}s | {:>7} | {:.4e}",
            r.step,
            r.t_end(),
            r.workers[0].up_bits,
            r.f_x
        );
    }
    let first = res.records.first().unwrap().f_x;
    let last = res.records.last().unwrap().f_x;
    println!(
        "\nf(x) improved {first:.3e} -> {last:.3e} over {:.1} virtual seconds",
        res.total_time
    );
    println!("mean step time: {:.2}s (deadline 2·t_comm + t_comp = 1.8s)", res.mean_step_time());
    Ok(())
}
