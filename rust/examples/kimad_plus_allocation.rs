//! Kimad+ under the hood: watch the knapsack DP allocate one budget
//! across heterogeneous layers, versus the uniform split.
//!
//! Builds a synthetic model whose layers have very different gradient
//! energy profiles, sweeps the budget, and prints the per-layer K each
//! policy chooses plus the resulting total error — the §4.3/Fig. 9
//! mechanism in isolation.
//!
//!     cargo run --release --example kimad_plus_allocation

use kimad::kimad::{CompressPolicy, ErrorCurve, Selector};
use kimad::model::ModelLayout;
use kimad::util::rng::Rng;

fn main() {
    // Three "layers": spiky (few huge coords), flat, decaying.
    let sizes = [256usize, 512, 256];
    let layout = ModelLayout::synthetic(&sizes);
    let layers = layout.layers();
    let mut rng = Rng::seed_from_u64(21);

    let mut diff = Vec::new();
    for i in 0..sizes[0] {
        diff.push(if i < 8 { 50.0 } else { 0.05 * rng.range_f32(-1.0, 1.0) });
    }
    for _ in 0..sizes[1] {
        diff.push(rng.range_f32(-1.0, 1.0));
    }
    for i in 0..sizes[2] {
        diff.push((-(i as f32) / 40.0).exp() * rng.range_f32(-2.0, 2.0));
    }

    let curves: Vec<ErrorCurve> = layers
        .iter()
        .map(|l| ErrorCurve::build(&diff[l.offset..l.offset + l.size]))
        .collect();

    let uniform = Selector::new(CompressPolicy::KimadUniform);
    let plus = Selector::new(CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] });
    let optimal = Selector::new(CompressPolicy::WholeModelTopK);

    println!(
        "{:>10} | {:>18} | {:>18} | {:>18}",
        "budget(K)", "Kimad err", "Kimad+ err", "optimal err"
    );
    for budget_k in [16u64, 64, 128, 256, 512] {
        let budget = budget_k * 64;
        let u = uniform.select(&diff, &layers, budget);
        let p = plus.select(&diff, &layers, budget);
        let o = optimal.select(&diff, &layers, budget);
        println!(
            "{:>10} | {:>8.2} {:>9} | {:>8.2} {:>9} | {:>8.2} {:>9}",
            budget_k,
            u.predicted_error(&curves),
            format!("{:?}", u.k_per_layer),
            p.predicted_error(&curves),
            format!("{:?}", p.k_per_layer),
            o.predicted_error(&curves),
            format!("{:?}", o.k_per_layer),
        );
    }
    println!("\nKimad+ shifts budget toward the spiky/decaying layers; the uniform split");
    println!("wastes coordinates on the flat layer. 'optimal' = whole-model TopK oracle.");
}
