//! Fig. 1 reproduction: emit the EC2-like 4-worker bandwidth traces
//! (and demonstrate the monitor tracking them).
//!
//!     cargo run --release --example bandwidth_trace > fig1.csv

use kimad::bandwidth::{BandwidthMonitor, EwmaMonitor};
use kimad::reports::fig1::ec2_like_traces;

fn main() {
    let traces = ec2_like_traces(21);
    let mut monitors: Vec<EwmaMonitor> =
        (0..traces.len()).map(|_| EwmaMonitor::new(0.7)).collect();

    println!("time_s,worker,true_mbps,estimate_mbps");
    let mut t = 0.0;
    while t <= 120.0 {
        for (i, tr) in traces.iter().enumerate() {
            let b = tr.at(t);
            // The monitor sees a 100 ms transfer worth of bytes.
            monitors[i].observe(b * 0.1, 0.1);
            let est = monitors[i].estimate_or(b);
            println!("{t:.1},{},{:.2},{:.2}", i + 1, b / 1e6, est / 1e6);
        }
        t += 0.5;
    }
    eprintln!("wrote 4-worker EC2-like trace (stdout); plot time_s vs true_mbps per worker");
}
