//! Figs. 3–6 workload as a standalone example: GD vs tuned EF21 vs
//! Kimad on the §4.1 quadratic under a chosen bandwidth regime.
//!
//!     cargo run --release --example synthetic_quadratic [xsmall|small|oscillation|high] [--full]

use kimad::reports::synthetic::{tuned_comparison, Scenario};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scenario = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("xsmall");
    let fast = !args.iter().any(|a| a == "--full");
    let scn = match scenario {
        "xsmall" => Scenario::XSmall,
        "small" => Scenario::Small,
        "oscillation" => Scenario::Oscillation,
        "high" => Scenario::High,
        other => {
            eprintln!("unknown scenario '{other}' (xsmall|small|oscillation|high)");
            std::process::exit(1);
        }
    };

    println!("scenario: {} (fast={fast}; --full for the paper-scale grid)", scn.id());
    let set = tuned_comparison(scn, fast);
    println!("{:<28} {:>12} {:>16}", "method", "final f(x)", "t to f<=1e-3");
    for s in &set.series {
        let reach = s
            .first_x_below(1e-3)
            .map(|t| format!("{t:.1}s"))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<28} {:>12.3e} {:>16}",
            s.name,
            s.last_y().unwrap_or(f64::NAN),
            reach
        );
    }
}
