//! Property-based invariants over the core algorithms (util::prop,
//! seeded + replayable).

use kimad::compress::{
    compression_error, Compressor, Identity, OneBitSign, QuantizeBits, RandK, TopK,
};
use kimad::ef21::Estimator;
use kimad::ef21::theory::{canonical_consts, max_gamma};
use kimad::kimad::knapsack::{allocate, topk_options, KnapsackParams, Option_};
use kimad::kimad::{CompressPolicy, ErrorCurve, Selector};
use kimad::model::{Layer, ModelLayout};
use kimad::util::prop::check;
use kimad::util::rng::Rng;

fn rand_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.range_f32(-5.0, 5.0)).collect()
}

#[test]
fn prop_error_curve_matches_explicit_topk() {
    check("error-curve == explicit topk error", 11, 60, |rng| {
        let d = rng.range_usize(1, 400);
        let u = rand_vec(rng, d);
        let k = rng.range_usize(0, d + 1);
        let curve = ErrorCurve::build(&u);
        let explicit = compression_error(&TopK::new(k), &u);
        assert!(
            (curve.at(k) - explicit).abs() <= 1e-6 * explicit.max(1.0),
            "d={d} k={k}: {} vs {explicit}",
            curve.at(k)
        );
    });
}

#[test]
fn prop_error_curve_monotone() {
    check("error-curve monotone non-increasing", 12, 40, |rng| {
        let d = rng.range_usize(1, 1000);
        let curve = ErrorCurve::build(&rand_vec(rng, d));
        for k in 1..=d {
            assert!(curve.err[k] <= curve.err[k - 1] + 1e-9);
        }
    });
}

#[test]
fn prop_compressors_contract() {
    check("all compressors satisfy the alpha-contraction bound", 13, 40, |rng| {
        let d = rng.range_usize(1, 300);
        let u = rand_vec(rng, d);
        let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum();
        let k = rng.range_usize(0, d + 1);
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(k)),
            Box::new(Identity),
            Box::new(QuantizeBits::new(1 + rng.range_usize(0, 16) as u64)),
            Box::new(OneBitSign),
        ];
        for c in comps {
            let err = compression_error(c.as_ref(), &u);
            assert!(
                err <= (1.0 - c.alpha(d)) * norm + 1e-3 * norm.max(1.0),
                "{} violates contraction: err={err} norm={norm}",
                c.name()
            );
        }
    });
}

#[test]
fn prop_randk_contracts_in_expectation() {
    check("randk mean error ~ (1-k/d)||u||^2", 14, 8, |rng| {
        let d = 150 + rng.range_usize(0, 100);
        let k = rng.range_usize(1, d);
        let u = rand_vec(rng, d);
        let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum();
        let c = RandK::new(k, rng.next_u64());
        let trials = 120;
        let mean: f64 = (0..trials)
            .map(|_| compression_error(&c, &u))
            .sum::<f64>()
            / trials as f64;
        let expect = (1.0 - k as f64 / d as f64) * norm;
        assert!(
            (mean - expect).abs() <= 0.25 * norm / (k as f64).sqrt() + 0.05 * norm,
            "d={d} k={k}: mean={mean} expect={expect}"
        );
    });
}

#[test]
fn prop_knapsack_respects_budget_and_beats_uniform() {
    check("kimad+ dp: within budget, never worse than uniform", 15, 40, |rng| {
        let n_layers = rng.range_usize(1, 6);
        let sizes: Vec<usize> = (0..n_layers).map(|_| rng.range_usize(8, 120)).collect();
        let layout = ModelLayout::synthetic(&sizes);
        let layers = layout.layers();
        let d_total: usize = sizes.iter().sum();
        let diff = rand_vec(rng, d_total);
        let budget = (rng.range_usize(0, d_total + 1) as u64) * 64;

        let plus = Selector::new(CompressPolicy::KimadPlus { discretization: 800, ratios: vec![] })
            .select(&diff, &layers, budget);
        let uni = Selector::new(CompressPolicy::KimadUniform).select(&diff, &layers, budget);
        assert!(plus.planned_bits <= budget, "dp exceeded budget");
        assert!(uni.planned_bits <= budget, "uniform exceeded budget");

        let curves: Vec<ErrorCurve> = layers
            .iter()
            .map(|l| ErrorCurve::build(&diff[l.offset..l.offset + l.size]))
            .collect();
        // Grid restriction means "not worse" holds up to one grid step
        // of slack per layer; use the uniform selection evaluated on
        // the same curves as the reference.
        let pe = plus.predicted_error(&curves);
        let ue = uni.predicted_error(&curves);
        assert!(
            pe <= ue * 1.10 + 1e-9,
            "dp {pe} much worse than uniform {ue} (budget {budget})"
        );
    });
}

#[test]
fn prop_knapsack_matches_bruteforce() {
    check("kimad+ dp == brute force on small instances", 16, 30, |rng| {
        let n = rng.range_usize(1, 4);
        let options: Vec<Vec<Option_>> = (0..n)
            .map(|_| {
                let m = rng.range_usize(1, 5);
                let mut v = vec![Option_ { bits: 0, error: rng.range_f64(0.0, 10.0) }];
                for _ in 1..m {
                    v.push(Option_ {
                        bits: rng.range_usize(0, 60) as u64,
                        error: rng.range_f64(0.0, 10.0),
                    });
                }
                v
            })
            .collect();
        let budget = rng.range_usize(0, 150) as u64;
        let a = allocate(
            &options,
            KnapsackParams { budget_bits: budget, discretization: budget.max(1) as usize },
        );
        let mut best = f64::INFINITY;
        let mut stack = vec![(0usize, 0u64, 0.0f64)];
        while let Some((i, bits, err)) = stack.pop() {
            if bits > budget {
                continue;
            }
            if i == options.len() {
                best = best.min(err);
                continue;
            }
            for o in &options[i] {
                stack.push((i + 1, bits + o.bits, err + o.error));
            }
        }
        assert!(a.total_bits <= budget);
        assert!((a.total_error - best).abs() < 1e-9, "dp={} bf={best}", a.total_error);
    });
}

#[test]
fn prop_topk_options_cover_budget_zero() {
    check("topk_options always include a zero-bit option", 17, 30, |rng| {
        let d = rng.range_usize(1, 200);
        let curve = ErrorCurve::build(&rand_vec(rng, d));
        let opts = topk_options(
            &[curve],
            &kimad::kimad::knapsack::paper_ratio_grid(),
            64,
        );
        assert!(opts[0].iter().any(|o| o.bits == 0));
    });
}

#[test]
fn prop_ef21_error_never_increases_on_fixed_target() {
    check("ef21 advance contracts toward a fixed target", 18, 40, |rng| {
        let d = rng.range_usize(1, 200);
        let target = rand_vec(rng, d);
        let layer = Layer { id: 0, name: "l".into(), offset: 0, size: d };
        let mut est = Estimator::zeros(d);
        let mut scratch = Vec::new();
        let k = rng.range_usize(1, d + 1);
        let mut prev = f64::INFINITY;
        for _ in 0..12 {
            est.compress_advance(&TopK::new(k), &target, &layer, &mut scratch);
            let err = est.layer_error(&target, &layer);
            assert!(err <= prev + 1e-6, "error increased: {err} > {prev}");
            prev = err;
        }
    });
}

#[test]
fn prop_theory_gamma_positive_and_monotone_in_alpha() {
    check("Eq.(9) step size: positive, monotone in alpha", 19, 40, |rng| {
        let ell = rng.range_usize(1, 6);
        let alphas: Vec<f64> = (0..ell).map(|_| rng.range_f64(0.05, 1.0)).collect();
        let ls: Vec<f64> = (0..ell).map(|_| rng.range_f64(0.1, 10.0)).collect();
        let lg = ls.iter().cloned().fold(0.0, f64::max) * rng.range_f64(1.0, 2.0);
        let w = vec![1.0; ell];
        let g = max_gamma(&alphas, &ls, lg, &w, None);
        assert!(g > 0.0 && g.is_finite());
        // Better compressors (larger alpha everywhere) allow larger gamma.
        let better: Vec<f64> = alphas.iter().map(|a| (a + 0.3).min(1.0)).collect();
        let g2 = max_gamma(&better, &ls, lg, &w, None);
        assert!(g2 >= g - 1e-12, "g={g} g2={g2}");
        for &a in &alphas {
            let c = canonical_consts(a);
            assert!((1.0 - c.alpha) * (1.0 + c.zeta) < 1.0 + 1e-12);
        }
    });
}

#[test]
fn prop_selection_budget_safety() {
    check("selector never plans beyond the budget (adaptive policies)", 20, 50, |rng| {
        let n_layers = rng.range_usize(1, 5);
        let sizes: Vec<usize> = (0..n_layers).map(|_| rng.range_usize(4, 100)).collect();
        let layout = ModelLayout::synthetic(&sizes);
        let layers = layout.layers();
        let diff = rand_vec(rng, sizes.iter().sum());
        let budget = rng.range_usize(0, 12_000) as u64;
        for policy in [
            CompressPolicy::KimadUniform,
            CompressPolicy::KimadPlus { discretization: 400, ratios: vec![] },
            CompressPolicy::WholeModelTopK,
        ] {
            let sel = Selector::new(policy.clone()).select(&diff, &layers, budget);
            assert!(
                sel.planned_bits <= budget,
                "{policy:?} planned {} > budget {budget}",
                sel.planned_bits
            );
        }
    });
}

#[test]
fn prop_json_value_roundtrip() {
    use kimad::util::json::Value;
    check("json serialize/parse roundtrip", 21, 60, |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Value {
            match if depth == 0 { rng.range_usize(0, 4) } else { rng.range_usize(0, 6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.next_f64() < 0.5),
                2 => Value::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => Value::Str(format!("s{}\"\\\n{}", rng.next_u64() % 100, "é")),
                4 => Value::Arr((0..rng.range_usize(0, 4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Value::Obj(
                    (0..rng.range_usize(0, 4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 3);
        let back = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    });
}

#[test]
fn prop_netsim_transfer_inverts_integrate() {
    use kimad::bandwidth::{BandwidthTrace, SinSquaredTrace};
    check("transfer_time is the inverse of integrate", 22, 40, |rng| {
        let tr = SinSquaredTrace::new(
            rng.range_f64(10.0, 1e6),
            rng.range_f64(0.01, 2.0),
            rng.range_f64(1.0, 1e5),
        );
        let t0 = rng.range_f64(0.0, 50.0);
        let bits = rng.range_f64(1.0, 1e6);
        let dt = tr.transfer_time(t0, bits);
        let got = tr.integrate(t0, t0 + dt);
        assert!(
            (got - bits).abs() / bits < 5e-3,
            "bits={bits} got={got} dt={dt}"
        );
    });
}
