//! CLI integration: drive the `kimad` binary end to end.

use std::process::Command;

fn kimad() -> Command {
    Command::new(env!("CARGO_BIN_EXE_kimad"))
}

#[test]
fn help_lists_subcommands() {
    let out = kimad().arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let cmds =
        ["train", "report", "scenarios", "synthetic", "trace", "presets", "gen-artifacts"];
    for cmd in cmds {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn scenarios_runs_default_grid_and_writes_cell_summaries() {
    let dir = std::env::temp_dir().join(format!("kimad-cli-scen-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = kimad()
        .args([
            "scenarios",
            "--rounds",
            "10",
            "--threads",
            "4",
            "--out-dir",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // The default grid is 2 traces x 4 policies x 3 modes x 2 workers.
    assert!(text.contains("48 cells"), "{text}");
    let index = std::fs::read_to_string(dir.join("index.json")).unwrap();
    assert!(index.contains("\"n_cells\":48"), "{index}");
    let n_json = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref()
                .unwrap()
                .path()
                .extension()
                .is_some_and(|x| x == "json")
        })
        .count();
    assert_eq!(n_json, 48 + 1, "one summary per cell + index.json");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenarios_modes_flag_overrides_the_mode_axis() {
    let dir = std::env::temp_dir().join(format!("kimad-cli-modes-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = kimad()
        .args([
            "scenarios",
            "--rounds",
            "6",
            "--threads",
            "2",
            "--modes",
            "semisync:0.5,async:0.8",
            "--out-dir",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // 2 traces x 4 policies x 2 modes x 2 workers = 32 cells.
    let index = std::fs::read_to_string(dir.join("index.json")).unwrap();
    assert!(index.contains("\"n_cells\":32"), "{index}");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("semisync"), "{text}");
    assert!(text.contains("async"), "{text}");
    assert!(!text.contains("_sync_"), "sync cells must be absent:\n{text}");
    let _ = std::fs::remove_dir_all(&dir);

    let bad = kimad()
        .args(["scenarios", "--modes", "lockstep", "--print-grid"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
}

#[test]
fn scenarios_resume_reuses_cached_cells() {
    let dir = std::env::temp_dir().join(format!("kimad-cli-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let run = |extra: &[&str]| {
        let mut args = vec!["scenarios", "--rounds", "6", "--threads", "2"];
        args.extend_from_slice(extra);
        args.extend_from_slice(&["--out-dir", dir.to_str().unwrap()]);
        kimad().args(&args).output().unwrap()
    };
    // Cold: 2 traces x 4 policies x 1 mode x 2 workers = 16 cells.
    let cold = run(&["--modes", "sync"]);
    assert!(cold.status.success(), "{}", String::from_utf8_lossy(&cold.stderr));
    let text = String::from_utf8_lossy(&cold.stdout);
    assert!(text.contains("cache: 0 hits, 16 misses"), "{text}");
    let index = std::fs::read(dir.join("index.json")).unwrap();
    // Resume over the unchanged grid: every cell hits, nothing runs,
    // and the index comes out byte-identical.
    let warm = run(&["--modes", "sync", "--resume"]);
    assert!(warm.status.success(), "{}", String::from_utf8_lossy(&warm.stderr));
    let text = String::from_utf8_lossy(&warm.stdout);
    assert!(text.contains("cache: 16 hits, 0 misses"), "{text}");
    assert!(text.contains(" hit |"), "table must flag reused cells:\n{text}");
    assert_eq!(std::fs::read(dir.join("index.json")).unwrap(), index);
    // Widening the mode axis re-runs only the new cells.
    let wider = run(&["--modes", "sync,semisync:0.5", "--resume"]);
    assert!(wider.status.success(), "{}", String::from_utf8_lossy(&wider.stderr));
    let text = String::from_utf8_lossy(&wider.stdout);
    assert!(text.contains("cache: 16 hits, 16 misses"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
    // The cache modes are mutually exclusive.
    let bad = run(&["--resume", "--fresh"]);
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("mutually exclusive"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenarios_print_grid_roundtrips_through_file() {
    let dir = std::env::temp_dir().join(format!("kimad-cli-grid-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let printed = kimad().args(["scenarios", "--print-grid"]).output().unwrap();
    assert!(printed.status.success());
    let grid_path = dir.join("grid.json");
    std::fs::write(&grid_path, &printed.stdout).unwrap();
    // A 1-cell run from the printed grid file (shrunk via --rounds).
    let out = kimad()
        .args([
            "scenarios",
            "--grid",
            grid_path.to_str().unwrap(),
            "--rounds",
            "5",
            "--out-dir",
            dir.join("out").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("out/index.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_artifacts_then_deep_workload_scenarios_end_to_end() {
    // The offline deep-model path: a native (JAX-free) artifact set
    // feeds a --workload deep:tiny grid, cell ids and summaries carry
    // the workload column, and `presets` reads the generated manifest.
    let dir = std::env::temp_dir().join(format!("kimad-cli-deep-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let art = dir.join("artifacts");
    let out = kimad()
        .args(["gen-artifacts", "--presets", "tiny", "--out-dir", art.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(art.join("manifest.json").exists());
    assert!(art.join("layout-tiny.json").exists());
    assert!(art.join("params-tiny.bin").exists());

    let presets = kimad()
        .args(["presets", "--artifacts", art.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(presets.status.success());
    assert!(String::from_utf8_lossy(&presets.stdout).contains("tiny"));

    let scen_dir = dir.join("out");
    let out = kimad()
        .args([
            "scenarios",
            "--rounds",
            "4",
            "--threads",
            "2",
            "--workload",
            "deep:tiny",
            "--artifacts",
            art.to_str().unwrap(),
            "--modes",
            "sync",
            "--out-dir",
            scen_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let index = std::fs::read_to_string(scen_dir.join("index.json")).unwrap();
    // 1 workload x 2 traces x 4 policies x 1 mode x 2 worker counts.
    assert!(index.contains("\"n_cells\":16"), "{index}");
    assert!(index.contains("deep-tiny_"), "{index}");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("deep-tiny"), "{text}");

    // A bad workload token fails at the CLI, before any cell runs.
    let bad = kimad()
        .args(["scenarios", "--workload", "resnet:18", "--print-grid"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_subcommand_fails() {
    let out = kimad().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn trace_emits_csv() {
    let out = kimad()
        .args([
            "trace",
            "--spec",
            r#"{"kind": "sin_squared", "eta": 100.0, "theta": 0.5, "delta": 10.0, "phase": 0.0}"#,
            "--seconds",
            "5",
            "--step",
            "1",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = text.trim().lines().collect();
    assert_eq!(lines[0], "time_s,bps");
    assert_eq!(lines.len(), 7); // header + t=0..5
    let first_val: f64 = lines[1].split(',').nth(1).unwrap().parse().unwrap();
    assert!((first_val - 10.0).abs() < 1e-6); // sin(0)=0 -> delta
}

#[test]
fn trace_rejects_bad_spec() {
    let out = kimad()
        .args(["trace", "--spec", r#"{"kind": "nope"}"#])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn train_runs_quadratic_config_and_writes_csv() {
    let dir = std::env::temp_dir().join(format!("kimad-cli-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("exp.json");
    std::fs::write(
        &cfg_path,
        r#"{
            "name": "cli-test", "m": 2, "rounds": 20, "seed": 21,
            "workload": {"kind": "quadratic", "d": 30, "n_layers": 3, "t_comp": 0.1},
            "budget": {"mode": "per_direction", "t_comm": 0.9},
            "up_policy": {"kind": "kimad_uniform"},
            "down_policy": {"kind": "kimad_uniform"},
            "optimizer": {"gamma": 0.05},
            "uplink": {"kind": "sin_squared", "eta": 512.0, "theta": 0.1, "delta": 64.0},
            "downlink": {"kind": "constant", "bps": 1e7}
        }"#,
    )
    .unwrap();
    let csv_path = dir.join("out.csv");
    let out = kimad()
        .args([
            "train",
            "--config",
            cfg_path.to_str().unwrap(),
            "--csv",
            csv_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rounds=20"), "{text}");
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv.starts_with("series,time_s,value"));
    assert!(csv.lines().count() > 40); // 3 series x 20 rounds + header
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn report_fig1_writes_csv() {
    let dir = std::env::temp_dir().join(format!("kimad-cli-fig1-{}", std::process::id()));
    let out = kimad()
        .args(["report", "fig1", "--fast", "--out-dir", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fig1"));
    assert!(dir.join("fig1_bandwidth.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn report_unknown_id_fails() {
    let out = kimad().args(["report", "fig99"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn presets_lists_models_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let out = kimad().args(["presets"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("tiny"), "{text}");
    assert!(text.contains("params"));
}
