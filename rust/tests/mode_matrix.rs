//! Execution-mode matrix: the event engine's contract.
//!
//! * `Sync` on the event engine is **bit-identical** to the frozen
//!   pre-refactor loop (`Simulation::round_reference`) — the golden
//!   test of the event-driven rewrite.
//! * Semi-sync and async runs are deterministic across thread counts
//!   and across repeated runs.
//! * With homogeneous workers, async staleness is bounded by M.

use std::sync::Arc;

use kimad::bandwidth::{ConstantTrace, SinSquaredTrace};
use kimad::coordinator::{
    ComputeModel, ExecMode, QuadraticSource, RoundRecord, SimConfig, Simulation,
};
use kimad::kimad::{BudgetParams, CompressPolicy};
use kimad::netsim::{Link, NetSim};
use kimad::optim::{LayerwiseSgd, Schedule};
use kimad::quadratic::Quadratic;

const D: usize = 40;

/// Per-worker phase-shifted sin² uplinks over a fat downlink.
fn wave_net(m: usize) -> NetSim {
    NetSim::new(
        (0..m)
            .map(|i| {
                Link::new(
                    Arc::new(
                        SinSquaredTrace::new(1500.0, 0.13, 200.0).with_phase(0.2 * i as f64),
                    ),
                    Arc::new(ConstantTrace::new(1e6)),
                )
            })
            .collect(),
    )
}

/// Identical constant links — the homogeneous setting for staleness
/// bounds.
fn flat_net(m: usize, bps: f64) -> NetSim {
    NetSim::new(
        (0..m)
            .map(|_| {
                Link::new(
                    Arc::new(ConstantTrace::new(bps)),
                    Arc::new(ConstantTrace::new(bps)),
                )
            })
            .collect(),
    )
}

fn build(
    m: usize,
    net: NetSim,
    policy: CompressPolicy,
    mode: ExecMode,
    compute: ComputeModel,
    threads: usize,
) -> Simulation<QuadraticSource> {
    let q = Quadratic::paper_instance(D);
    let layers = q.layout(4).layers();
    let src = QuadraticSource::new(q, 0.1);
    let cfg = SimConfig {
        m,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 0.9 },
        up_policy: policy.clone(),
        down_policy: policy,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.02)),
        layers,
        warm_start: true,
        prior_bps: 800.0,
        round_deadline: Some(1.9),
        budget_safety: 1.0,
        threads,
        mode,
        compute,
    };
    Simulation::new(cfg, net, src, vec![1.0f32; D])
}

fn run_reference(sim: &mut Simulation<QuadraticSource>, n: u64) -> Vec<RoundRecord> {
    (0..n).map(|_| sim.round_reference().unwrap()).collect()
}

#[test]
fn sync_event_engine_bit_matches_reference_loop() {
    // The golden test: for every policy and worker count, the
    // event-driven Sync engine reproduces the pre-refactor loop's
    // records exactly — same bits, same timings, same floats.
    for policy in [
        CompressPolicy::KimadUniform,
        CompressPolicy::KimadPlus { discretization: 300, ratios: vec![] },
        CompressPolicy::WholeModelTopK,
        CompressPolicy::FixedRatio { ratio: 0.3 },
    ] {
        for m in [1usize, 3] {
            let mut engine = build(
                m,
                wave_net(m),
                policy.clone(),
                ExecMode::Sync,
                ComputeModel::Constant,
                1,
            );
            let mut oracle = build(
                m,
                wave_net(m),
                policy.clone(),
                ExecMode::Sync,
                ComputeModel::Constant,
                1,
            );
            let got = engine.run(40).unwrap();
            let want = run_reference(&mut oracle, 40);
            assert_eq!(got, want, "{policy:?} m={m}: event engine diverged");
        }
    }
}

#[test]
fn sync_bit_identity_with_heterogeneous_downlinks() {
    // Regression: worker 0's ComputeDone fires before worker 1's
    // BroadcastDone when downlink speeds differ by orders of magnitude
    // — the sync drain must dispatch interleaved milestone kinds.
    let net = NetSim::new(vec![
        Link::new(
            Arc::new(ConstantTrace::new(1500.0)),
            Arc::new(ConstantTrace::new(1e6)), // fast downlink
        ),
        Link::new(
            Arc::new(ConstantTrace::new(1500.0)),
            Arc::new(ConstantTrace::new(300.0)), // slow downlink
        ),
    ]);
    let oracle_net = NetSim::new(vec![
        Link::new(Arc::new(ConstantTrace::new(1500.0)), Arc::new(ConstantTrace::new(1e6))),
        Link::new(Arc::new(ConstantTrace::new(1500.0)), Arc::new(ConstantTrace::new(300.0))),
    ]);
    let mut engine = build(
        2,
        net,
        CompressPolicy::KimadUniform,
        ExecMode::Sync,
        ComputeModel::Constant,
        1,
    );
    let mut oracle = build(
        2,
        oracle_net,
        CompressPolicy::KimadUniform,
        ExecMode::Sync,
        ComputeModel::Constant,
        1,
    );
    let got = engine.run(25).unwrap();
    let want = run_reference(&mut oracle, 25);
    assert_eq!(got, want, "interleaved milestones diverged from the reference");
}

#[test]
fn sync_bit_identity_holds_across_thread_counts() {
    // Engine with 2 threads vs reference with 3: chunking must never
    // leak into results on either side.
    let policy = CompressPolicy::KimadUniform;
    let mut engine = build(
        4,
        wave_net(4),
        policy.clone(),
        ExecMode::Sync,
        ComputeModel::Constant,
        2,
    );
    let mut oracle = build(
        4,
        wave_net(4),
        policy,
        ExecMode::Sync,
        ComputeModel::Constant,
        3,
    );
    let got = engine.run(30).unwrap();
    let want = run_reference(&mut oracle, 30);
    assert_eq!(got, want);
}

#[test]
fn semisync_deterministic_across_thread_counts_and_runs() {
    let straggler = ComputeModel::Profile { factors: vec![1.0, 1.0, 1.0, 8.0] };
    let runs: Vec<Vec<RoundRecord>> = [1usize, 2, 0]
        .iter()
        .map(|&threads| {
            let mut s = build(
                4,
                wave_net(4),
                CompressPolicy::KimadUniform,
                ExecMode::SemiSync { quorum: 2 },
                straggler.clone(),
                threads,
            );
            s.run(50).unwrap()
        })
        .collect();
    assert_eq!(runs[0], runs[1], "threads=2 changed semisync results");
    assert_eq!(runs[0], runs[2], "threads=auto changed semisync results");
    // Quorum respected: every round closes with >= 2 arrivals counted
    // (pre-deadline stragglers can push it above the quorum).
    for r in &runs[0] {
        assert!(r.n_arrivals() >= 2, "round {} closed early", r.step);
    }
    // The 8x straggler shows up as positive staleness somewhere.
    assert!(runs[0]
        .iter()
        .flat_map(|r| &r.workers)
        .any(|w| w.worker == 3 && w.staleness > 0));
}

#[test]
fn async_deterministic_across_thread_counts_and_runs() {
    let runs: Vec<Vec<RoundRecord>> = [1usize, 4, 0]
        .iter()
        .map(|&threads| {
            let mut s = build(
                3,
                wave_net(3),
                CompressPolicy::KimadUniform,
                ExecMode::Async { damping: 0.7 },
                ComputeModel::Lognormal { sigma: 0.3, seed: 5 },
                threads,
            );
            s.run(80).unwrap()
        })
        .collect();
    assert_eq!(runs[0], runs[1], "threads=4 changed async results");
    assert_eq!(runs[0], runs[2], "threads=auto changed async results");
}

#[test]
fn async_staleness_bounded_by_m_for_homogeneous_workers() {
    // Identical links + constant compute + fixed-ratio compression:
    // every chain has the same duration, so between one worker's model
    // snapshot and its arrival each other worker lands at most once —
    // staleness <= M.
    let m = 4;
    let mut s = build(
        m,
        flat_net(m, 2000.0),
        CompressPolicy::FixedRatio { ratio: 0.5 },
        ExecMode::Async { damping: 1.0 },
        ComputeModel::Constant,
        1,
    );
    let recs = s.run(120).unwrap();
    let mut saw_positive = false;
    for r in &recs {
        assert_eq!(r.n_arrivals(), 1);
        for w in &r.workers {
            assert!(
                w.staleness <= m as u64,
                "round {}: worker {} staleness {} > M",
                r.step,
                w.worker,
                w.staleness
            );
            saw_positive |= w.staleness > 0;
        }
    }
    assert!(saw_positive, "M>1 async runs must observe staleness");
    // Virtual time is monotone non-decreasing across arrival-paced
    // rounds.
    for pair in recs.windows(2) {
        assert!(pair[1].t_start >= pair[0].t_start);
    }
}

#[test]
fn semisync_outpaces_sync_under_heavy_stragglers() {
    // One worker computes 30x slower than the deadline allows: sync
    // rounds stall on it, semi-sync rounds close at the quorum/deadline
    // and keep the virtual clock moving.
    let straggler = ComputeModel::Profile { factors: vec![1.0, 1.0, 1.0, 30.0] };
    let mut sync = build(
        4,
        wave_net(4),
        CompressPolicy::KimadUniform,
        ExecMode::Sync,
        straggler.clone(),
        1,
    );
    let mut semi = build(
        4,
        wave_net(4),
        CompressPolicy::KimadUniform,
        ExecMode::SemiSync { quorum: 2 },
        straggler,
        1,
    );
    sync.run(20).unwrap();
    semi.run(20).unwrap();
    assert!(
        semi.clock < sync.clock,
        "semi-sync {:.1}s should beat sync {:.1}s over 20 straggler rounds",
        semi.clock,
        sync.clock
    );
}
