//! End-to-end simulator integration: full experiments through the
//! driver, across policies, worker counts and bandwidth patterns.

use kimad::bandwidth::TraceSpec;
use kimad::config::{ExperimentConfig, OptimizerSpec, WorkloadSpec};
use kimad::driver::run_experiment;
use kimad::kimad::{BudgetParams, CompressPolicy};

fn quad_cfg(m: usize, policy: CompressPolicy, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "it".into(),
        m,
        participation: 1.0,
        cohorts: 0,
        workload: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 },
        budget: BudgetParams::PerDirection { t_comm: 0.9 },
        up_policy: policy.clone(),
        down_policy: policy,
        optimizer: OptimizerSpec { gamma: 0.03, layer_weights: vec![] },
        uplink: TraceSpec::SinSquared { eta: 512.0, theta: 0.1, delta: 64.0, phase: 0.0 },
        downlink: TraceSpec::Constant { bps: 1e7 },
        alpha: 1.0,
        rounds,
        prior_bps: 0.0,
        warm_start: true,
        single_layer: false,
        budget_safety: 1.0,
        threads: 0,
        shards: 0,
        thread_cap: 0,
        mode: kimad::config::ExecModeSpec::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
        transport: kimad::config::TransportSpec::Inproc,
        seed: 21,
    }
}

#[test]
fn all_policies_converge_on_quadratic() {
    for policy in [
        CompressPolicy::KimadUniform,
        CompressPolicy::KimadPlus { discretization: 300, ratios: vec![] },
        CompressPolicy::WholeModelTopK,
        CompressPolicy::FixedRatio { ratio: 0.3 },
    ] {
        let res = run_experiment(&quad_cfg(2, policy.clone(), 250), None, 0).unwrap();
        let first = res.records[0].f_x;
        let last = res.records.last().unwrap().f_x;
        assert!(
            last < first * 0.05,
            "{policy:?}: f {first:.3e} -> {last:.3e}"
        );
    }
}

#[test]
fn deterministic_given_seed() {
    let a = run_experiment(&quad_cfg(3, CompressPolicy::KimadUniform, 40), None, 0).unwrap();
    let b = run_experiment(&quad_cfg(3, CompressPolicy::KimadUniform, 40), None, 0).unwrap();
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra, rb, "simulation must be bit-reproducible");
    }
}

#[test]
fn worker_count_scales_structurally() {
    for m in [1usize, 2, 8] {
        let res = run_experiment(&quad_cfg(m, CompressPolicy::KimadUniform, 10), None, 0).unwrap();
        for r in &res.records {
            assert_eq!(r.workers.len(), m);
        }
    }
}

#[test]
fn kimad_respects_budget_after_warmup() {
    let res = run_experiment(&quad_cfg(2, CompressPolicy::KimadUniform, 60), None, 0).unwrap();
    // After the monitor warms, uplink bits per round are bounded by the
    // (estimate x window) budget; the true bandwidth never exceeds
    // eta + delta, so bits <= (eta+delta) * t_comm plus slack for the
    // EWMA overshoot.
    let cap = (512.0 + 64.0) * 0.9 * 1.35 + 64.0;
    for r in res.records.iter().skip(5) {
        for w in &r.workers {
            assert!(
                (w.up_bits as f64) <= cap,
                "round {} sent {} bits (cap {cap})",
                r.step,
                w.up_bits
            );
        }
    }
}

#[test]
fn kimad_plus_error_not_worse_than_uniform() {
    // Same budget, layer-heterogeneous gradients (quadratic with
    // log-spaced curvature): the DP allocation must not lose to the
    // uniform split on mean compression error (Fig. 9's shape).
    let uni = run_experiment(&quad_cfg(1, CompressPolicy::KimadUniform, 120), None, 0).unwrap();
    let plus = run_experiment(
        &quad_cfg(1, CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] }, 120),
        None,
        0,
    )
    .unwrap();
    let mean = |r: &kimad::driver::ExperimentResult| {
        r.records.iter().map(|x| x.mean_compression_error()).sum::<f64>()
            / r.records.len() as f64
    };
    let (mu, mp) = (mean(&uni), mean(&plus));
    assert!(
        mp <= mu * 1.05 + 1e-12,
        "kimad+ mean err {mp:.4e} vs uniform {mu:.4e}"
    );
}

#[test]
fn deadline_scheduling_floors_round_times() {
    let res = run_experiment(&quad_cfg(2, CompressPolicy::KimadUniform, 30), None, 0).unwrap();
    // deadline = 2 * t_comm + t_comp = 1.9s
    for r in &res.records {
        assert!(r.duration >= 1.9 - 1e-9, "round {} took {}", r.step, r.duration);
    }
}

#[test]
fn round_budget_mode_works_end_to_end() {
    let mut cfg = quad_cfg(2, CompressPolicy::KimadUniform, 60);
    cfg.budget = BudgetParams::RoundBudget { t: 2.0, t_comp: 0.1 };
    let res = run_experiment(&cfg, None, 0).unwrap();
    assert!(res.records.last().unwrap().f_x < res.records[0].f_x);
    for r in &res.records {
        assert!(r.duration >= 2.0 - 1e-9);
    }
}

#[test]
fn single_layer_vs_layered_both_converge() {
    let mut cfg = quad_cfg(1, CompressPolicy::KimadUniform, 200);
    cfg.single_layer = true;
    let single = run_experiment(&cfg, None, 0).unwrap();
    cfg.single_layer = false;
    let layered = run_experiment(&cfg, None, 0).unwrap();
    assert!(single.records.last().unwrap().f_x < single.records[0].f_x * 0.1);
    assert!(layered.records.last().unwrap().f_x < layered.records[0].f_x * 0.1);
}

#[test]
fn congestion_alpha_slows_rounds() {
    let mut slow = quad_cfg(1, CompressPolicy::FixedRatio { ratio: 1.0 }, 15);
    slow.downlink = TraceSpec::Constant { bps: 2000.0 };
    let base = run_experiment(&slow, None, 0).unwrap();
    slow.alpha = 4.0;
    let congested = run_experiment(&slow, None, 0).unwrap();
    assert!(
        congested.total_time > base.total_time,
        "alpha=4 should lengthen broadcasts: {} vs {}",
        congested.total_time,
        base.total_time
    );
}

#[test]
fn config_json_roundtrip_through_driver() {
    let cfg = quad_cfg(2, CompressPolicy::KimadPlus { discretization: 500, ratios: vec![] }, 25);
    let text = cfg.to_json_string();
    let parsed =
        ExperimentConfig::from_json(&kimad::util::json::Value::parse(&text).unwrap()).unwrap();
    assert_eq!(parsed, cfg);
    let a = run_experiment(&cfg, None, 0).unwrap();
    let b = run_experiment(&parsed, None, 0).unwrap();
    assert_eq!(a.records, b.records);
}
