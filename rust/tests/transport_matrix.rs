//! The wire-bit golden harness: a wired run (coordinator + M worker
//! peers exchanging real frames over UDS/TCP) must move exactly the
//! bytes the in-process engine computes — frame for frame — and
//! produce identical [`ExperimentResult`]s, with and without seeded
//! transport faults.
//!
//! Workers run as an in-process tree (`SpawnMode::Thread`) so the
//! harness stays hermetic under `cargo test`; the frames still cross
//! real sockets through the full reliable-delivery stack.

use std::time::Duration;

use kimad::bandwidth::TraceSpec;
use kimad::config::{ExperimentConfig, OptimizerSpec, TransportSpec, WorkloadSpec};
use kimad::driver::WarmFamily;
use kimad::kimad::{BudgetParams, CompressPolicy};
use kimad::transport::endpoint::TimeoutCfg;
use kimad::transport::faults::FaultPlan;
use kimad::transport::frame::{self, PayloadKind};
use kimad::transport::{run_wired_captured, SpawnMode, WireOpts};

/// 1×4 topology, 5 rounds, §4.1 quadratic, oscillating uplink.
fn wired_cfg(policy: CompressPolicy, safety: f64, transport: TransportSpec) -> ExperimentConfig {
    ExperimentConfig {
        name: "wire".into(),
        m: 4,
        participation: 1.0,
        cohorts: 0,
        workload: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 },
        budget: BudgetParams::PerDirection { t_comm: 0.9 },
        up_policy: policy.clone(),
        down_policy: policy,
        optimizer: OptimizerSpec { gamma: 0.03, layer_weights: vec![] },
        uplink: TraceSpec::SinSquared { eta: 512.0, theta: 0.1, delta: 64.0, phase: 0.0 },
        downlink: TraceSpec::Constant { bps: 1e7 },
        alpha: 1.0,
        rounds: 5,
        prior_bps: 0.0,
        warm_start: true,
        single_layer: false,
        budget_safety: safety,
        threads: 1,
        shards: 0,
        thread_cap: 0,
        mode: kimad::config::ExecModeSpec::Sync,
        compute: kimad::coordinator::ComputeModel::Constant,
        transport,
        seed: 21,
    }
}

fn policies() -> Vec<(&'static str, CompressPolicy)> {
    vec![
        ("ef21-fixed25", CompressPolicy::FixedRatio { ratio: 0.25 }),
        ("kimad", CompressPolicy::KimadUniform),
        ("kimad-plus", CompressPolicy::KimadPlus { discretization: 400, ratios: vec![] }),
        ("whole-topk", CompressPolicy::WholeModelTopK),
    ]
}

/// Thread-spawned wired options; `ack_base` lowered so fault-injected
/// retransmissions keep the suite fast.
fn thread_opts(faults: FaultPlan) -> WireOpts {
    WireOpts {
        faults,
        timeouts: TimeoutCfg {
            ack_base: Duration::from_millis(30),
            ..TimeoutCfg::default()
        },
        spawn: SpawnMode::Thread,
    }
}

/// What the in-process engine says must cross the wire: per round, a
/// `Broadcast` to each worker (identical payload) then each worker's
/// `Upload`, in worker order.
fn expected_frames(
    family: &WarmFamily,
    cfg: &ExperimentConfig,
) -> Vec<(PayloadKind, u32, u64, Vec<u8>)> {
    let mut cell = family.build_wired(cfg).unwrap();
    let mut out = Vec::new();
    for _ in 0..cfg.rounds {
        cell.round().unwrap();
        let wire = cell.take_wire().unwrap();
        let bcast = frame::encode_msgs(&wire.broadcast);
        for id in 0..cfg.m {
            out.push((PayloadKind::Broadcast, id as u32, wire.step, bcast.clone()));
        }
        for id in 0..cfg.m {
            let upload = frame::encode_msgs(&wire.uploads[id]);
            out.push((PayloadKind::Upload, id as u32, wire.step, upload));
        }
    }
    out
}

fn assert_frames_match(
    name: &str,
    expected: &[(PayloadKind, u32, u64, Vec<u8>)],
    captured: &[kimad::transport::CapturedFrame],
) {
    assert_eq!(captured.len(), expected.len(), "{name}: captured frame count");
    for (i, (cap, exp)) in captured.iter().zip(expected).enumerate() {
        assert_eq!(cap.kind, exp.0, "{name}: frame {i} kind");
        assert_eq!(cap.worker, exp.1, "{name}: frame {i} worker");
        assert_eq!(cap.round, exp.2, "{name}: frame {i} round");
        assert_eq!(cap.payload, exp.3, "{name}: frame {i} payload bytes");
    }
}

#[test]
fn uds_wire_bits_match_inproc_engine_frame_for_frame() {
    for (name, policy) in policies() {
        for safety in [1.0, 0.8] {
            let cfg = wired_cfg(policy.clone(), safety, TransportSpec::Uds);
            let family = WarmFamily::prepare(&cfg, None).unwrap();
            let expected = expected_frames(&family, &cfg);
            let (wired, captured) =
                run_wired_captured(&family, &cfg, &thread_opts(FaultPlan::none()), 0).unwrap();
            assert_frames_match(name, &expected, &captured);

            // The run's results are byte-identical to the in-process
            // engine's; only wall-clock metadata may differ.
            let mut inproc_cfg = cfg.clone();
            inproc_cfg.transport = TransportSpec::Inproc;
            let inproc = family.run(&inproc_cfg).unwrap();
            assert_eq!(wired.records, inproc.records, "{name} s{safety}: records");
            assert_eq!(wired.total_time, inproc.total_time, "{name} s{safety}: virtual clock");
            assert_eq!(wired.n_params, inproc.n_params, "{name} s{safety}: n_params");
        }
    }
}

#[test]
fn tcp_wire_bits_match_inproc_engine() {
    let cfg = wired_cfg(CompressPolicy::KimadUniform, 1.0, TransportSpec::Tcp);
    let family = WarmFamily::prepare(&cfg, None).unwrap();
    let expected = expected_frames(&family, &cfg);
    let (wired, captured) =
        run_wired_captured(&family, &cfg, &thread_opts(FaultPlan::none()), 0).unwrap();
    assert_frames_match("tcp-kimad", &expected, &captured);
    let mut inproc_cfg = cfg.clone();
    inproc_cfg.transport = TransportSpec::Inproc;
    assert_eq!(wired.records, family.run(&inproc_cfg).unwrap().records);
}

#[test]
fn faulted_wire_converges_to_identical_state() {
    // Seeded drops, duplicates, truncations and delays on every leg:
    // the reliable layer must retransmit through all of it and land
    // the exact same frames — and therefore the exact same model
    // state — as a clean wired run and the in-process engine.
    let plan = FaultPlan::parse("drop=0.15,dup=0.1,trunc=0.1,delay=0.2,delay_ms=2,seed=7").unwrap();
    let cfg = wired_cfg(CompressPolicy::KimadUniform, 1.0, TransportSpec::Uds);
    let family = WarmFamily::prepare(&cfg, None).unwrap();
    let expected = expected_frames(&family, &cfg);

    let (faulted, captured) = run_wired_captured(&family, &cfg, &thread_opts(plan), 0).unwrap();
    assert_frames_match("faulted", &expected, &captured);

    let (clean, _) =
        run_wired_captured(&family, &cfg, &thread_opts(FaultPlan::none()), 0).unwrap();
    assert_eq!(faulted.records, clean.records, "faulted vs clean wired records");

    let mut inproc_cfg = cfg.clone();
    inproc_cfg.transport = TransportSpec::Inproc;
    let inproc = family.run(&inproc_cfg).unwrap();
    assert_eq!(faulted.records, inproc.records, "faulted wired vs inproc records");
}

#[test]
fn wired_dispatch_through_family_run() {
    // `WarmFamily::run` on a wire-transport config must route through
    // the transport layer (thread spawn under cargo test) and still
    // return in-process-identical records.
    std::env::set_var("KIMAD_WIRE_SPAWN", "thread");
    let cfg = wired_cfg(CompressPolicy::FixedRatio { ratio: 0.25 }, 1.0, TransportSpec::Uds);
    let family = WarmFamily::prepare(&cfg, None).unwrap();
    let wired = family.run(&cfg).unwrap();
    let mut inproc_cfg = cfg.clone();
    inproc_cfg.transport = TransportSpec::Inproc;
    assert_eq!(wired.records, family.run(&inproc_cfg).unwrap().records);
    std::env::remove_var("KIMAD_WIRE_SPAWN");
}

#[test]
fn population_and_async_cells_refuse_the_wire() {
    let mut pop = wired_cfg(CompressPolicy::KimadUniform, 1.0, TransportSpec::Uds);
    pop.participation = 0.5;
    let family = WarmFamily::prepare(&pop, None).unwrap();
    assert!(family.build_wired(&pop).is_err(), "population cells must stay inproc");

    let mut async_cfg = wired_cfg(CompressPolicy::KimadUniform, 1.0, TransportSpec::Uds);
    async_cfg.mode = kimad::config::ExecModeSpec::Async { damping: 0.5 };
    let family = WarmFamily::prepare(&async_cfg, None).unwrap();
    assert!(family.build_wired(&async_cfg).is_err(), "async cells must stay inproc");
}
