//! Integration tests for the `kimad bench` harness: the BENCH_*.json
//! schema round-trips through the report types, and the kernel suite's
//! allocation counts are deterministic (and exactly zero on the
//! buffer-reuse paths) under a real installed counting allocator.

use std::sync::Mutex;

use kimad::bench::{
    allocs, kernels, BenchConfig, BenchReport, CountingAlloc, E2eRecord, KernelRecord,
};

/// Install the counting allocator so the `allocs` column in this test
/// binary is real, exactly as in the bench binaries.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes every test in this binary: the allocation-counting tests
/// read the global counter, and any concurrently allocating test
/// thread (even the JSON one) would pollute their deltas.
static ALLOC_LOCK: Mutex<()> = Mutex::new(());

fn sample_report() -> BenchReport {
    BenchReport {
        commit: "deadbeefcafe".into(),
        config: BenchConfig {
            host: "ci".into(),
            quick: true,
            samples: 3,
            sizes: vec![1 << 16, 1 << 20],
            threads: 8,
        },
        kernels: vec![KernelRecord {
            name: "diff".into(),
            n: 65536,
            ns_per_iter: 12345.5,
            bytes_per_iter: 786432,
            allocs: 0,
        }],
        e2e: vec![E2eRecord {
            grid: "quick-r20".into(),
            cells: 48,
            wall_ms: 1500.0,
            build_ms: 120.0,
            cells_per_sec: 32.0,
        }],
    }
}

#[test]
fn bench_report_round_trips_through_json_text() {
    let _guard = ALLOC_LOCK.lock().unwrap();
    let report = sample_report();
    let text = report.to_json().to_string();
    let back = BenchReport::parse(&text).expect("emitted JSON must parse back");
    assert_eq!(back.to_json().to_string(), text, "round-trip must be lossless");
    assert_eq!(back.commit, "deadbeefcafe");
    assert_eq!(back.config.sizes, vec![65536, 1048576]);
    assert_eq!(back.kernels[0].name, "diff");
    assert_eq!(back.e2e[0].grid, "quick-r20");
    assert_eq!(back.e2e[0].build_ms, 120.0);

    // The schema the CI gate greps for: every required key is present.
    for key in ["\"commit\"", "\"config\"", "\"kernels\"", "\"e2e\"", "\"ns_per_iter\"",
        "\"bytes_per_iter\"", "\"allocs\"", "\"cells_per_sec\"", "\"build_ms\""]
    {
        assert!(text.contains(key), "schema key {key} missing from {text}");
    }
}

#[test]
fn counting_allocator_is_installed_and_counts() {
    let _guard = ALLOC_LOCK.lock().unwrap();
    let before = allocs();
    let v = std::hint::black_box(vec![0u8; 4096]);
    drop(v);
    assert!(allocs() > before, "installed CountingAlloc must count heap allocations");
}

#[test]
fn kernel_alloc_counts_are_deterministic_and_zero_on_reuse_paths() {
    let _guard = ALLOC_LOCK.lock().unwrap();
    // Tiny size + 1 sample: fast, but the same warm/count protocol as
    // the real `kimad bench` run.
    let first = kernels::run_kernels(&[64], 1);
    let second = kernels::run_kernels(&[64], 1);
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.n, b.n);
        assert_eq!(
            a.allocs, b.allocs,
            "allocation count for {} must be deterministic across runs",
            a.name
        );
    }
    for rec in &first {
        if kernels::alloc_free_kernels().contains(&rec.name.as_str()) {
            assert_eq!(
                rec.allocs, 0,
                "warm {} path must be allocation-free, saw {} allocs/iter",
                rec.name, rec.allocs
            );
        }
    }
}
