//! Shard-count matrix: the sharded server path's contract.
//!
//! Sharding the server's aggregation path (mirror delivery, Σ w_m û_m,
//! the optimizer step) and — since PR 4 — the broadcast compression
//! phase (diff x − x̂, `A^compress` selection, EF21 compress-advance)
//! is a pure parallelization: for every execution mode, every shard
//! count and every thread count the records must be **bit-identical**.
//! Sync additionally stays bit-identical to the frozen pre-refactor
//! loop (`Simulation::round_reference`), which is asserted against
//! forced shard counts here (the unforced golden lives in
//! `mode_matrix.rs`, untouched).

use std::sync::Arc;

use kimad::bandwidth::{ConstantTrace, SinSquaredTrace};
use kimad::coordinator::{
    ComputeModel, ExecMode, QuadraticSource, RoundRecord, SimConfig, Simulation,
};
use kimad::kimad::{BudgetParams, CompressPolicy};
use kimad::netsim::{Link, NetSim};
use kimad::optim::{LayerwiseSgd, Schedule};
use kimad::quadratic::Quadratic;

const D: usize = 48;
const N_LAYERS: usize = 6;
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

/// Per-worker phase-shifted sin² uplinks over a fat downlink.
fn wave_net(m: usize) -> NetSim {
    NetSim::new(
        (0..m)
            .map(|i| {
                Link::new(
                    Arc::new(
                        SinSquaredTrace::new(1500.0, 0.13, 200.0).with_phase(0.2 * i as f64),
                    ),
                    Arc::new(ConstantTrace::new(1e6)),
                )
            })
            .collect(),
    )
}

/// Identical constant links: every sync upload lands at the same
/// timestamp, so the batched drain actually forms multi-worker batches.
fn flat_net(m: usize, bps: f64) -> NetSim {
    NetSim::new(
        (0..m)
            .map(|_| {
                Link::new(
                    Arc::new(ConstantTrace::new(bps)),
                    Arc::new(ConstantTrace::new(bps)),
                )
            })
            .collect(),
    )
}

fn build(
    m: usize,
    net: NetSim,
    policy: CompressPolicy,
    mode: ExecMode,
    compute: ComputeModel,
    threads: usize,
    shards: usize,
) -> Simulation<QuadraticSource> {
    let q = Quadratic::paper_instance(D);
    let layers = q.layout(N_LAYERS).layers();
    let src = QuadraticSource::new(q, 0.1);
    let cfg = SimConfig {
        m,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 0.9 },
        up_policy: policy.clone(),
        down_policy: policy,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.02)),
        layers,
        warm_start: true,
        prior_bps: 800.0,
        round_deadline: Some(1.9),
        budget_safety: 1.0,
        threads,
        mode,
        compute,
    };
    let mut sim = Simulation::new(cfg, net, src, vec![1.0f32; D]);
    sim.shards = shards;
    sim
}

fn run_for_shards(
    policy: CompressPolicy,
    mode: ExecMode,
    compute: ComputeModel,
    threads: usize,
    rounds: u64,
) -> Vec<Vec<RoundRecord>> {
    SHARD_COUNTS
        .iter()
        .map(|&shards| {
            let mut s =
                build(4, wave_net(4), policy.clone(), mode, compute.clone(), threads, shards);
            s.run(rounds).unwrap()
        })
        .collect()
}

#[test]
fn sync_bit_identical_across_shard_counts_and_matches_reference() {
    for policy in [
        CompressPolicy::KimadUniform,
        CompressPolicy::KimadPlus { discretization: 300, ratios: vec![] },
        CompressPolicy::WholeModelTopK,
    ] {
        let mut oracle = build(
            4,
            wave_net(4),
            policy.clone(),
            ExecMode::Sync,
            ComputeModel::Constant,
            1,
            1,
        );
        let want: Vec<RoundRecord> =
            (0..30).map(|_| oracle.round_reference().unwrap()).collect();
        let runs = run_for_shards(policy.clone(), ExecMode::Sync, ComputeModel::Constant, 1, 30);
        for r in runs {
            assert_eq!(r, want, "{policy:?}: sharded sync diverged from the reference");
        }
    }
}

#[test]
fn semisync_bit_identical_across_shard_and_thread_counts() {
    let straggler = ComputeModel::Profile { factors: vec![1.0, 1.0, 1.0, 8.0] };
    let mode = ExecMode::SemiSync { quorum: 2 };
    let base = run_for_shards(CompressPolicy::KimadUniform, mode, straggler.clone(), 1, 50);
    assert_eq!(base[0], base[1], "shards=2 changed semisync results");
    assert_eq!(base[0], base[2], "shards=4 changed semisync results");
    // Thread count is independent of the shard axis.
    let threaded = run_for_shards(CompressPolicy::KimadUniform, mode, straggler, 3, 50);
    assert_eq!(base[0], threaded[2], "threads=3/shards=4 diverged from serial");
    // The run still trains and respects the quorum.
    for r in &base[0] {
        assert!(r.n_arrivals() >= 2, "round {} closed below quorum", r.step);
        assert!(r.f_x.is_finite());
    }
}

#[test]
fn semisync_batches_simultaneous_arrivals_into_the_closing_round() {
    // Homogeneous links + constant compute: all 4 uploads land at the
    // same timestamp every round. The batched drain must aggregate the
    // whole batch (4 arrivals) even though the quorum is 2 — and stay
    // bit-identical across shard counts while doing it.
    let mode = ExecMode::SemiSync { quorum: 2 };
    let runs: Vec<Vec<RoundRecord>> = SHARD_COUNTS
        .iter()
        .map(|&shards| {
            let mut s = build(
                4,
                flat_net(4, 2000.0),
                CompressPolicy::FixedRatio { ratio: 0.5 },
                mode,
                ComputeModel::Constant,
                1,
                shards,
            );
            s.run(25).unwrap()
        })
        .collect();
    assert_eq!(runs[0], runs[1]);
    assert_eq!(runs[0], runs[2]);
    for r in &runs[0] {
        assert_eq!(
            r.n_arrivals(),
            4,
            "round {}: simultaneous arrivals must aggregate as one batch",
            r.step
        );
        assert_eq!(r.max_staleness(), 0);
    }
}

#[test]
fn async_bit_identical_across_shard_and_thread_counts() {
    let compute = ComputeModel::Lognormal { sigma: 0.3, seed: 5 };
    let mode = ExecMode::Async { damping: 0.7 };
    let base = run_for_shards(CompressPolicy::KimadUniform, mode, compute.clone(), 1, 80);
    assert_eq!(base[0], base[1], "shards=2 changed async results");
    assert_eq!(base[0], base[2], "shards=4 changed async results");
    let threaded = run_for_shards(CompressPolicy::KimadUniform, mode, compute, 4, 80);
    assert_eq!(base[0], threaded[1], "threads=4/shards=2 diverged from serial");
    // Arrival-paced rounds with monotone virtual time, and the model
    // trains under per-worker broadcast channels.
    for pair in base[0].windows(2) {
        assert!(pair[1].t_start >= pair[0].t_start);
    }
    assert_eq!(base[0].iter().filter(|r| r.n_arrivals() != 1).count(), 0);
    assert!(base[0].last().unwrap().f_x.is_finite());
}

#[test]
fn async_per_worker_channels_converge() {
    // The per-worker x̂_m mirrors replace the shared broadcast channel;
    // the damped async loop must still drive the quadratic down.
    let mut s = build(
        2,
        flat_net(2, 64.0 * 8.0),
        CompressPolicy::KimadUniform,
        ExecMode::Async { damping: 0.7 },
        ComputeModel::Constant,
        1,
        2,
    );
    s.cfg.round_deadline = None;
    let recs = s.run(400).unwrap();
    assert_eq!(s.server.x_hats.len(), 2, "async owns one mirror per worker");
    let first = recs[0].f_x;
    let last = recs.last().unwrap().f_x;
    assert!(last < first * 0.5, "f0={first} fK={last}");
    // Every mirror individually tracks the model: its distance to x is
    // finite and small relative to the starting point.
    for xh in &s.server.x_hats {
        let dist: f64 = xh
            .value
            .iter()
            .zip(&s.server.x)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(dist.is_finite());
    }
}

#[test]
fn broadcast_shard_matrix_bit_identical_across_modes_and_policies() {
    // The PR-4 broadcast contract: with forced shard counts the
    // broadcast phase itself runs the parallel fan-out (diff fill,
    // curve builds, compress-advance) in every execution mode — the
    // records must stay bit-identical to the fully serialized run for
    // every down-policy, including the curve-driven Kimad+ knapsack
    // and the whole-model TopK global pass.
    let straggler = ComputeModel::Profile { factors: vec![1.0, 1.0, 2.0, 5.0] };
    for policy in [
        CompressPolicy::FixedRatio { ratio: 0.4 },
        CompressPolicy::KimadUniform,
        CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
        CompressPolicy::WholeModelTopK,
    ] {
        for mode in [
            ExecMode::Sync,
            ExecMode::SemiSync { quorum: 2 },
            ExecMode::Async { damping: 0.8 },
        ] {
            let mut base = build(4, wave_net(4), policy.clone(), mode, straggler.clone(), 1, 1);
            let want = base.run(30).unwrap();
            for shards in [2usize, 4] {
                for threads in [1usize, 3] {
                    let mut s = build(
                        4,
                        wave_net(4),
                        policy.clone(),
                        mode,
                        straggler.clone(),
                        threads,
                        shards,
                    );
                    let got = s.run(30).unwrap();
                    assert_eq!(
                        got, want,
                        "{policy:?} {mode:?} shards={shards} threads={threads}: \
                         sharded broadcast diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn shards_auto_and_forced_agree() {
    // shards = 0 (auto) resolves to some count; whatever it picks must
    // match the forced serialized run bit for bit.
    for mode in [
        ExecMode::Sync,
        ExecMode::SemiSync { quorum: 3 },
        ExecMode::Async { damping: 0.9 },
    ] {
        let mut auto = build(
            4,
            wave_net(4),
            CompressPolicy::KimadUniform,
            mode,
            ComputeModel::Constant,
            1,
            0,
        );
        let mut forced = build(
            4,
            wave_net(4),
            CompressPolicy::KimadUniform,
            mode,
            ComputeModel::Constant,
            1,
            1,
        );
        let a = auto.run(30).unwrap();
        let b = forced.run(30).unwrap();
        assert_eq!(a, b, "{mode:?}: auto shards diverged");
    }
}
