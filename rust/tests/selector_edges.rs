//! Edge cases of the Kimad budget machinery: zero budget, single-layer
//! models, budgets exceeding the whole model, and the empty-`ratios`
//! fallback to the paper's {0.01 + 0.02k} grid.

use kimad::compress::F32_BITS;
use kimad::kimad::knapsack::{allocate, paper_ratio_grid, topk_options, KnapsackParams};
use kimad::kimad::{CompressPolicy, ErrorCurve, Selector};
use kimad::model::ModelLayout;
use kimad::util::rng::Rng;

const COORD_BITS: u64 = 64; // index + value on the sparse wire

fn rand_vec(seed: u64, d: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..d).map(|_| rng.range_f32(-3.0, 3.0)).collect()
}

/// Random magnitudes bounded away from zero: every coordinate carries
/// energy, so "keep everything" is the unique optimum at full budget
/// (no zero-value ties for the knapsack DP to exploit).
fn nonzero_vec(seed: u64, d: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..d)
        .map(|_| {
            let v = rng.range_f32(0.5, 3.0);
            if rng.next_f64() < 0.5 {
                -v
            } else {
                v
            }
        })
        .collect()
}

fn adaptive_policies() -> Vec<CompressPolicy> {
    vec![
        CompressPolicy::KimadUniform,
        CompressPolicy::KimadPlus { discretization: 500, ratios: vec![] },
        CompressPolicy::WholeModelTopK,
    ]
}

#[test]
fn zero_budget_selects_nothing_everywhere() {
    let layout = ModelLayout::synthetic(&[16, 48, 16]);
    let layers = layout.layers();
    let diff = rand_vec(1, 80);
    for policy in adaptive_policies() {
        let sel = Selector::new(policy.clone()).select(&diff, &layers, 0);
        assert!(
            sel.k_per_layer.iter().all(|&k| k == 0),
            "{policy:?} selected coordinates with zero budget: {:?}",
            sel.k_per_layer
        );
        assert_eq!(sel.planned_bits, 0, "{policy:?}");
    }
}

#[test]
fn single_layer_model_spends_whole_budget() {
    let layout = ModelLayout::synthetic(&[64]);
    let layers = layout.layers();
    // Strictly positive, all-distinct magnitudes: the error curve is
    // strictly decreasing, so every policy's optimum is unique.
    let diff: Vec<f32> = (1..=64).map(|i| i as f32 / 7.0).collect();
    for budget_k in [1u64, 7, 33, 64] {
        let budget = budget_k * COORD_BITS;
        for policy in adaptive_policies() {
            let sel = Selector::new(policy.clone()).select(&diff, &layers, budget);
            assert_eq!(sel.k_per_layer.len(), 1, "{policy:?}");
            assert!(sel.planned_bits <= budget, "{policy:?} at budget_k={budget_k}");
            // A single layer leaves no split to optimize: every policy
            // should spend the full coordinate budget.
            assert_eq!(
                sel.k_per_layer[0] as u64, budget_k,
                "{policy:?} at budget_k={budget_k}"
            );
        }
    }
}

#[test]
fn budget_larger_than_model_caps_at_full_rank() {
    let layout = ModelLayout::synthetic(&[10, 20, 10]);
    let layers = layout.layers();
    let d_total = 40usize;
    let diff = nonzero_vec(3, d_total);
    let budget = 10 * d_total as u64 * COORD_BITS; // 10x the model
    let curves: Vec<ErrorCurve> = layers
        .iter()
        .map(|l| ErrorCurve::build(&diff[l.offset..l.offset + l.size]))
        .collect();
    for policy in adaptive_policies() {
        let sel = Selector::new(policy.clone()).select(&diff, &layers, budget);
        let total: usize = sel.k_per_layer.iter().sum();
        assert_eq!(total, d_total, "{policy:?} must keep every coordinate");
        for (l, &k) in layers.iter().zip(&sel.k_per_layer) {
            assert!(k <= l.size, "{policy:?}: k={k} > layer size {}", l.size);
        }
        assert_eq!(sel.predicted_error(&curves), 0.0, "{policy:?}");
    }
}

#[test]
fn empty_ratio_grid_falls_back_to_paper_grid() {
    // Layers above the exact-grid threshold (d > 128) exercise the
    // ratio grid; with `ratios: vec![]` the selection must match an
    // explicit paper grid exactly.
    let layout = ModelLayout::synthetic(&[300, 500]);
    let layers = layout.layers();
    let diff = rand_vec(4, 800);
    let budget = 120 * COORD_BITS;
    let implicit =
        Selector::new(CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] })
            .select(&diff, &layers, budget);
    let explicit = Selector::new(CompressPolicy::KimadPlus {
        discretization: 1000,
        ratios: paper_ratio_grid(),
    })
    .select(&diff, &layers, budget);
    assert_eq!(implicit, explicit);
    assert!(implicit.planned_bits <= budget);
}

#[test]
fn paper_grid_never_reaches_one_but_exact_grid_does() {
    // The §4.3 grid tops out at 0.99, so a >128-coord layer keeps at
    // most ceil(0.99 d) coordinates; small layers use the exact K grid
    // and can reach full rank. Both must respect the budget.
    let big = 200usize;
    let curve_big = ErrorCurve::build(&rand_vec(5, big));
    let opts = topk_options(&[curve_big], &paper_ratio_grid(), COORD_BITS);
    let max_k = opts[0].iter().map(|o| o.bits / COORD_BITS).max().unwrap();
    assert_eq!(max_k as usize, (0.99f64 * big as f64).ceil() as usize);

    let small = 100usize;
    let curve_small = ErrorCurve::build(&rand_vec(6, small));
    let opts = topk_options(&[curve_small], &paper_ratio_grid(), COORD_BITS);
    let max_k = opts[0].iter().map(|o| o.bits / COORD_BITS).max().unwrap();
    assert_eq!(max_k as usize, small, "exact grid covers full rank");
}

#[test]
fn knapsack_zero_budget_and_oversized_budget() {
    let curves = vec![
        ErrorCurve::build(&nonzero_vec(7, 60)),
        ErrorCurve::build(&nonzero_vec(8, 90)),
    ];
    let options = topk_options(&curves, &paper_ratio_grid(), COORD_BITS);

    let zero = allocate(&options, KnapsackParams { budget_bits: 0, discretization: 100 });
    assert_eq!(zero.total_bits, 0);
    assert!(!zero.degraded);
    let full_energy: f64 = curves.iter().map(|c| c.total()).sum();
    assert!((zero.total_error - full_energy).abs() < 1e-9);

    let huge = allocate(
        &options,
        KnapsackParams { budget_bits: u64::MAX / 4, discretization: 2000 },
    );
    assert!(!huge.degraded);
    // Exact K grids (d <= 128): the oversized budget keeps everything.
    assert_eq!(huge.total_bits, (60 + 90) * COORD_BITS);
    assert!(huge.total_error < 1e-12);
}

#[test]
fn knapsack_single_layer_budget_sweep_monotone() {
    // More budget can never increase the optimal error.
    let curve = ErrorCurve::build(&rand_vec(9, 120));
    let options = topk_options(&[curve], &paper_ratio_grid(), COORD_BITS);
    let mut prev = f64::INFINITY;
    for budget_k in 0..=120u64 {
        let a = allocate(
            &options,
            KnapsackParams { budget_bits: budget_k * COORD_BITS, discretization: 500 },
        );
        assert!(a.total_bits <= budget_k * COORD_BITS);
        assert!(
            a.total_error <= prev + 1e-9,
            "error rose at budget_k={budget_k}: {} > {prev}",
            a.total_error
        );
        prev = a.total_error;
    }
    assert!(prev < 1e-12, "full budget reaches zero error");
}

#[test]
fn selection_consistent_under_f32_bits_wire_math() {
    // Guard the 64-bit sparse coordinate assumption the budget math
    // rests on (index + value), so a wire-format change cannot silently
    // skew every budget by a constant factor.
    assert_eq!(COORD_BITS, F32_BITS + kimad::compress::IDX_BITS);
}
