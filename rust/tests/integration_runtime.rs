//! Runtime integration: the rust coordinator driving the AOT-compiled
//! JAX/Pallas artifacts through PJRT. Requires `make artifacts`; every
//! test skips (with a message) when artifacts/ is absent so `cargo
//! test` stays green on a fresh checkout.

use kimad::coordinator::GradientSource;
use kimad::kimad::ErrorCurve;
use kimad::runtime::{ArtifactStore, PjrtModelSource, Runtime};
use kimad::util::rng::Rng;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open("artifacts") {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn train_step_loss_and_grads() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut src = PjrtModelSource::load(&rt, &store, "tiny", 0.3, 1.0).unwrap();
    let layout = store.layout("tiny").unwrap();
    let params = store.initial_params("tiny").unwrap();
    let mut grads = vec![0.0f32; layout.n_params];
    let loss = src.update(0, 0, &params, &mut grads).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    // At a random init the cross-entropy sits near ln(10).
    assert!((loss - (10f64).ln()).abs() < 1.5, "loss={loss}");
    let norm: f64 = grads.iter().map(|&g| (g as f64).powi(2)).sum();
    assert!(norm > 0.0 && norm.is_finite());
}

#[test]
fn sgd_on_pjrt_gradients_reduces_loss() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut src = PjrtModelSource::load(&rt, &store, "tiny", 0.3, 1.0).unwrap();
    let layout = store.layout("tiny").unwrap();
    let mut params = store.initial_params("tiny").unwrap();
    let mut grads = vec![0.0f32; layout.n_params];
    let first = src.update(0, 0, &params, &mut grads).unwrap();
    let mut last = first;
    for step in 0..40 {
        last = src.update(0, step, &params, &mut grads).unwrap();
        for (p, &g) in params.iter_mut().zip(&grads) {
            *p -= 0.05 * g;
        }
    }
    assert!(
        last < first - 0.15,
        "loss did not drop: {first:.4} -> {last:.4}"
    );
}

#[test]
fn eval_step_counts_consistent() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut src = PjrtModelSource::load(&rt, &store, "tiny", 0.3, 1.0).unwrap();
    let params = store.initial_params("tiny").unwrap();
    let e = src.evaluate(&params, 2).unwrap();
    assert!(e.loss.is_finite());
    assert!(e.top1 >= 0.0 && e.top1 <= 1.0);
    assert!(e.top5 >= e.top1 && e.top5 <= 1.0);
    assert_eq!(e.n, 2 * store.layout("tiny").unwrap().batch);
    // Evaluation is deterministic.
    let e2 = src.evaluate(&params, 2).unwrap();
    assert_eq!(e.loss, e2.loss);
    assert_eq!(e.top1, e2.top1);
}

#[test]
fn pallas_error_curve_kernel_matches_rust() {
    // The L1 Pallas kernel (compress_error) and the rust-native
    // ErrorCurve must compute the same eps(K) — this pins the two
    // stacks together numerically.
    let Some(store) = store() else { return };
    let Ok(kernel) = store.kernel("compress_error_d4096") else {
        eprintln!("skipping: compress_error kernel not exported");
        return;
    };
    let d = kernel.d;
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&store.path(&kernel.hlo)).unwrap();

    let mut rng = Rng::seed_from_u64(42);
    let u: Vec<f32> = (0..d).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    let lit = kimad::runtime::client::literal_f32(&u, &[d]).unwrap();
    let out = exe.run(&[lit]).unwrap();
    assert_eq!(out.len(), 1);
    let kernel_curve = out[0].to_vec::<f32>().unwrap();
    assert_eq!(kernel_curve.len(), d + 1);

    let rust_curve = ErrorCurve::build(&u);
    for k in (0..=d).step_by(97) {
        let a = kernel_curve[k] as f64;
        let b = rust_curve.at(k);
        assert!(
            (a - b).abs() <= 1e-3 * b.abs().max(1.0),
            "eps({k}): pallas {a} vs rust {b}"
        );
    }
}

#[test]
fn pallas_ef21_kernel_matches_rust() {
    let Some(store) = store() else { return };
    let Ok(kernel) = store.kernel("ef21_apply_d4096") else {
        eprintln!("skipping: ef21_apply kernel not exported");
        return;
    };
    let d = kernel.d;
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&store.path(&kernel.hlo)).unwrap();

    let mut rng = Rng::seed_from_u64(7);
    let u: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let uh: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mask: Vec<f32> = (0..d)
        .map(|_| if rng.next_f64() < 0.3 { 1.0 } else { 0.0 })
        .collect();

    let out = exe
        .run(&[
            kimad::runtime::client::literal_f32(&u, &[d]).unwrap(),
            kimad::runtime::client::literal_f32(&uh, &[d]).unwrap(),
            kimad::runtime::client::literal_f32(&mask, &[d]).unwrap(),
        ])
        .unwrap();
    let got = out[0].to_vec::<f32>().unwrap();
    for i in (0..d).step_by(131) {
        let want = uh[i] + mask[i] * (u[i] - uh[i]);
        assert!((got[i] - want).abs() < 1e-6, "i={i}: {} vs {want}", got[i]);
    }
}

#[test]
fn full_deep_experiment_smoke() {
    // The fig8-style pipeline end to end (tiny rounds count).
    let Some(_store) = store() else { return };
    use kimad::kimad::CompressPolicy;
    use kimad::reports::{deep, ReportCtx};
    let ctx = ReportCtx::fast();
    let mut cfg = deep::base_config(&ctx, CompressPolicy::KimadUniform, 1.0, 2);
    cfg.rounds = 5;
    let res = kimad::driver::run_experiment(&cfg, Some("artifacts"), 1).unwrap();
    assert_eq!(res.records.len(), 5);
    assert!(res.records.iter().all(|r| r.loss.is_finite()));
    assert!(res.eval.unwrap().top5 >= 0.0);
}
