//! Tier-1 gate + fixture corpus for `kimad tidy` (rust/src/analysis/).
//!
//! Two halves:
//!
//! * the crate's own tree must scan clean — zero diagnostics, which
//!   also means zero unused allows — the same check CI runs via
//!   `cargo run --release -- tidy`;
//! * a fixture corpus proving every registered rule fires on a
//!   minimal violating snippet and stays quiet on its fixed twin,
//!   plus the suppression edge cases (allow-with-reason, unused
//!   allow, malformed allow, doc-comment and string-literal
//!   false-positive regressions).

use std::path::Path;

use kimad::analysis::rules::{rule_ids, REGISTRY};
use kimad::analysis::scan_file_source;
use kimad::analysis::scan_root;
use kimad::bench::kernels::alloc_free_kernels;

fn fires(rel: &str, src: &str, rule: &str) -> bool {
    scan_file_source(rel, src).diagnostics.iter().any(|d| d.rule == rule)
}

fn diag_count(rel: &str, src: &str) -> usize {
    scan_file_source(rel, src).diagnostics.len()
}

// ---------------------------------------------------------------- tree

#[test]
fn own_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = scan_root(root).expect("scan own tree");
    assert!(report.files_scanned > 50, "walk found only {} files", report.files_scanned);
    assert!(report.clean(), "tidy findings on the tree:\n{}", report.render_human(true));
    assert!(report.allows_used > 0, "the tree documents its exemptions via tidy:allow");
}

#[test]
fn json_report_shape() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = scan_root(root).expect("scan own tree");
    let js = report.to_json().to_string();
    for key in ["\"clean\"", "\"diagnostics\"", "\"rules\"", "\"files_scanned\""] {
        assert!(js.contains(key), "JSON report missing {key}: {js}");
    }
}

#[test]
fn registry_is_complete_and_unique() {
    let ids = rule_ids();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), ids.len(), "duplicate rule id in REGISTRY");
    assert_eq!(ids.len(), 15, "rule count drifted from the documented set");
    for r in REGISTRY {
        assert!(!r.summary.is_empty() && !r.section.is_empty() && !r.hint.is_empty());
    }
}

// --------------------------------------------------------- determinism

#[test]
fn hash_collections_fires_in_engine_dirs_only() {
    let src = "use std::collections::HashMap;\n";
    assert!(fires("src/coordinator/x.rs", src, "hash-collections"));
    assert!(fires("src/netsim/x.rs", src, "hash-collections"));
    assert!(!fires("src/util/x.rs", src, "hash-collections"));
    let fixed = "use std::collections::BTreeMap;\n";
    assert_eq!(diag_count("src/coordinator/x.rs", fixed), 0);
}

#[test]
fn wall_clock_fires_outside_allowlist() {
    let src = "fn f() -> u64 {\n    let t = std::time::Instant::now();\n    0\n}\n";
    assert!(fires("src/kimad/x.rs", src, "wall-clock"));
    assert!(!fires("src/transport/x.rs", src, "wall-clock"));
    assert!(!fires("src/bench/timing.rs", src, "wall-clock"));
    assert!(!fires("benches/x.rs", src, "wall-clock"));
}

#[test]
fn cache_key_code_is_held_to_the_btree_only_rule() {
    // The content-addressed cell cache lives in src/scenarios/ — an
    // engine dir — so its key/probe code cannot reach for unordered
    // maps: canonical JSON (and therefore every cache key) depends on
    // deterministic iteration order.
    let src = "use std::collections::HashMap;\n";
    assert!(fires("src/scenarios/cache.rs", src, "hash-collections"));
    assert!(fires("src/scenarios/cache.rs", "let s = HashSet::new();\n", "hash-collections"));
    let fixed = "use std::collections::BTreeMap;\n";
    assert_eq!(diag_count("src/scenarios/cache.rs", fixed), 0);
}

#[test]
fn scenario_cache_wall_clock_needs_a_reasoned_allow() {
    // The matrix runner's cache banner reads Instant::now for its
    // elapsed metric; src/scenarios/ is *not* on the wall-clock
    // allowlist, so that read must carry a reasoned tidy:allow — the
    // pattern run_matrix_cached uses.
    let bare = "fn f() -> u64 {\n    let t0 = std::time::Instant::now();\n    0\n}\n";
    assert!(fires("src/scenarios/mod.rs", bare, "wall-clock"));
    let allowed = "fn f() -> u64 {\n    \
                   // tidy:allow(wall-clock) -- cache banner elapsed metric only\n    \
                   let t0 = std::time::Instant::now();\n    0\n}\n";
    let scan = kimad::analysis::scan_file_source("src/scenarios/mod.rs", allowed);
    assert!(scan.diagnostics.is_empty(), "allow failed: {:?}", scan.diagnostics[0].message);
    assert_eq!(scan.allows_used, 1);
}

#[test]
fn wall_clock_relaxed_under_cfg_test() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f() {\n        \
               let t = std::time::Instant::now();\n    }\n}\n";
    assert!(!fires("src/kimad/x.rs", src, "wall-clock"));
}

#[test]
fn ambient_rng_fires_everywhere() {
    let src = "fn f() -> u32 {\n    let mut rng = thread_rng();\n    0\n}\n";
    assert!(fires("src/util/x.rs", src, "ambient-rng"));
    let src2 = "fn f() -> f64 {\n    rand::random()\n}\n";
    assert!(fires("src/util/x.rs", src2, "ambient-rng"));
    let fixed = "fn f(seed: u64) -> u64 {\n    seed.wrapping_mul(3)\n}\n";
    assert_eq!(diag_count("src/util/x.rs", fixed), 0);
}

#[test]
fn float_reduce_fires_in_scope() {
    let src = "fn total(xs: &[f32]) -> f32 {\n    xs.iter().copied().sum()\n}\n";
    assert!(fires("src/ef21/x.rs", src, "float-reduce"));
    assert!(fires("src/compress/x.rs", src, "float-reduce"));
    assert!(!fires("src/metrics/x.rs", src, "float-reduce"));
    assert!(!fires("src/util/chunk.rs", src, "float-reduce"));
}

#[test]
fn float_reduce_integer_witness_passes() {
    let same_line = "fn n(xs: &[u32]) -> u64 {\n    xs.iter().map(|x| u64::from(*x)).sum()\n}\n";
    assert!(!fires("src/ef21/x.rs", same_line, "float-reduce"));
    let lookback = concat!(
        "fn n(xs: &[usize]) -> usize {\n",
        "    let total: usize = xs\n",
        "        .iter()\n",
        "        .sum();\n",
        "    total\n",
        "}\n"
    );
    assert!(!fires("src/ef21/x.rs", lookback, "float-reduce"));
}

// --------------------------------------------------------- wire safety

#[test]
fn numeric_cast_fires_in_transport_only() {
    let src = "fn f(n: usize) -> u32 {\n    n as u32\n}\n";
    assert!(fires("src/transport/x.rs", src, "numeric-cast"));
    assert!(!fires("src/kimad/x.rs", src, "numeric-cast"));
    let fixed = "fn f(n: usize) -> u32 {\n    u32::try_from(n).unwrap_or(u32::MAX)\n}\n";
    assert!(!fires("src/transport/x.rs", fixed, "numeric-cast"));
}

#[test]
fn decode_panic_fires_in_decode_paths() {
    let index = "fn decode(buf: &[u8]) -> Result<u8, FrameError> {\n    \
                 let b = buf[0];\n    Ok(b)\n}\n";
    assert!(fires("src/transport/x.rs", index, "decode-panic"));
    let unwrap = "fn decode(buf: &[u8]) -> Result<u8, FrameError> {\n    \
                  let b = buf.first().unwrap();\n    Ok(*b)\n}\n";
    assert!(fires("src/transport/x.rs", unwrap, "decode-panic"));
    let total = "fn decode(buf: &[u8]) -> Result<u8, FrameError> {\n    \
                 buf.first().copied().ok_or(FrameError::Truncated)\n}\n";
    assert!(!fires("src/transport/x.rs", total, "decode-panic"));
    let helper = "fn helper(n: usize) -> usize {\n    n.checked_add(1).unwrap()\n}\n";
    assert!(!fires("src/transport/x.rs", helper, "decode-panic"));
}

#[test]
fn safety_comment_required_for_unsafe() {
    let bare = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    assert!(fires("src/util/x.rs", bare, "safety-comment"));
    let doc = "fn f(p: *const u8) -> u8 {\n    // SAFETY: p is valid for reads\n    \
               unsafe { *p }\n}\n";
    assert!(!fires("src/util/x.rs", doc, "safety-comment"));
}

// ------------------------------------------------------------ hot path

#[test]
fn alloc_free_region_rejects_allocation() {
    let src = "// tidy:alloc-free(diff)\nfn diff(out: &mut [f32], xs: &[f32]) {\n    \
               let tmp = xs.to_vec();\n}\n";
    assert!(fires("src/util/x.rs", src, "alloc-free"));
    let fixed = "// tidy:alloc-free(diff)\nfn diff(out: &mut [f32], xs: &[f32]) {\n    \
                 for (o, x) in out.iter_mut().zip(xs) {\n        *o = *x;\n    }\n}\n";
    assert!(!fires("src/util/x.rs", fixed, "alloc-free"));
}

#[test]
fn alloc_free_marker_names_are_checked() {
    let src = "// tidy:alloc-free(bogus)\nfn f() {}\n";
    assert!(fires("src/util/x.rs", src, "alloc-free-coverage"));
    assert!(alloc_free_kernels().contains(&"diff"), "registry anchor kernel exists");
}

// ------------------------------------------------------------ style

#[test]
fn line_width_caps_at_100() {
    let long = format!("fn f() {{}} // {}\n", "x".repeat(88));
    assert!(fires("src/util/x.rs", &long, "line-width"));
    let ok = format!("fn f() {{}} // {}\n", "x".repeat(80));
    assert!(!fires("src/util/x.rs", &ok, "line-width"));
}

#[test]
fn tab_and_trailing_whitespace() {
    assert!(fires("src/util/x.rs", "fn f() {\n\tlet x = 1;\n}\n", "tab-char"));
    assert!(fires("src/util/x.rs", "fn f() {} \n", "trailing-space"));
    assert!(fires("src/util/x.rs", "fn f() {}\n   \nfn g() {}\n", "trailing-space"));
    assert_eq!(diag_count("src/util/x.rs", "fn f() {}\n\nfn g() {}\n"), 0);
}

#[test]
fn import_order_within_blocks() {
    let bad = "use std::fmt;\nuse crate::alpha;\n";
    assert!(fires("src/util/x.rs", bad, "import-order"));
    let good = "use crate::alpha;\n\nuse std::fmt;\n";
    assert!(!fires("src/util/x.rs", good, "import-order"));
    let blocks = "use std::fmt;\n\nuse crate::alpha;\n";
    assert!(!fires("src/util/x.rs", blocks, "import-order"));
    let selfs = "use std::fmt;\nuse self::alpha;\n";
    assert!(!fires("src/util/x.rs", selfs, "import-order"));
}

// ------------------------------------------------------- suppressions

#[test]
fn allow_with_reason_suppresses_and_counts() {
    let src = "fn total(xs: &[f32]) -> f32 {\n    \
               // tidy:allow(float-reduce) -- fixture: serial fold, deterministic\n    \
               xs.iter().copied().sum()\n}\n";
    let scan = scan_file_source("src/ef21/x.rs", src);
    assert!(scan.diagnostics.is_empty(), "allow failed: {:?}", scan.diagnostics[0].message);
    assert_eq!(scan.allows_used, 1);
}

#[test]
fn unused_allow_is_an_error() {
    let src = "fn f() {}\n// tidy:allow(wall-clock) -- stale exemption\nfn g() {}\n";
    let scan = scan_file_source("src/util/x.rs", src);
    assert_eq!(scan.allows_used, 0);
    assert!(scan.diagnostics.iter().any(|d| d.rule == "unused-allow"));
}

#[test]
fn malformed_allows_are_errors() {
    let unknown = "fn f() {}\n// tidy:allow(not-a-rule) -- whatever\n";
    assert!(fires("src/util/x.rs", unknown, "allow-syntax"));
    let no_reason = "fn f() {}\n// tidy:allow(wall-clock)\n";
    assert!(fires("src/util/x.rs", no_reason, "allow-syntax"));
    let no_parens = "fn f() {}\n// tidy:allow wall-clock -- reason\n";
    assert!(fires("src/util/x.rs", no_parens, "allow-syntax"));
}

// -------------------------------------------------- lexer regressions

#[test]
fn string_literals_never_fire() {
    let src = "fn f() -> String {\n    \
               let s = \"Instant::now HashMap thread_rng xs.sum()\";\n    \
               s.to_string()\n}\n";
    assert_eq!(diag_count("src/coordinator/x.rs", src), 0);
}

#[test]
fn raw_strings_never_fire() {
    let src = "fn f() -> &'static str {\n    \
               r#\"thread_rng() and a \"quote\" and a tidy:allow(wall-clock) -- x\"#\n}\n";
    assert_eq!(diag_count("src/util/x.rs", src), 0);
}

#[test]
fn doc_comments_are_not_directives() {
    let src = "/// Write `tidy:allow(wall-clock) -- why` above the call.\nfn f() {}\n";
    let scan = scan_file_source("src/util/x.rs", src);
    assert!(scan.diagnostics.is_empty(), "doc text parsed as directive");
    assert_eq!(scan.allows_used, 0);
}

#[test]
fn char_literals_and_lifetimes_lex_cleanly() {
    let src = "fn f<'a>(xs: &'a [u8]) -> char {\n    let c = '\\n';\n    let d = '{';\n    c\n}\n";
    assert_eq!(diag_count("src/util/x.rs", src), 0);
}
