//! Property suite over the compressor family: wire accounting, support
//! containment, quantization round-trip bounds and the
//! `compress == compress_into` bit-identity contract, swept across
//! random shapes and seeds (util::prop, seeded + replayable).

use kimad::compress::{
    compression_error, Compressed, Compressor, Identity, LowRank, OneBitSign, QuantizeBits,
    RandK, TopK,
};
use kimad::util::prop::check;
use kimad::util::rng::Rng;

fn rand_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.range_f32(-8.0, 8.0)).collect()
}

/// A randomized panel covering every compressor family, sized for
/// dimension `d`. RandK instances are seeded from the property RNG so
/// each case sweeps a different sampling stream.
fn panel(rng: &mut Rng, d: usize) -> Vec<Box<dyn Compressor>> {
    let k = rng.range_usize(0, d + 1);
    let bits = 1 + rng.range_usize(0, 32) as u64;
    let rows = 1 + rng.range_usize(0, 12);
    let cols = 1 + rng.range_usize(0, 12);
    let rank = 1 + rng.range_usize(0, rows.min(cols));
    vec![
        Box::new(Identity),
        Box::new(TopK::new(k)),
        Box::new(RandK::new(k, rng.next_u64())),
        Box::new(QuantizeBits::new(bits)),
        Box::new(OneBitSign),
        Box::new(LowRank::new(rows, cols, rank)),
    ]
}

#[test]
fn prop_wire_bits_never_exceed_planned() {
    check("wire_bits(compress(u)) <= planned_bits(d)", 31, 60, |rng| {
        let d = rng.range_usize(1, 400);
        let u = rand_vec(rng, d);
        for c in panel(rng, d) {
            let msg = c.compress(&u);
            assert!(
                msg.wire_bits() <= c.planned_bits(d),
                "{}: wire {} > planned {} at d={d}",
                c.name(),
                msg.wire_bits(),
                c.planned_bits(d)
            );
        }
    });
}

#[test]
fn prop_sparsifier_support_is_subset_of_input() {
    check("TopK/RandK: distinct in-range indices, values from u", 32, 60, |rng| {
        let d = rng.range_usize(1, 500);
        let u = rand_vec(rng, d);
        let k = rng.range_usize(0, d + 2);
        let comps: Vec<Box<dyn Compressor>> =
            vec![Box::new(TopK::new(k)), Box::new(RandK::new(k, rng.next_u64()))];
        for c in comps {
            let Compressed::Sparse { dim, idx, val } = c.compress(&u) else {
                panic!("{} must produce a sparse message", c.name());
            };
            assert_eq!(dim, d, "{}", c.name());
            assert_eq!(idx.len(), k.min(d), "{}: kept count", c.name());
            assert_eq!(idx.len(), val.len(), "{}", c.name());
            let mut seen = idx.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), idx.len(), "{}: indices must be distinct", c.name());
            for (&i, &v) in idx.iter().zip(&val) {
                assert!((i as usize) < d, "{}: index {i} out of range {d}", c.name());
                assert_eq!(v.to_bits(), u[i as usize].to_bits(), "{}: value copied", c.name());
            }
        }
    });
}

#[test]
fn prop_quantize_roundtrip_error_bounded() {
    check("quantize: per-coordinate error <= half a grid step", 33, 60, |rng| {
        let d = rng.range_usize(1, 300);
        let u = rand_vec(rng, d);
        let bits = 1 + rng.range_usize(0, 32) as u64;
        let q = QuantizeBits::new(bits);
        let dec = q.compress(&u).to_dense(d);
        let scale = u.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if scale == 0.0 || bits >= 32 {
            // Passthrough cases are exact.
            assert_eq!(dec, u, "bits={bits} scale={scale}");
            return;
        }
        let levels = ((1u64 << (bits - 1)) - 1).max(1) as f64;
        let step = scale as f64 / levels;
        for (i, (&a, &b)) in u.iter().zip(&dec).enumerate() {
            let err = ((a - b) as f64).abs();
            assert!(
                err <= step / 2.0 + 1e-5 * scale as f64,
                "bits={bits} coord {i}: |{a} - {b}| = {err} > step/2 = {}",
                step / 2.0
            );
        }
    });
}

#[test]
fn prop_contraction_bound_holds_across_panel() {
    check("err(u) <= (1 - alpha(d)) ||u||^2 for deterministic compressors", 34, 40, |rng| {
        let d = rng.range_usize(1, 300);
        let u = rand_vec(rng, d);
        let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum();
        let k = rng.range_usize(0, d + 1);
        // RandK is excluded: its bound holds in expectation only
        // (prop_invariants.rs covers the statistical version).
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(TopK::new(k)),
            Box::new(QuantizeBits::new(1 + rng.range_usize(0, 16) as u64)),
            Box::new(OneBitSign),
        ];
        for c in comps {
            let err = compression_error(c.as_ref(), &u);
            assert!(
                err <= (1.0 - c.alpha(d)) * norm + 1e-3 * norm.max(1.0),
                "{}: err={err} > (1-alpha)*norm={}",
                c.name(),
                (1.0 - c.alpha(d)) * norm
            );
        }
    });
}

#[test]
fn prop_compress_into_bit_identical_to_compress() {
    check("compress_into == compress, bit for bit, into dirty buffers", 35, 60, |rng| {
        let d = rng.range_usize(1, 400);
        let u = rand_vec(rng, d);
        let seed = rng.next_u64();
        let k = rng.range_usize(0, d + 1);
        let bits = 1 + rng.range_usize(0, 32) as u64;
        let rows = 1 + rng.range_usize(0, 10);
        let cols = 1 + rng.range_usize(0, 10);
        // Two independent instances per family: RandK advances an
        // internal call counter, so the fresh-allocation path and the
        // buffer-reuse path must each consume their own stream.
        let make = |rng_seed: u64| -> Vec<Box<dyn Compressor>> {
            vec![
                Box::new(Identity),
                Box::new(TopK::new(k)),
                Box::new(RandK::new(k, rng_seed)),
                Box::new(QuantizeBits::new(bits)),
                Box::new(OneBitSign),
                Box::new(LowRank::new(rows, cols, 1 + (k % rows.min(cols)))),
            ]
        };
        let fresh = make(seed);
        let reused = make(seed);
        for (a, b) in fresh.iter().zip(&reused) {
            let want = a.compress(&u);
            // Pre-dirty the buffer with a different variant and stale
            // content so reuse can't pass by accident.
            let mut out = Compressed::Factors {
                rows: 2,
                cols: 2,
                u: vec![9.0; 4],
                v: vec![-9.0; 4],
            };
            b.compress_into(&u, &mut out);
            assert_eq!(out, want, "{}: first compress_into", a.name());
            // Second pass through the now-warm buffer.
            let want2 = a.compress(&u);
            b.compress_into(&u, &mut out);
            assert_eq!(out, want2, "{}: warm compress_into", a.name());
        }
    });
}
