//! Suppression directives and hot-path markers.
//!
//! Two directives are recognized, **only in plain comments** (doc
//! comments are documentation — see the lexer):
//!
//! * `tidy:allow(<rule>) -- <reason>` suppresses `<rule>` on the line
//!   it shares with code, or — when it sits on a comment-only line —
//!   on the next code line within five lines. The reason is
//!   mandatory; a missing reason, an unknown rule, or a bare
//!   `tidy:allow` is an `allow-syntax` error. An allow that suppresses
//!   nothing is an `unused-allow` error, so stale exemptions cannot
//!   accumulate.
//! * `tidy:alloc-free(<name>)` opens an allocation-free region: from
//!   the first `{` at or after the marker to its matching brace. The
//!   names are cross-checked against
//!   `bench::kernels::alloc_free_kernels()` in both directions.

use std::collections::BTreeMap;

use super::lexer::Masked;

const ALLOW_KEY: &str = "tidy:allow";
const MARKER_KEY: &str = "tidy:alloc-free(";

/// One parsed `tidy:allow`, with its usage bit. `line` is 0-based.
pub struct AllowRec {
    pub line: usize,
    pub rule: String,
    pub used: bool,
}

/// All allows of one file, indexed by the (line, rule) they suppress.
#[derive(Default)]
pub struct AllowSet {
    pub allows: Vec<AllowRec>,
    by_target: BTreeMap<(usize, String), usize>,
}

impl AllowSet {
    /// If an allow targets `(line, rule)`, mark it used and return
    /// true (the diagnostic is suppressed). `line` is 0-based.
    pub fn suppress(&mut self, line: usize, rule: &str) -> bool {
        match self.by_target.get(&(line, rule.to_string())) {
            Some(&idx) => {
                self.allows[idx].used = true;
                true
            }
            None => false,
        }
    }
}

/// One `tidy:alloc-free(<name>)` marker. `line` is 0-based.
pub struct Marker {
    pub name: String,
    pub line: usize,
}

/// Parse every allow in the file. Returns the set plus the 0-based
/// lines and messages of malformed directives.
pub fn parse_allows(m: &Masked, known_rules: &[&str]) -> (AllowSet, Vec<(usize, String)>) {
    let mut set = AllowSet::default();
    let mut malformed = Vec::new();
    for ln in 0..m.len() {
        let ctext = &m.comment[ln];
        let mut start = 0;
        while let Some(off) = ctext[start..].find(ALLOW_KEY) {
            let p = start + off;
            let rest = &ctext[p + ALLOW_KEY.len()..];
            match parse_one_allow(rest, known_rules) {
                Some(rule) => {
                    let target = bind_target(m, ln);
                    let idx = set.allows.len();
                    set.allows.push(AllowRec { line: ln, rule: rule.clone(), used: false });
                    set.by_target.insert((target, rule), idx);
                }
                None => {
                    let msg =
                        "malformed tidy:allow — need tidy:allow(<rule>) -- <reason>".to_string();
                    malformed.push((ln, msg));
                }
            }
            start = p + ALLOW_KEY.len();
        }
    }
    (set, malformed)
}

/// Validate `(<rule>) -- <reason>` after the directive keyword and
/// return the rule name.
fn parse_one_allow(rest: &str, known_rules: &[&str]) -> Option<String> {
    let inner = rest.strip_prefix('(')?;
    let close = inner.find(')')?;
    let rule = &inner[..close];
    if !known_rules.contains(&rule) {
        return None;
    }
    let tail = inner[close + 1..].trim_start();
    let reason = tail.strip_prefix("--")?;
    if reason.trim().is_empty() {
        return None;
    }
    Some(rule.to_string())
}

/// The line an allow at `ln` suppresses: its own line when it shares
/// it with code, otherwise the next line carrying code (within five).
fn bind_target(m: &Masked, ln: usize) -> usize {
    if !m.code[ln].trim().is_empty() {
        return ln;
    }
    let hi = (ln + 6).min(m.len());
    for cand in ln + 1..hi {
        if !m.code[cand].trim().is_empty() {
            return cand;
        }
    }
    ln
}

/// Collect every `tidy:alloc-free(<name>)` marker in the file.
pub fn parse_markers(m: &Masked) -> Vec<Marker> {
    let mut out = Vec::new();
    for ln in 0..m.len() {
        let ctext = &m.comment[ln];
        if let Some(p) = ctext.find(MARKER_KEY) {
            let rest = &ctext[p + MARKER_KEY.len()..];
            if let Some(q) = rest.find(')') {
                out.push(Marker { name: rest[..q].to_string(), line: ln });
            }
        }
    }
    out
}
