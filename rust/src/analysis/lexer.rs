//! A small Rust source lexer that masks string literals and comments,
//! so rule matching never false-positives on doc text or message
//! strings.
//!
//! [`mask`] walks the source once with a character state machine and
//! produces, per line, two parallel views:
//!
//! * `code`: the raw line with every character that is *not* code
//!   (string/char-literal interiors, comment text) replaced by a
//!   space. Delimiters (`"`, `'`) survive so column positions line up
//!   with the original text.
//! * `comment`: only the text of **plain** comments (`//` and
//!   `/* .. */`). Doc comments (`///`, `//!`, `/** */`, `/*! */`) are
//!   documentation, not directives, and contribute nothing here — a
//!   rustdoc paragraph describing the allow syntax must never parse
//!   as an allow.
//!
//! Handled: nested block comments, escapes inside strings (including
//! `\`-newline continuations), raw strings `r#".."#` with any hash
//! count, byte strings, and the char-literal vs lifetime ambiguity
//! (`'a'` vs `'a`).

/// Per-line masked views of one source file. All vectors have the
/// same length: one entry per `\n`-separated line.
pub struct Masked {
    /// Raw source lines, exactly as split on `\n`.
    pub raw: Vec<String>,
    /// Code view: non-code characters blanked to spaces.
    pub code: Vec<String>,
    /// Plain-comment text, blanked elsewhere.
    pub comment: Vec<String>,
}

impl Masked {
    /// Number of lines.
    pub fn len(&self) -> usize {
        self.raw.len()
    }

    /// True when the file is empty (no lines at all never happens:
    /// even `""` yields one empty line).
    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    /// `//` comment; `doc` marks `///` and `//!`.
    Line { doc: bool },
    /// `/* */` comment at `depth`; `doc` marks `/**` and `/*!`.
    Block { doc: bool },
    /// String literal body (escape-aware).
    Str,
    /// Raw string body terminated by `"` + `hashes` `#`s.
    Raw { hashes: usize },
}

/// Mask one source file. Total over arbitrary input: unterminated
/// strings or comments simply stay in their state to EOF.
pub fn mask(src: &str) -> Masked {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut raw = Vec::new();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut cur_raw = String::new();
    let mut cur_code = String::new();
    let mut cur_comm = String::new();
    let mut st = State::Code;
    let mut depth = 0usize;
    let mut esc = false;
    let mut i = 0usize;

    macro_rules! flush_line {
        () => {
            raw.push(std::mem::take(&mut cur_raw));
            code.push(std::mem::take(&mut cur_code));
            comment.push(std::mem::take(&mut cur_comm));
        };
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            flush_line!();
            if let State::Line { .. } = st {
                st = State::Code;
            }
            i += 1;
            continue;
        }
        cur_raw.push(c);
        match st {
            State::Code => {
                let c2 = chars.get(i + 1).copied();
                if c == '/' && c2 == Some('/') {
                    let doc = matches!(chars.get(i + 2), Some(&'/') | Some(&'!'));
                    st = State::Line { doc };
                    cur_raw.push('/');
                    cur_code.push_str("  ");
                    cur_comm.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '/' && c2 == Some('*') {
                    let empty = chars.get(i + 2) == Some(&'*') && chars.get(i + 3) == Some(&'/');
                    let doc = matches!(chars.get(i + 2), Some(&'*') | Some(&'!')) && !empty;
                    st = State::Block { doc };
                    depth = 1;
                    cur_raw.push('*');
                    cur_code.push_str("  ");
                    cur_comm.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '"' {
                    st = State::Str;
                    esc = false;
                    cur_code.push('"');
                    i += 1;
                    continue;
                }
                if c == 'r' || c == 'b' {
                    if let Some((consumed, hashes)) = raw_string_prefix(&chars, i) {
                        // Emit the prefix (`r#"` etc.) as code, enter Raw.
                        cur_code.push(c);
                        for &pc in &chars[i + 1..i + consumed] {
                            cur_raw.push(pc);
                            cur_code.push(pc);
                        }
                        st = State::Raw { hashes };
                        i += consumed;
                        continue;
                    }
                    if c == 'b'
                        && c2 == Some('"')
                        && !(i > 0 && is_ident(chars[i - 1]))
                    {
                        cur_code.push('b');
                        i += 1;
                        continue;
                    }
                    cur_code.push(c);
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    if let Some(end) = char_literal_end(&chars, i) {
                        cur_code.push('\'');
                        for &lc in &chars[i + 1..end] {
                            cur_raw.push(lc);
                            cur_code.push(' ');
                        }
                        cur_raw.push('\'');
                        cur_code.push('\'');
                        i = end + 1;
                        continue;
                    }
                    cur_code.push('\''); // a lifetime tick is code
                    i += 1;
                    continue;
                }
                cur_code.push(c);
                i += 1;
            }
            State::Line { doc } => {
                cur_code.push(' ');
                cur_comm.push(if doc { ' ' } else { c });
                i += 1;
            }
            State::Block { doc } => {
                let c2 = chars.get(i + 1).copied();
                if c == '/' && c2 == Some('*') {
                    depth += 1;
                    cur_raw.push('*');
                    cur_code.push_str("  ");
                    cur_comm.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '*' && c2 == Some('/') {
                    depth -= 1;
                    cur_raw.push('/');
                    cur_code.push_str("  ");
                    cur_comm.push_str("  ");
                    if depth == 0 {
                        st = State::Code;
                    }
                    i += 2;
                    continue;
                }
                cur_code.push(' ');
                cur_comm.push(if doc { ' ' } else { c });
                i += 1;
            }
            State::Str => {
                if esc {
                    esc = false;
                    cur_code.push(' ');
                } else if c == '\\' {
                    esc = true;
                    cur_code.push(' ');
                } else if c == '"' {
                    st = State::Code;
                    cur_code.push('"');
                } else {
                    cur_code.push(' ');
                }
                i += 1;
            }
            State::Raw { hashes } => {
                if c == '"' && trailing_hashes(&chars, i + 1) >= hashes {
                    cur_code.push('"');
                    for k in 0..hashes {
                        cur_raw.push(chars[i + 1 + k]);
                        cur_code.push('#');
                    }
                    st = State::Code;
                    i += 1 + hashes;
                    continue;
                }
                cur_code.push(' ');
                i += 1;
            }
        }
    }
    raw.push(cur_raw);
    code.push(cur_code);
    comment.push(cur_comm);
    Masked { raw, code, comment }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// If `chars[i..]` starts a raw-string prefix (`r"`, `r#"`, `br"`,
/// `br#"`, any hash count), return `(prefix_len, hashes)` where
/// `prefix_len` includes the opening quote. `i` must point at `r` or
/// `b`; a preceding identifier character disqualifies it (so the `r`
/// at the end of `var` never opens a string).
fn raw_string_prefix(chars: &[char], i: usize) -> Option<(usize, usize)> {
    if i > 0 && is_ident(chars[i - 1]) {
        return None;
    }
    let mut j = i;
    if chars[j] == 'b' {
        if chars.get(j + 1) != Some(&'r') {
            return None;
        }
        j += 1;
    }
    if chars[j] != 'r' {
        return None;
    }
    let mut k = j + 1;
    while chars.get(k) == Some(&'#') {
        k += 1;
    }
    if chars.get(k) == Some(&'"') {
        Some((k - i + 1, k - (j + 1)))
    } else {
        None
    }
}

/// Count `#` characters starting at `chars[from]`.
fn trailing_hashes(chars: &[char], from: usize) -> usize {
    let mut h = 0;
    while chars.get(from + h) == Some(&'#') {
        h += 1;
    }
    h
}

/// If `chars[i]` (a `'`) opens a char literal, return the index of its
/// closing quote; `None` means it is a lifetime tick. Escaped forms
/// (`'\n'`, `'\u{1F600}'`) scan forward a bounded distance.
fn char_literal_end(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            let mut j = i + 3; // skip the escaped char
            while j < chars.len() && chars[j] != '\'' && j - i < 16 {
                j += 1;
            }
            (chars.get(j) == Some(&'\'')).then_some(j)
        }
        Some(&c1) if c1 != '\'' && chars.get(i + 2) == Some(&'\'') => Some(i + 2),
        _ => None,
    }
}
