//! Diagnostic type, deterministic ordering, and the two output
//! renderings: human-readable lines and the machine-readable JSON
//! report uploaded by the CI `tidy` job.

use crate::util::json::Value;

use super::rules;

/// One finding. `line` is 1-based (0 for whole-tree findings such as
/// a missing alloc-free marker).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Crate-relative path (`src/...`, `tests/...`, `benches/...`),
    /// or `(tree)` for findings not tied to a file.
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn new(file: &str, line: usize, rule: &'static str, message: String) -> Self {
        Diagnostic { file: file.to_string(), line, rule, message }
    }

    /// Deterministic report order: file, then line, then rule.
    fn key(&self) -> (&str, usize, &str, &str) {
        (&self.file, self.line, self.rule, &self.message)
    }
}

/// The result of one scan.
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    pub allows_used: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Sort diagnostics into the deterministic report order.
    pub fn sort(&mut self) {
        self.diagnostics.sort_by(|a, b| a.key().cmp(&b.key()));
    }

    /// Human rendering: one `file:line: [rule] message` per finding,
    /// a summary line last. With `fix_hints`, each finding carries the
    /// registry's remediation hint.
    pub fn render_human(&self, fix_hints: bool) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}:{}: [{}] {}\n", d.file, d.line, d.rule, d.message));
            if fix_hints {
                if let Some(r) = rules::rule(d.rule) {
                    out.push_str(&format!("    fix: {} ({})\n", r.hint, r.section));
                }
            }
        }
        out.push_str(&format!(
            "tidy: {} file(s), {} diagnostic(s), {} allow(s) used\n",
            self.files_scanned,
            self.diagnostics.len(),
            self.allows_used
        ));
        out
    }

    /// Machine-readable report for CI artifacts.
    pub fn to_json(&self) -> Value {
        let diags: Vec<Value> = self
            .diagnostics
            .iter()
            .map(|d| {
                Value::obj(vec![
                    ("file", Value::str(d.file.clone())),
                    ("line", Value::num(to_f64(d.line))),
                    ("rule", Value::str(d.rule)),
                    ("message", Value::str(d.message.clone())),
                ])
            })
            .collect();
        let rules: Vec<Value> = rules::REGISTRY
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("id", Value::str(r.id)),
                    ("summary", Value::str(r.summary)),
                    ("section", Value::str(r.section)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("clean", Value::Bool(self.clean())),
            ("files_scanned", Value::num(to_f64(self.files_scanned))),
            ("allows_used", Value::num(to_f64(self.allows_used))),
            ("diagnostics", Value::Arr(diags)),
            ("rules", Value::Arr(rules)),
        ])
    }
}

fn to_f64(n: usize) -> f64 {
    u32::try_from(n).map_or(f64::MAX, f64::from)
}
