//! `kimad tidy`: a dependency-free static-analysis pass that enforces
//! the engine's determinism, wire-safety, and hot-path invariants as
//! machine-checked rules.
//!
//! The scanner walks `src/`, `tests/`, and `benches/` under the crate
//! root, masks every file through [`lexer::mask`] (so string literals
//! and comments never false-positive), and applies the
//! [`rules::REGISTRY`] — each rule mapped one-to-one to a documented
//! invariant in `docs/ARCHITECTURE.md` §10. Violations are
//! suppressible only by an in-tree `tidy:allow(<rule>) -- <reason>`
//! directive, and allows that suppress nothing are themselves errors,
//! so the exemption list can only shrink unless a human writes down a
//! new reason.
//!
//! The pass runs three ways, all sharing this module: the `kimad
//! tidy` subcommand (human or `--json` output), the tier-1
//! integration test `tests/tidy.rs` (fails the build on any
//! diagnostic), and the CI `tidy` job (JSON report artifact).

pub mod allow;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

use crate::bench::kernels::alloc_free_kernels;

use self::allow::{parse_allows, parse_markers};
use self::lexer::{mask, Masked};
use self::report::{Diagnostic, Report};
use self::rules::{
    find_word, has_int_type_token, has_numeric_cast, has_slice_indexing, rule_ids, ALLOC_TOKENS,
    PANIC_TOKENS,
};

/// Directories holding engine code, where unordered-iteration types
/// are banned outright.
const ENGINE_DIRS: &[&str] = &["src/coordinator/", "src/netsim/", "src/scenarios/"];

/// Directories where float reductions must justify their order.
const REDUCE_DIRS: &[&str] = &[
    "src/coordinator/",
    "src/netsim/",
    "src/scenarios/",
    "src/ef21/",
    "src/kimad/",
    "src/compress/",
];

/// The fixed-order reduction home: the one file exempt from
/// `float-reduce` (it *defines* the ordered kernels).
const REDUCE_HOME: &str = "src/util/chunk.rs";

/// Files allowed to read the wall clock: the transport (real I/O
/// deadlines), bench timing, and the CLI's top-level duration prints.
const WALL_CLOCK_ALLOWED: &[&str] = &["src/bench/timing.rs", "src/bench/e2e.rs", "src/main.rs"];

/// Scan result for one file.
pub struct FileScan {
    pub diagnostics: Vec<Diagnostic>,
    /// `tidy:alloc-free` marker names found (for global coverage).
    pub markers: Vec<String>,
    pub allows_used: usize,
}

/// Scan one file's source text. `rel` is the crate-relative path with
/// `/` separators (`src/...`, `tests/...`, `benches/...`); the rule
/// scopes key off it.
pub fn scan_file_source(rel: &str, src: &str) -> FileScan {
    let m = mask(src);
    let ids = rule_ids();
    let (mut allows, malformed) = parse_allows(&m, &ids);
    let mut diags = Vec::new();
    for (ln, msg) in malformed {
        diags.push(Diagnostic::new(rel, ln + 1, "allow-syntax", msg));
    }

    let in_tests_dir = rel.starts_with("tests/") || rel.starts_with("benches/");
    let in_engine = ENGINE_DIRS.iter().any(|d| rel.starts_with(d));
    let in_reduce_scope = REDUCE_DIRS.iter().any(|d| rel.starts_with(d));
    let in_transport = rel.starts_with("src/transport/");
    let wall_allowed = in_transport || WALL_CLOCK_ALLOWED.contains(&rel);

    let test_lines = if in_tests_dir { vec![false; m.len()] } else { cfg_test_lines(&m) };
    let decode_lines = if in_transport { decode_path_lines(&m) } else { vec![false; m.len()] };
    let markers = parse_markers(&m);

    let mut emit = |allows: &mut allow::AllowSet,
                    diags: &mut Vec<Diagnostic>,
                    ln: usize,
                    rule: &'static str,
                    msg: String| {
        if !allows.suppress(ln, rule) {
            diags.push(Diagnostic::new(rel, ln + 1, rule, msg));
        }
    };

    for ln in 0..m.len() {
        let rawline = &m.raw[ln];
        let code = &m.code[ln];
        let non_test = !in_tests_dir && !test_lines[ln];

        // -- mechanical style --------------------------------------
        let width = rawline.chars().count();
        if width > 100 {
            let msg = format!("line is {width} columns (max 100)");
            emit(&mut allows, &mut diags, ln, "line-width", msg);
        }
        if rawline.contains('\t') {
            let msg = "tab character (spaces only)".to_string();
            emit(&mut allows, &mut diags, ln, "tab-char", msg);
        }
        let no_trail = rawline.trim_end_matches([' ', '\t']);
        if no_trail != rawline {
            let msg = if rawline.trim().is_empty() {
                "whitespace-only line".to_string()
            } else {
                "trailing whitespace".to_string()
            };
            emit(&mut allows, &mut diags, ln, "trailing-space", msg);
        }

        // -- determinism -------------------------------------------
        if in_engine {
            for w in ["HashMap", "HashSet"] {
                if find_word(code, w).is_some() {
                    let msg = format!("{w} in engine code — use BTreeMap/BTreeSet");
                    emit(&mut allows, &mut diags, ln, "hash-collections", msg);
                }
            }
        }
        if non_test && !wall_allowed {
            for w in ["Instant::now", "SystemTime::now"] {
                if code.contains(w) {
                    let msg = format!("{w} outside the wall-clock allowlist");
                    emit(&mut allows, &mut diags, ln, "wall-clock", msg);
                }
            }
        }
        for w in ["thread_rng", "from_entropy", "from_os_rng"] {
            if find_word(code, w).is_some() {
                let msg = format!("{w} — derive streams from util::rng only");
                emit(&mut allows, &mut diags, ln, "ambient-rng", msg);
            }
        }
        if code.replace(' ', "").contains("rand::random") {
            let msg = "rand::random — derive streams from util::rng only".to_string();
            emit(&mut allows, &mut diags, ln, "ambient-rng", msg);
        }
        if non_test && in_reduce_scope && rel != REDUCE_HOME && has_float_reduce(&m, ln) {
            let msg =
                "float .sum()/.product() — fixed-order reductions only (util::chunk)".to_string();
            emit(&mut allows, &mut diags, ln, "float-reduce", msg);
        }

        // -- wire safety -------------------------------------------
        if non_test && in_transport {
            if has_numeric_cast(code) {
                let msg = "`as` numeric cast in transport — use try_from".to_string();
                emit(&mut allows, &mut diags, ln, "numeric-cast", msg);
            }
            if decode_lines[ln] {
                let panic_tok = PANIC_TOKENS.iter().find(|w| code.contains(*w));
                if let Some(w) = panic_tok {
                    let name = w.trim_end_matches('(');
                    let msg = format!("{name} in a decode path — decoding is total");
                    emit(&mut allows, &mut diags, ln, "decode-panic", msg);
                } else if has_slice_indexing(code) {
                    let msg = "slice indexing in a decode path — use get()".to_string();
                    emit(&mut allows, &mut diags, ln, "decode-panic", msg);
                }
            }
        }
        if find_word(code, "unsafe").is_some() && !has_safety_comment(&m, ln) {
            let msg = "unsafe without a `// SAFETY:` comment".to_string();
            emit(&mut allows, &mut diags, ln, "safety-comment", msg);
        }
    }

    // -- alloc-free regions ----------------------------------------
    let required = alloc_free_kernels();
    for marker in &markers {
        if !required.contains(&marker.name.as_str()) {
            let msg =
                format!("marker '{}' not in bench::kernels::alloc_free_kernels()", marker.name);
            diags.push(Diagnostic::new(rel, marker.line + 1, "alloc-free-coverage", msg));
        }
        let (lo, hi) = brace_region(&m, marker.line);
        for ln in lo..=hi {
            if let Some(tok) = ALLOC_TOKENS.iter().find(|t| m.code[ln].contains(*t)) {
                let msg = format!("{tok} inside `tidy:alloc-free({})` region", marker.name);
                emit(&mut allows, &mut diags, ln, "alloc-free", msg);
            }
        }
    }

    // -- import order ----------------------------------------------
    check_import_order(rel, &m, &mut diags);

    // -- unused allows ---------------------------------------------
    let allows_used = allows.allows.iter().filter(|a| a.used).count();
    for a in &allows.allows {
        if !a.used {
            let msg = format!("unused tidy:allow({})", a.rule);
            diags.push(Diagnostic::new(rel, a.line + 1, "unused-allow", msg));
        }
    }

    let marker_names = markers.into_iter().map(|mk| mk.name).collect();
    FileScan { diagnostics: diags, markers: marker_names, allows_used }
}

/// Scan a whole crate tree (`src/`, `tests/`, `benches/` under
/// `root`) and cross-check alloc-free marker coverage.
pub fn scan_root(root: &Path) -> anyhow::Result<Report> {
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches"] {
        collect_rs_files(&root.join(sub), &mut files)?;
    }
    let mut diagnostics = Vec::new();
    let mut all_markers: Vec<String> = Vec::new();
    let mut allows_used = 0;
    let files_scanned = files.len();
    for path in &files {
        let rel = rel_path(root, path);
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let scan = scan_file_source(&rel, &src);
        diagnostics.extend(scan.diagnostics);
        all_markers.extend(scan.markers);
        allows_used += scan.allows_used;
    }
    for req in alloc_free_kernels() {
        if !all_markers.iter().any(|name| name == req) {
            let msg =
                format!("alloc_free_kernels() entry '{req}' has no tidy:alloc-free marker");
            diagnostics.push(Diagnostic::new("(tree)", 0, "alloc-free-coverage", msg));
        }
    }
    let mut report = Report { diagnostics, files_scanned, allows_used };
    report.sort();
    Ok(report)
}

/// Locate the crate root for a default `kimad tidy` invocation: the
/// manifest dir when running under cargo, else a probe for
/// `rust/src/lib.rs` / `src/lib.rs` beneath the working directory.
pub fn default_root() -> PathBuf {
    if let Ok(dir) = std::env::var("CARGO_MANIFEST_DIR") {
        return PathBuf::from(dir);
    }
    for cand in ["rust", "."] {
        if Path::new(cand).join("src/lib.rs").exists() {
            return PathBuf::from(cand);
        }
    }
    PathBuf::from(".")
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

/// Lines covered by `#[cfg(test)]` items: unit-test rules relax there.
fn cfg_test_lines(m: &Masked) -> Vec<bool> {
    let mut out = vec![false; m.len()];
    for ln in 0..m.len() {
        if m.code[ln].contains("#[cfg(test)]") {
            let (lo, hi) = brace_region(m, ln);
            for flag in out.iter_mut().take(hi + 1).skip(lo) {
                *flag = true;
            }
        }
    }
    out
}

/// Lines inside decode-path functions: any `fn` whose signature
/// mentions `FrameError` or `Decoded`. Decoding is total (§9), so
/// these bodies may not contain panicking constructs.
fn decode_path_lines(m: &Masked) -> Vec<bool> {
    let mut out = vec![false; m.len()];
    let mut ln = 0;
    while ln < m.len() {
        if find_word(&m.code[ln], "fn").is_some() {
            let mut end = ln;
            let mut sig = String::new();
            for j in ln..(ln + 12).min(m.len()) {
                sig.push_str(&m.code[j]);
                sig.push(' ');
                end = j;
                if m.code[j].contains('{') || m.code[j].contains(';') {
                    break;
                }
            }
            if find_word(&sig, "FrameError").is_some() || find_word(&sig, "Decoded").is_some() {
                let (_, hi) = brace_region(m, end);
                for flag in out.iter_mut().take(hi + 1).skip(ln) {
                    *flag = true;
                }
                ln = hi + 1;
                continue;
            }
        }
        ln += 1;
    }
    out
}

/// Lines covered from the first `{` at or after `start` to its
/// matching close (inclusive), counting braces in the code view only.
fn brace_region(m: &Masked, start: usize) -> (usize, usize) {
    let mut depth = 0i64;
    let mut seen = false;
    for ln in start..m.len() {
        for c in m.code[ln].chars() {
            match c {
                '{' => {
                    depth += 1;
                    seen = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if seen && depth <= 0 {
            return (start, ln);
        }
    }
    (start, m.len().saturating_sub(1))
}

/// Float-reduce detection with the "integer witness" escape: a
/// `.sum()`/`.product()` whose statement names an integer type (in
/// the reduction line or up to six lines back, stopping at a
/// statement boundary) is an ordered integer reduction, not a float
/// one.
fn has_float_reduce(m: &Masked, ln: usize) -> bool {
    let code = &m.code[ln];
    let reduces = code.contains(".sum()")
        || code.contains(".sum::<")
        || code.contains(".product()")
        || code.contains(".product::<");
    if !reduces {
        return false;
    }
    if has_int_type_token(code) {
        return false;
    }
    let mut j = ln;
    let mut back = 0;
    while j > 0 && back < 6 {
        j -= 1;
        back += 1;
        let prev = m.code[j].trim_end();
        if prev.trim().is_empty() {
            continue;
        }
        if has_int_type_token(&m.code[j]) {
            return false;
        }
        if prev.ends_with(';') || prev.ends_with('{') || prev.ends_with('}') {
            break;
        }
    }
    true
}

/// `unsafe` needs a `// SAFETY:` comment on its line or within the
/// three lines above.
fn has_safety_comment(m: &Masked, ln: usize) -> bool {
    let lo = ln.saturating_sub(3);
    (lo..=ln).any(|j| m.comment[j].contains("SAFETY:"))
}

/// Within a contiguous `use` block, non-`self`/`super` items must be
/// sorted by (case-insensitive, then exact) first-line key.
fn check_import_order(rel: &str, m: &Masked, diags: &mut Vec<Diagnostic>) {
    let mut items: Vec<(usize, String)> = Vec::new();
    let mut ln = 0;
    while ln < m.len() {
        let trimmed = m.code[ln].trim();
        if trimmed.starts_with("use ") || trimmed.starts_with("pub use ") {
            let start = ln;
            let key = trimmed.strip_prefix("pub ").unwrap_or(trimmed);
            let key = key.strip_prefix("use ").unwrap_or(key).to_string();
            while !m.code[ln].contains(';') && ln + 1 < m.len() {
                ln += 1;
            }
            items.push((start, key));
            ln += 1;
        } else {
            flush_import_block(rel, &items, diags);
            items.clear();
            ln += 1;
        }
    }
    flush_import_block(rel, &items, diags);
}

fn flush_import_block(rel: &str, items: &[(usize, String)], diags: &mut Vec<Diagnostic>) {
    let keys: Vec<&(usize, String)> = items
        .iter()
        .filter(|(_, k)| !k.starts_with("self") && !k.starts_with("super"))
        .collect();
    for pair in keys.windows(2) {
        let (_, ka) = pair[0];
        let (lb, kb) = pair[1];
        if (ka.to_lowercase(), ka) > (kb.to_lowercase(), kb) {
            let short_a: String = ka.chars().take(40).collect();
            let short_b: String = kb.chars().take(40).collect();
            let msg = format!("use items unsorted: '{short_b}' after '{short_a}'");
            diags.push(Diagnostic::new(rel, lb + 1, "import-order", msg));
        }
    }
}
