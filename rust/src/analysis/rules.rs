//! The rule registry and the low-level token matchers shared by the
//! scanner. Every rule maps one-to-one to a documented invariant in
//! `docs/ARCHITECTURE.md` (the `section` field), and every rule has a
//! firing + passing fixture pair in `tests/tidy.rs`.
//!
//! Matching is hand-rolled word search over the lexer's masked code
//! view — no regexes, no dependencies — so the scanner can run as a
//! tier-1 test in the offline workspace.

/// One registered rule.
pub struct Rule {
    /// Stable id, the name used in `tidy:allow(<id>)`.
    pub id: &'static str,
    /// One-line statement of the invariant.
    pub summary: &'static str,
    /// The ARCHITECTURE.md section the rule enforces.
    pub section: &'static str,
    /// Suggested remediation, shown by `kimad tidy --fix-report`.
    pub hint: &'static str,
}

/// The full registry, in severity-then-name order. Rule ids are the
/// vocabulary of `tidy:allow`; adding a rule here requires fixtures
/// in `tests/tidy.rs` and a row in ARCHITECTURE.md §10.
pub const REGISTRY: &[Rule] = &[
    Rule {
        id: "hash-collections",
        summary: "HashMap/HashSet in engine code (coordinator/, netsim/, scenarios/)",
        section: "§6 determinism checklist",
        hint: "use BTreeMap/BTreeSet: iteration order must be deterministic",
    },
    Rule {
        id: "wall-clock",
        summary: "Instant::now/SystemTime::now outside the wall-clock allowlist",
        section: "§6 determinism checklist",
        hint: "engine time is virtual; wall time only in transport/, bench timing, and main",
    },
    Rule {
        id: "ambient-rng",
        summary: "thread_rng/rand::random/entropy-seeded RNG",
        section: "§6 determinism checklist",
        hint: "derive a seeded stream from util::rng instead",
    },
    Rule {
        id: "float-reduce",
        summary: "float .sum()/.product() outside util/chunk.rs",
        section: "§6 fixed reduction order",
        hint: "use util::chunk kernels, or tidy:allow with a determinism argument",
    },
    Rule {
        id: "numeric-cast",
        summary: "`as` numeric cast in transport/",
        section: "§9 wire format",
        hint: "use try_from: silent truncation corrupts wire fields",
    },
    Rule {
        id: "decode-panic",
        summary: "unwrap/expect/panic/indexing in a decode path",
        section: "§9 decoding is total",
        hint: "return a typed FrameError: arbitrary bytes must never panic",
    },
    Rule {
        id: "safety-comment",
        summary: "`unsafe` without a `// SAFETY:` comment",
        section: "§7 counting allocator",
        hint: "state the invariant that makes the unsafe block sound",
    },
    Rule {
        id: "alloc-free",
        summary: "allocation inside a tidy:alloc-free region",
        section: "§7 zero-allocation kernels",
        hint: "reuse caller-provided scratch; the hotpath bench proves these stay alloc-free",
    },
    Rule {
        id: "alloc-free-coverage",
        summary: "alloc-free markers out of sync with bench::kernels::alloc_free_kernels()",
        section: "§7 zero-allocation kernels",
        hint: "every benched kernel carries a marker, every marker names a benched kernel",
    },
    Rule {
        id: "line-width",
        summary: "line longer than 100 columns",
        section: "§10 mechanical style",
        hint: "wrap to rustfmt.toml's max_width = 100",
    },
    Rule {
        id: "tab-char",
        summary: "tab character",
        section: "§10 mechanical style",
        hint: "indent with spaces",
    },
    Rule {
        id: "trailing-space",
        summary: "trailing whitespace",
        section: "§10 mechanical style",
        hint: "strip end-of-line whitespace",
    },
    Rule {
        id: "import-order",
        summary: "use items out of order within a block",
        section: "§10 mechanical style",
        hint: "sort case-insensitively (self/super first, exempt)",
    },
    Rule {
        id: "allow-syntax",
        summary: "malformed tidy:allow directive",
        section: "§10 invariants as lints",
        hint: "write tidy:allow(<rule>) -- <reason>, with a real reason",
    },
    Rule {
        id: "unused-allow",
        summary: "tidy:allow that suppresses nothing",
        section: "§10 invariants as lints",
        hint: "delete the stale exemption",
    },
];

/// Rule ids, for directive validation.
pub fn rule_ids() -> Vec<&'static str> {
    REGISTRY.iter().map(|r| r.id).collect()
}

/// Look up a rule by id.
pub fn rule(id: &str) -> Option<&'static Rule> {
    REGISTRY.iter().find(|r| r.id == id)
}

const INT_TYPES: &[&str] =
    &["u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"];
const FLOAT_TYPES: &[&str] = &["f32", "f64"];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Find `word` in `code` with identifier-boundary guards on both
/// sides. Returns the char offset of the first match.
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let chars: Vec<char> = code.chars().collect();
    let pat: Vec<char> = word.chars().collect();
    if pat.is_empty() || chars.len() < pat.len() {
        return None;
    }
    for start in 0..=chars.len() - pat.len() {
        if chars[start..start + pat.len()] != pat[..] {
            continue;
        }
        let before_ok = start == 0 || !is_ident(chars[start - 1]);
        let end = start + pat.len();
        let after_ok = end >= chars.len() || !is_ident(chars[end]);
        if before_ok && after_ok {
            return Some(start);
        }
    }
    None
}

/// True when the masked line contains an `as <numeric-type>` cast.
pub fn has_numeric_cast(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    let mut start = 0;
    while start + 2 <= chars.len() {
        if chars[start] != 'a' || chars.get(start + 1) != Some(&'s') {
            start += 1;
            continue;
        }
        let before_ok = start == 0 || !is_ident(chars[start - 1]);
        let after = start + 2;
        if before_ok && chars.get(after).is_some_and(|c| c.is_whitespace()) {
            let mut j = after;
            while chars.get(j).is_some_and(|c| c.is_whitespace()) {
                j += 1;
            }
            let mut k = j;
            while chars.get(k).is_some_and(|&c| is_ident(c)) {
                k += 1;
            }
            let ty: String = chars[j..k].iter().collect();
            if INT_TYPES.contains(&ty.as_str()) || FLOAT_TYPES.contains(&ty.as_str()) {
                return true;
            }
        }
        start += 2;
    }
    false
}

/// True when the masked line indexes a value (`ident[`, `)[`, `][`) —
/// a potential panic site in decode paths. Type positions (`&[u8]`,
/// `Vec<[u8; 4]>`) don't match: their `[` follows punctuation.
pub fn has_slice_indexing(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    for p in 1..chars.len() {
        if chars[p] == '[' && (is_ident(chars[p - 1]) || chars[p - 1] == ')' || chars[p - 1] == ']')
        {
            return true;
        }
    }
    false
}

/// True when any word-bounded integer-type token appears in the line
/// (the float-reduce "integer witness": `let n: u64 = xs.iter().sum()`
/// is an ordered integer reduction, not a float one).
pub fn has_int_type_token(code: &str) -> bool {
    INT_TYPES.iter().any(|t| find_word(code, t).is_some())
}

/// Tokens that allocate, banned inside `tidy:alloc-free` regions.
pub const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec![",
    ".to_vec(",
    ".clone(",
    ".collect(",
    "Box::new",
    "String::new",
    "format!(",
    ".to_string(",
    ".to_owned(",
];

/// Panicking constructs banned in decode paths (prefix match).
pub const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];
