//! Experiment driver: turn an [`ExperimentConfig`] into a running
//! simulation — shared by the CLI, the examples and every bench.

use crate::bandwidth::{BandwidthTrace, PerWorkerTraces};
use crate::config::{ExperimentConfig, WorkloadSpec};
use crate::coordinator::{QuadraticSource, RoundRecord, SimConfig, Simulation};
use crate::kimad::BudgetParams;
use crate::model::Layer;
use crate::netsim::{Link, NetSim};
use crate::optim::{LayerwiseSgd, Schedule};
use crate::quadratic::Quadratic;
use crate::runtime::{ArtifactStore, EvalMetrics, PjrtModelSource, Runtime};

/// Everything an experiment produced.
pub struct ExperimentResult {
    pub records: Vec<RoundRecord>,
    pub layers: Vec<Layer>,
    pub n_params: usize,
    /// Final-model evaluation (deep model only).
    pub eval: Option<EvalMetrics>,
    /// Virtual seconds simulated.
    pub total_time: f64,
}

impl ExperimentResult {
    pub fn mean_step_time(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.duration).sum::<f64>() / self.records.len() as f64
    }
}

/// Numerical mean of a trace over its first `horizon` seconds.
pub fn trace_mean_bps(trace: &dyn BandwidthTrace, horizon: f64) -> f64 {
    trace.integrate(0.0, horizon) / horizon
}

/// Build the M-link netsim from the config's trace specs.
pub fn build_netsim(cfg: &ExperimentConfig) -> NetSim {
    let pairs = PerWorkerTraces::build(&cfg.uplink, &cfg.downlink, cfg.m);
    NetSim::new(
        pairs
            .into_iter()
            .map(|(up, down)| Link::new(up, down))
            .collect(),
    )
    .with_alpha(cfg.alpha)
}

fn prior_bps(cfg: &ExperimentConfig) -> f64 {
    if cfg.prior_bps > 0.0 {
        cfg.prior_bps
    } else {
        trace_mean_bps(cfg.uplink.build().as_ref(), 120.0)
    }
}

/// The synchronized round schedule implied by the budget: the paper's
/// user-given t covers down + compute + up (§3.1).
fn round_deadline(budget: &crate::kimad::BudgetParams, t_comp: f64) -> f64 {
    match budget {
        crate::kimad::BudgetParams::RoundBudget { t, .. } => *t,
        crate::kimad::BudgetParams::PerDirection { t_comm } => 2.0 * t_comm + t_comp,
    }
}

fn sim_config(
    cfg: &ExperimentConfig,
    layers: Vec<Layer>,
    t_comp: f64,
    prior_bps: f64,
) -> SimConfig {
    SimConfig {
        m: cfg.m,
        weights: vec![],
        budget: cfg.budget,
        up_policy: cfg.up_policy.clone(),
        down_policy: cfg.down_policy.clone(),
        optimizer: LayerwiseSgd::new(Schedule::Constant(cfg.optimizer.gamma))
            .with_layer_weights(cfg.optimizer.layer_weights.clone()),
        layers,
        warm_start: cfg.warm_start,
        prior_bps,
        round_deadline: Some(round_deadline(&cfg.budget, t_comp)),
        budget_safety: cfg.budget_safety,
        threads: cfg.threads,
        mode: cfg.mode.resolve(cfg.m),
        compute: cfg.compute.clone(),
    }
}

/// Pre-built state one *cell family* of quadratic experiments shares
/// (same uplink trace × workload × M): the `Quadratic` instance, the
/// layer layout and the cold-start bandwidth prior (a numerical trace
/// integration). The scenario matrix prepares one of these per family
/// and runs every member cell against it, instead of re-deriving all
/// three per cell.
///
/// `run` is the *same* code path [`run_experiment`] takes for the
/// quadratic workload — `run_experiment` delegates here with a
/// just-prepared instance — so warm (reused) and cold (fresh) runs are
/// bit-identical by construction.
pub struct WarmQuadratic {
    workload: WorkloadSpec,
    uplink: crate::bandwidth::TraceSpec,
    m: usize,
    cfg_prior: f64,
    q: Quadratic,
    layout: crate::model::ModelLayout,
    t_comp: f64,
    prior_bps: f64,
}

impl WarmQuadratic {
    /// Build the family state from one member's config.
    pub fn prepare(cfg: &ExperimentConfig) -> anyhow::Result<Self> {
        let WorkloadSpec::Quadratic { d, n_layers, t_comp } = &cfg.workload else {
            anyhow::bail!(
                "warm-cell reuse covers the quadratic workload (deep models load artifacts)"
            );
        };
        let q = Quadratic::paper_instance(*d);
        let layout = q.layout(*n_layers);
        Ok(Self {
            workload: cfg.workload.clone(),
            uplink: cfg.uplink.clone(),
            m: cfg.m,
            cfg_prior: cfg.prior_bps,
            q,
            layout,
            t_comp: *t_comp,
            prior_bps: prior_bps(cfg),
        })
    }

    /// Is `cfg` a member of this family? (Everything the warm state
    /// was derived from must match; policy, mode, safety, shards and
    /// the downlink are free axes.)
    pub fn compatible(&self, cfg: &ExperimentConfig) -> bool {
        cfg.workload == self.workload
            && cfg.uplink == self.uplink
            && cfg.m == self.m
            && cfg.prior_bps == self.cfg_prior
    }

    /// Run one member cell to completion from the warm state.
    pub fn run(&self, cfg: &ExperimentConfig) -> anyhow::Result<ExperimentResult> {
        anyhow::ensure!(
            self.compatible(cfg),
            "experiment '{}' is not a member of this cell family",
            cfg.name
        );
        let layers = if cfg.single_layer {
            self.layout.single_layer()
        } else {
            self.layout.layers()
        };
        let d = self.q.dim();
        let src = QuadraticSource::new(self.q.clone(), self.t_comp);
        let x0 = vec![1.0f32; d];
        let sim_cfg = sim_config(cfg, layers.clone(), self.t_comp, self.prior_bps);
        let mut sim = Simulation::new(sim_cfg, build_netsim(cfg), src, x0);
        sim.shards = cfg.shards;
        sim.thread_cap = cfg.thread_cap;
        let records = sim.run(cfg.rounds)?;
        let total_time = sim.clock;
        Ok(ExperimentResult { records, layers, n_params: d, eval: None, total_time })
    }
}

/// Run a full experiment to completion.
///
/// `artifacts`: directory for deep-model workloads (ignored for the
/// quadratic). Evaluation batches for the deep model: `eval_batches`.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    artifacts: Option<&str>,
    eval_batches: usize,
) -> anyhow::Result<ExperimentResult> {
    match &cfg.workload {
        WorkloadSpec::Quadratic { .. } => WarmQuadratic::prepare(cfg)?.run(cfg),
        WorkloadSpec::DeepModel { preset, sigma, t_comp } => {
            let store = match artifacts {
                Some(dir) => ArtifactStore::open(dir)?,
                None => ArtifactStore::open_default()?,
            };
            let rt = Runtime::cpu()?;
            let layout = store.layout(preset)?;
            // §4.2: T_comp = ModelSize / AverageBandwidth when not given.
            let t_comp = if *t_comp > 0.0 {
                *t_comp
            } else {
                let avg = trace_mean_bps(cfg.uplink.build().as_ref(), 120.0);
                layout.wire_bits() as f64 / avg
            };
            let src = PjrtModelSource::load(&rt, &store, preset, *sigma, t_comp)?;
            let layers = if cfg.single_layer {
                layout.single_layer()
            } else {
                layout.layers()
            };
            let x0 = store.initial_params(preset)?;
            let n_params = layout.n_params;
            let sim_cfg = sim_config(cfg, layers.clone(), t_comp, prior_bps(cfg));
            let mut sim = Simulation::new(sim_cfg, build_netsim(cfg), src, x0);
            sim.shards = cfg.shards;
            sim.thread_cap = cfg.thread_cap;
            let records = sim.run(cfg.rounds)?;
            let total_time = sim.clock;
            let eval = if eval_batches > 0 {
                Some(sim.source.evaluate(&sim.server.x, eval_batches)?)
            } else {
                None
            };
            Ok(ExperimentResult { records, layers, n_params, eval, total_time })
        }
    }
}

/// The §4.2 bandwidth pattern (30–330 Mbps sin², per-worker noise) used
/// by the deep-model experiments; factored here so benches, examples
/// and configs stay consistent.
pub fn paper_bandwidth_spec(seed: u64) -> crate::bandwidth::TraceSpec {
    // theta 0.05 -> ~125 s period, matching the slow swings visible in
    // the paper's Fig. 7 time axis; multi-round troughs are what make
    // fixed-size messages miss the deadline (Table 1's straggler tail).
    crate::bandwidth::TraceSpec::NoisySinSquared {
        eta: 300e6,
        theta: 0.05,
        delta: 30e6,
        phase: 0.0,
        noise_sigma: 0.15,
        seed,
        horizon: 100_000.0,
    }
}

/// Eq.(2)/§4.2 budget helper used across experiments.
pub fn per_direction(t_comm: f64) -> BudgetParams {
    BudgetParams::PerDirection { t_comm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::TraceSpec;
    use crate::config::{ExecModeSpec, OptimizerSpec};
    use crate::coordinator::ComputeModel;
    use crate::kimad::CompressPolicy;

    fn quad_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "t".into(),
            m: 2,
            workload: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.01 },
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadUniform,
            down_policy: CompressPolicy::KimadUniform,
            optimizer: OptimizerSpec { gamma: 0.02, layer_weights: vec![] },
            uplink: TraceSpec::Constant { bps: 512.0 },
            downlink: TraceSpec::Constant { bps: 512.0 },
            alpha: 1.0,
            rounds: 50,
            prior_bps: 0.0,
            warm_start: true,
            single_layer: false,
            budget_safety: 1.0,
            threads: 0,
            shards: 0,
            thread_cap: 0,
            mode: ExecModeSpec::Sync,
            compute: ComputeModel::Constant,
            seed: 21,
        }
    }

    #[test]
    fn quadratic_experiment_runs() {
        let res = run_experiment(&quad_cfg(), None, 0).unwrap();
        assert_eq!(res.records.len(), 50);
        assert!(res.total_time > 0.0);
        assert!(res.mean_step_time() > 0.0);
        assert!(res.records.last().unwrap().f_x < res.records[0].f_x);
    }

    #[test]
    fn netsim_has_m_links() {
        let net = build_netsim(&quad_cfg());
        assert_eq!(net.n_workers(), 2);
    }

    #[test]
    fn trace_mean_constant() {
        let t = TraceSpec::Constant { bps: 100.0 }.build();
        assert!((trace_mean_bps(t.as_ref(), 10.0) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn single_layer_flag() {
        let mut cfg = quad_cfg();
        cfg.single_layer = true;
        let res = run_experiment(&cfg, None, 0).unwrap();
        assert_eq!(res.layers.len(), 1);
    }

    #[test]
    fn mode_and_compute_reach_the_engine() {
        let mut cfg = quad_cfg();
        cfg.mode = ExecModeSpec::SemiSync { participation: 0.5 };
        cfg.compute = ComputeModel::Profile { factors: vec![1.0, 6.0] };
        let res = run_experiment(&cfg, None, 0).unwrap();
        // M=2, participation 0.5 -> quorum 1: rounds close on the fast
        // worker while the straggler's uploads land late.
        assert!(res.records.iter().all(|r| r.n_arrivals() >= 1));
        assert!(res
            .records
            .iter()
            .flat_map(|r| &r.workers)
            .any(|w| w.staleness > 0));

        cfg.mode = ExecModeSpec::Async { damping: 0.6 };
        let res = run_experiment(&cfg, None, 0).unwrap();
        assert!(res.records.iter().all(|r| r.n_arrivals() == 1));
        assert!(res.total_time > 0.0);
    }

    #[test]
    fn warm_family_runs_match_cold_runs_bitwise() {
        // One WarmQuadratic serving several cells (different policies,
        // modes, safeties) must reproduce the cold path bit for bit —
        // it IS the cold path, minus the rebuilds.
        let warm = WarmQuadratic::prepare(&quad_cfg()).unwrap();
        for (policy, mode, safety) in [
            (CompressPolicy::KimadUniform, ExecModeSpec::Sync, 1.0),
            (
                CompressPolicy::KimadPlus { discretization: 300, ratios: vec![] },
                ExecModeSpec::SemiSync { participation: 0.5 },
                0.8,
            ),
            (CompressPolicy::WholeModelTopK, ExecModeSpec::Async { damping: 0.7 }, 1.0),
        ] {
            let mut cfg = quad_cfg();
            cfg.up_policy = policy.clone();
            cfg.down_policy = policy;
            cfg.mode = mode;
            cfg.budget_safety = safety;
            assert!(warm.compatible(&cfg));
            let a = warm.run(&cfg).unwrap();
            let b = run_experiment(&cfg, None, 0).unwrap();
            assert_eq!(a.records, b.records, "warm diverged from cold");
            assert_eq!(a.total_time, b.total_time);
        }
        // A different trace or M is a different family.
        let mut other = quad_cfg();
        other.m = 3;
        assert!(!warm.compatible(&other));
        let mut other = quad_cfg();
        other.uplink = TraceSpec::Constant { bps: 999.0 };
        assert!(!warm.compatible(&other));
        assert!(warm.run(&other).is_err());
    }

    #[test]
    fn shards_reach_the_engine_without_changing_results() {
        let base = run_experiment(&quad_cfg(), None, 0).unwrap();
        for shards in [1usize, 2, 3] {
            let mut cfg = quad_cfg();
            cfg.shards = shards;
            let res = run_experiment(&cfg, None, 0).unwrap();
            for (a, b) in base.records.iter().zip(&res.records) {
                assert_eq!(a, b, "shards={shards} changed the records");
            }
        }
    }
}
