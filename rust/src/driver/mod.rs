//! Experiment driver: turn an [`ExperimentConfig`] into a running
//! simulation — shared by the CLI, the examples and every bench.
//!
//! The unit of reuse is the [`WarmFamily`]: everything immutable that a
//! *cell family* of experiments shares (same workload × uplink trace ×
//! downlink trace × M × prior) is built once — the per-worker bandwidth
//! traces behind `Arc` handles, the workload instance (the `Quadratic`,
//! or the opened `ArtifactStore` + layout + initial params for the deep
//! model) and the trace-derived `prior_bps`/`T_comp` — and every member
//! cell runs from that warm state. [`run_experiment`] itself just
//! prepares a single-use family and runs it, so warm (reused) and cold
//! (fresh) runs are bit-identical **by construction**: the warm path is
//! the cold path minus the rebuilds.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bandwidth::{BandwidthTrace, PerWorkerTraces, TraceSpec};
use crate::config::{ExperimentConfig, WorkloadSpec};
use crate::coordinator::{
    ExecMode, GradientSource, PopulationSim, PopulationSpec, QuadraticSource, RoundRecord,
    RoundWire, SimConfig, Simulation,
};
use crate::kimad::BudgetParams;
use crate::model::{Layer, ModelLayout, NativeModelSource};
use crate::netsim::{Link, NetSim};
use crate::optim::{LayerwiseSgd, Schedule};
use crate::quadratic::Quadratic;
use crate::runtime::{ArtifactStore, EvalMetrics, Executable, PjrtModelSource, Runtime};

/// Everything an experiment produced.
pub struct ExperimentResult {
    pub records: Vec<RoundRecord>,
    pub layers: Vec<Layer>,
    pub n_params: usize,
    /// Final-model evaluation (deep model only).
    pub eval: Option<EvalMetrics>,
    /// Virtual seconds simulated.
    pub total_time: f64,
    /// Wall-clock milliseconds spent constructing the run (gradient
    /// source, initial parameters, simulation assembly) before the
    /// first round — the per-cell build cost the scenario matrix
    /// attributes separately from steady-state `wall_ms`.
    pub build_ms: f64,
}

impl ExperimentResult {
    pub fn mean_step_time(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.duration).sum::<f64>() / self.records.len() as f64
    }
}

/// Version of the execution engine's *results contract*. Bump this
/// when a change alters any summary bit for an unchanged config
/// (optimizer step order, trace sampling, aggregation order, budget
/// arithmetic, ...): content-addressed result caches
/// (`scenarios::cache`) fold it into every key, so bumping it retires
/// all previously cached summaries at once instead of silently serving
/// results the current engine would not reproduce.
pub const ENGINE_VERSION: u32 = 1;

/// The engine-identity string folded into every scenario cache key:
/// cell results depend on the engine results contract, the wire frame
/// codec, and the compressor panel — and on nothing else outside the
/// config itself (never wall clock, transport, or pool layout; those
/// are bit-invariant by the determinism contract the tests enforce).
pub fn engine_fingerprint() -> String {
    format!(
        "engine-v{ENGINE_VERSION};frame-v{};panel={}",
        crate::transport::frame::VERSION,
        crate::compress::PANEL
    )
}

/// Numerical mean of a trace over its first `horizon` seconds.
pub fn trace_mean_bps(trace: &dyn BandwidthTrace, horizon: f64) -> f64 {
    trace.integrate(0.0, horizon) / horizon
}

/// The per-worker (uplink, downlink) trace handles one family shares.
pub type SharedLinks = Vec<(Arc<dyn BandwidthTrace>, Arc<dyn BandwidthTrace>)>;

/// Build the netsim from the config's trace specs — the cold twin of
/// [`WarmFamily::netsim`] (fresh builds instead of `Arc` clones;
/// bit-identical, since trace construction is deterministic). Dense
/// configs get one link per worker; population configs get one link per
/// *cohort* ([`ExperimentConfig::n_links`]), which is what keeps a
/// million-client population's network state O(cohorts).
pub fn build_netsim(cfg: &ExperimentConfig) -> NetSim {
    let pairs = PerWorkerTraces::build(&cfg.uplink, &cfg.downlink, cfg.n_links());
    NetSim::new(
        pairs
            .into_iter()
            .map(|(up, down)| Link::new(up, down))
            .collect(),
    )
    .with_alpha(cfg.alpha)
}

/// The synchronized round schedule implied by the budget: the paper's
/// user-given t covers down + compute + up (§3.1).
fn round_deadline(budget: &crate::kimad::BudgetParams, t_comp: f64) -> f64 {
    match budget {
        crate::kimad::BudgetParams::RoundBudget { t, .. } => *t,
        crate::kimad::BudgetParams::PerDirection { t_comm } => 2.0 * t_comm + t_comp,
    }
}

fn sim_config(
    cfg: &ExperimentConfig,
    layers: Vec<Layer>,
    t_comp: f64,
    prior_bps: f64,
) -> SimConfig {
    SimConfig {
        m: cfg.m,
        weights: vec![],
        budget: cfg.budget,
        up_policy: cfg.up_policy.clone(),
        down_policy: cfg.down_policy.clone(),
        optimizer: LayerwiseSgd::new(Schedule::Constant(cfg.optimizer.gamma))
            .with_layer_weights(cfg.optimizer.layer_weights.clone()),
        layers,
        warm_start: cfg.warm_start,
        prior_bps,
        round_deadline: Some(round_deadline(&cfg.budget, t_comp)),
        budget_safety: cfg.budget_safety,
        threads: cfg.threads,
        mode: cfg.mode.resolve(cfg.m),
        compute: cfg.compute.clone(),
    }
}

/// The deep arm's gradient source: PJRT when this build carries the
/// real backend, the native transformer otherwise. Either way the
/// source is a pure function of (layout, params, batch), so a run is
/// reproducible within its backend.
pub enum DeepSource {
    Pjrt(PjrtModelSource),
    Native(NativeModelSource),
}

impl DeepSource {
    /// Evaluate `params` on `n_batches` held-out batches.
    pub fn evaluate(&mut self, params: &[f32], n_batches: usize) -> anyhow::Result<EvalMetrics> {
        match self {
            DeepSource::Pjrt(s) => s.evaluate(params, n_batches),
            DeepSource::Native(s) => s.evaluate(params, n_batches),
        }
    }
}

/// The engine a cell actually runs: the dense event-driven
/// [`Simulation`] (every worker materialized) or the
/// [`PopulationSim`] (M described as a population, only the sampled
/// quorum materialized). The driver picks per config
/// ([`ExperimentConfig::is_population`]) and the rest of the run path
/// is engine-agnostic — which is what makes p = 1 population cells
/// directly comparable (bit-identical at C = M) to dense ones.
enum EngineSim<S: GradientSource> {
    Dense(Simulation<S>),
    Population(PopulationSim<S>),
}

impl<S: GradientSource> EngineSim<S> {
    fn new(
        cfg: &ExperimentConfig,
        sim_cfg: SimConfig,
        net: NetSim,
        source: S,
        x0: Vec<f32>,
    ) -> anyhow::Result<Self> {
        if cfg.is_population() {
            let pop = PopulationSpec {
                population: cfg.m,
                participation: cfg.participation,
                cohorts: cfg.resolved_cohorts(),
                seed: cfg.seed,
            };
            let mut sim = PopulationSim::new(sim_cfg, pop, net, source, x0)?;
            sim.shards = cfg.shards;
            sim.thread_cap = cfg.thread_cap;
            Ok(EngineSim::Population(sim))
        } else {
            let mut sim = Simulation::new(sim_cfg, net, source, x0);
            sim.shards = cfg.shards;
            sim.thread_cap = cfg.thread_cap;
            Ok(EngineSim::Dense(sim))
        }
    }

    fn run(&mut self, rounds: u64) -> anyhow::Result<Vec<RoundRecord>> {
        match self {
            EngineSim::Dense(s) => s.run(rounds),
            EngineSim::Population(s) => s.run(rounds),
        }
    }

    fn clock(&self) -> f64 {
        match self {
            EngineSim::Dense(s) => s.clock,
            EngineSim::Population(s) => s.clock,
        }
    }

    /// The gradient source and the final model, borrowed together
    /// (deep-model evaluation needs both at once).
    fn source_and_model(&mut self) -> (&mut S, &[f32]) {
        match self {
            EngineSim::Dense(s) => (&mut s.source, &s.server.x),
            EngineSim::Population(s) => (&mut s.source, &s.x),
        }
    }

    /// Take the model vector out (returned to the family's x0 pool).
    fn take_model(&mut self) -> Vec<f32> {
        match self {
            EngineSim::Dense(s) => std::mem::take(&mut s.server.x),
            EngineSim::Population(s) => std::mem::take(&mut s.x),
        }
    }
}

impl GradientSource for DeepSource {
    fn dim(&self) -> usize {
        match self {
            DeepSource::Pjrt(s) => s.dim(),
            DeepSource::Native(s) => s.dim(),
        }
    }

    fn update(
        &mut self,
        worker: usize,
        step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64> {
        match self {
            DeepSource::Pjrt(s) => s.update(worker, step, x_hat, out),
            DeepSource::Native(s) => s.update(worker, step, x_hat, out),
        }
    }

    fn t_comp(&self) -> f64 {
        match self {
            DeepSource::Pjrt(s) => GradientSource::t_comp(s),
            DeepSource::Native(s) => GradientSource::t_comp(s),
        }
    }
}

/// State every warm family shares regardless of workload: the identity
/// fields the family was derived from, the `Arc`-built per-worker
/// traces, and the cold-start bandwidth prior.
struct FamilyBase {
    workload: WorkloadSpec,
    uplink: TraceSpec,
    downlink: TraceSpec,
    m: usize,
    /// The member configs' `prior_bps` field (<= 0 means derived).
    cfg_prior: f64,
    links: SharedLinks,
    prior_bps: f64,
    /// Recycled model-vector buffers (the x0/server-model allocation,
    /// the largest per-cell buffer): member cells check one out at
    /// build time and return it after the run, so a warm family pays
    /// the allocation once per concurrent cell instead of once per
    /// cell. Contents are always fully overwritten before use, so
    /// pooling cannot change results.
    pool: Mutex<Vec<Vec<f32>>>,
}

impl FamilyBase {
    /// Check a buffer out of the pool (empty `Vec` when none is free).
    fn take_buf(&self) -> Vec<f32> {
        self.pool
            .lock()
            .ok()
            .and_then(|mut p| p.pop())
            .unwrap_or_default()
    }

    /// Return a buffer to the pool for the next member cell.
    fn put_buf(&self, buf: Vec<f32>) {
        if let Ok(mut p) = self.pool.lock() {
            p.push(buf);
        }
    }
}

/// Open the artifact directory a deep family loads from (`None` =
/// `./artifacts` or `$KIMAD_ARTIFACTS`).
pub fn open_artifact_store(artifacts: Option<&str>) -> anyhow::Result<ArtifactStore> {
    match artifacts {
        Some(dir) => ArtifactStore::open(dir),
        None => ArtifactStore::open_default(),
    }
}

/// Quadratic-workload family state: the §4.1 instance + layer layout.
pub struct WarmQuadratic {
    base: FamilyBase,
    q: Quadratic,
    layout: ModelLayout,
    t_comp: f64,
}

/// Deep-model family state: the opened [`ArtifactStore`] (shareable
/// across families — the scenario matrix opens each artifacts dir
/// once), the CPU [`Runtime`] with its two **pre-compiled** HLO
/// executables (when the PJRT backend is real and the set carries
/// real HLO), the parsed layout, the shared initial parameters and
/// the trace-derived `T_comp` — everything that made the pre-family
/// deep arm expensive to run per cell.
///
/// # Thread-safety contract for real PJRT bindings
///
/// The scenario matrix shares families across scoped threads, so
/// `WarmDeep` must be `Sync` — which in a vendored-`xla` build
/// requires the binding's client/executable types to be `Send + Sync`
/// **and** concurrent `Executable::run` on one compiled module to be
/// safe. If the vendored bindings are `!Sync`, this fails to compile
/// (loudly, at the `thread::scope` spawn) — do NOT paper over it with
/// an `unsafe impl`; wrap executions in a mutex or fall back to
/// per-cell compilation instead.
pub struct WarmDeep {
    base: FamilyBase,
    store: Arc<ArtifactStore>,
    /// Keep-alive for the PJRT client the compiled executables came
    /// from; never read after `prepare` (underscore-named so the
    /// stub build, whose executables carry no real client handle,
    /// doesn't flag it as dead).
    _rt: Option<Runtime>,
    /// Compiled (train, eval) modules, shared by every member cell's
    /// source — HLO compilation is the most expensive setup step.
    exes: Option<(Arc<Executable>, Arc<Executable>)>,
    layout: ModelLayout,
    x0: Arc<Vec<f32>>,
    sigma: f32,
    t_comp: f64,
}

impl WarmDeep {
    /// A fresh gradient source for one member cell. Sources are
    /// consumed mutably by the simulation, so each cell gets its own;
    /// the expensive shared parts — store open, layout parse, params
    /// read, HLO compiles, trace builds — live in the family.
    fn source(&self) -> anyhow::Result<DeepSource> {
        Ok(match &self.exes {
            Some((train, eval)) => DeepSource::Pjrt(PjrtModelSource::from_parts(
                self.layout.clone(),
                train.clone(),
                eval.clone(),
                self.sigma,
                self.store.seed(),
                self.t_comp,
            )),
            None => DeepSource::Native(NativeModelSource::new(
                &self.layout,
                self.sigma,
                self.store.seed(),
                self.t_comp,
            )?),
        })
    }
}

/// Pre-built state one *cell family* of experiments shares (same
/// workload × uplink trace × downlink trace × M × prior): the workload
/// instance, the layer layout, the `Arc`-shared per-worker bandwidth
/// traces and the cold-start prior. The scenario matrix prepares one
/// per family and runs every member cell against it instead of
/// re-deriving everything per cell.
///
/// `run` is the *same* code path [`run_experiment`] takes —
/// `run_experiment` delegates here with a just-prepared family — so
/// warm (reused) and cold (fresh) runs are bit-identical by
/// construction, for both workloads.
pub enum WarmFamily {
    Quadratic(WarmQuadratic),
    Deep(WarmDeep),
}

impl WarmFamily {
    /// Build the family state from one member's config. `artifacts` is
    /// the deep-model artifact directory (`None` = `./artifacts` or
    /// `$KIMAD_ARTIFACTS`; ignored for the quadratic).
    pub fn prepare(cfg: &ExperimentConfig, artifacts: Option<&str>) -> anyhow::Result<Self> {
        Self::prepare_with(cfg, artifacts, None)
    }

    /// [`Self::prepare`] with an optional pre-opened artifact store to
    /// share across families: the scenario matrix opens each artifacts
    /// directory once and hands every deep family the same handle
    /// (whose internal params cache then reads each preset from disk
    /// once). `None` opens from `artifacts` as `prepare` does.
    pub fn prepare_with(
        cfg: &ExperimentConfig,
        artifacts: Option<&str>,
        store: Option<Arc<ArtifactStore>>,
    ) -> anyhow::Result<Self> {
        // Build every trace once: the per-link pairs (M worker links
        // dense, C cohort links for a population), plus — only when
        // something derives from it — one base uplink that both the
        // cold-start prior and the §4.2 T_comp derivation read (the
        // pre-family deep arm built it twice, once per derivation;
        // configs with an explicit prior and T_comp skip the 120 s
        // integration entirely).
        let links = PerWorkerTraces::build(&cfg.uplink, &cfg.downlink, cfg.n_links());
        let needs_mean = cfg.prior_bps <= 0.0
            || matches!(&cfg.workload, WorkloadSpec::DeepModel { t_comp, .. } if *t_comp <= 0.0);
        let mean_up = if needs_mean {
            trace_mean_bps(cfg.uplink.build().as_ref(), 120.0)
        } else {
            f64::NAN // never read: both consumers take their explicit value
        };
        let prior_bps = if cfg.prior_bps > 0.0 { cfg.prior_bps } else { mean_up };
        let base = FamilyBase {
            workload: cfg.workload.clone(),
            uplink: cfg.uplink.clone(),
            downlink: cfg.downlink.clone(),
            m: cfg.m,
            cfg_prior: cfg.prior_bps,
            links,
            prior_bps,
            pool: Mutex::new(Vec::new()),
        };
        match &cfg.workload {
            WorkloadSpec::Quadratic { d, n_layers, t_comp } => {
                let q = Quadratic::paper_instance(*d);
                let layout = q.layout(*n_layers);
                Ok(WarmFamily::Quadratic(WarmQuadratic { base, q, layout, t_comp: *t_comp }))
            }
            WorkloadSpec::DeepModel { preset, sigma, t_comp } => {
                let store = match store {
                    Some(s) => s,
                    None => Arc::new(open_artifact_store(artifacts)?),
                };
                // PJRT needs both a real backend AND real lowered HLO;
                // a `gen-artifacts` set (placeholder HLO) runs on the
                // native transformer even in a PJRT-enabled build.
                // Compilation happens here, once per family.
                let rt = if Runtime::available() && store.has_real_hlo(preset)? {
                    Some(Runtime::cpu()?)
                } else {
                    None
                };
                let exes = match &rt {
                    Some(rt) => Some(PjrtModelSource::compile(rt, &store, preset)?),
                    None => None,
                };
                let layout = store.layout(preset)?;
                // §4.2: T_comp = ModelSize / AverageBandwidth when not
                // given explicitly.
                let t_comp = if *t_comp > 0.0 {
                    *t_comp
                } else {
                    layout.wire_bits() as f64 / mean_up
                };
                let x0 = store.initial_params_shared(preset)?;
                Ok(WarmFamily::Deep(WarmDeep {
                    base,
                    store,
                    _rt: rt,
                    exes,
                    layout,
                    x0,
                    sigma: *sigma,
                    t_comp,
                }))
            }
        }
    }

    fn base(&self) -> &FamilyBase {
        match self {
            WarmFamily::Quadratic(f) => &f.base,
            WarmFamily::Deep(f) => &f.base,
        }
    }

    /// Is `cfg` a member of this family? Everything the warm state was
    /// derived from must match — workload, both trace specs, M, the
    /// built link count (a population cell with C cohort links is not
    /// interchangeable with a dense M-link cell of the same M) and the
    /// prior field; policy, mode, safety, shards, participation (at a
    /// fixed link count) and alpha stay free axes. (The downlink joined
    /// the key when families started sharing the built downlink traces;
    /// a scenario grid's downlink is base-constant, so grid grouping is
    /// unaffected.)
    pub fn compatible(&self, cfg: &ExperimentConfig) -> bool {
        let b = self.base();
        cfg.workload == b.workload
            && cfg.uplink == b.uplink
            && cfg.downlink == b.downlink
            && cfg.m == b.m
            && cfg.n_links() == b.links.len()
            && cfg.prior_bps == b.cfg_prior
    }

    /// The family's shared per-worker trace handles (test hook: member
    /// netsims hold `Arc::ptr_eq` clones of exactly these).
    pub fn links(&self) -> &SharedLinks {
        &self.base().links
    }

    /// The deep family's shared artifact store (`None` for the
    /// quadratic) — test hook for the one-open-per-directory contract.
    pub fn artifact_store(&self) -> Option<&Arc<ArtifactStore>> {
        match self {
            WarmFamily::Deep(f) => Some(&f.store),
            WarmFamily::Quadratic(_) => None,
        }
    }

    /// Assemble a member cell's netsim from the family's shared trace
    /// handles — [`build_netsim`]'s warm twin (`Arc` clones instead of
    /// fresh builds; bit-identical by construction).
    pub fn netsim(&self, cfg: &ExperimentConfig) -> NetSim {
        let links = self
            .base()
            .links
            .iter()
            .map(|(up, down)| Link::new(up.clone(), down.clone()))
            .collect();
        NetSim::new(links).with_alpha(cfg.alpha)
    }

    /// Run one member cell to completion from the warm state.
    pub fn run(&self, cfg: &ExperimentConfig) -> anyhow::Result<ExperimentResult> {
        self.run_with_eval(cfg, 0)
    }

    /// [`Self::run`] plus a final-model evaluation on `eval_batches`
    /// held-out batches (deep model only; the quadratic has no eval
    /// notion and ignores it).
    pub fn run_with_eval(
        &self,
        cfg: &ExperimentConfig,
        eval_batches: usize,
    ) -> anyhow::Result<ExperimentResult> {
        anyhow::ensure!(
            self.compatible(cfg),
            "experiment '{}' is not a member of this cell family",
            cfg.name
        );
        // Wire transports run the same rounds as real frames between a
        // coordinator and M worker peers; the transport layer builds
        // its replicas through `build_wired` (never back through here),
        // so this dispatch cannot recurse.
        if cfg.transport.is_wire() {
            return crate::transport::run_wired(self, cfg, eval_batches);
        }
        match self {
            WarmFamily::Quadratic(f) => {
                #[allow(clippy::disallowed_methods)]
                let t_build = Instant::now(); // tidy:allow(wall-clock) -- build_ms metric only
                let layers = if cfg.single_layer {
                    f.layout.single_layer()
                } else {
                    f.layout.layers()
                };
                let d = f.q.dim();
                let src = QuadraticSource::new(f.q.clone(), f.t_comp);
                // Pooled x0 buffer: cleared + refilled, so the values
                // are exactly those of a fresh `vec![1.0; d]`.
                let mut x0 = f.base.take_buf();
                x0.clear();
                x0.resize(d, 1.0);
                let sim_cfg = sim_config(cfg, layers.clone(), f.t_comp, f.base.prior_bps);
                let mut sim = EngineSim::new(cfg, sim_cfg, self.netsim(cfg), src, x0)?;
                let build_ms = t_build.elapsed().as_secs_f64() * 1e3;
                let records = sim.run(cfg.rounds)?;
                let total_time = sim.clock();
                f.base.put_buf(sim.take_model());
                Ok(ExperimentResult {
                    records,
                    layers,
                    n_params: d,
                    eval: None,
                    total_time,
                    build_ms,
                })
            }
            WarmFamily::Deep(f) => {
                #[allow(clippy::disallowed_methods)]
                let t_build = Instant::now(); // tidy:allow(wall-clock) -- build_ms metric only
                let layers = if cfg.single_layer {
                    f.layout.single_layer()
                } else {
                    f.layout.layers()
                };
                let src = f.source()?;
                let sim_cfg = sim_config(cfg, layers.clone(), f.t_comp, f.base.prior_bps);
                // Pooled x0 buffer: cleared + refilled from the shared
                // initial params, byte-identical to a fresh clone.
                let mut x0 = f.base.take_buf();
                x0.clear();
                x0.extend_from_slice(f.x0.as_ref());
                let mut sim = EngineSim::new(cfg, sim_cfg, self.netsim(cfg), src, x0)?;
                let build_ms = t_build.elapsed().as_secs_f64() * 1e3;
                let records = sim.run(cfg.rounds)?;
                let total_time = sim.clock();
                let eval = if eval_batches > 0 {
                    let (source, model) = sim.source_and_model();
                    Some(source.evaluate(model, eval_batches)?)
                } else {
                    None
                };
                f.base.put_buf(sim.take_model());
                let n_params = f.layout.n_params;
                Ok(ExperimentResult {
                    records,
                    layers,
                    n_params,
                    eval,
                    total_time,
                    build_ms,
                })
            }
        }
    }
}

/// A wire-tapped dense engine for the multi-process transport: the
/// deterministic [`Simulation`] replica both the coordinator and every
/// worker process rebuild from the same config + seed, stepped in
/// lockstep round by round. Wraps both workload arms so the transport
/// layer stays workload-agnostic.
pub enum WiredEngine {
    Quadratic(Simulation<QuadraticSource>),
    Deep(Simulation<DeepSource>),
}

/// One wired replica plus the run metadata [`ExperimentResult`] needs.
pub struct WiredCell {
    engine: WiredEngine,
    pub layers: Vec<Layer>,
    pub n_params: usize,
}

impl WiredCell {
    /// Run one round and return its record.
    pub fn round(&mut self) -> anyhow::Result<RoundRecord> {
        match &mut self.engine {
            WiredEngine::Quadratic(s) => s.round(),
            WiredEngine::Deep(s) => s.round(),
        }
    }

    /// Take the round's captured wire content (the tap is always on
    /// for wired cells).
    pub fn take_wire(&mut self) -> anyhow::Result<RoundWire> {
        let wire = match &mut self.engine {
            WiredEngine::Quadratic(s) => s.take_wire(),
            WiredEngine::Deep(s) => s.take_wire(),
        };
        wire.ok_or_else(|| anyhow::anyhow!("wired round produced no wire capture"))
    }

    /// Virtual seconds simulated so far.
    pub fn clock(&self) -> f64 {
        match &self.engine {
            WiredEngine::Quadratic(s) => s.clock,
            WiredEngine::Deep(s) => s.clock,
        }
    }

    /// The current model vector.
    pub fn model(&self) -> &[f32] {
        match &self.engine {
            WiredEngine::Quadratic(s) => &s.server.x,
            WiredEngine::Deep(s) => &s.server.x,
        }
    }

    /// Final-model evaluation (deep model only, like
    /// [`WarmFamily::run_with_eval`]).
    pub fn evaluate(&mut self, eval_batches: usize) -> anyhow::Result<Option<EvalMetrics>> {
        match &mut self.engine {
            WiredEngine::Quadratic(_) => Ok(None),
            WiredEngine::Deep(s) => {
                let metrics = s.source.evaluate(&s.server.x, eval_batches)?;
                Ok(Some(metrics))
            }
        }
    }
}

impl WarmFamily {
    /// Build one wire-tapped engine replica for `cfg` — the exact
    /// build sequence of [`Self::run_with_eval`]'s in-process arms
    /// (fresh x0 instead of the pooled buffer: pooled buffers are
    /// refilled to the same bytes, and replicas never return them).
    /// Wire runs are dense Sync only: partial participation and
    /// arrival-ordered modes have no lockstep barrier to replicate.
    pub fn build_wired(&self, cfg: &ExperimentConfig) -> anyhow::Result<WiredCell> {
        anyhow::ensure!(
            self.compatible(cfg),
            "experiment '{}' is not a member of this cell family",
            cfg.name
        );
        anyhow::ensure!(
            !cfg.is_population(),
            "wire transports run dense cells only (participation = 1, cohorts = 0); \
             population runs stay inproc"
        );
        anyhow::ensure!(
            matches!(cfg.mode.resolve(cfg.m), ExecMode::Sync),
            "wire transports support the sync execution mode only; \
             semisync/async runs stay inproc"
        );
        match self {
            WarmFamily::Quadratic(f) => {
                let layers = if cfg.single_layer {
                    f.layout.single_layer()
                } else {
                    f.layout.layers()
                };
                let d = f.q.dim();
                let src = QuadraticSource::new(f.q.clone(), f.t_comp);
                let sim_cfg = sim_config(cfg, layers.clone(), f.t_comp, f.base.prior_bps);
                let mut sim = Simulation::new(sim_cfg, self.netsim(cfg), src, vec![1.0; d]);
                sim.shards = cfg.shards;
                sim.thread_cap = cfg.thread_cap;
                sim.wire_tap = true;
                Ok(WiredCell { engine: WiredEngine::Quadratic(sim), layers, n_params: d })
            }
            WarmFamily::Deep(f) => {
                let layers = if cfg.single_layer {
                    f.layout.single_layer()
                } else {
                    f.layout.layers()
                };
                let src = f.source()?;
                let sim_cfg = sim_config(cfg, layers.clone(), f.t_comp, f.base.prior_bps);
                let x0 = f.x0.as_ref().clone();
                let mut sim = Simulation::new(sim_cfg, self.netsim(cfg), src, x0);
                sim.shards = cfg.shards;
                sim.thread_cap = cfg.thread_cap;
                sim.wire_tap = true;
                let n_params = f.layout.n_params;
                Ok(WiredCell { engine: WiredEngine::Deep(sim), layers, n_params })
            }
        }
    }
}

/// Run a full experiment to completion.
///
/// `artifacts`: directory for deep-model workloads (ignored for the
/// quadratic). Evaluation batches for the deep model: `eval_batches`.
///
/// Delegates to a single-use [`WarmFamily`] — the same code path the
/// scenario matrix reuses across cells — so warm and cold runs are
/// bit-identical by construction.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    artifacts: Option<&str>,
    eval_batches: usize,
) -> anyhow::Result<ExperimentResult> {
    WarmFamily::prepare(cfg, artifacts)?.run_with_eval(cfg, eval_batches)
}

/// The §4.2 bandwidth pattern (30–330 Mbps sin², per-worker noise) used
/// by the deep-model experiments; factored here so benches, examples
/// and configs stay consistent.
pub fn paper_bandwidth_spec(seed: u64) -> crate::bandwidth::TraceSpec {
    // theta 0.05 -> ~125 s period, matching the slow swings visible in
    // the paper's Fig. 7 time axis; multi-round troughs are what make
    // fixed-size messages miss the deadline (Table 1's straggler tail).
    crate::bandwidth::TraceSpec::NoisySinSquared {
        eta: 300e6,
        theta: 0.05,
        delta: 30e6,
        phase: 0.0,
        noise_sigma: 0.15,
        seed,
        horizon: 100_000.0,
    }
}

/// Eq.(2)/§4.2 budget helper used across experiments.
pub fn per_direction(t_comm: f64) -> BudgetParams {
    BudgetParams::PerDirection { t_comm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::TraceSpec;
    use crate::config::{ExecModeSpec, OptimizerSpec};
    use crate::coordinator::ComputeModel;
    use crate::kimad::CompressPolicy;
    use crate::runtime::write_native_artifacts;

    fn quad_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "t".into(),
            m: 2,
            participation: 1.0,
            cohorts: 0,
            workload: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.01 },
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadUniform,
            down_policy: CompressPolicy::KimadUniform,
            optimizer: OptimizerSpec { gamma: 0.02, layer_weights: vec![] },
            uplink: TraceSpec::Constant { bps: 512.0 },
            downlink: TraceSpec::Constant { bps: 512.0 },
            alpha: 1.0,
            rounds: 50,
            prior_bps: 0.0,
            warm_start: true,
            single_layer: false,
            budget_safety: 1.0,
            threads: 0,
            shards: 0,
            thread_cap: 0,
            mode: ExecModeSpec::Sync,
            compute: ComputeModel::Constant,
            transport: crate::config::TransportSpec::Inproc,
            seed: 21,
        }
    }

    fn policy_mode_safety_variants() -> [(CompressPolicy, ExecModeSpec, f64); 3] {
        [
            (CompressPolicy::KimadUniform, ExecModeSpec::Sync, 1.0),
            (
                CompressPolicy::KimadPlus { discretization: 300, ratios: vec![] },
                ExecModeSpec::SemiSync { participation: 0.5 },
                0.8,
            ),
            (CompressPolicy::WholeModelTopK, ExecModeSpec::Async { damping: 0.7 }, 1.0),
        ]
    }

    #[test]
    fn quadratic_experiment_runs() {
        let res = run_experiment(&quad_cfg(), None, 0).unwrap();
        assert_eq!(res.records.len(), 50);
        assert!(res.total_time > 0.0);
        assert!(res.mean_step_time() > 0.0);
        assert!(res.records.last().unwrap().f_x < res.records[0].f_x);
    }

    #[test]
    fn netsim_has_m_links() {
        let net = build_netsim(&quad_cfg());
        assert_eq!(net.n_workers(), 2);
    }

    #[test]
    fn trace_mean_constant() {
        let t = TraceSpec::Constant { bps: 100.0 }.build();
        assert!((trace_mean_bps(t.as_ref(), 10.0) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn single_layer_flag() {
        let mut cfg = quad_cfg();
        cfg.single_layer = true;
        let res = run_experiment(&cfg, None, 0).unwrap();
        assert_eq!(res.layers.len(), 1);
    }

    #[test]
    fn mode_and_compute_reach_the_engine() {
        let mut cfg = quad_cfg();
        cfg.mode = ExecModeSpec::SemiSync { participation: 0.5 };
        cfg.compute = ComputeModel::Profile { factors: vec![1.0, 6.0] };
        let res = run_experiment(&cfg, None, 0).unwrap();
        // M=2, participation 0.5 -> quorum 1: rounds close on the fast
        // worker while the straggler's uploads land late.
        assert!(res.records.iter().all(|r| r.n_arrivals() >= 1));
        assert!(res
            .records
            .iter()
            .flat_map(|r| &r.workers)
            .any(|w| w.staleness > 0));

        cfg.mode = ExecModeSpec::Async { damping: 0.6 };
        let res = run_experiment(&cfg, None, 0).unwrap();
        assert!(res.records.iter().all(|r| r.n_arrivals() == 1));
        assert!(res.total_time > 0.0);
    }

    #[test]
    fn warm_family_runs_match_cold_runs_bitwise() {
        // One WarmFamily serving several cells (different policies,
        // modes, safeties) must reproduce the cold path bit for bit —
        // it IS the cold path, minus the rebuilds.
        let warm = WarmFamily::prepare(&quad_cfg(), None).unwrap();
        for (policy, mode, safety) in policy_mode_safety_variants() {
            let mut cfg = quad_cfg();
            cfg.up_policy = policy.clone();
            cfg.down_policy = policy;
            cfg.mode = mode;
            cfg.budget_safety = safety;
            assert!(warm.compatible(&cfg));
            let a = warm.run(&cfg).unwrap();
            let b = run_experiment(&cfg, None, 0).unwrap();
            assert_eq!(a.records, b.records, "warm diverged from cold");
            assert_eq!(a.total_time, b.total_time);
        }
        // A different trace, downlink or M is a different family.
        let mut other = quad_cfg();
        other.m = 3;
        assert!(!warm.compatible(&other));
        let mut other = quad_cfg();
        other.uplink = TraceSpec::Constant { bps: 999.0 };
        assert!(!warm.compatible(&other));
        assert!(warm.run(&other).is_err());
        let mut other = quad_cfg();
        other.downlink = TraceSpec::Constant { bps: 999.0 };
        assert!(!warm.compatible(&other));
    }

    #[test]
    fn deep_warm_family_matches_cold_runs_bitwise() {
        // The deep arm of the same invariant, on the native backend
        // against a generated tiny-preset artifact set: one
        // WarmFamily::Deep serving several cells reproduces
        // run_experiment record for record, eval included.
        let dir =
            std::env::temp_dir().join(format!("kimad-deep-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_native_artifacts(&dir, &["tiny".to_string()], 21).unwrap();
        let art = dir.to_str().unwrap().to_string();

        let mut base = quad_cfg();
        base.workload =
            WorkloadSpec::DeepModel { preset: "tiny".into(), sigma: 0.3, t_comp: 0.5 };
        base.rounds = 4;
        let warm = WarmFamily::prepare(&base, Some(&art)).unwrap();
        assert!(matches!(warm, WarmFamily::Deep(_)));
        for (policy, mode, safety) in policy_mode_safety_variants() {
            let mut cfg = base.clone();
            cfg.up_policy = policy.clone();
            cfg.down_policy = policy;
            cfg.mode = mode;
            cfg.budget_safety = safety;
            assert!(warm.compatible(&cfg));
            let a = warm.run_with_eval(&cfg, 1).unwrap();
            let b = run_experiment(&cfg, Some(&art), 1).unwrap();
            assert_eq!(a.records, b.records, "deep warm diverged from cold");
            assert_eq!(a.total_time, b.total_time);
            assert_eq!(a.eval, b.eval, "eval must flow through the warm path too");
            assert!(a.eval.unwrap().loss.is_finite());
        }
        // A different preset is a different family (workload mismatch).
        let mut other = base.clone();
        other.workload =
            WorkloadSpec::DeepModel { preset: "small".into(), sigma: 0.3, t_comp: 0.5 };
        assert!(!warm.compatible(&other));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn family_netsim_shares_trace_handles_with_fresh_build_semantics() {
        // (a) The warm netsim holds Arc::ptr_eq clones of the family's
        // built traces — each trace is built once per family. (b) Its
        // transfers are bit-identical to a cold build_netsim's, even
        // for seeded (OU-noise) traces.
        let mut cfg = quad_cfg();
        cfg.uplink = TraceSpec::NoisySinSquared {
            eta: 3000.0,
            theta: 0.3,
            delta: 500.0,
            phase: 0.0,
            noise_sigma: 0.2,
            seed: 7,
            horizon: 500.0,
        };
        let warm = WarmFamily::prepare(&cfg, None).unwrap();
        let shared = warm.netsim(&cfg);
        let fresh = build_netsim(&cfg);
        for w in 0..cfg.m {
            assert!(Arc::ptr_eq(&shared.link(w).up, &warm.links()[w].0));
            assert!(Arc::ptr_eq(&shared.link(w).down, &warm.links()[w].1));
            // Two netsims assembled from the same family share handles.
            assert!(Arc::ptr_eq(&warm.netsim(&cfg).link(w).up, &shared.link(w).up));
            for (t0, bits) in [(0.0, 1e3), (3.7, 5e4), (41.2, 1.0)] {
                use crate::netsim::Direction;
                for dir in [Direction::Up, Direction::Down] {
                    assert_eq!(
                        shared.transfer(w, dir, t0, bits),
                        fresh.transfer(w, dir, t0, bits),
                        "worker {w} t0={t0} bits={bits}"
                    );
                }
            }
        }
    }

    #[test]
    fn x0_pool_recycles_without_changing_results() {
        // The second warm run checks its x0 buffer out of the family
        // pool (stocked by the first run's returned server model); the
        // refill must make it indistinguishable from a fresh build.
        let cfg = quad_cfg();
        let warm = WarmFamily::prepare(&cfg, None).unwrap();
        let a = warm.run(&cfg).unwrap();
        let b = warm.run(&cfg).unwrap();
        assert_eq!(a.records, b.records, "pooled x0 changed the run");
        assert_eq!(a.total_time, b.total_time);
        let cold = run_experiment(&cfg, None, 0).unwrap();
        assert_eq!(a.records, cold.records);
        assert!(a.build_ms >= 0.0 && cold.build_ms >= 0.0);
    }

    #[test]
    fn population_p1_full_cohorts_matches_dense_through_the_driver() {
        // The tentpole invariant at the driver layer: forcing the
        // population engine (cohorts = M) at p = 1 reproduces the dense
        // run record for record — same traces, same warm family
        // machinery, different engine.
        let dense = run_experiment(&quad_cfg(), None, 0).unwrap();
        let mut cfg = quad_cfg();
        cfg.cohorts = cfg.m; // population engine, dense link map
        assert!(cfg.is_population());
        let pop = run_experiment(&cfg, None, 0).unwrap();
        assert_eq!(dense.records, pop.records, "population p=1 diverged from dense");
        assert_eq!(dense.total_time, pop.total_time);
    }

    #[test]
    fn population_warm_family_matches_cold_and_guards_link_count() {
        let mut cfg = quad_cfg();
        cfg.m = 40;
        cfg.participation = 0.25;
        cfg.cohorts = 8;
        let warm = WarmFamily::prepare(&cfg, None).unwrap();
        assert_eq!(warm.links().len(), 8, "population families build cohort links");
        let a = warm.run(&cfg).unwrap();
        let b = run_experiment(&cfg, None, 0).unwrap();
        assert_eq!(a.records, b.records, "population warm diverged from cold");
        // Every round carries exactly the quorum, sampled from the
        // population.
        for r in &a.records {
            assert_eq!(r.workers.len(), 10);
            assert!(r.workers.iter().all(|w| w.worker < 40));
        }
        // Same M but a different link count is a different family.
        let mut dense_cfg = quad_cfg();
        dense_cfg.m = 40;
        assert!(!warm.compatible(&dense_cfg));
        // Population + non-sync mode is rejected, not silently run.
        let mut bad = cfg.clone();
        bad.mode = ExecModeSpec::Async { damping: 0.7 };
        assert!(run_experiment(&bad, None, 0).is_err());
    }

    #[test]
    fn large_population_runs_in_quorum_sized_state() {
        // A hundred-thousand-client population with a 10-client quorum
        // must build C links + q seats, never 1e5 of anything — this
        // test is fast precisely because the contract holds.
        let mut cfg = quad_cfg();
        cfg.m = 100_000;
        cfg.participation = 1e-4;
        cfg.rounds = 5;
        assert_eq!(cfg.quorum(), 10);
        assert_eq!(cfg.n_links(), 64, "auto cohorts");
        assert_eq!(build_netsim(&cfg).n_workers(), 64);
        let res = run_experiment(&cfg, None, 0).unwrap();
        assert_eq!(res.records.len(), 5);
        for r in &res.records {
            assert_eq!(r.workers.len(), 10);
            assert!(r.f_x.is_finite());
        }
    }

    #[test]
    fn shards_reach_the_engine_without_changing_results() {
        let base = run_experiment(&quad_cfg(), None, 0).unwrap();
        for shards in [1usize, 2, 3] {
            let mut cfg = quad_cfg();
            cfg.shards = shards;
            let res = run_experiment(&cfg, None, 0).unwrap();
            for (a, b) in base.records.iter().zip(&res.records) {
                assert_eq!(a, b, "shards={shards} changed the records");
            }
        }
    }
}
