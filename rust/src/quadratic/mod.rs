//! The paper's synthetic workload (§4.1): f(x) = ½ Σ a_i x_i², d = 30.
//!
//! Lower-bounded by 0, layer-smooth (Assumption 1 with L_i = max a over
//! the layer's coordinates) and globally smooth (L = max_i a_i), so it
//! sits exactly inside Theorem 1's assumptions — the reason the paper
//! uses it to fine-tune learning rates per compression ratio.

use crate::model::{Layer, ModelLayout};

/// f(x) = ½ Σ a_i x_i² with a_i > 0.
#[derive(Debug, Clone)]
pub struct Quadratic {
    pub a: Vec<f64>,
}

impl Quadratic {
    pub fn new(a: Vec<f64>) -> Self {
        assert!(a.iter().all(|&v| v > 0.0), "a_i must be positive");
        Self { a }
    }

    /// The paper's d=30 instance: a_i log-spaced over [1, 10] so layers
    /// have heterogeneous curvature (seeded, deterministic).
    pub fn paper_instance(d: usize) -> Self {
        let a = (0..d)
            .map(|i| 10f64.powf(i as f64 / (d.max(2) - 1) as f64))
            .collect();
        Self::new(a)
    }

    pub fn dim(&self) -> usize {
        self.a.len()
    }

    pub fn value(&self, x: &[f32]) -> f64 {
        0.5 * x
            .iter()
            .zip(&self.a)
            .map(|(&xi, &ai)| ai * (xi as f64) * (xi as f64))
            .sum::<f64>()
    }

    /// ∇f(x) = a ⊙ x, written into `out`.
    pub fn grad_into(&self, x: &[f32], out: &mut [f32]) {
        for ((o, &xi), &ai) in out.iter_mut().zip(x).zip(&self.a) {
            *o = (ai as f32) * xi;
        }
    }

    pub fn grad(&self, x: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0; self.dim()];
        self.grad_into(x, &mut g);
        g
    }

    pub fn grad_norm_sq(&self, x: &[f32]) -> f64 {
        x.iter()
            .zip(&self.a)
            .map(|(&xi, &ai)| (ai * xi as f64).powi(2))
            .sum()
    }

    /// Global smoothness constant L (Assumption 2).
    pub fn l_global(&self) -> f64 {
        self.a.iter().cloned().fold(0.0, f64::max)
    }

    /// Layer smoothness constants L_i (Assumption 1) for a layout.
    pub fn l_layers(&self, layers: &[Layer]) -> Vec<f64> {
        layers
            .iter()
            .map(|l| {
                self.a[l.offset..l.offset + l.size]
                    .iter()
                    .cloned()
                    .fold(0.0, f64::max)
            })
            .collect()
    }

    /// Split the d coordinates into `n_layers` roughly equal layers.
    pub fn layout(&self, n_layers: usize) -> ModelLayout {
        let d = self.dim();
        let n = n_layers.clamp(1, d);
        let base = d / n;
        let extra = d % n;
        let sizes: Vec<usize> = (0..n).map(|i| base + usize::from(i < extra)).collect();
        ModelLayout::synthetic(&sizes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_and_grad() {
        let q = Quadratic::new(vec![1.0, 2.0]);
        let x = [3.0f32, 1.0];
        assert!((q.value(&x) - (0.5 * 9.0 + 1.0)).abs() < 1e-9);
        assert_eq!(q.grad(&x), vec![3.0, 2.0]);
        assert!((q.grad_norm_sq(&x) - (9.0 + 4.0)).abs() < 1e-9);
    }

    #[test]
    fn paper_instance_properties() {
        let q = Quadratic::paper_instance(30);
        assert_eq!(q.dim(), 30);
        assert!((q.a[0] - 1.0).abs() < 1e-12);
        assert!((q.a[29] - 10.0).abs() < 1e-9);
        assert!((q.l_global() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn layer_constants() {
        let q = Quadratic::new(vec![1.0, 5.0, 2.0, 9.0]);
        let layout = q.layout(2);
        let layers = layout.layers();
        assert_eq!(layers.len(), 2);
        assert_eq!(q.l_layers(&layers), vec![5.0, 9.0]);
    }

    #[test]
    fn layout_uneven_split() {
        let q = Quadratic::paper_instance(30);
        let layout = q.layout(4);
        let sizes: Vec<usize> = layout.layers().iter().map(|l| l.size).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 30);
        assert_eq!(sizes, vec![8, 8, 7, 7]);
    }

    #[test]
    fn gd_converges_under_l_step() {
        let q = Quadratic::paper_instance(10);
        let mut x = vec![1.0f32; 10];
        let gamma = (1.0 / q.l_global()) as f32;
        for _ in 0..500 {
            let g = q.grad(&x);
            for (xi, gi) in x.iter_mut().zip(g) {
                *xi -= gamma * gi;
            }
        }
        assert!(q.value(&x) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive() {
        Quadratic::new(vec![1.0, 0.0]);
    }
}
