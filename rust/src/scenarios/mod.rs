//! Scenario-matrix engine: sweep {workload × bandwidth trace ×
//! compression policy × execution mode × worker count × budget safety
//! factor × participation fraction × server shard count} and execute
//! the cross-product in parallel, one JSON summary per cell.
//!
//! The worker axis scales to populations: a cell with `participation
//! < 1` (or an explicit `base.cohorts`) runs the population engine —
//! `m` is a *population* size, each round samples `quorum = ceil(p·m)`
//! clients, and per-cell state is O(quorum + cohorts), so
//! `worker_counts: [1000000]` is a normal axis value.
//!
//! This is how the repo evaluates "as many scenarios as you can
//! imagine" (ROADMAP) the way Accordion and the gradient-compression
//! utility study sweep regimes: a grid is declared (in code or as a
//! JSON file), expanded deterministically, and each cell runs a full
//! experiment on a work-stealing thread pool. Per-cell results are
//! bit-reproducible regardless of pool size.
//!
//! Two scaling mechanisms keep big grids honest:
//!
//! * **Cell families** — cells sharing {workload × uplink trace × M}
//!   reuse one [`WarmFamily`]: the `Arc`-shared bandwidth traces, the
//!   workload instance (the `Quadratic`, or the deep model's
//!   `ArtifactStore`/layout/initial params) and the trace-derived
//!   prior/`T_comp` are built once per family, not once per cell
//!   ([`plan_families`]). Warm and cold runs are bit-identical (the
//!   warm path *is* the cold path minus the rebuilds — asserted in
//!   tests).
//! * **Cooperative thread budget** — [`thread_budget`] splits the
//!   machine between the matrix pool and the cells
//!   (`workers × per-cell ≤ available_parallelism`), and every cell
//!   config is clamped to its slice
//!   (`ExperimentConfig::clamp_parallelism`) before it runs —
//!   replacing the old nested auto pools that could spawn N×N threads
//!   on an N-core box.
//!
//! Outputs land under an output directory as `<cell-id>.json` plus an
//! `index.json` manifest — the shape `reports/` consumes. The
//! directory doubles as a **content-addressed result cache**
//! ([`cache`], `--resume`): every cell file embeds its canonical
//! config and a hash key, per-cell files and `index.json` are written
//! incrementally and atomically after each completed cell, and
//! [`run_matrix_cached`] skips cells whose verified summary is already
//! on disk — so a 10⁴-cell grid is a growing database of results, not
//! a one-shot run.

pub mod cache;

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bandwidth::TraceSpec;
use crate::config::{
    compute_from_json, compute_to_json, policy_from_json, policy_to_json, workload_from_json,
    workload_to_json, ExecModeSpec, ExperimentConfig, OptimizerSpec, TransportSpec, WorkloadSpec,
};
use crate::coordinator::ComputeModel;
use crate::driver::{open_artifact_store, ExperimentResult, WarmFamily};
use crate::kimad::{BudgetParams, CompressPolicy};
use crate::runtime::ArtifactStore;
use crate::util::atomicfile::write_atomic;
use crate::util::json::Value;

pub use cache::{
    cell_cache_key, cell_path, probe_cell, CacheMode, IncrementalWriter, MissReason, Probe,
};

/// One named workload in the grid — the axis that mixes the §4.1
/// quadratic and deep-model presets in a single sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedWorkload {
    pub name: String,
    pub spec: WorkloadSpec,
}

impl NamedWorkload {
    /// Name the workload by its [`WorkloadSpec::short_name`].
    pub fn from_spec(spec: WorkloadSpec) -> Self {
        Self { name: spec.short_name(), spec }
    }
}

/// One named uplink bandwidth pattern in the grid.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedTrace {
    pub name: String,
    pub spec: TraceSpec,
}

/// One named `A^compress` policy in the grid.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedPolicy {
    pub name: String,
    pub policy: CompressPolicy,
}

/// One execution mode in the grid. Parameterized modes embed their
/// parameter in the name (`semisync0.75`, `async0.9`) so sweeps over
/// several participations/dampings expand to distinct cell ids.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NamedMode {
    pub spec: ExecModeSpec,
}

impl NamedMode {
    pub fn name(&self) -> String {
        match self.spec {
            ExecModeSpec::Sync => "sync".into(),
            ExecModeSpec::SemiSync { participation } => format!("semisync{participation}"),
            ExecModeSpec::Async { damping } => format!("async{damping}"),
        }
    }
}

/// Per-cell constants: the schedule and environment every cell shares
/// (the workload itself is an axis — see [`ScenarioGrid::workloads`]).
#[derive(Debug, Clone, PartialEq)]
pub struct GridBase {
    /// Per-direction communication-time budget (§4.2 convention).
    pub t_comm: f64,
    pub gamma: f64,
    pub rounds: u64,
    /// Downlink pattern (shared; the sweep varies the uplink).
    pub downlink: TraceSpec,
    pub warm_start: bool,
    /// Per-worker compute-time model shared by every cell (the
    /// straggler axis: profile/lognormal models make semi-sync and
    /// async cells diverge from lockstep).
    pub compute: ComputeModel,
    pub seed: u64,
    /// Artifact directory for deep-model workloads (`None` =
    /// `./artifacts` or `$KIMAD_ARTIFACTS`).
    pub artifacts: Option<String>,
    /// Cohort count for population cells (clients share links via
    /// `client % cohorts`): 0 = dense per-worker links at
    /// participation 1, auto (`min(m, 64)`) otherwise. A non-zero
    /// value forces the population engine even at participation 1.
    pub cohorts: usize,
    /// How cells execute: in-process (default) or over a real
    /// transport ([`crate::transport`]). Runtime-only — set from the
    /// CLI (`kimad scenarios --transport ...`), never serialized, so a
    /// grid's `index.json` is byte-identical however its cells ran.
    pub transport: TransportSpec,
}

/// The declarative scenario matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioGrid {
    pub name: String,
    pub base: GridBase,
    /// Workload axis: §4.1 quadratics and/or deep-model presets. Deep
    /// entries run against `base.artifacts` (PJRT when the backend is
    /// real, the native transformer otherwise).
    pub workloads: Vec<NamedWorkload>,
    pub traces: Vec<NamedTrace>,
    pub policies: Vec<NamedPolicy>,
    pub modes: Vec<NamedMode>,
    pub worker_counts: Vec<usize>,
    pub safety_factors: Vec<f64>,
    /// Per-round participation axis: 1.0 = dense (every worker, the
    /// classic engine); p < 1 samples `ceil(p·m)` clients per round on
    /// the population engine (Sync modes only). `[1.0]` = dense only.
    pub participations: Vec<f64>,
    /// Server-shard axis (`Simulation::shards`): sharding is
    /// bit-deterministic, so this axis exists to measure wall-clock
    /// scaling, not to change results. `[1]` = serialized only.
    pub shard_counts: Vec<usize>,
}

/// One expanded cell: a unique id plus the full experiment config.
#[derive(Debug, Clone)]
pub struct ScenarioCell {
    pub id: String,
    pub workload: String,
    pub trace: String,
    pub policy: String,
    pub mode: String,
    pub m: usize,
    pub safety: f64,
    /// Per-round participation fraction (1.0 = dense).
    pub participation: f64,
    pub shards: usize,
    pub cfg: ExperimentConfig,
}

/// What one executed cell produced.
#[derive(Debug, Clone, PartialEq)]
pub struct CellSummary {
    pub id: String,
    pub workload: String,
    pub trace: String,
    pub policy: String,
    pub mode: String,
    pub m: usize,
    pub safety: f64,
    /// Per-round participation fraction (1.0 = dense: every worker in
    /// every round).
    pub participation: f64,
    /// Sampled clients per round — `ceil(participation · m)`, = m for
    /// dense cells. The column that makes population rows comparable:
    /// per-round bits and losses are quorum-sized, not m-sized.
    pub quorum: usize,
    /// Server-shard knob the cell ran with (0 = auto).
    pub shards: usize,
    /// Transport the cell executed over (`"inproc"`, `"tcp"`, `"uds"`).
    /// Results are transport-invariant by the wire-bit-identity
    /// contract; the column records how this run actually moved bytes.
    pub transport: String,
    pub rounds: usize,
    /// Final objective f(x) at the server model (NaN for workloads
    /// without an objective notion — the deep model reports loss).
    pub final_f_x: f64,
    /// Final mean worker loss.
    pub final_loss: f64,
    /// Σ over rounds and workers of uplink bits.
    pub total_up_bits: u64,
    /// Σ over rounds of broadcast bits.
    pub total_down_bits: u64,
    /// Virtual seconds simulated.
    pub virtual_time_s: f64,
    pub mean_step_time_s: f64,
    /// Mean seconds from round start to upload arrival, over every
    /// (round, arrival) pair — the straggler-lag column.
    pub mean_arrival_lag_s: f64,
    /// Largest staleness any aggregated upload carried (0 in sync).
    pub max_staleness: u64,
    /// Wall-clock milliseconds of the cell's steady-state run — the
    /// rounds themselves, with construction attributed to `build_ms`.
    pub wall_ms: f64,
    /// Wall-clock milliseconds of per-cell construction (config
    /// clone/clamp plus workload-source and simulation build — the
    /// family warm-up a cold first cell pays). Kept out of `wall_ms`
    /// so e2e cells/sec is comparable warm vs cold.
    pub build_ms: f64,
}

impl ScenarioGrid {
    /// The built-in quick grid: 1 workload × 2 traces × 4 policies × 3
    /// execution modes × 2 worker counts (× 1 safety factor) over the
    /// §4.1 quadratic — the smallest sweep that exercises every
    /// `CompressPolicy` and every `ExecMode` under both a flat and an
    /// oscillating link. The compute profile makes the last of four
    /// workers a 4× straggler, so the semi-sync and async cells
    /// actually diverge from lockstep.
    pub fn default_grid() -> Self {
        let cb = 64.0; // bits per sparse coordinate
        Self {
            name: "quick".into(),
            base: GridBase {
                t_comm: 0.9,
                gamma: 0.03,
                rounds: 60,
                downlink: TraceSpec::Constant { bps: 1e7 },
                warm_start: true,
                compute: ComputeModel::Profile { factors: vec![1.0, 1.0, 1.0, 4.0] },
                seed: 21,
                artifacts: None,
                cohorts: 0,
                transport: TransportSpec::Inproc,
            },
            workloads: vec![NamedWorkload {
                name: "quad".into(),
                spec: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 },
            }],
            traces: vec![
                NamedTrace {
                    name: "flat".into(),
                    spec: TraceSpec::Constant { bps: 16.0 * cb },
                },
                NamedTrace {
                    name: "wave".into(),
                    spec: TraceSpec::SinSquared {
                        eta: 24.0 * cb,
                        theta: 0.1,
                        delta: 2.0 * cb,
                        phase: 0.0,
                    },
                },
            ],
            policies: vec![
                NamedPolicy {
                    name: "ef21-fixed25".into(),
                    policy: CompressPolicy::FixedRatio { ratio: 0.25 },
                },
                NamedPolicy {
                    name: "kimad".into(),
                    policy: CompressPolicy::KimadUniform,
                },
                NamedPolicy {
                    name: "kimad-plus".into(),
                    policy: CompressPolicy::KimadPlus { discretization: 400, ratios: vec![] },
                },
                NamedPolicy {
                    name: "whole-topk".into(),
                    policy: CompressPolicy::WholeModelTopK,
                },
            ],
            modes: vec![
                NamedMode { spec: ExecModeSpec::Sync },
                NamedMode { spec: ExecModeSpec::SemiSync { participation: 0.5 } },
                NamedMode { spec: ExecModeSpec::Async { damping: 0.5 } },
            ],
            worker_counts: vec![1, 4],
            safety_factors: vec![1.0],
            participations: vec![1.0],
            shard_counts: vec![1],
        }
    }

    /// Total number of cells in the cross-product.
    pub fn n_cells(&self) -> usize {
        self.workloads.len() * self.traces.len() * self.policies.len() * self.modes.len()
            * self.worker_counts.len() * self.safety_factors.len()
            * self.participations.len() * self.shard_counts.len()
    }

    /// Expand the cross-product in deterministic (workload-major,
    /// then trace-major) order.
    pub fn expand(&self) -> Vec<ScenarioCell> {
        let mut cells = Vec::with_capacity(self.n_cells());
        for wl in &self.workloads {
            for tr in &self.traces {
                for pol in &self.policies {
                    for mode in &self.modes {
                        for &m in &self.worker_counts {
                            for &safety in &self.safety_factors {
                                for &p in &self.participations {
                                    for &shards in &self.shard_counts {
                                        cells.push(
                                            self.cell(wl, tr, pol, mode, m, safety, p, shards),
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    #[allow(clippy::too_many_arguments)] // private expansion helper over the 8 axes
    fn cell(
        &self,
        wl: &NamedWorkload,
        tr: &NamedTrace,
        pol: &NamedPolicy,
        mode: &NamedMode,
        m: usize,
        safety: f64,
        participation: f64,
        shards: usize,
    ) -> ScenarioCell {
        // Dense cells (p = 1) keep their pre-population ids byte for
        // byte; only sampled cells grow a `_p` token.
        let ptok = if participation == 1.0 {
            String::new()
        } else {
            format!("_p{participation}")
        };
        let id = format!(
            "{}_{}_{}_{}_m{m}_s{safety}{ptok}_sh{shards}",
            wl.name,
            tr.name,
            pol.name,
            mode.name()
        );
        let cfg = ExperimentConfig {
            name: id.clone(),
            m,
            participation,
            cohorts: self.base.cohorts,
            workload: wl.spec.clone(),
            budget: BudgetParams::PerDirection { t_comm: self.base.t_comm },
            up_policy: pol.policy.clone(),
            down_policy: pol.policy.clone(),
            optimizer: OptimizerSpec { gamma: self.base.gamma, layer_weights: vec![] },
            uplink: tr.spec.clone(),
            downlink: self.base.downlink.clone(),
            alpha: 1.0,
            rounds: self.base.rounds,
            prior_bps: 0.0,
            warm_start: self.base.warm_start,
            single_layer: false,
            budget_safety: safety,
            // The grid level owns the parallelism; one thread per cell
            // keeps the pool honest. The shard axis is the deliberate
            // exception (results are shard-invariant); run_matrix
            // clamps it to the cooperative per-cell budget.
            threads: 1,
            shards,
            thread_cap: 0,
            mode: mode.spec,
            compute: self.base.compute.clone(),
            transport: self.base.transport,
            seed: self.base.seed,
        };
        ScenarioCell {
            id,
            workload: wl.name.clone(),
            trace: tr.name.clone(),
            policy: pol.name.clone(),
            mode: mode.name(),
            m,
            safety,
            participation,
            shards,
            cfg,
        }
    }

    /// Reject empty axes and duplicate cell ids before running.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.workloads.is_empty(), "grid '{}' has no workloads", self.name);
        anyhow::ensure!(!self.traces.is_empty(), "grid '{}' has no traces", self.name);
        anyhow::ensure!(!self.policies.is_empty(), "grid '{}' has no policies", self.name);
        anyhow::ensure!(!self.modes.is_empty(), "grid '{}' has no execution modes", self.name);
        anyhow::ensure!(
            !self.worker_counts.is_empty(),
            "grid '{}' has no worker counts",
            self.name
        );
        anyhow::ensure!(
            !self.safety_factors.is_empty(),
            "grid '{}' has no safety factors",
            self.name
        );
        anyhow::ensure!(
            !self.shard_counts.is_empty(),
            "grid '{}' has no shard counts",
            self.name
        );
        anyhow::ensure!(
            !self.participations.is_empty(),
            "grid '{}' has no participations",
            self.name
        );
        for &p in &self.participations {
            crate::config::check_pop_participation(p)
                .map_err(|e| anyhow::anyhow!("grid '{}': {e}", self.name))?;
        }
        // Population cells (sampled participation, or cohort-shared
        // links) run Sync rounds only — semisync/async already model
        // partial participation as a race outcome.
        if self.participations.iter().any(|&p| p < 1.0) || self.base.cohorts != 0 {
            anyhow::ensure!(
                self.modes.iter().all(|m| m.spec == ExecModeSpec::Sync),
                "grid '{}': population cells (participation < 1 or base.cohorts != 0) \
                 require all-Sync modes",
                self.name
            );
        }
        anyhow::ensure!(
            self.worker_counts.iter().all(|&m| m >= 1),
            "worker counts must be >= 1"
        );
        let mut ids: Vec<String> = self.expand().into_iter().map(|c| c.id).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        anyhow::ensure!(
            ids.len() == n,
            "grid '{}' expands to duplicate cell ids (axis names must be unique)",
            self.name
        );
        Ok(())
    }

    // -- JSON codec (grid files) ---------------------------------------

    pub fn to_json(&self) -> Value {
        let mut base_fields = vec![
            ("t_comm", Value::num(self.base.t_comm)),
            ("gamma", Value::num(self.base.gamma)),
            ("rounds", Value::num(self.base.rounds as f64)),
            ("downlink", self.base.downlink.to_json()),
            ("warm_start", Value::Bool(self.base.warm_start)),
            ("compute", compute_to_json(&self.base.compute)),
            ("seed", Value::num(self.base.seed as f64)),
        ];
        if let Some(dir) = &self.base.artifacts {
            base_fields.push(("artifacts", Value::str(dir.clone())));
        }
        // Dense grids serialize exactly as they did before the
        // population axis existed (and parse back identically).
        if self.base.cohorts != 0 {
            base_fields.push(("cohorts", Value::num(self.base.cohorts as f64)));
        }
        Value::obj(vec![
            ("name", Value::str(self.name.clone())),
            ("base", Value::obj(base_fields)),
            (
                "workloads",
                Value::Arr(
                    self.workloads
                        .iter()
                        .map(|w| {
                            Value::obj(vec![
                                ("name", Value::str(w.name.clone())),
                                ("spec", workload_to_json(&w.spec)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "modes",
                Value::Arr(self.modes.iter().map(|m| m.spec.to_json()).collect()),
            ),
            (
                "traces",
                Value::Arr(
                    self.traces
                        .iter()
                        .map(|t| {
                            Value::obj(vec![
                                ("name", Value::str(t.name.clone())),
                                ("spec", t.spec.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "policies",
                Value::Arr(
                    self.policies
                        .iter()
                        .map(|p| {
                            Value::obj(vec![
                                ("name", Value::str(p.name.clone())),
                                ("policy", policy_to_json(&p.policy)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "worker_counts",
                Value::Arr(
                    self.worker_counts
                        .iter()
                        .map(|&m| Value::num(m as f64))
                        .collect(),
                ),
            ),
            (
                "safety_factors",
                Value::Arr(
                    self.safety_factors
                        .iter()
                        .map(|&s| Value::num(s))
                        .collect(),
                ),
            ),
            (
                "participations",
                Value::Arr(
                    self.participations
                        .iter()
                        .map(|&p| Value::num(p))
                        .collect(),
                ),
            ),
            (
                "shard_counts",
                Value::Arr(
                    self.shard_counts
                        .iter()
                        .map(|&s| Value::num(s as f64))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let b = v.get("base")?;
        let base = GridBase {
            t_comm: b.get("t_comm")?.as_f64()?,
            gamma: b.get("gamma")?.as_f64()?,
            rounds: b.get("rounds")?.as_u64()?,
            downlink: TraceSpec::from_json(b.get("downlink")?)?,
            warm_start: b
                .opt("warm_start")
                .and_then(|x| x.as_bool().ok())
                .unwrap_or(true),
            compute: match b.opt("compute") {
                None => ComputeModel::Constant,
                Some(c) => compute_from_json(c)?,
            },
            seed: b.opt("seed").and_then(|x| x.as_u64().ok()).unwrap_or(21),
            artifacts: b
                .opt("artifacts")
                .and_then(|x| x.as_str().ok())
                .map(|s| s.to_string()),
            cohorts: b.opt("cohorts").and_then(|x| x.as_usize().ok()).unwrap_or(0),
            // Runtime-only (CLI `--transport`); grid files never carry it.
            transport: TransportSpec::Inproc,
        };
        // Grids predating the workload axis hardcoded the quadratic's
        // knobs in base: {d, n_layers, t_comp}.
        let workloads = match v.opt("workloads") {
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|w| {
                    Ok(NamedWorkload {
                        name: w.get("name")?.as_str()?.to_string(),
                        spec: workload_from_json(w.get("spec")?)?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![NamedWorkload {
                name: "quad".into(),
                spec: WorkloadSpec::Quadratic {
                    d: b.get("d")?.as_usize()?,
                    n_layers: b.get("n_layers")?.as_usize()?,
                    t_comp: b.get("t_comp")?.as_f64()?,
                },
            }],
        };
        // Grids predating the mode axis run lockstep.
        let modes = match v.opt("modes") {
            None => vec![NamedMode { spec: ExecModeSpec::Sync }],
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|m| Ok(NamedMode { spec: ExecModeSpec::from_json(m)? }))
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        let traces = v
            .get("traces")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(NamedTrace {
                    name: t.get("name")?.as_str()?.to_string(),
                    spec: TraceSpec::from_json(t.get("spec")?)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let policies = v
            .get("policies")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(NamedPolicy {
                    name: p.get("name")?.as_str()?.to_string(),
                    policy: policy_from_json(p.get("policy")?)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let worker_counts = v
            .get("worker_counts")?
            .as_arr()?
            .iter()
            .map(|m| m.as_usize())
            .collect::<anyhow::Result<Vec<_>>>()?;
        let safety_factors = v
            .get("safety_factors")?
            .as_arr()?
            .iter()
            .map(|s| s.as_f64())
            .collect::<anyhow::Result<Vec<_>>>()?;
        // Grids predating the participation axis run dense.
        let participations = match v.opt("participations") {
            None => vec![1.0],
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|p| p.as_f64())
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        // Grids predating the shard axis run the serialized server.
        let shard_counts = match v.opt("shard_counts") {
            None => vec![1],
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            base,
            workloads,
            traces,
            policies,
            modes,
            worker_counts,
            safety_factors,
            participations,
            shard_counts,
        })
    }

    pub fn from_json_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Value::parse(&text)?)
    }
}

impl CellSummary {
    pub fn to_json(&self) -> Value {
        // JSON has no NaN: workloads without an f(x) notion (the deep
        // model) serialize their objective column as null.
        let num_or_null = |n: f64| if n.is_finite() { Value::num(n) } else { Value::Null };
        Value::obj(vec![
            ("id", Value::str(self.id.clone())),
            ("workload", Value::str(self.workload.clone())),
            ("trace", Value::str(self.trace.clone())),
            ("policy", Value::str(self.policy.clone())),
            ("mode", Value::str(self.mode.clone())),
            ("m", Value::num(self.m as f64)),
            ("safety", Value::num(self.safety)),
            ("participation", Value::num(self.participation)),
            ("quorum", Value::num(self.quorum as f64)),
            ("shards", Value::num(self.shards as f64)),
            ("transport", Value::str(self.transport.clone())),
            ("rounds", Value::num(self.rounds as f64)),
            ("final_f_x", num_or_null(self.final_f_x)),
            ("final_loss", num_or_null(self.final_loss)),
            ("total_up_bits", Value::num(self.total_up_bits as f64)),
            ("total_down_bits", Value::num(self.total_down_bits as f64)),
            ("virtual_time_s", Value::num(self.virtual_time_s)),
            ("mean_step_time_s", Value::num(self.mean_step_time_s)),
            ("mean_arrival_lag_s", Value::num(self.mean_arrival_lag_s)),
            ("max_staleness", Value::num(self.max_staleness as f64)),
            ("wall_ms", Value::num(self.wall_ms)),
            ("build_ms", Value::num(self.build_ms)),
        ])
    }

    /// Inverse of [`CellSummary::to_json`] — how a cache hit
    /// ([`probe_cell`]) rehydrates a summary from disk. `null`
    /// objective columns parse back to NaN, so `to_json ∘ from_json`
    /// is the identity on the bytes (asserted in tests).
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let num_or_nan = |key: &str| -> anyhow::Result<f64> {
            match v.get(key)? {
                Value::Null => Ok(f64::NAN),
                other => other.as_f64(),
            }
        };
        Ok(Self {
            id: v.get("id")?.as_str()?.to_string(),
            workload: v.get("workload")?.as_str()?.to_string(),
            trace: v.get("trace")?.as_str()?.to_string(),
            policy: v.get("policy")?.as_str()?.to_string(),
            mode: v.get("mode")?.as_str()?.to_string(),
            m: v.get("m")?.as_usize()?,
            safety: v.get("safety")?.as_f64()?,
            participation: v.get("participation")?.as_f64()?,
            quorum: v.get("quorum")?.as_usize()?,
            shards: v.get("shards")?.as_usize()?,
            transport: v.get("transport")?.as_str()?.to_string(),
            rounds: v.get("rounds")?.as_usize()?,
            final_f_x: num_or_nan("final_f_x")?,
            final_loss: num_or_nan("final_loss")?,
            total_up_bits: v.get("total_up_bits")?.as_u64()?,
            total_down_bits: v.get("total_down_bits")?.as_u64()?,
            virtual_time_s: v.get("virtual_time_s")?.as_f64()?,
            mean_step_time_s: v.get("mean_step_time_s")?.as_f64()?,
            mean_arrival_lag_s: v.get("mean_arrival_lag_s")?.as_f64()?,
            max_staleness: v.get("max_staleness")?.as_u64()?,
            wall_ms: v.get("wall_ms")?.as_f64()?,
            build_ms: v.get("build_ms")?.as_f64()?,
        })
    }
}

/// Roll one executed cell's records up into its summary row.
fn summarize(
    cell: &ScenarioCell,
    res: &ExperimentResult,
    wall_ms: f64,
    build_ms: f64,
) -> anyhow::Result<CellSummary> {
    let last = res
        .records
        .last()
        .ok_or_else(|| anyhow::anyhow!("cell '{}' produced no rounds", cell.id))?;
    let total_up_bits: u64 = res.records.iter().map(|r| r.total_up_bits()).sum();
    let total_down_bits: u64 = res.records.iter().map(|r| r.down_bits).sum();
    let n_arrivals: usize = res.records.iter().map(|r| r.n_arrivals()).sum();
    let mean_arrival_lag_s = if n_arrivals == 0 {
        0.0
    } else {
        res.records
            .iter()
            .flat_map(|r| &r.workers)
            .map(|w| w.arrival_lag)
            // tidy:allow(float-reduce) -- serial fold in record order, deterministic
            .sum::<f64>()
            / n_arrivals as f64
    };
    let max_staleness = res.records.iter().map(|r| r.max_staleness()).max().unwrap_or(0);
    Ok(CellSummary {
        id: cell.id.clone(),
        workload: cell.workload.clone(),
        trace: cell.trace.clone(),
        policy: cell.policy.clone(),
        mode: cell.mode.clone(),
        m: cell.m,
        safety: cell.safety,
        participation: cell.participation,
        quorum: cell.cfg.quorum(),
        shards: cell.shards,
        transport: cell.cfg.transport.as_str().to_string(),
        rounds: res.records.len(),
        final_f_x: last.f_x,
        final_loss: last.loss,
        total_up_bits,
        total_down_bits,
        virtual_time_s: res.total_time,
        mean_step_time_s: res.mean_step_time(),
        mean_arrival_lag_s,
        max_staleness,
        wall_ms,
        build_ms,
    })
}

/// Execute one expanded cell to completion from its family's warm
/// state, under the cooperative per-cell thread budget.
fn run_cell(
    cell: &ScenarioCell,
    warm: &WarmFamily,
    cell_threads: usize,
) -> anyhow::Result<CellSummary> {
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now(); // tidy:allow(wall-clock) -- cell wall_ms metric only
    let mut cfg = cell.cfg.clone();
    cfg.clamp_parallelism(cell_threads);
    let pre_ms = t0.elapsed().as_secs_f64() * 1e3;
    let res = warm
        .run(&cfg)
        .map_err(|e| anyhow::anyhow!("cell '{}': {e}", cell.id))?;
    // `res.build_ms` is the in-run construction (source + simulation
    // build); together with the config clone/clamp above it is the
    // cell's build cost, kept out of the steady-state wall_ms.
    let build_ms = pre_ms + res.build_ms;
    let wall_ms = (t0.elapsed().as_secs_f64() * 1e3 - build_ms).max(0.0);
    summarize(cell, &res, wall_ms, build_ms)
}

/// Group `cells` into warm families keyed by {workload × uplink trace
/// × M} and prepare each family **once** — the traces are built once,
/// the deep-model artifacts opened once. Returns the families plus
/// each cell's family index, in cell order.
///
/// Public as the build-count probe the tests use: the number of
/// `WarmFamily` values *is* the number of trace/artifact builds the
/// matrix performs, and each family's [`WarmFamily::links`] handles
/// are the (`Arc::ptr_eq`-testable) allocations every member netsim
/// shares.
pub fn plan_families(
    cells: &[ScenarioCell],
    artifacts: Option<&str>,
) -> anyhow::Result<(Vec<WarmFamily>, Vec<usize>)> {
    // The link count joins the key: a population cell (C cohort links)
    // and a dense cell of the same M build different trace sets and
    // must not share a family.
    let mut keys: Vec<(&str, &str, usize, usize)> = Vec::new();
    let mut families: Vec<WarmFamily> = Vec::new();
    let mut cell_family = Vec::with_capacity(cells.len());
    // One ArtifactStore per artifacts directory, opened lazily and
    // handed to every deep family (its params cache then reads each
    // preset from disk once, however many families share the preset).
    let mut store: Option<Arc<ArtifactStore>> = None;
    for cell in cells {
        let key = (cell.workload.as_str(), cell.trace.as_str(), cell.m, cell.cfg.n_links());
        let fi = match keys.iter().position(|k| *k == key) {
            Some(i) => i,
            None => {
                keys.push(key);
                if store.is_none()
                    && matches!(cell.cfg.workload, WorkloadSpec::DeepModel { .. })
                {
                    store = Some(Arc::new(open_artifact_store(artifacts)?));
                }
                families.push(WarmFamily::prepare_with(&cell.cfg, artifacts, store.clone())?);
                keys.len() - 1
            }
        };
        cell_family.push(fi);
    }
    Ok((families, cell_family))
}

/// The cooperative thread budget: how many matrix workers to run and
/// how many simulation threads each cell may use, so that
/// `workers × per-cell ≤ available_parallelism` (the pre-PR-4 runner
/// let every cell's auto knobs grab all cores under a full worker
/// pool — up to N×N threads on an N-core box). A caller explicitly
/// oversubscribing the pool (`threads > cores`) gets serial cells.
pub fn thread_budget(n_cells: usize, threads: usize) -> (usize, usize) {
    let avail = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let workers = if threads == 0 { avail } else { threads }.clamp(1, n_cells.max(1));
    (workers, (avail / workers).max(1))
}

/// Run every cell of the grid on a pool of `threads` workers (0 =
/// available parallelism), returning summaries in expansion order.
/// Cells run with the cooperative per-cell budget from
/// [`thread_budget`]; use [`run_matrix_with`] to override it.
pub fn run_matrix(grid: &ScenarioGrid, threads: usize) -> anyhow::Result<Vec<CellSummary>> {
    run_matrix_with(grid, threads, 0)
}

/// [`run_matrix`] with an explicit per-cell thread budget
/// (`cell_threads`; 0 = the cooperative default
/// `available_parallelism / workers`). Raising it deliberately
/// oversubscribes — useful when sweeping the shard axis for wall-clock
/// scaling on an otherwise idle box.
///
/// Cells are grouped into *families* ([`plan_families`]): the
/// `Arc`-shared bandwidth traces, the workload instance (quadratic, or
/// the deep model's store/layout/params) and the trace-derived
/// prior/`T_comp` are built once per family ([`WarmFamily`]) and every
/// member cell starts from that warm state — bit-identical to a cold
/// build, since the warm path is the cold path minus the rebuilds.
pub fn run_matrix_with(
    grid: &ScenarioGrid,
    threads: usize,
    cell_threads: usize,
) -> anyhow::Result<Vec<CellSummary>> {
    Ok(run_matrix_cached(grid, threads, cell_threads, None, CacheMode::Fresh)?.summaries)
}

/// What one [`run_matrix_cached`] sweep did: the summaries (expansion
/// order, exactly as [`run_matrix_with`] returns them) plus the cache
/// ledger the CLI banner and table report.
#[derive(Debug)]
pub struct MatrixRun {
    pub summaries: Vec<CellSummary>,
    /// Per-cell hit flag, expansion order (`true` = reused from disk).
    pub hits: Vec<bool>,
    /// Cells reused from the cache (`hits.iter().filter(|h| **h)`).
    pub n_hits: usize,
    /// Cells actually executed this run.
    pub n_executed: usize,
    /// Probed entries that existed but could not be reused (pre-cache
    /// layout, stale config or engine version, corrupt JSON) — these
    /// re-ran and were overwritten, loudly counted rather than
    /// silently trusted.
    pub n_stale: usize,
    /// Warm families prepared — *miss* cells only, so a fully-cached
    /// family builds nothing (no traces, no artifact store).
    pub n_families: usize,
    /// Wall seconds for the whole sweep (probe + prep + cells).
    pub elapsed_s: f64,
}

/// [`run_matrix_with`], plus the content-addressed cell cache
/// ([`cache`]): when `out_dir` is set, every completed cell publishes
/// `<id>.json` (summary + cache envelope) and a refreshed `index.json`
/// — incrementally and atomically, so interruption never leaves a torn
/// manifest — and under [`CacheMode::Resume`] cells whose verified
/// summary already sits in `out_dir` are skipped entirely: no family
/// prep, no execution, just the stored [`CellSummary`].
///
/// Warm-family planning runs over the **miss** cells only: a grid that
/// hits everywhere builds zero families (and never opens a deep
/// workload's artifact store).
pub fn run_matrix_cached(
    grid: &ScenarioGrid,
    threads: usize,
    cell_threads: usize,
    out_dir: Option<&Path>,
    mode: CacheMode,
) -> anyhow::Result<MatrixRun> {
    #[allow(clippy::disallowed_methods)]
    // tidy:allow(wall-clock) -- cache banner elapsed metric only, never in results
    let t0 = Instant::now();
    grid.validate()?;
    let cells = grid.expand();
    let mut writer = match out_dir {
        Some(dir) => Some(IncrementalWriter::open(dir, grid, &cells)?),
        None => None,
    };

    // Probe phase (resume only): verified hits short-circuit to their
    // stored summaries and join the index immediately.
    let mut cached: Vec<Option<CellSummary>> = (0..cells.len()).map(|_| None).collect();
    let mut n_stale = 0usize;
    if mode == CacheMode::Resume {
        if let (Some(dir), Some(w)) = (out_dir, writer.as_mut()) {
            for (i, cell) in cells.iter().enumerate() {
                match probe_cell(dir, cell) {
                    Probe::Hit(s) => {
                        cached[i] = Some(*s);
                        w.mark_hit(i);
                    }
                    Probe::Miss(MissReason::Absent) => {}
                    Probe::Miss(_) => n_stale += 1,
                }
            }
            w.write_index()?;
        }
    }
    let n_hits = cached.iter().filter(|c| c.is_some()).count();

    // Family prep over the miss cells only, serial in expansion order
    // (deterministic and cheap relative to the sweep: one trace +
    // workload build per family instead of per cell).
    let miss: Vec<usize> = (0..cells.len()).filter(|&i| cached[i].is_none()).collect();
    let miss_cells: Vec<ScenarioCell> = miss.iter().map(|&i| cells[i].clone()).collect();
    let (families, cell_family) = plan_families(&miss_cells, grid.base.artifacts.as_deref())?;
    let n_families = families.len();
    let (n_threads, budget) = thread_budget(miss_cells.len(), threads);
    let per_cell = if cell_threads == 0 { budget } else { cell_threads };

    type CellSlot = Mutex<Option<anyhow::Result<CellSummary>>>;
    let next = AtomicUsize::new(0);
    let slots: Vec<CellSlot> = (0..miss_cells.len()).map(|_| Mutex::new(None)).collect();
    let families = &families;
    let cell_family = &cell_family;
    let writer = Mutex::new(writer);
    let miss_ref = &miss;
    let writer_ref = &writer;
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= miss_cells.len() {
                    break;
                }
                // Publish as soon as the cell completes (completion
                // order): the index converges to the same bytes
                // regardless, because membership is rewritten in
                // expansion order on every commit.
                let out = run_cell(&miss_cells[k], &families[cell_family[k]], per_cell)
                    .and_then(|summary| {
                        let mut w = writer_ref.lock().expect("writer poisoned");
                        if let Some(w) = w.as_mut() {
                            w.commit(miss_ref[k], &summary)?;
                        }
                        Ok(summary)
                    });
                *slots[k].lock().expect("cell slot poisoned") = Some(out);
            });
        }
    });
    let executed: Vec<CellSummary> = slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("cell slot poisoned")
                .expect("work queue covered every cell")
        })
        .collect::<anyhow::Result<_>>()?;

    // Re-interleave hits and executed cells into expansion order.
    let hits: Vec<bool> = cached.iter().map(|c| c.is_some()).collect();
    let mut executed_iter = executed.into_iter();
    let summaries: Vec<CellSummary> = cached
        .into_iter()
        .map(|c| match c {
            Some(s) => s,
            None => executed_iter.next().expect("one executed summary per miss"),
        })
        .collect();
    Ok(MatrixRun {
        hits,
        n_hits,
        n_executed: miss.len(),
        n_stale,
        n_families,
        elapsed_s: t0.elapsed().as_secs_f64(),
        summaries,
    })
}

/// The `index.json` manifest body: the grid spec (self-describing
/// results directories) plus the completed cell files in expansion
/// order. Shared by [`write_summaries`] and the incremental writer so
/// one-shot and resumed sweeps emit byte-identical manifests.
fn index_value(grid: &ScenarioGrid, files: &[String]) -> Value {
    Value::obj(vec![
        ("grid", grid.to_json()),
        ("n_cells", Value::num(files.len() as f64)),
        (
            "cells",
            Value::Arr(files.iter().map(|f| Value::str(f.clone())).collect()),
        ),
    ])
}

/// Write `<id>.json` per cell plus an `index.json` manifest (grid spec
/// included, so a results directory is self-describing). Every file is
/// published atomically (tmp + rename). Note the cells written here
/// carry no cache envelope — [`run_matrix_cached`] is the caching
/// writer; this helper serializes summaries the caller already holds.
pub fn write_summaries(
    out_dir: &Path,
    grid: &ScenarioGrid,
    summaries: &[CellSummary],
) -> anyhow::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    for s in summaries {
        let path = out_dir.join(format!("{}.json", sanitize(&s.id)));
        write_atomic(&path, s.to_json().to_string().as_bytes())?;
    }
    let files: Vec<String> = summaries
        .iter()
        .map(|s| format!("{}.json", sanitize(&s.id)))
        .collect();
    write_atomic(
        &out_dir.join("index.json"),
        index_value(grid, &files).to_string().as_bytes(),
    )?;
    Ok(())
}

/// Make a cell id filesystem-safe.
fn sanitize(id: &str) -> String {
    id.chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '-' })
        .collect()
}

/// Render a compact markdown table over the summaries (CLI output).
/// With `hits` (per-cell, expansion order — [`MatrixRun::hits`]) a
/// `cache` column distinguishes reused cells from executed ones.
pub fn render_table(summaries: &[CellSummary], hits: Option<&[bool]>) -> String {
    let mut out = String::from(
        "| cell | wl | rounds | final f(x) | up Mbit | step s | lag s | stale | pop | p | q \
         | sh | wall ms | build ms |",
    );
    out.push_str(if hits.is_some() { " cache |\n" } else { "\n" });
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    out.push_str(if hits.is_some() { "---|\n" } else { "\n" });
    for (i, s) in summaries.iter().enumerate() {
        out.push_str(&format!(
            "| {} | {} | {} | {:.3e} | {:.3} | {:.2} | {:.2} | {} | {} | {} | {} | {} \
             | {:.0} | {:.0} |",
            s.id,
            s.workload,
            s.rounds,
            s.final_f_x,
            s.total_up_bits as f64 / 1e6,
            s.mean_step_time_s,
            s.mean_arrival_lag_s,
            s.max_staleness,
            s.m,
            s.participation,
            s.quorum,
            s.shards,
            s.wall_ms,
            s.build_ms,
        ));
        match hits {
            Some(h) if h.get(i).copied().unwrap_or(false) => out.push_str(" hit |\n"),
            Some(_) => out.push_str(" run |\n"),
            None => out.push('\n'),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::runtime::write_native_artifacts;

    fn tiny_grid() -> ScenarioGrid {
        let mut g = ScenarioGrid::default_grid();
        g.base.rounds = 12;
        g.policies.truncate(2);
        g.worker_counts = vec![1, 2];
        g
    }

    /// A quad + deep-tiny grid over a generated native artifact set.
    /// Callers remove `dir` when done.
    fn mixed_grid(tag: &str) -> (ScenarioGrid, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("kimad-mixed-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_native_artifacts(&dir, &["tiny".to_string()], 21).unwrap();
        let mut g = tiny_grid();
        g.base.rounds = 4;
        g.base.artifacts = Some(dir.to_str().unwrap().to_string());
        g.policies.truncate(1);
        g.modes.truncate(2); // sync + semisync
        g.worker_counts = vec![2];
        g.workloads.push(NamedWorkload {
            name: "deep-tiny".into(),
            spec: WorkloadSpec::DeepModel { preset: "tiny".into(), sigma: 0.3, t_comp: 0.5 },
        });
        (g, dir)
    }

    #[test]
    fn expansion_is_full_cross_product() {
        let g = ScenarioGrid::default_grid();
        assert_eq!(g.n_cells(), 2 * 4 * 3 * 2, "default workload and shard axes are singletons");
        let cells = g.expand();
        assert_eq!(cells.len(), g.n_cells());
        let mut ids: Vec<_> = cells.iter().map(|c| c.id.clone()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), cells.len(), "ids must be unique");
        g.validate().unwrap();
        // Cell ids lead with the workload column.
        assert!(cells.iter().all(|c| c.id.starts_with("quad_")));
        assert!(cells.iter().all(|c| c.workload == "quad"));
        // Every execution mode appears in the expansion (parameterized
        // modes carry their parameter in the name: semisync0.5).
        for mode in ["sync", "semisync", "async"] {
            assert!(
                cells.iter().any(|c| c.mode.starts_with(mode)),
                "missing {mode} cells"
            );
        }
    }

    #[test]
    fn workload_axis_expands_and_groups_families() {
        let (g, dir) = mixed_grid("families");
        g.validate().unwrap();
        // 2 workloads x 2 traces x 1 policy x 2 modes x 1 m.
        assert_eq!(g.n_cells(), 8);
        let cells = g.expand();
        assert!(cells.iter().any(|c| c.id.starts_with("quad_")));
        assert!(cells.iter().any(|c| c.id.starts_with("deep-tiny_")));
        // Families group by {workload x trace x M}: 2 x 2 x 1 = 4
        // preparations for 8 cells — each family's traces and (deep)
        // artifacts are built exactly once.
        let (families, cell_family) = plan_families(&cells, g.base.artifacts.as_deref()).unwrap();
        assert_eq!(families.len(), 4);
        assert_eq!(cell_family.len(), cells.len());
        for (cell, &fi) in cells.iter().zip(cell_family.iter()) {
            assert!(families[fi].compatible(&cell.cfg), "{}", cell.id);
            // Same key => same family index; different key => different.
            for (other, &fj) in cells.iter().zip(cell_family.iter()) {
                let same_key = cell.workload == other.workload
                    && cell.trace == other.trace
                    && cell.m == other.m;
                assert_eq!(same_key, fi == fj, "{} vs {}", cell.id, other.id);
            }
        }
        // Member netsims share the family's Arc trace handles.
        for (cell, &fi) in cells.iter().zip(cell_family.iter()) {
            let net = families[fi].netsim(&cell.cfg);
            for w in 0..cell.m {
                assert!(Arc::ptr_eq(&net.link(w).up, &families[fi].links()[w].0));
                assert!(Arc::ptr_eq(&net.link(w).down, &families[fi].links()[w].1));
            }
        }
        // All deep families share ONE opened ArtifactStore (whose
        // params cache reads each preset from disk once).
        let deep: Vec<_> =
            families.iter().filter_map(|f| f.artifact_store()).collect();
        assert_eq!(deep.len(), 2, "two deep families (one per trace)");
        assert!(Arc::ptr_eq(deep[0], deep[1]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_quad_deep_matrix_runs_warm_equals_cold() {
        // The acceptance invariant: a mixed quad+deep grid runs through
        // the family path and reproduces the per-cell cold path
        // (run_experiment) bit for bit, deterministically across pool
        // sizes, deep cells included.
        let (g, dir) = mixed_grid("run");
        let warm = run_matrix(&g, 4).unwrap();
        let serial = run_matrix(&g, 1).unwrap();
        assert_eq!(warm.len(), g.n_cells());
        let art = g.base.artifacts.as_deref();
        for (w, cell) in warm.iter().zip(g.expand()) {
            assert_eq!(w.id, cell.id);
            let res = crate::driver::run_experiment(&cell.cfg, art, 0).unwrap();
            let mut cold = summarize(&cell, &res, 0.0, 0.0).unwrap();
            let mut w_cmp = w.clone();
            w_cmp.wall_ms = 0.0;
            w_cmp.build_ms = 0.0;
            cold.build_ms = 0.0;
            // Deep cells carry f_x = NaN (no objective notion), and
            // NaN != NaN under PartialEq — normalize when BOTH sides
            // agree it is NaN so the whole-struct compare still bites.
            if w_cmp.final_f_x.is_nan() && cold.final_f_x.is_nan() {
                w_cmp.final_f_x = 0.0;
                cold.final_f_x = 0.0;
            }
            assert_eq!(w_cmp, cold, "warm summary diverged from cold for {}", w.id);
        }
        for (a, b) in warm.iter().zip(&serial) {
            assert_eq!(a.final_loss, b.final_loss, "{}", a.id);
            assert_eq!(a.total_up_bits, b.total_up_bits, "{}", a.id);
        }
        // Deep cells actually trained (finite loss, bits on the wire).
        for s in warm.iter().filter(|s| s.workload == "deep-tiny") {
            assert!(s.final_loss.is_finite(), "{}", s.id);
            assert!(s.total_up_bits > 0, "{}", s.id);
            assert!(s.final_f_x.is_nan(), "deep model has no f(x) notion: {}", s.id);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn grid_json_roundtrip() {
        let g = ScenarioGrid::default_grid();
        let text = g.to_json().to_string();
        let back = ScenarioGrid::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, g);
        // The workload axis and artifacts dir round-trip too.
        let (g, dir) = mixed_grid("json");
        let back = ScenarioGrid::from_json(&Value::parse(&g.to_json().to_string()).unwrap());
        assert_eq!(back.unwrap(), g);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validate_rejects_degenerate_grids() {
        let mut g = ScenarioGrid::default_grid();
        g.policies.clear();
        assert!(g.validate().is_err());
        let mut g = ScenarioGrid::default_grid();
        g.worker_counts = vec![0];
        assert!(g.validate().is_err());
        let mut g = ScenarioGrid::default_grid();
        g.traces[1].name = g.traces[0].name.clone();
        assert!(g.validate().is_err());
        let mut g = ScenarioGrid::default_grid();
        g.modes.clear();
        assert!(g.validate().is_err());
        let mut g = ScenarioGrid::default_grid();
        g.shard_counts.clear();
        assert!(g.validate().is_err());
        let mut g = ScenarioGrid::default_grid();
        g.workloads.clear();
        assert!(g.validate().is_err());
        // Two workloads with the same name collide on cell ids.
        let mut g = ScenarioGrid::default_grid();
        g.workloads.push(g.workloads[0].clone());
        assert!(g.validate().is_err());
        // Two modes with the same name collide on cell ids.
        let mut g = ScenarioGrid::default_grid();
        g.modes = vec![
            NamedMode { spec: ExecModeSpec::Async { damping: 0.5 } },
            NamedMode { spec: ExecModeSpec::Async { damping: 0.5 } },
        ];
        assert!(g.validate().is_err());
    }

    #[test]
    fn parameterized_mode_variants_coexist() {
        // The point of the parameterized tokens: sweeping several
        // participations/dampings in one grid expands to distinct ids.
        let mut g = ScenarioGrid::default_grid();
        g.modes = vec![
            NamedMode { spec: ExecModeSpec::SemiSync { participation: 0.25 } },
            NamedMode { spec: ExecModeSpec::SemiSync { participation: 0.75 } },
            NamedMode { spec: ExecModeSpec::Async { damping: 0.5 } },
            NamedMode { spec: ExecModeSpec::Async { damping: 0.9 } },
        ];
        g.validate().unwrap();
        let names: Vec<_> = g.modes.iter().map(|m| m.name()).collect();
        assert_eq!(names, ["semisync0.25", "semisync0.75", "async0.5", "async0.9"]);
    }

    #[test]
    fn grids_without_workload_or_mode_axes_parse_as_before() {
        // Backward compatibility: grid files written before the
        // workload, mode and shard axes still parse — base carried the
        // quadratic knobs {d, n_layers, t_comp} directly, cells ran
        // lockstep with uniform compute on the serialized server.
        let mut v = ScenarioGrid::default_grid().to_json();
        if let Value::Obj(fields) = &mut v {
            fields.remove("workloads");
            fields.remove("modes");
            fields.remove("shard_counts");
            if let Some(Value::Obj(bf)) = fields.get_mut("base") {
                bf.remove("compute");
                bf.insert("d".into(), Value::num(30.0));
                bf.insert("n_layers".into(), Value::num(3.0));
                bf.insert("t_comp".into(), Value::num(0.1));
            }
        }
        let g = ScenarioGrid::from_json(&v).unwrap();
        assert_eq!(
            g.workloads,
            vec![NamedWorkload {
                name: "quad".into(),
                spec: WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 },
            }]
        );
        assert_eq!(g.modes, vec![NamedMode { spec: ExecModeSpec::Sync }]);
        assert_eq!(g.base.compute, ComputeModel::Constant);
        assert_eq!(g.shard_counts, vec![1]);
        assert_eq!(g.base.artifacts, None);
    }

    #[test]
    fn shard_axis_expands_and_never_changes_results() {
        let mut g = tiny_grid();
        g.base.rounds = 10;
        g.policies.truncate(1);
        g.modes.truncate(2); // sync + semisync
        g.worker_counts = vec![2];
        g.shard_counts = vec![1, 3];
        g.validate().unwrap();
        // 1 workload x 2 traces x 1 policy x 2 modes x 1 m x 2 shards.
        assert_eq!(g.n_cells(), 8);
        let cells = g.expand();
        assert!(cells.iter().any(|c| c.id.ends_with("_sh1")));
        assert!(cells.iter().any(|c| c.id.ends_with("_sh3")));
        let summaries = run_matrix(&g, 2).unwrap();
        // Pair up sh1/sh3 cells: identical ids modulo the suffix must
        // produce identical results — the shard axis only measures
        // wall-clock, never bits.
        for s1 in summaries.iter().filter(|s| s.shards == 1) {
            let base_id = s1.id.trim_end_matches("_sh1");
            let s3 = summaries
                .iter()
                .find(|s| s.shards == 3 && s.id.trim_end_matches("_sh3") == base_id)
                .expect("matching sh3 cell");
            assert_eq!(s1.final_f_x, s3.final_f_x, "{base_id}");
            assert_eq!(s1.total_up_bits, s3.total_up_bits, "{base_id}");
            assert_eq!(s1.virtual_time_s, s3.virtual_time_s, "{base_id}");
        }
    }

    #[test]
    fn warm_reuse_matches_cold_build_byte_identical() {
        // The family path must be indistinguishable from running every
        // cell cold through run_experiment — including the bytes of
        // index.json (wall_ms lives only in per-cell files, which is
        // why the summaries are compared field-wise instead).
        let g = tiny_grid();
        let warm = run_matrix(&g, 2).unwrap();
        let cold: Vec<CellSummary> = g
            .expand()
            .iter()
            .map(|cell| {
                // The pre-family cold path: a fresh build per cell.
                let res = crate::driver::run_experiment(&cell.cfg, None, 0).unwrap();
                summarize(cell, &res, 0.0, 0.0).unwrap()
            })
            .collect();
        assert_eq!(warm.len(), cold.len());
        for (w, c) in warm.iter().zip(&cold) {
            // Every field except the wall-clock timing columns must be
            // bit-identical (CellSummary is PartialEq, so zeroing the
            // timing fields compares the whole struct at once).
            let mut w_cmp = w.clone();
            let mut c_cmp = c.clone();
            w_cmp.wall_ms = 0.0;
            w_cmp.build_ms = 0.0;
            c_cmp.build_ms = 0.0;
            assert_eq!(w_cmp, c_cmp, "warm summary diverged from cold for {}", w.id);
        }
        let dir_w = std::env::temp_dir().join(format!("kimad-warm-{}", std::process::id()));
        let dir_c = std::env::temp_dir().join(format!("kimad-cold-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir_w);
        let _ = std::fs::remove_dir_all(&dir_c);
        write_summaries(&dir_w, &g, &warm).unwrap();
        write_summaries(&dir_c, &g, &cold).unwrap();
        let a = std::fs::read(dir_w.join("index.json")).unwrap();
        let b = std::fs::read(dir_c.join("index.json")).unwrap();
        assert_eq!(a, b, "warm index.json must be byte-identical to cold");
        let _ = std::fs::remove_dir_all(&dir_w);
        let _ = std::fs::remove_dir_all(&dir_c);
    }

    #[test]
    fn thread_budget_never_oversubscribes() {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        for threads in [0usize, 1, 2, avail, avail + 3] {
            for n_cells in [1usize, 5, 100] {
                let (workers, per_cell) = thread_budget(n_cells, threads);
                assert!(workers >= 1 && per_cell >= 1);
                assert!(workers <= n_cells.max(1));
                // The rule: never more than the machine — unless the
                // caller explicitly oversubscribed the pool itself, in
                // which case cells run serial (per_cell == 1).
                if workers <= avail {
                    assert!(
                        workers * per_cell <= avail,
                        "threads={threads} n_cells={n_cells}: {workers}x{per_cell} > {avail}"
                    );
                } else {
                    assert_eq!(per_cell, 1);
                }
            }
        }
    }

    #[test]
    fn cell_configs_are_clamped_to_the_budget() {
        // Regression (PR-4 headline bugfix): a grid sweeping auto or
        // huge shard counts must not hand cells unbounded parallelism —
        // every cfg entering the simulation is clamped to the per-cell
        // budget.
        let mut g = tiny_grid();
        g.shard_counts = vec![0, 64];
        g.validate().unwrap();
        let (workers, per_cell) = thread_budget(g.n_cells(), 0);
        for cell in g.expand() {
            let mut cfg = cell.cfg.clone();
            cfg.clamp_parallelism(per_cell);
            assert!(cfg.threads <= per_cell, "{}", cell.id);
            assert!(cfg.shards <= per_cell, "{}: explicit shards clamped", cell.id);
            assert_eq!(cfg.thread_cap, per_cell, "{}: auto knobs capped", cell.id);
        }
        // And the grid still runs correctly under the clamp (the shard
        // axis stays bit-invariant).
        g.base.rounds = 6;
        g.policies.truncate(1);
        g.modes.truncate(1);
        g.worker_counts = vec![2];
        let summaries = run_matrix(&g, workers).unwrap();
        let s0 = summaries.iter().find(|s| s.shards == 0).unwrap();
        let s64 = summaries.iter().find(|s| s.shards == 64).unwrap();
        assert_eq!(s0.final_f_x, s64.final_f_x);
        assert_eq!(s0.total_up_bits, s64.total_up_bits);
    }

    #[test]
    fn matrix_runs_and_is_deterministic_across_pool_sizes() {
        let g = tiny_grid();
        let serial = run_matrix(&g, 1).unwrap();
        let parallel = run_matrix(&g, 4).unwrap();
        assert_eq!(serial.len(), g.n_cells());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.id, b.id, "expansion order must be stable");
            assert_eq!(a.final_f_x, b.final_f_x, "{}", a.id);
            assert_eq!(a.total_up_bits, b.total_up_bits, "{}", a.id);
            assert_eq!(a.rounds, b.rounds, "{}", a.id);
        }
        // Cells actually trained: the quadratic objective dropped.
        for s in &serial {
            assert!(s.final_f_x.is_finite(), "{}", s.id);
            assert!(s.virtual_time_s > 0.0, "{}", s.id);
        }
    }

    #[test]
    fn summaries_written_one_per_cell() {
        let dir = std::env::temp_dir().join(format!("kimad-scen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let g = tiny_grid();
        let summaries = run_matrix(&g, 0).unwrap();
        write_summaries(&dir, &g, &summaries).unwrap();
        for s in &summaries {
            let p = dir.join(format!("{}.json", sanitize(&s.id)));
            let v = Value::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
            assert_eq!(v.get("id").unwrap().as_str().unwrap(), s.id);
            assert_eq!(v.get("workload").unwrap().as_str().unwrap(), s.workload);
            assert!(v.get("final_f_x").unwrap().as_f64().unwrap().is_finite());
        }
        let idx =
            Value::parse(&std::fs::read_to_string(dir.join("index.json")).unwrap()).unwrap();
        assert_eq!(
            idx.get("n_cells").unwrap().as_usize().unwrap(),
            summaries.len()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn participation_axis_expands_with_stable_dense_ids() {
        let mut g = tiny_grid();
        g.modes.truncate(1); // population cells are Sync-only
        g.participations = vec![1.0, 0.5];
        g.validate().unwrap();
        // 1 workload x 2 traces x 2 policies x 1 mode x 2 m x 2 p.
        assert_eq!(g.n_cells(), 16);
        let cells = g.expand();
        // Dense cells keep their pre-population ids byte for byte;
        // sampled cells carry the `_p` token before the shard suffix.
        let dense: Vec<_> = cells.iter().filter(|c| c.participation == 1.0).collect();
        let sampled: Vec<_> = cells.iter().filter(|c| c.participation == 0.5).collect();
        assert_eq!(dense.len(), 8);
        assert_eq!(sampled.len(), 8);
        assert!(dense.iter().all(|c| !c.id.contains("_p") && c.id.ends_with("_sh1")));
        assert!(sampled.iter().all(|c| c.id.contains("_p0.5_sh1")));
        assert!(dense.iter().all(|c| !c.cfg.is_population()));
        assert!(sampled.iter().all(|c| c.cfg.is_population()));
        // Validation: participation range and the Sync-only rule.
        let mut bad = g.clone();
        bad.participations = vec![0.0];
        assert!(bad.validate().is_err());
        let mut bad = g.clone();
        bad.participations = vec![1.5];
        assert!(bad.validate().is_err());
        let mut bad = g.clone();
        bad.modes = ScenarioGrid::default_grid().modes; // sync+semisync+async
        assert!(bad.validate().is_err(), "population x non-sync modes must be rejected");
        // Cohorts alone (participation 1.0) also forces the Sync rule.
        let mut bad = tiny_grid();
        bad.base.cohorts = 2;
        assert!(bad.validate().is_err());
        bad.modes.truncate(1);
        bad.validate().unwrap();
    }

    #[test]
    fn population_cells_run_warm_equals_cold_with_quorum_columns() {
        let mut g = tiny_grid();
        g.base.rounds = 8;
        g.policies.truncate(1);
        g.modes.truncate(1); // sync
        // M = 100: population cells auto-resolve to 64 cohort links,
        // dense cells keep 100 per-worker links — distinct families.
        g.worker_counts = vec![100];
        g.participations = vec![1.0, 0.25];
        let summaries = run_matrix(&g, 2).unwrap();
        assert_eq!(summaries.len(), g.n_cells());
        for (s, cell) in summaries.iter().zip(g.expand()) {
            // Quorum column: ceil(p * m).
            let expect_q = (s.participation * s.m as f64).ceil() as usize;
            assert_eq!(s.quorum, expect_q, "{}", s.id);
            // Warm family path == cold per-cell path, population cells
            // included.
            let res = crate::driver::run_experiment(&cell.cfg, None, 0).unwrap();
            let cold = summarize(&cell, &res, 0.0, 0.0).unwrap();
            let mut w = s.clone();
            w.wall_ms = 0.0;
            w.build_ms = 0.0;
            assert_eq!(w, cold, "warm diverged from cold for {}", s.id);
        }
        // Population cells group into their own families (cohort links
        // != dense links), dense cells into theirs.
        let cells = g.expand();
        let (families, cell_family) = plan_families(&cells, None).unwrap();
        assert_eq!(families.len(), 4, "2 traces x {{dense, population}}");
        for (cell, &fi) in cells.iter().zip(cell_family.iter()) {
            assert_eq!(families[fi].links().len(), cell.cfg.n_links(), "{}", cell.id);
        }
        // The summary JSON carries the population columns, and the
        // transport column records how the cells ran (inproc here).
        let v = summaries[0].to_json();
        assert!(v.get("participation").is_ok() && v.get("quorum").is_ok());
        assert_eq!(v.get("transport").unwrap().as_str().unwrap(), "inproc");
    }

    #[test]
    fn population_grid_json_roundtrips_and_old_grids_parse_dense() {
        let mut g = tiny_grid();
        g.modes.truncate(1);
        g.participations = vec![1.0, 0.01];
        g.base.cohorts = 16;
        let back = ScenarioGrid::from_json(&Value::parse(&g.to_json().to_string()).unwrap());
        assert_eq!(back.unwrap(), g);
        // A grid JSON written before the participation axis parses as
        // dense p = 1 with per-worker links.
        let mut v = ScenarioGrid::default_grid().to_json();
        if let Value::Obj(fields) = &mut v {
            fields.remove("participations");
        }
        let g = ScenarioGrid::from_json(&v).unwrap();
        assert_eq!(g.participations, vec![1.0]);
        assert_eq!(g.base.cohorts, 0);
        assert_eq!(g, ScenarioGrid::default_grid());
    }

    #[test]
    fn sanitize_keeps_ids_safe() {
        assert_eq!(sanitize("quad_wave_kimad_m4_s0.8"), "quad_wave_kimad_m4_s0.8");
        assert_eq!(sanitize("a/b c"), "a-b-c");
    }

    /// A 4-cell grid (2 traces x 2 policies) — the cheapest sweep the
    /// cache tests can interrupt, resume, and tamper with.
    fn cache_grid() -> ScenarioGrid {
        let mut g = tiny_grid();
        g.base.rounds = 6;
        g.modes.truncate(1);
        g.worker_counts = vec![2];
        g
    }

    #[test]
    fn cache_keys_are_stable_unique_and_transport_invariant() {
        let g = cache_grid();
        let cells = g.expand();
        let keys: Vec<String> = cells.iter().map(|c| cell_cache_key(&c.cfg)).collect();
        for k in &keys {
            assert_eq!(k.len(), 64, "SHA-256 hex");
            assert!(k.chars().all(|c| c.is_ascii_hexdigit()), "{k}");
        }
        for i in 0..keys.len() {
            for j in i + 1..keys.len() {
                assert_ne!(keys[i], keys[j], "{} vs {}", cells[i].id, cells[j].id);
            }
        }
        // The transport never reaches the key: results are
        // transport-invariant, so a wired run resumes an inproc cache.
        let mut wired = cells[0].cfg.clone();
        wired.transport = TransportSpec::Tcp;
        assert_eq!(cell_cache_key(&wired), keys[0]);
        // Anything that changes the experiment changes the key; the
        // key itself is a pure function of the config.
        let mut more = cells[0].cfg.clone();
        more.rounds += 1;
        assert_ne!(cell_cache_key(&more), keys[0]);
        assert_eq!(cell_cache_key(&cells[0].cfg), keys[0]);
    }

    #[test]
    fn cell_summary_json_roundtrips_including_nan_objective() {
        let g = cache_grid();
        let run = run_matrix_cached(&g, 1, 1, None, CacheMode::Fresh).unwrap();
        assert_eq!(run.n_hits, 0);
        assert_eq!(run.n_executed, g.n_cells());
        for s in &run.summaries {
            let back = CellSummary::from_json(&s.to_json()).unwrap();
            assert_eq!(&back, s, "{}", s.id);
        }
        // The deep model's objective columns serialize as null and
        // parse back to NaN; to_json ∘ from_json is the identity on
        // the bytes either way.
        let mut s = run.summaries[0].clone();
        s.final_f_x = f64::NAN;
        s.final_loss = f64::NAN;
        let v = s.to_json();
        let back = CellSummary::from_json(&v).unwrap();
        assert!(back.final_f_x.is_nan() && back.final_loss.is_nan());
        assert_eq!(back.to_json().to_string(), v.to_string());
    }

    #[test]
    fn resume_skips_every_cell_and_reuses_index_bytes() {
        let dir = std::env::temp_dir().join(format!("kimad-cache-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let g = cache_grid();
        let cold = run_matrix_cached(&g, 2, 1, Some(&dir), CacheMode::Fresh).unwrap();
        assert_eq!((cold.n_hits, cold.n_executed), (0, g.n_cells()));
        let index = std::fs::read(dir.join("index.json")).unwrap();
        let cell0 = cell_path(&dir, &cold.summaries[0].id);
        let cell0_bytes = std::fs::read(&cell0).unwrap();
        let warm = run_matrix_cached(&g, 2, 1, Some(&dir), CacheMode::Resume).unwrap();
        assert_eq!((warm.n_hits, warm.n_executed), (g.n_cells(), 0));
        assert_eq!(warm.n_families, 0, "a full-hit sweep builds no families");
        assert!(warm.hits.iter().all(|&h| h));
        assert_eq!(std::fs::read(dir.join("index.json")).unwrap(), index);
        assert_eq!(std::fs::read(&cell0).unwrap(), cell0_bytes, "hits never rewrite files");
        // A hit *is* the summary the fresh run produced — timings
        // included, because they come from the stored file.
        for (a, b) in cold.summaries.iter().zip(&warm.summaries) {
            assert_eq!(a, b, "{}", a.id);
        }
        // Fresh mode ignores the populated cache and re-executes.
        let fresh = run_matrix_cached(&g, 2, 1, Some(&dir), CacheMode::Fresh).unwrap();
        assert_eq!((fresh.n_hits, fresh.n_executed), (0, g.n_cells()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interrupted_run_resumes_and_index_matches_one_shot() {
        let pid = std::process::id();
        let one = std::env::temp_dir().join(format!("kimad-cache-oneshot-{pid}"));
        let cut = std::env::temp_dir().join(format!("kimad-cache-interrupted-{pid}"));
        let _ = std::fs::remove_dir_all(&one);
        let _ = std::fs::remove_dir_all(&cut);
        let g = cache_grid();
        let n = g.n_cells();
        let k = 2;
        let full = run_matrix_cached(&g, 1, 1, Some(&one), CacheMode::Fresh).unwrap();
        // Simulate an interrupted sweep: commit only the first k cells,
        // then drop the writer mid-run — the in-process stand-in for a
        // killed process, since every commit already hit disk
        // atomically before the drop.
        {
            let cells = g.expand();
            let mut w = IncrementalWriter::open(&cut, &g, &cells).unwrap();
            for i in 0..k {
                w.commit(i, &full.summaries[i]).unwrap();
            }
        }
        let idx =
            Value::parse(&std::fs::read_to_string(cut.join("index.json")).unwrap()).unwrap();
        assert_eq!(idx.get("n_cells").unwrap().as_usize().unwrap(), k, "torn run: k cells");
        let resumed = run_matrix_cached(&g, 2, 1, Some(&cut), CacheMode::Resume).unwrap();
        assert_eq!(resumed.n_hits, k, "exactly the committed cells hit");
        assert_eq!(resumed.n_executed, n - k, "exactly the missing cells executed");
        assert_eq!(
            std::fs::read(cut.join("index.json")).unwrap(),
            std::fs::read(one.join("index.json")).unwrap(),
            "resumed index must be byte-identical to the one-shot index"
        );
        for (a, b) in full.summaries.iter().zip(&resumed.summaries) {
            let mut b = b.clone();
            b.wall_ms = a.wall_ms;
            b.build_ms = a.build_ms;
            assert_eq!(*a, b, "{}", a.id);
        }
        let _ = std::fs::remove_dir_all(&one);
        let _ = std::fs::remove_dir_all(&cut);
    }

    #[test]
    fn probe_distinguishes_absent_precache_stale_and_corrupt() {
        let dir = std::env::temp_dir().join(format!("kimad-cache-probe-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let g = cache_grid();
        let cells = g.expand();
        let cell = &cells[0];
        assert!(matches!(probe_cell(&dir, cell), Probe::Miss(MissReason::Absent)));
        // Pre-cache layout: a summary without the cache envelope.
        let run = run_matrix_cached(&g, 1, 1, None, CacheMode::Fresh).unwrap();
        write_summaries(&dir, &g, &run.summaries).unwrap();
        assert!(matches!(probe_cell(&dir, cell), Probe::Miss(MissReason::PreCache)));
        // A committed envelope verifies and hits.
        let mut w = IncrementalWriter::open(&dir, &g, &cells).unwrap();
        w.commit(0, &run.summaries[0]).unwrap();
        match probe_cell(&dir, cell) {
            Probe::Hit(s) => assert_eq!(s.id, cell.id),
            other => panic!("expected hit, got {other:?}"),
        }
        // Same id, different experiment (rounds changed): stale.
        let mut g2 = g.clone();
        g2.base.rounds += 1;
        let cells2 = g2.expand();
        assert_eq!(cells2[0].id, cell.id, "rounds are not part of the id");
        assert!(matches!(probe_cell(&dir, &cells2[0]), Probe::Miss(MissReason::Stale)));
        // Tampering with the stored config breaks the stored key's
        // integrity re-hash: corrupt, not silently trusted.
        let p = cell_path(&dir, &cell.id);
        let tampered =
            std::fs::read_to_string(&p).unwrap().replace("\"rounds\":6", "\"rounds\":7");
        std::fs::write(&p, &tampered).unwrap();
        assert!(matches!(probe_cell(&dir, cell), Probe::Miss(MissReason::Corrupt)));
        // Unparseable JSON: corrupt.
        std::fs::write(&p, "{not json").unwrap();
        assert!(matches!(probe_cell(&dir, cell), Probe::Miss(MissReason::Corrupt)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incremental_writer_and_write_summaries_agree_on_index_bytes() {
        let pid = std::process::id();
        let a = std::env::temp_dir().join(format!("kimad-cache-idx-a-{pid}"));
        let b = std::env::temp_dir().join(format!("kimad-cache-idx-b-{pid}"));
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
        let g = cache_grid();
        let run = run_matrix_cached(&g, 2, 1, None, CacheMode::Fresh).unwrap();
        write_summaries(&a, &g, &run.summaries).unwrap();
        let cells = g.expand();
        let mut w = IncrementalWriter::open(&b, &g, &cells).unwrap();
        // Commit in reverse completion order: index membership is
        // rewritten in expansion order regardless.
        for i in (0..cells.len()).rev() {
            w.commit(i, &run.summaries[i]).unwrap();
        }
        assert_eq!(
            std::fs::read(a.join("index.json")).unwrap(),
            std::fs::read(b.join("index.json")).unwrap()
        );
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
