//! Content-addressed cell cache (docs/ARCHITECTURE.md §11): the layer
//! that turns a scenario out_dir from a one-shot dump into a growing,
//! resumable database of results.
//!
//! Every cell file carries a *cache envelope* next to its summary: the
//! cell's canonical config ([`ExperimentConfig::canonical_json`] — the
//! exact experiment JSON, transport stripped, keys sorted) plus a hex
//! key hashing that config together with the engine fingerprint
//! ([`crate::driver::engine_fingerprint`]: engine results contract,
//! wire frame codec version, compressor panel). What is **never**
//! hashed: `wall_ms`/`build_ms` (timings), the transport (results are
//! transport-invariant), pool layout, or axis ordering — the key
//! addresses *what experiment ran*, nothing about how fast or where.
//!
//! The determinism contract the whole repo enforces — bit-identical
//! summaries across thread pools, shard counts, warm/cold families and
//! transports — is exactly what makes hash-equality a sound cache key:
//! a verified hit *is* the summary a fresh run would produce, minus
//! the wall clock.
//!
//! A probe re-hashes the **stored** canonical config before trusting
//! an entry, so a corrupt, hand-edited, pre-cache or version-drifted
//! file re-runs loudly ([`MissReason`]) instead of poisoning results.

use std::path::{Path, PathBuf};

use crate::config::ExperimentConfig;
use crate::driver::engine_fingerprint;
use crate::scenarios::{sanitize, CellSummary, ScenarioCell, ScenarioGrid};
use crate::util::atomicfile::write_atomic;
use crate::util::hash::sha256_hex;
use crate::util::json::Value;

/// Bump when the cell-file cache envelope changes shape (not when the
/// engine changes — that is [`crate::driver::ENGINE_VERSION`]'s job).
pub const CACHE_SCHEMA_VERSION: u32 = 1;

/// Should the matrix reuse on-disk summaries?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Ignore existing entries; execute and overwrite every cell.
    Fresh,
    /// Probe `out_dir` first and skip verified hits (`--resume`).
    Resume,
}

/// The stable hex key a cell's results are addressed by: SHA-256 over
/// the engine fingerprint plus the cell's canonical config bytes.
pub fn cell_cache_key(cfg: &ExperimentConfig) -> String {
    key_for_canonical(&cfg.canonical_json())
}

fn key_for_canonical(canon: &str) -> String {
    let payload =
        format!("kimad-cell-cache-v{CACHE_SCHEMA_VERSION};{}\n{canon}", engine_fingerprint());
    sha256_hex(payload.as_bytes())
}

/// Where a cell's summary lives: the filename stays the human-readable
/// sanitized id (what `reports/` and the CI smokes list); content
/// addressing lives *inside* the file as the `cache_key`/`config`
/// envelope, verified on every probe.
pub fn cell_path(out_dir: &Path, id: &str) -> PathBuf {
    out_dir.join(format!("{}.json", sanitize(id)))
}

/// Why a probe did not produce a reusable summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissReason {
    /// No file under the cell's id.
    Absent,
    /// A pre-cache summary (no envelope) — written before this layer
    /// existed; re-run and upgrade in place.
    PreCache,
    /// Envelope present but the stored key does not re-hash from the
    /// stored config, or the summary body does not parse: the entry is
    /// damaged or hand-edited.
    Corrupt,
    /// A valid entry for a *different* experiment or engine version
    /// (config drift under an unchanged id, or a fingerprint bump).
    Stale,
}

/// Outcome of probing `out_dir` for one cell.
#[derive(Debug, Clone)]
pub enum Probe {
    /// A verified summary, reused without executing the cell.
    Hit(Box<CellSummary>),
    Miss(MissReason),
}

/// Probe `out_dir` for `cell`'s summary. Trust requires all of:
/// the file parses, its envelope is present, the stored canonical
/// config re-hashes to the stored key (integrity), that key equals the
/// key of the config the cell wants to run (identity — this is where
/// stale entries and engine-version drift land), and the summary body
/// round-trips with the cell's id.
pub fn probe_cell(out_dir: &Path, cell: &ScenarioCell) -> Probe {
    let path = cell_path(out_dir, &cell.id);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => return Probe::Miss(MissReason::Absent),
    };
    let v = match Value::parse(&text) {
        Ok(v) => v,
        Err(_) => return Probe::Miss(MissReason::Corrupt),
    };
    let (stored_key, stored_cfg) = match (v.opt("cache_key"), v.opt("config")) {
        (Some(k), Some(c)) => match k.as_str() {
            Ok(k) => (k.to_string(), c),
            Err(_) => return Probe::Miss(MissReason::Corrupt),
        },
        _ => return Probe::Miss(MissReason::PreCache),
    };
    // Integrity: the stored envelope must re-hash from its own bytes.
    if key_for_canonical(&stored_cfg.to_string()) != stored_key {
        return Probe::Miss(MissReason::Corrupt);
    }
    // Identity: the entry must address the experiment this cell runs
    // under the *current* engine fingerprint.
    if stored_key != cell_cache_key(&cell.cfg) {
        return Probe::Miss(MissReason::Stale);
    }
    match CellSummary::from_json(&v) {
        Ok(s) if s.id == cell.id => Probe::Hit(Box::new(s)),
        _ => Probe::Miss(MissReason::Corrupt),
    }
}

/// Incremental, atomic matrix writer: one `<id>.json` per completed
/// cell (summary + cache envelope) and a refreshed `index.json` after
/// every commit, each published via tmp + rename
/// ([`crate::util::atomicfile`]). An interrupted sweep therefore
/// leaves a valid directory whose index lists exactly the cells that
/// completed — the state `--resume` picks up from. Dropping the writer
/// mid-run loses nothing already committed (the resume-semantics test
/// does exactly that).
pub struct IncrementalWriter {
    out_dir: PathBuf,
    grid: ScenarioGrid,
    /// Per cell, expansion order: target filename, cache key, and the
    /// canonical config bytes the key hashes.
    files: Vec<String>,
    keys: Vec<String>,
    canons: Vec<String>,
    done: Vec<bool>,
}

impl IncrementalWriter {
    pub fn open(
        out_dir: &Path,
        grid: &ScenarioGrid,
        cells: &[ScenarioCell],
    ) -> anyhow::Result<Self> {
        std::fs::create_dir_all(out_dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", out_dir.display()))?;
        let canons: Vec<String> = cells.iter().map(|c| c.cfg.canonical_json()).collect();
        Ok(Self {
            out_dir: out_dir.to_path_buf(),
            grid: grid.clone(),
            files: cells.iter().map(|c| format!("{}.json", sanitize(&c.id))).collect(),
            keys: canons.iter().map(|c| key_for_canonical(c)).collect(),
            canons,
            done: vec![false; cells.len()],
        })
    }

    /// Record cell `i` as already on disk (a verified cache hit): the
    /// existing file is kept byte for byte; only index membership
    /// changes.
    pub fn mark_hit(&mut self, i: usize) {
        self.done[i] = true;
    }

    /// Publish cell `i`'s summary (with its cache envelope) and
    /// refresh `index.json`, both atomically.
    pub fn commit(&mut self, i: usize, s: &CellSummary) -> anyhow::Result<()> {
        let mut v = s.to_json();
        if let Value::Obj(fields) = &mut v {
            fields.insert("cache_key".into(), Value::str(self.keys[i].clone()));
            fields.insert("config".into(), Value::parse(&self.canons[i])?);
        }
        write_atomic(&self.out_dir.join(&self.files[i]), v.to_string().as_bytes())?;
        self.done[i] = true;
        self.write_index()
    }

    /// Rewrite `index.json` over the cells completed so far, in
    /// expansion order — so the final index of an interrupted-then-
    /// resumed sweep is byte-identical to an uninterrupted one.
    pub fn write_index(&self) -> anyhow::Result<()> {
        let files: Vec<String> = self
            .files
            .iter()
            .zip(&self.done)
            .filter(|(_, &d)| d)
            .map(|(f, _)| f.clone())
            .collect();
        let index = super::index_value(&self.grid, &files);
        write_atomic(&self.out_dir.join("index.json"), index.to_string().as_bytes())
    }
}
