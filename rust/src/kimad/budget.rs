//! Eq. (2): the compression budget.
//!
//! With time budget `t` for a full round, computation time `T_comp` and
//! current bandwidth estimate `B`, the bits a single direction may put
//! on the wire are
//!
//! `c = B · (t − T_comp) / 2`                                   (2)
//!
//! (the ½ splits the remaining time between uplink and downlink). §4.2
//! also uses the single-direction form `c = T_comm · B` when the user
//! budgets communication time per direction explicitly — both are
//! provided.

/// How the per-round time budget is specified.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BudgetParams {
    /// Paper Eq. (2): `t` covers down + compute + up; the non-compute
    /// remainder is split between the two directions.
    RoundBudget { t: f64, t_comp: f64 },
    /// §4.2 convention: a fixed communication-time budget per direction
    /// (`T_comm`), so `c = T_comm · B`.
    PerDirection { t_comm: f64 },
}

impl BudgetParams {
    /// Time available to ONE direction of communication.
    pub fn direction_seconds(&self) -> f64 {
        match *self {
            BudgetParams::RoundBudget { t, t_comp } => ((t - t_comp) / 2.0).max(0.0),
            BudgetParams::PerDirection { t_comm } => t_comm.max(0.0),
        }
    }
}

/// Eq. (2): budget in bits for one direction given bandwidth estimate
/// `b_bps`. Returns 0 when the time budget is already exhausted by
/// computation (the compressor will then send the cheapest message it
/// can — Kimad never sends *nothing*, see `select.rs`).
pub fn compression_budget(params: BudgetParams, b_bps: f64) -> u64 {
    let secs = params.direction_seconds();
    if secs <= 0.0 || b_bps <= 0.0 {
        return 0;
    }
    (b_bps * secs).floor() as u64
}

/// [`compression_budget`] scaled by the DC2-style safety factor (see
/// `SimConfig::budget_safety`): the one shared form of the
/// `budget × safety` rounding, hoisted here so the uplink leg, the
/// shared broadcast and the per-worker broadcast can never drift apart.
///
/// The product is computed in f64 (safety is a ratio, not bits) and
/// cast back with explicit saturation: `safety > 1` can push the
/// product past `u64::MAX`, and a NaN or non-positive product clamps
/// to 0 — the same values the `as u64` float cast produces, spelled
/// out so the edge cases are visible and unit-tested.
pub fn effective_budget(params: BudgetParams, b_bps: f64, safety: f64) -> u64 {
    let scaled = compression_budget(params, b_bps) as f64 * safety;
    if scaled.is_nan() || scaled <= 0.0 {
        0
    } else if scaled >= u64::MAX as f64 {
        u64::MAX
    } else {
        scaled as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_halves_remaining_time() {
        let p = BudgetParams::RoundBudget { t: 1.0, t_comp: 0.5 };
        // c = B (t - T_comp)/2 = 100 * 0.25
        assert_eq!(compression_budget(p, 100.0), 25);
    }

    #[test]
    fn per_direction_is_t_comm_times_b() {
        let p = BudgetParams::PerDirection { t_comm: 1.0 };
        assert_eq!(compression_budget(p, 330e6), 330_000_000);
    }

    #[test]
    fn exhausted_budget_is_zero() {
        let p = BudgetParams::RoundBudget { t: 0.4, t_comp: 0.5 };
        assert_eq!(compression_budget(p, 1e9), 0);
        assert_eq!(
            compression_budget(BudgetParams::PerDirection { t_comm: 1.0 }, 0.0),
            0
        );
    }

    #[test]
    fn budget_scales_linearly_with_bandwidth() {
        let p = BudgetParams::PerDirection { t_comm: 0.5 };
        assert_eq!(
            compression_budget(p, 200.0),
            2 * compression_budget(p, 100.0)
        );
    }

    #[test]
    fn effective_budget_applies_safety() {
        let p = BudgetParams::PerDirection { t_comm: 1.0 };
        // safety = 1 is the identity on the raw budget.
        assert_eq!(effective_budget(p, 1000.0, 1.0), 1000);
        // Conservative factors truncate downward, never round up.
        assert_eq!(effective_budget(p, 1000.0, 0.8), 800);
        assert_eq!(effective_budget(p, 999.0, 0.5), 499);
        // safety > 1 scales up (an aggressive operator choice).
        assert_eq!(effective_budget(p, 1000.0, 1.5), 1500);
    }

    #[test]
    fn effective_budget_zero_safety_is_zero() {
        let p = BudgetParams::PerDirection { t_comm: 1.0 };
        assert_eq!(effective_budget(p, 1e9, 0.0), 0);
        assert_eq!(effective_budget(p, 1e9, -0.5), 0);
        assert_eq!(effective_budget(p, 1e9, f64::NAN), 0);
    }

    #[test]
    fn effective_budget_saturates_near_u64_max() {
        // A budget near u64::MAX times safety > 1 must clamp instead of
        // wrapping. b_bps = 2^63 over one second floors to 2^63 bits.
        let p = BudgetParams::PerDirection { t_comm: 1.0 };
        let huge = (1u64 << 63) as f64;
        assert_eq!(effective_budget(p, huge, 4.0), u64::MAX);
        // And at safety = 1 the huge budget survives unscaled.
        assert_eq!(effective_budget(p, huge, 1.0), 1u64 << 63);
    }
}
