//! Eq. (2): the compression budget.
//!
//! With time budget `t` for a full round, computation time `T_comp` and
//! current bandwidth estimate `B`, the bits a single direction may put
//! on the wire are
//!
//! `c = B · (t − T_comp) / 2`                                   (2)
//!
//! (the ½ splits the remaining time between uplink and downlink). §4.2
//! also uses the single-direction form `c = T_comm · B` when the user
//! budgets communication time per direction explicitly — both are
//! provided.

/// How the per-round time budget is specified.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BudgetParams {
    /// Paper Eq. (2): `t` covers down + compute + up; the non-compute
    /// remainder is split between the two directions.
    RoundBudget { t: f64, t_comp: f64 },
    /// §4.2 convention: a fixed communication-time budget per direction
    /// (`T_comm`), so `c = T_comm · B`.
    PerDirection { t_comm: f64 },
}

impl BudgetParams {
    /// Time available to ONE direction of communication.
    pub fn direction_seconds(&self) -> f64 {
        match *self {
            BudgetParams::RoundBudget { t, t_comp } => ((t - t_comp) / 2.0).max(0.0),
            BudgetParams::PerDirection { t_comm } => t_comm.max(0.0),
        }
    }
}

/// Eq. (2): budget in bits for one direction given bandwidth estimate
/// `b_bps`. Returns 0 when the time budget is already exhausted by
/// computation (the compressor will then send the cheapest message it
/// can — Kimad never sends *nothing*, see `select.rs`).
pub fn compression_budget(params: BudgetParams, b_bps: f64) -> u64 {
    let secs = params.direction_seconds();
    if secs <= 0.0 || b_bps <= 0.0 {
        return 0;
    }
    (b_bps * secs).floor() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_halves_remaining_time() {
        let p = BudgetParams::RoundBudget { t: 1.0, t_comp: 0.5 };
        // c = B (t - T_comp)/2 = 100 * 0.25
        assert_eq!(compression_budget(p, 100.0), 25);
    }

    #[test]
    fn per_direction_is_t_comm_times_b() {
        let p = BudgetParams::PerDirection { t_comm: 1.0 };
        assert_eq!(compression_budget(p, 330e6), 330_000_000);
    }

    #[test]
    fn exhausted_budget_is_zero() {
        let p = BudgetParams::RoundBudget { t: 0.4, t_comp: 0.5 };
        assert_eq!(compression_budget(p, 1e9), 0);
        assert_eq!(
            compression_budget(BudgetParams::PerDirection { t_comm: 1.0 }, 0.0),
            0
        );
    }

    #[test]
    fn budget_scales_linearly_with_bandwidth() {
        let p = BudgetParams::PerDirection { t_comm: 0.5 };
        assert_eq!(
            compression_budget(p, 200.0),
            2 * compression_budget(p, 100.0)
        );
    }
}
