//! The paper's contribution: bandwidth-adaptive compression (Kimad,
//! §3.1), layer-adaptive budget allocation (Kimad+, §3.2), and the
//! compressor-selection algorithm `A^compress` of Algorithm 3.
//!
//! * [`budget`] — Eq. (2): a time budget times a bandwidth estimate is
//!   a bit budget, `c = t_comm · b̂`.
//! * [`select`] — `A^compress` (Algorithm 3 lines 4/11): bit budget →
//!   per-layer TopK sizes, under four policies.
//! * [`error_curve`] — ε_i(k), the squared error of keeping the k
//!   largest-|u| coordinates of layer i (the knapsack's value table).
//! * [`knapsack`] — Algorithm 4's DP: minimize Σ ε_i(k_i) subject to
//!   Σ k_i·bits ≤ c.
//!
//! # Example: budget-aware selection
//!
//! With a steep first layer and a flat second one, the Kimad+ knapsack
//! pours the whole budget into the layer where the error curve falls
//! fastest:
//!
//! ```
//! use kimad::kimad::{CompressPolicy, Selector};
//! use kimad::model::ModelLayout;
//!
//! let layers = ModelLayout::synthetic(&[4, 4]).layers();
//! let diff = [8.0f32, 7.0, 6.0, 5.0, 0.4, 0.3, 0.2, 0.1];
//! let budget_bits = 4 * 64; // room for 4 sparse coordinates
//! let policy = CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] };
//! let sel = Selector::new(policy).select(&diff, &layers, budget_bits);
//! assert_eq!(sel.k_per_layer, vec![4, 0]); // all 4 coords to layer 0
//! assert!(sel.planned_bits <= budget_bits);
//! ```

pub mod budget;
pub mod error_curve;
pub mod knapsack;
pub mod select;

pub use budget::{compression_budget, effective_budget, BudgetParams};
pub use error_curve::ErrorCurve;
pub use knapsack::{allocate, Allocation, KnapsackParams};
pub use select::{CompressPolicy, SelectScratch, Selection, Selector};
