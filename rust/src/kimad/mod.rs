//! The paper's contribution: bandwidth-adaptive compression (Kimad,
//! §3.1), layer-adaptive budget allocation (Kimad+, §3.2), and the
//! compressor-selection algorithm `A^compress` of Algorithm 3.

pub mod budget;
pub mod error_curve;
pub mod knapsack;
pub mod select;

pub use budget::{compression_budget, BudgetParams};
pub use error_curve::ErrorCurve;
pub use knapsack::{allocate, Allocation, KnapsackParams};
pub use select::{CompressPolicy, Selection, Selector};
