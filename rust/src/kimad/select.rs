//! `A^compress` (Algorithm 3 lines 4/11): choose compressors from Ω
//! given the vector to compress, the layer structure, and the budget.
//!
//! Four policies cover the paper's methods and baselines:
//!
//! * `FixedRatio` — the EF21 baseline (§4.2): the same TopK ratio for
//!   every layer and every round, bandwidth-oblivious.
//! * `KimadUniform` — Kimad (§3.1): the budget from Eq. (2) spread at a
//!   uniform ratio across layers ("per-layer basis, in accordance with
//!   common practice").
//! * `KimadPlus` — Kimad+ (§3.2): the knapsack DP allocates the same
//!   budget non-uniformly to minimize total error.
//! * `WholeModelTopK` — the Fig. 9 "optimal" baseline: select K with
//!   whole-model information (one global TopK over the concatenated
//!   vector), which is the error-optimal allocation for sparsification.

use crate::compress::{TopK, F32_BITS, IDX_BITS};
use crate::kimad::ErrorCurve;
use crate::kimad::knapsack::{allocate, topk_options, KnapsackParams};
use crate::model::Layer;

/// Bits per kept coordinate for sparse TopK payloads.
pub const SPARSE_COORD_BITS: u64 = IDX_BITS + F32_BITS;

#[derive(Debug, Clone, PartialEq)]
pub enum CompressPolicy {
    /// Same ratio everywhere, every round (EF21 fixed baseline).
    FixedRatio { ratio: f64 },
    /// Kimad: budget-derived uniform ratio.
    KimadUniform,
    /// Kimad+: knapsack DP over a ratio grid.
    KimadPlus {
        discretization: usize,
        /// Candidate ratios; empty = the paper's grid {0.01 + 0.02k}.
        ratios: Vec<f64>,
    },
    /// Whole-model-information TopK (Fig. 9 optimal baseline).
    WholeModelTopK,
}

/// The outcome of one `A^compress` call: per-layer TopK sizes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Selection {
    pub k_per_layer: Vec<usize>,
    pub planned_bits: u64,
}

impl Selection {
    pub fn compressors(&self) -> Vec<TopK> {
        self.k_per_layer.iter().map(|&k| TopK::new(k)).collect()
    }

    /// Predicted squared error from precomputed curves (no compression
    /// performed) — used by Fig. 9 without a second pass.
    pub fn predicted_error(&self, curves: &[ErrorCurve]) -> f64 {
        self.k_per_layer
            .iter()
            .zip(curves)
            .map(|(&k, c)| c.at(k))
            // tidy:allow(float-reduce) -- serial fold in layer order, deterministic
            .sum()
    }
}

/// Reusable state for [`Selector::select_into`] — the allocation-free
/// form the broadcast hot path runs every round. One scratch per
/// selection site; the buffers warm up on the first call.
#[derive(Debug, Clone, Default)]
pub struct SelectScratch {
    /// Layer indices sorted by size descending (the `KimadUniform`
    /// remainder-distribution order; ties broken by index, matching a
    /// stable sort over the original order).
    order: Vec<usize>,
    /// Whole-model TopK index buffer.
    idx: Vec<u32>,
    /// Whole-model TopK packed-key quickselect scratch, reused across
    /// rounds (the per-instance twin of the compressor's thread-local).
    packed: Vec<u64>,
    /// Per-layer error curves (`KimadPlus`). Only consumed by the next
    /// `select_into` when [`set_curves_ready`](Self::set_curves_ready)
    /// was called after an external fill — see [`curves_mut`](Self::curves_mut).
    curves: Vec<ErrorCurve>,
    curves_ready: bool,
}

impl SelectScratch {
    /// Size the curve slots to `n_layers` and hand them out for an
    /// external — possibly sharded — fill. The caller must store layer
    /// `i`'s curve (built over exactly `diff[layers[i]]`) in slot `i`
    /// and then call [`set_curves_ready`](Self::set_curves_ready); the
    /// next [`Selector::select_into`] then skips its own serial build.
    /// Curves are pure per-layer functions of `diff`, so an external
    /// fill is bit-identical to the internal one.
    pub fn curves_mut(&mut self, n_layers: usize) -> &mut [ErrorCurve] {
        self.curves.resize_with(n_layers, || ErrorCurve { err: Vec::new() });
        &mut self.curves
    }

    /// Mark the curve slots as freshly built for the next
    /// `select_into` call (consumed — one call, one selection).
    pub fn set_curves_ready(&mut self) {
        self.curves_ready = true;
    }
}

/// Stateless selector (the per-endpoint instance exists so policies
/// with internal state — none today — stay possible).
#[derive(Debug, Clone)]
pub struct Selector {
    pub policy: CompressPolicy,
}

impl Selector {
    pub fn new(policy: CompressPolicy) -> Self {
        Self { policy }
    }

    /// Does this policy consume per-layer [`ErrorCurve`]s? Callers that
    /// already fan per-layer work across threads can prebuild the
    /// curves ([`SelectScratch::curves_mut`] +
    /// [`SelectScratch::set_curves_ready`]) before
    /// [`select_into`](Self::select_into) instead of paying the serial
    /// build inside the selection.
    pub fn needs_curves(&self) -> bool {
        matches!(self.policy, CompressPolicy::KimadPlus { .. })
    }

    /// Select compressors for `diff` (the EF21 difference vector)
    /// partitioned by `layers`, under `budget_bits` for this direction.
    /// `FixedRatio` ignores the budget (that is the point of the
    /// baseline); all other policies respect it exactly.
    pub fn select(&self, diff: &[f32], layers: &[Layer], budget_bits: u64) -> Selection {
        let mut scratch = SelectScratch::default();
        let mut out = Selection::default();
        self.select_into(diff, layers, budget_bits, &mut scratch, &mut out);
        out
    }

    /// [`select`](Self::select) into caller-owned buffers — the
    /// allocation-free form (for the budget-driven sparsification
    /// policies; `KimadPlus` still allocates inside the knapsack DP).
    /// Bit-identical to `select` for every policy.
    pub fn select_into(
        &self,
        diff: &[f32],
        layers: &[Layer],
        budget_bits: u64,
        scratch: &mut SelectScratch,
        out: &mut Selection,
    ) {
        out.k_per_layer.clear();
        match &self.policy {
            CompressPolicy::FixedRatio { ratio } => {
                out.k_per_layer.extend(layers.iter().map(|l| ratio_to_k(*ratio, l.size)));
            }
            CompressPolicy::KimadUniform => {
                let d_total: usize = layers.iter().map(|l| l.size).sum();
                let k_budget = (budget_bits / SPARSE_COORD_BITS) as usize;
                let ratio = if d_total == 0 {
                    0.0
                } else {
                    (k_budget as f64 / d_total as f64).min(1.0)
                };
                // Floor per layer so the total never exceeds budget.
                out.k_per_layer.extend(
                    layers.iter().map(|l| ((ratio * l.size as f64).floor() as usize).min(l.size)),
                );
                // Distribute the remainder greedily by layer size. The
                // (Reverse(size), index) key on an unstable sort equals
                // the stable sort by Reverse(size) — indices are unique
                // — without the stable sort's temp allocation.
                let mut used: usize = out.k_per_layer.iter().sum();
                if ratio < 1.0 {
                    scratch.order.clear();
                    scratch.order.extend(0..layers.len());
                    scratch.order.sort_unstable_by_key(|&i| (std::cmp::Reverse(layers[i].size), i));
                    for &i in scratch.order.iter().cycle().take(layers.len() * 2) {
                        if used >= k_budget.min(d_total) {
                            break;
                        }
                        if out.k_per_layer[i] < layers[i].size {
                            out.k_per_layer[i] += 1;
                            used += 1;
                        }
                    }
                }
            }
            CompressPolicy::KimadPlus { discretization, ratios } => {
                let grid = if ratios.is_empty() {
                    crate::kimad::knapsack::paper_ratio_grid()
                } else {
                    ratios.clone()
                };
                if !(scratch.curves_ready && scratch.curves.len() == layers.len()) {
                    let curves = scratch.curves_mut(layers.len());
                    for (l, slot) in layers.iter().zip(curves.iter_mut()) {
                        *slot = ErrorCurve::build(&diff[l.offset..l.offset + l.size]);
                    }
                }
                let options = topk_options(&scratch.curves, &grid, SPARSE_COORD_BITS);
                let alloc = allocate(
                    &options,
                    KnapsackParams { budget_bits, discretization: *discretization },
                );
                // Map chosen option back to K (option bits / coord bits).
                for (&j, o) in alloc.choice.iter().zip(&options) {
                    out.k_per_layer.push((o[j].bits / SPARSE_COORD_BITS) as usize);
                }
            }
            CompressPolicy::WholeModelTopK => {
                let d_total: usize = layers.iter().map(|l| l.size).sum();
                let k_global = ((budget_bits / SPARSE_COORD_BITS) as usize).min(d_total);
                TopK::select_indices_with(diff, k_global, &mut scratch.idx, &mut scratch.packed);
                out.k_per_layer.resize(layers.len(), 0);
                for &i in &scratch.idx {
                    let i = i as usize;
                    // Layers are contiguous and sorted by offset.
                    let li = layers
                        .partition_point(|l| l.offset + l.size <= i)
                        .min(layers.len() - 1);
                    out.k_per_layer[li] += 1;
                }
            }
        }
        // Prebuilt curves are good for exactly one selection.
        scratch.curves_ready = false;
        out.planned_bits = planned_bits(&out.k_per_layer);
    }
}

fn ratio_to_k(ratio: f64, d: usize) -> usize {
    ((ratio.clamp(0.0, 1.0) * d as f64).ceil() as usize).min(d)
}

fn planned_bits(k_per_layer: &[usize]) -> u64 {
    k_per_layer.iter().map(|&k| k as u64 * SPARSE_COORD_BITS).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelLayout;

    fn layers3() -> Vec<Layer> {
        ModelLayout::synthetic(&[10, 20, 10]).layers()
    }

    fn diff40() -> Vec<f32> {
        (0..40).map(|i| (40 - i) as f32 / 10.0).collect()
    }

    #[test]
    fn fixed_ratio_ignores_budget() {
        let s = Selector::new(CompressPolicy::FixedRatio { ratio: 0.5 });
        let sel = s.select(&diff40(), &layers3(), 0);
        assert_eq!(sel.k_per_layer, vec![5, 10, 5]);
    }

    #[test]
    fn kimad_uniform_respects_budget() {
        let s = Selector::new(CompressPolicy::KimadUniform);
        for budget_k in [0u64, 1, 7, 20, 40, 100] {
            let sel = s.select(&diff40(), &layers3(), budget_k * SPARSE_COORD_BITS);
            let total: usize = sel.k_per_layer.iter().sum();
            assert!(total as u64 <= budget_k.min(40), "budget_k={budget_k} total={total}");
            assert!(sel.planned_bits <= budget_k * SPARSE_COORD_BITS);
            // Uses the whole budget when it can.
            if budget_k <= 40 {
                assert_eq!(total as u64, budget_k.min(40));
            }
        }
    }

    #[test]
    fn kimad_plus_no_worse_than_uniform() {
        let layers = layers3();
        // Heterogeneous layer energies: first layer has huge entries.
        let mut diff = vec![0.1f32; 40];
        for i in 0..10 {
            diff[i] = 10.0 - i as f32;
        }
        let budget = 10 * SPARSE_COORD_BITS;
        let uni = Selector::new(CompressPolicy::KimadUniform).select(&diff, &layers, budget);
        let plus = Selector::new(CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] })
            .select(&diff, &layers, budget);
        let curves: Vec<ErrorCurve> = layers
            .iter()
            .map(|l| ErrorCurve::build(&diff[l.offset..l.offset + l.size]))
            .collect();
        assert!(plus.planned_bits <= budget);
        assert!(
            plus.predicted_error(&curves) <= uni.predicted_error(&curves) + 1e-9,
            "plus {} uniform {}",
            plus.predicted_error(&curves),
            uni.predicted_error(&curves)
        );
    }

    #[test]
    fn whole_model_is_optimal_for_sparsification() {
        let layers = layers3();
        let diff = diff40();
        let budget = 12 * SPARSE_COORD_BITS;
        let whole = Selector::new(CompressPolicy::WholeModelTopK).select(&diff, &layers, budget);
        let plus = Selector::new(CompressPolicy::KimadPlus { discretization: 4000, ratios: vec![] })
            .select(&diff, &layers, budget);
        let curves: Vec<ErrorCurve> = layers
            .iter()
            .map(|l| ErrorCurve::build(&diff[l.offset..l.offset + l.size]))
            .collect();
        let total_k: usize = whole.k_per_layer.iter().sum();
        assert_eq!(total_k, 12);
        assert!(
            whole.predicted_error(&curves) <= plus.predicted_error(&curves) + 1e-9,
            "whole-model TopK must lower-bound grid-restricted Kimad+"
        );
    }

    #[test]
    fn whole_model_layer_attribution() {
        let layers = ModelLayout::synthetic(&[2, 2]).layers();
        let diff = [0.1f32, 9.0, 8.0, 0.2];
        let sel = Selector::new(CompressPolicy::WholeModelTopK)
            .select(&diff, &layers, 2 * SPARSE_COORD_BITS);
        assert_eq!(sel.k_per_layer, vec![1, 1]);
    }

    #[test]
    fn zero_dim_layers_safe() {
        let s = Selector::new(CompressPolicy::KimadUniform);
        let sel = s.select(&[], &[], 100);
        assert!(sel.k_per_layer.is_empty());
        assert_eq!(sel.planned_bits, 0);
    }

    #[test]
    fn select_into_matches_select_for_every_policy() {
        // The buffer-reuse form must be bit-identical to the allocating
        // one, including across repeated calls on one warm scratch.
        let layers = layers3();
        let diff = diff40();
        for policy in [
            CompressPolicy::FixedRatio { ratio: 0.3 },
            CompressPolicy::KimadUniform,
            CompressPolicy::KimadPlus { discretization: 500, ratios: vec![] },
            CompressPolicy::WholeModelTopK,
        ] {
            let s = Selector::new(policy.clone());
            let mut scratch = SelectScratch::default();
            let mut out = Selection::default();
            for budget_k in [0u64, 3, 11, 40, 100] {
                let want = s.select(&diff, &layers, budget_k * SPARSE_COORD_BITS);
                s.select_into(
                    &diff,
                    &layers,
                    budget_k * SPARSE_COORD_BITS,
                    &mut scratch,
                    &mut out,
                );
                assert_eq!(out, want, "{policy:?} budget_k={budget_k}");
            }
        }
    }

    #[test]
    fn prebuilt_curves_match_internal_build() {
        // The sharded broadcast prebuilds the per-layer error curves in
        // parallel; consuming them must give the same selection as the
        // internal serial build — and the ready flag is one-shot.
        let layers = layers3();
        let mut diff = vec![0.1f32; 40];
        for (i, d) in diff.iter_mut().enumerate().take(10) {
            *d = 10.0 - i as f32;
        }
        let s = Selector::new(CompressPolicy::KimadPlus { discretization: 800, ratios: vec![] });
        assert!(s.needs_curves());
        assert!(!Selector::new(CompressPolicy::KimadUniform).needs_curves());
        let budget = 9 * SPARSE_COORD_BITS;
        let want = s.select(&diff, &layers, budget);

        let mut scratch = SelectScratch::default();
        let curves = scratch.curves_mut(layers.len());
        for (l, slot) in layers.iter().zip(curves.iter_mut()) {
            *slot = ErrorCurve::build(&diff[l.offset..l.offset + l.size]);
        }
        scratch.set_curves_ready();
        let mut out = Selection::default();
        s.select_into(&diff, &layers, budget, &mut scratch, &mut out);
        assert_eq!(out, want, "prebuilt curves diverged from internal build");
        assert!(!scratch.curves_ready, "ready flag must be consumed");

        // Without re-arming, the next call rebuilds internally (same
        // result — the flag only skips work, never changes it).
        s.select_into(&diff, &layers, budget, &mut scratch, &mut out);
        assert_eq!(out, want);
    }
}
