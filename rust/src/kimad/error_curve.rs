//! The TopK error curve ε(K) = ||u − TopK(u)||² for all K at once.
//!
//! This is the rust twin of the L1 Pallas kernel
//! `python/compile/kernels/topk_error.py` (same math: sort squared
//! magnitudes descending, suffix-sum). The coordinator uses this native
//! implementation on its hot path; an integration test
//! (`rust/tests/integration_runtime.rs`) checks it against the
//! PJRT-executed Pallas kernel artifact bit-for-bit (within f32 accum
//! tolerance), proving the two stacks compute the same quantity.

/// Precomputed ε(K) for K = 0..=d over one layer's update vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorCurve {
    /// `err[k]` = squared L2 error of keeping the k largest-|u| coords.
    pub err: Vec<f64>,
}

impl ErrorCurve {
    /// O(d log d) build (sort dominates; the suffix sum is one pass).
    pub fn build(u: &[f32]) -> Self {
        let mut sq: Vec<f64> = u.iter().map(|&v| (v as f64) * (v as f64)).collect();
        sq.sort_by(|a, b| b.total_cmp(a));
        let d = sq.len();
        let mut err = vec![0.0; d + 1];
        let mut acc = 0.0;
        for k in (0..d).rev() {
            acc += sq[k];
            err[k] = acc;
        }
        Self { err }
    }

    pub fn dim(&self) -> usize {
        self.err.len() - 1
    }

    /// ε(K), clamping K to [0, d].
    pub fn at(&self, k: usize) -> f64 {
        self.err[k.min(self.dim())]
    }

    /// Total energy ||u||² = ε(0).
    pub fn total(&self) -> f64 {
        self.err[0]
    }

    /// Smallest K with ε(K) ≤ `target` (the "optimal whole-model TopK"
    /// baseline of Fig. 9 inverts the curve this way).
    pub fn min_k_for_error(&self, target: f64) -> usize {
        // err is non-increasing: binary search the first index <= target.
        let mut lo = 0usize;
        let mut hi = self.dim();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.err[mid] <= target {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compression_error, TopK};

    #[test]
    fn matches_explicit_compression() {
        let u = [4.0f32, -3.0, 2.0, 1.0, 0.0];
        let c = ErrorCurve::build(&u);
        for k in 0..=5 {
            let want = compression_error(&TopK::new(k), &u);
            assert!((c.at(k) - want).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn endpoints() {
        let u = [1.0f32, 2.0];
        let c = ErrorCurve::build(&u);
        assert!((c.total() - 5.0).abs() < 1e-12);
        assert_eq!(c.at(2), 0.0);
        assert_eq!(c.at(99), 0.0);
    }

    #[test]
    fn monotone_nonincreasing() {
        let u: Vec<f32> = (0..100).map(|i| ((i * 37) % 19) as f32 - 9.0).collect();
        let c = ErrorCurve::build(&u);
        for k in 1..=100 {
            assert!(c.err[k] <= c.err[k - 1] + 1e-12);
        }
    }

    #[test]
    fn min_k_inverts() {
        let u = [3.0f32, 2.0, 1.0];
        let c = ErrorCurve::build(&u); // err = [14, 5, 1, 0]
        assert_eq!(c.min_k_for_error(14.0), 0);
        assert_eq!(c.min_k_for_error(5.0), 1);
        assert_eq!(c.min_k_for_error(4.9), 2);
        assert_eq!(c.min_k_for_error(0.0), 3);
    }

    #[test]
    fn empty_vector() {
        let c = ErrorCurve::build(&[]);
        assert_eq!(c.dim(), 0);
        assert_eq!(c.at(0), 0.0);
    }
}
