//! Kimad+ (§3.2, Algorithm 4): allocate the compression budget across
//! layers to minimize total compression error — a knapsack solved by
//! dynamic programming in O(N·K·D).
//!
//! In knapsack terms (the paper: "Kimad+ uses the compression budget c
//! as the knapsack size and the compression error as the weight"):
//! capacity = budget `c` in bits (discretized into D buckets), item i =
//! layer i with one option per candidate compression parameter, option
//! weight = compressed size `b_{i,j}`, option value = compression error
//! ε_i(j) (minimized).
//!
//! NOTE on fidelity: the paper's Algorithm 4 listing mixes its `e_i` and
//! `cost_i` loop indices (lines 16–20) and describes discretizing the
//! *error* while the DP clearly ranges over discretized *budget*; we
//! implement the semantically consistent version above, which matches
//! the stated O(N·K·D) complexity and the L-GReCo construction it
//! adapts. `argmin(DP[N])` (line 25) equals the last feasible bucket
//! because total error is non-increasing in allowed cost; we take the
//! same minimum.

/// One candidate (parameter j) for one layer: wire bits + error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Option_ {
    pub bits: u64,
    pub error: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KnapsackParams {
    /// Budget `c` in bits for the whole model, this direction.
    pub budget_bits: u64,
    /// Discretization factor D (the paper's deep runs use 1000).
    pub discretization: usize,
}

/// The DP result: one chosen option index per layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub choice: Vec<usize>,
    pub total_bits: u64,
    pub total_error: f64,
    /// True when the budget could not fit even the cheapest option per
    /// layer and the allocator fell back to cheapest-per-layer.
    pub degraded: bool,
}

/// Solve the Kimad+ knapsack. `options[i]` lists the candidates for
/// layer i (must be non-empty). Layers with a single option are forced.
///
/// Guarantee: if every layer offers a 0-bit option (e.g. K=0), the
/// result always satisfies `total_bits <= budget_bits` exactly;
/// otherwise a `degraded` cheapest-per-layer fallback may exceed it.
pub fn allocate(options: &[Vec<Option_>], params: KnapsackParams) -> Allocation {
    let n = options.len();
    assert!(options.iter().all(|o| !o.is_empty()), "empty option list");
    let d = params.discretization.max(1);
    let budget = params.budget_bits;

    // Bucket width; ceil so that an option's discretized cost never
    // understates its real cost (keeps the budget guarantee exact).
    //
    // Exactness fast path: real option costs are multiples of the
    // sparse coordinate size (64 bits), so when floor(budget/gcd) fits
    // within D buckets the DP is *exact*, not approximate — the ceil
    // rounding otherwise drops up to one coordinate per layer.
    let gcd_all = options
        .iter()
        .flatten()
        .map(|o| o.bits)
        .filter(|&b| b > 0)
        .fold(0u64, gcd);
    let (step, cap) = if gcd_all > 0 && budget / gcd_all <= d as u64 {
        (gcd_all as f64, (budget / gcd_all) as usize)
    } else {
        let step = (budget as f64 / d as f64).max(1.0);
        let cap = ((budget as f64 / step).floor() as usize).min(d);
        (step, cap)
    };
    let bucket = |bits: u64| -> usize { ((bits as f64) / step).ceil() as usize };

    const INF: f64 = f64::INFINITY;
    // dp[b] = min total error with total discretized cost exactly <= b.
    let mut dp = vec![INF; cap + 1];
    // parent[i][b] = option index chosen for layer i at bucket b.
    let mut parent: Vec<Vec<u32>> = Vec::with_capacity(n);
    dp[0] = 0.0;

    let mut prev = dp.clone();
    for opts in options {
        for v in dp.iter_mut() {
            *v = INF;
        }
        let mut par = vec![u32::MAX; cap + 1];
        for (j, opt) in opts.iter().enumerate() {
            let cb = bucket(opt.bits);
            if cb > cap {
                continue; // option alone exceeds the budget
            }
            for b in cb..=cap {
                let base = prev[b - cb];
                if base == INF {
                    continue;
                }
                let t = base + opt.error;
                if t < dp[b] {
                    dp[b] = t;
                    par[b] = j as u32;
                }
            }
        }
        parent.push(par);
        std::mem::swap(&mut dp, &mut prev);
    }
    // After the swap, `prev` holds the final layer's dp row.
    let final_dp = &prev;

    // Best bucket = argmin error (== last feasible by monotonicity).
    let mut best_b = usize::MAX;
    let mut best = INF;
    for (b, &e) in final_dp.iter().enumerate() {
        if e < best {
            best = e;
            best_b = b;
        }
    }

    if best_b == usize::MAX {
        // Infeasible even after discretization: degrade to the cheapest
        // option per layer (Kimad still sends *something* — see §3.1).
        let mut choice = Vec::with_capacity(n);
        let mut bits = 0u64;
        let mut err = 0.0;
        for opts in options {
            let (j, o) = opts
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.bits.cmp(&b.1.bits))
                .unwrap();
            choice.push(j);
            bits += o.bits;
            err += o.error;
        }
        return Allocation { choice, total_bits: bits, total_error: err, degraded: true };
    }

    // Backtrack.
    let mut choice = vec![0usize; n];
    let mut b = best_b;
    // Recompute dp rows is avoided by storing full parent table; walk it
    // back using the recorded option at each layer. To know the bucket
    // consumed at layer i we need that option's cost bucket.
    for i in (0..n).rev() {
        let j = parent[i][b];
        debug_assert_ne!(j, u32::MAX, "backtrack hit an unreachable state");
        let j = j as usize;
        choice[i] = j;
        b -= ((options[i][j].bits as f64) / step).ceil() as usize;
    }

    let total_bits: u64 = choice
        .iter()
        .zip(options)
        .map(|(&j, o)| o[j].bits)
        .sum();
    let total_error: f64 = choice
        .iter()
        .zip(options)
        .map(|(&j, o)| o[j].error)
        // tidy:allow(float-reduce) -- serial fold in layer order, deterministic
        .sum();
    Allocation { choice, total_bits, total_error, degraded: false }
}

fn gcd(a: u64, b: u64) -> u64 {
    if a == 0 {
        b
    } else {
        gcd(b % a, a)
    }
}

/// Build per-layer TopK options from error curves and a ratio grid
/// (§4.3 uses ratios {0.01 + 0.02k} ∩ [0.01, 1]). Includes K=0 so the
/// budget guarantee of [`allocate`] always holds. Layers small enough
/// that the ratio grid is coarser than single coordinates (d <= 128)
/// get the exact K grid instead — same O(N·K·D) complexity class,
/// strictly better allocations. `bits_per_coord` is 64 for sparse
/// f32+index payloads (see compress::topk).
pub fn topk_options(
    curves: &[crate::kimad::ErrorCurve],
    ratios: &[f64],
    bits_per_coord: u64,
) -> Vec<Vec<Option_>> {
    curves
        .iter()
        .map(|c| {
            let d = c.dim();
            let mut opts = vec![Option_ { bits: 0, error: c.at(0) }];
            if d <= 128 {
                for k in 1..=d {
                    opts.push(Option_ { bits: k as u64 * bits_per_coord, error: c.at(k) });
                }
                return opts;
            }
            let mut seen_k = std::collections::BTreeSet::new();
            seen_k.insert(0usize);
            for &r in ratios {
                let k = ((r * d as f64).ceil() as usize).min(d);
                if seen_k.insert(k) {
                    opts.push(Option_ { bits: k as u64 * bits_per_coord, error: c.at(k) });
                }
            }
            opts
        })
        .collect()
}

/// The §4.3 ratio grid: {x = 0.01 + 0.02k | 0.01 <= x <= 1}.
pub fn paper_ratio_grid() -> Vec<f64> {
    let mut out = Vec::new();
    let mut k = 0;
    loop {
        let x = 0.01 + 0.02 * k as f64;
        if x > 1.0 {
            break;
        }
        out.push(x);
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kimad::ErrorCurve;

    fn opt(bits: u64, error: f64) -> Option_ {
        Option_ { bits, error }
    }

    #[test]
    fn single_layer_picks_best_within_budget() {
        let options = vec![vec![opt(0, 10.0), opt(50, 5.0), opt(100, 1.0), opt(200, 0.0)]];
        let a = allocate(&options, KnapsackParams { budget_bits: 100, discretization: 100 });
        assert_eq!(a.choice, vec![2]);
        assert_eq!(a.total_bits, 100);
        assert!(!a.degraded);
    }

    #[test]
    fn budget_respected_across_layers() {
        // Two layers; budget forces a tradeoff: giving layer 0 the big
        // option (err 0) costs 80, leaving only 20 for layer 1 (err 7);
        // total 7. The balanced split gives 3 + 3 = 6.
        let options = vec![
            vec![opt(0, 9.0), opt(40, 3.0), opt(80, 0.0)],
            vec![opt(0, 9.0), opt(20, 7.0), opt(40, 3.0), opt(80, 0.0)],
        ];
        let a = allocate(&options, KnapsackParams { budget_bits: 100, discretization: 100 });
        assert!(a.total_bits <= 100);
        assert_eq!(a.total_error, 6.0);
        assert_eq!(a.choice, vec![1, 2]);
    }

    #[test]
    fn zero_budget_takes_zero_options() {
        let options = vec![
            vec![opt(0, 5.0), opt(10, 0.0)],
            vec![opt(0, 3.0), opt(10, 0.0)],
        ];
        let a = allocate(&options, KnapsackParams { budget_bits: 0, discretization: 10 });
        assert_eq!(a.total_bits, 0);
        assert_eq!(a.total_error, 8.0);
        assert!(!a.degraded);
    }

    #[test]
    fn infeasible_degrades_to_cheapest() {
        let options = vec![vec![opt(100, 1.0), opt(200, 0.0)]];
        let a = allocate(&options, KnapsackParams { budget_bits: 10, discretization: 10 });
        assert!(a.degraded);
        assert_eq!(a.choice, vec![0]);
    }

    #[test]
    fn beats_uniform_allocation() {
        // Layer 0 has steep error decay, layer 1 is flat: Kimad+ should
        // shift budget to layer 0, beating the uniform split.
        let u0: Vec<f32> = vec![10.0, 9.0, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1];
        let u1: Vec<f32> = vec![1.0; 8];
        let curves = vec![ErrorCurve::build(&u0), ErrorCurve::build(&u1)];
        let ratios: Vec<f64> = (1..=8).map(|k| k as f64 / 8.0).collect();
        let options = topk_options(&curves, &ratios, 64);
        let budget = 8 * 64; // room for 8 of 16 coords total
        let a = allocate(&options, KnapsackParams { budget_bits: budget, discretization: 1000 });
        assert!(a.total_bits <= budget);
        // Uniform: 4 coords each -> err0 = eps0(4), err1 = eps1(4).
        let uniform = curves[0].at(4) + curves[1].at(4);
        assert!(
            a.total_error <= uniform + 1e-9,
            "dp {} vs uniform {uniform}",
            a.total_error
        );
    }

    #[test]
    fn paper_grid_shape() {
        let g = paper_ratio_grid();
        assert!((g[0] - 0.01).abs() < 1e-12);
        assert!((g[1] - 0.03).abs() < 1e-12);
        assert!(*g.last().unwrap() <= 1.0);
        assert_eq!(g.len(), 50);
    }

    #[test]
    fn dp_matches_bruteforce_small() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(17);
        for _ in 0..30 {
            let n = rng.range_usize(1, 4);
            let options: Vec<Vec<Option_>> = (0..n)
                .map(|_| {
                    let m = rng.range_usize(1, 5);
                    let mut v = vec![opt(0, rng.range_f64(0.0, 10.0))];
                    for _ in 1..m {
                        v.push(opt(rng.range_usize(0, 50) as u64, rng.range_f64(0.0, 10.0)));
                    }
                    v
                })
                .collect();
            let budget = rng.range_usize(0, 120) as u64;
            // D high enough to make discretization exact (step = 1 bit).
            let params =
                KnapsackParams { budget_bits: budget, discretization: budget.max(1) as usize };
            let a = allocate(&options, params);

            // Brute force.
            let mut best = f64::INFINITY;
            let mut stack = vec![(0usize, 0u64, 0.0f64)];
            while let Some((i, bits, err)) = stack.pop() {
                if bits > budget {
                    continue;
                }
                if i == options.len() {
                    best = best.min(err);
                    continue;
                }
                for o in &options[i] {
                    stack.push((i + 1, bits + o.bits, err + o.error));
                }
            }
            assert!(a.total_bits <= budget);
            assert!(
                (a.total_error - best).abs() < 1e-9,
                "dp={} brute={best}",
                a.total_error
            );
        }
    }
}
