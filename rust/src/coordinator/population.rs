//! The population/cohort round engine: a million simulated clients,
//! O(quorum + cohorts) resident state.
//!
//! The dense engine ([`Simulation`](super::Simulation)) materializes
//! per-worker state — a trace pair, an EF21 estimator û_m, in-flight
//! message buffers, a bandwidth monitor — for every one of its M
//! workers, which caps M in the hundreds. This engine models the
//! federated regime Kimad targets instead: M is a *population* size,
//! and each synchronous round
//!
//! 1. **samples** `quorum = ceil(p · M)` distinct clients with Floyd's
//!    algorithm ([`Rng::sample_distinct_sorted_into`]) from a per-round
//!    stream derived as `seed → SAMPLER_STREAM → round` — a pure
//!    function of `(seed, round)`, so the schedule is identical for
//!    every thread count, shard count, and resume point;
//! 2. **seats** the sampled clients in a recycled pool of `quorum`
//!    worker slots (the j-th seat always holds the j-th smallest
//!    sampled client). A seat keeps its occupant's EF21 state across
//!    rounds while the occupant re-appears; a reassigned seat resets to
//!    a cold client (zeroed û, fresh monitor) — at p = 1 occupants
//!    never change, which is one half of the dense bit-identity
//!    argument;
//! 3. runs the **same round kernels** as the dense Sync path — the
//!    crate-visible [`upload_leg`]/[`deliver_upload`] worker leg and
//!    the sharded broadcast/aggregate/step server kernels — over the
//!    seats only, in the dense engine's exact event order (broadcast
//!    milestones sorted by (arrival time, client); reductions in
//!    client-ascending order). That is the other half: with p = 1 and
//!    C = M every operation sequence is the dense one, so the rounds
//!    are bit-identical by construction (asserted in the tests).
//!
//! Clients share physical links through **cohorts**: client c uses
//! cohort `c % C`'s (uplink, downlink) trace pair and downlink
//! monitor, so the netsim carries C links instead of M. With C = M the
//! cohort map is the identity and the traces are exactly the dense
//! per-worker ones.
//!
//! Per-round cost is O(C + quorum · d); resident memory is
//! O(quorum · d + C) — both independent of M, which is what lets a
//! `--workers 1000000 --participation 0.001` cell finish in seconds.

use crate::bandwidth::{BandwidthMonitor, EwmaMonitor};
use crate::compress::Identity;
use crate::ef21::Estimator;
use crate::kimad::{effective_budget, Selector};
use crate::netsim::{Direction, NetSim};
use crate::util::rng::Rng;

use super::round::{RoundRecord, WorkerRound};
use super::shard::{self, ShardPlan};
use super::sim::{
    deliver_upload, effective_shards, effective_threads, upload_leg, ExecMode, SimConfig,
    UploadCtx, UploadLeg, PROBE_BITS, PROBE_WINDOW,
};
use super::worker::{GradientSource, WorkerState};

/// The sampler's stream tag: participant sampling draws from
/// `seed_from_u64(seed).derive(SAMPLER_STREAM).derive(round)`, so it
/// can never collide with the compute-model or trace seed derivations
/// (documented in docs/ARCHITECTURE.md §8 — changing this constant
/// changes every sampled schedule).
pub const SAMPLER_STREAM: u64 = 0x504f_505f_5341_4d50; // "POP_SAMP"

/// The round `round`'s participant set: `quorum` distinct client ids in
/// ascending order, a pure function of `(seed, population, quorum,
/// round)`. Exposed as a free function so determinism is testable
/// without building a simulation.
pub fn sample_round(seed: u64, population: usize, quorum: usize, round: u64, out: &mut Vec<u32>) {
    let mut rng = Rng::seed_from_u64(seed).derive(SAMPLER_STREAM).derive(round);
    rng.sample_distinct_sorted_into(population, quorum, out);
}

/// The population model: how many clients exist, what fraction of them
/// a round samples, and how they share physical links.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PopulationSpec {
    /// Population size M (the config's `m`).
    pub population: usize,
    /// Per-round participation fraction p in (0, 1].
    pub participation: f64,
    /// Cohort count C: client c uses link `c % C`. C = M reproduces
    /// dense per-worker links exactly.
    pub cohorts: usize,
    /// Sampling seed (the config's `seed`).
    pub seed: u64,
}

impl PopulationSpec {
    /// Per-round sampled quorum: `ceil(p · M)`, clamped to `[1, M]`.
    pub fn quorum(&self) -> usize {
        ((self.participation * self.population as f64).ceil() as usize)
            .clamp(1, self.population.max(1))
    }

    /// The cohort (physical link index) client `client` belongs to.
    pub fn cohort_of(&self, client: u32) -> usize {
        client as usize % self.cohorts
    }
}

/// One recycled worker slot: the per-worker state of whichever sampled
/// client currently occupies it, plus the per-round leg bookkeeping the
/// dense engine keeps in its `Chain`.
struct Seat {
    state: WorkerState,
    /// Current occupant (None = never assigned).
    client: Option<u32>,
    down_seconds: f64,
    /// BroadcastDone time `t0 + down_seconds` — kept as the computed
    /// f64 (not re-derived) so the gradient-phase sort ties break
    /// exactly like the dense event queue's (time, worker) order.
    t_bd: f64,
    t_comp: f64,
    up_start: f64,
    loss: f64,
    leg: UploadLeg,
}

impl Seat {
    fn new(dim: usize) -> Self {
        Self {
            state: WorkerState::new(0, dim),
            client: None,
            down_seconds: 0.0,
            t_bd: 0.0,
            t_comp: 0.0,
            up_start: 0.0,
            loss: f64::NAN,
            leg: UploadLeg::default(),
        }
    }

    /// Re-seat a different client: reset to the cold state a fresh
    /// `WorkerState` would have (zeroed EF21 estimator and update
    /// vector, fresh bandwidth monitor), pointing at the new occupant's
    /// cohort link. Scratch buffers (`diff`, `msgs`, selection state)
    /// are fully overwritten every round — the same reuse contract the
    /// dense engine already relies on across rounds — so they carry
    /// nothing over. The seat's server-side û mirror is zeroed by the
    /// caller alongside this.
    fn assign(&mut self, client: u32, cohort: usize) {
        self.client = Some(client);
        self.state.id = cohort;
        self.state.u_hat.value.iter_mut().for_each(|v| *v = 0.0);
        self.state.u.iter_mut().for_each(|v| *v = 0.0);
        self.state.monitor = Box::new(EwmaMonitor::new(0.7));
    }
}

/// A running population simulation: server + `quorum` seats + C cohort
/// links + the gradient source. The API mirrors [`Simulation`]
/// (`shards`/`thread_cap` knobs, `run`, a public model vector) so the
/// driver can swap engines per config.
///
/// [`Simulation`]: super::Simulation
pub struct PopulationSim<S: GradientSource> {
    pub cfg: SimConfig,
    pub pop: PopulationSpec,
    pub net: NetSim,
    pub source: S,
    /// The global model x^k (the dense engine's `server.x`).
    pub x: Vec<f32>,
    /// Shared broadcast estimator x̂ (Sync rounds have one channel).
    pub x_hat: Estimator,
    /// Per-cohort downlink monitors (the dense engine's per-worker
    /// `down_monitors`, one per physical link).
    pub down_monitors: Vec<Box<dyn BandwidthMonitor>>,
    pub clock: f64,
    pub step: u64,
    /// See [`Simulation::shards`](super::Simulation::shards).
    pub shards: usize,
    /// See [`Simulation::thread_cap`](super::Simulation::thread_cap).
    pub thread_cap: usize,
    /// Per-seat server-side û mirrors, contiguous so the sharded
    /// aggregate kernel runs over them unchanged.
    u_hats: Vec<Estimator>,
    /// Uniform aggregation weights 1/quorum (= the dense 1/M at p = 1).
    weights: Vec<f64>,
    seats: Vec<Seat>,
    /// This round's sampled clients, ascending.
    sampled: Vec<u32>,
    /// Reusable gradient-phase ordering scratch.
    order: Vec<usize>,
    up_selector: Selector,
    down_selector: Selector,
    agg: Vec<f32>,
    diff: Vec<f32>,
    scratch: Vec<f32>,
    warmed: bool,
    plan: ShardPlan,
    bcast: shard::BroadcastScratch,
}

impl<S: GradientSource> PopulationSim<S> {
    pub fn new(
        cfg: SimConfig,
        pop: PopulationSpec,
        net: NetSim,
        source: S,
        x0: Vec<f32>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            matches!(cfg.mode, ExecMode::Sync),
            "population sampling runs Sync rounds only: semisync/async already model \
             partial participation as a race outcome, and layering sampled \
             participation on top would double-count it"
        );
        anyhow::ensure!(
            cfg.weights.is_empty(),
            "population aggregation is uniform 1/quorum; explicit per-worker weights \
             are a dense-path feature"
        );
        anyhow::ensure!(pop.population >= 1, "population must be >= 1");
        anyhow::ensure!(
            cfg.m == pop.population,
            "SimConfig.m ({}) != population ({})",
            cfg.m,
            pop.population
        );
        anyhow::ensure!(
            pop.participation > 0.0 && pop.participation <= 1.0,
            "participation must be in (0, 1], got {}",
            pop.participation
        );
        anyhow::ensure!(
            pop.cohorts >= 1 && pop.cohorts <= pop.population,
            "cohorts must be in [1, population], got {}",
            pop.cohorts
        );
        anyhow::ensure!(
            net.n_workers() == pop.cohorts,
            "netsim links ({}) != cohorts ({})",
            net.n_workers(),
            pop.cohorts
        );
        assert_eq!(x0.len(), source.dim(), "x0 dim != source dim");
        let dim = x0.len();
        let q = pop.quorum();
        let up_selector = Selector::new(cfg.up_policy.clone());
        let down_selector = Selector::new(cfg.down_policy.clone());
        let plan = ShardPlan::build(&cfg.layers, effective_shards(0, cfg.layers.len(), dim, 0));
        Ok(Self {
            cfg,
            pop,
            net,
            source,
            x: x0,
            x_hat: Estimator::zeros(dim),
            down_monitors: (0..pop.cohorts)
                .map(|_| Box::new(EwmaMonitor::new(0.7)) as Box<dyn BandwidthMonitor>)
                .collect(),
            clock: 0.0,
            step: 0,
            shards: 0,
            thread_cap: 0,
            u_hats: (0..q).map(|_| Estimator::zeros(dim)).collect(),
            weights: vec![1.0 / q as f64; q],
            seats: (0..q).map(|_| Seat::new(dim)).collect(),
            sampled: Vec::with_capacity(q),
            order: Vec::with_capacity(q),
            up_selector,
            down_selector,
            agg: vec![0.0; dim],
            diff: vec![0.0; dim],
            scratch: Vec::with_capacity(dim),
            warmed: false,
            plan,
            bcast: shard::BroadcastScratch::default(),
        })
    }

    /// The per-round quorum (seat count).
    pub fn quorum(&self) -> usize {
        self.seats.len()
    }

    /// The current round's sampled clients (ascending) — test hook.
    pub fn sampled(&self) -> &[u32] {
        &self.sampled
    }

    /// Rebuild the shard plan iff the `shards` knob changed (mirrors
    /// the dense engine).
    fn ensure_plan(&mut self) {
        let n = effective_shards(self.shards, self.cfg.layers.len(), self.x.len(), self.thread_cap);
        if self.plan.n_shards() != n && !self.cfg.layers.is_empty() {
            self.plan = ShardPlan::build(&self.cfg.layers, n);
        }
    }

    /// The shared half of the §4.2 warmup: advance x̂ to x⁰ by one
    /// uncompressed exchange (the dense `warm_start`'s first phase; the
    /// per-client half runs per seat on assignment).
    fn warm_shared(&mut self) {
        let id = Identity;
        for l in &self.cfg.layers {
            let target = &self.x[l.offset..l.offset + l.size];
            self.x_hat.compress_advance(&id, target, l, &mut self.scratch);
        }
    }

    /// Sample round `round`'s participants and (re)seat them. Seats
    /// whose occupant re-appears keep all state; reassigned seats reset
    /// cold and — under `warm_start` — run the per-client uncompressed
    /// warm exchange at the current x̂ (round 0 at p = 1 is therefore
    /// exactly the dense `warm_start` sequence).
    fn resample(&mut self, round: u64) -> anyhow::Result<()> {
        if self.pop.participation >= 1.0 {
            if self.sampled.len() != self.pop.population {
                self.sampled.clear();
                self.sampled.extend(0..self.pop.population as u32);
            }
        } else {
            sample_round(
                self.pop.seed,
                self.pop.population,
                self.seats.len(),
                round,
                &mut self.sampled,
            );
        }
        debug_assert_eq!(self.sampled.len(), self.seats.len());
        for j in 0..self.sampled.len() {
            let client = self.sampled[j];
            if self.seats[j].client == Some(client) {
                continue;
            }
            let cohort = self.pop.cohort_of(client);
            self.seats[j].assign(client, cohort);
            self.u_hats[j].value.iter_mut().for_each(|v| *v = 0.0);
            if self.cfg.warm_start {
                // The per-client §4.2 warm exchange (dense warm_start's
                // second phase): u at the current x̂, û := u
                // uncompressed, mirrored on the server.
                let seat = &mut self.seats[j];
                self.source
                    .update(client as usize, 0, &self.x_hat.value, &mut seat.state.u)?;
                let id = Identity;
                for l in &self.cfg.layers {
                    let target = &seat.state.u[l.offset..l.offset + l.size];
                    let msg =
                        seat.state.u_hat.compress_advance(&id, target, l, &mut seat.state.scratch);
                    self.u_hats[j].apply(&msg, l);
                }
            }
        }
        Ok(())
    }

    /// One synchronous population round: probe the C cohort links,
    /// broadcast the shared x̂ under the slowest-cohort budget, run the
    /// quorum's worker legs in the dense engine's event order, then
    /// aggregate Σ (1/q) û over the seats and step — all through the
    /// sharded server kernels.
    pub fn round(&mut self) -> anyhow::Result<RoundRecord> {
        self.ensure_plan();
        if self.cfg.warm_start && !self.warmed {
            self.warm_shared();
            self.warmed = true;
        }
        let k = self.step;
        self.resample(k)?;
        let t0 = self.clock;
        let q = self.seats.len();

        // Continuous bandwidth monitoring, one probe per cohort link
        // (the dense per-worker probe at C = M).
        for (c, mon) in self.down_monitors.iter_mut().enumerate() {
            let bd = self.net.window_bps(c, Direction::Down, t0, PROBE_WINDOW);
            mon.observe(PROBE_BITS, PROBE_BITS / bd.max(1e-9));
        }
        let b_down = self
            .down_monitors
            .iter()
            .map(|m| m.estimate_or(self.cfg.prior_bps))
            .fold(f64::INFINITY, f64::min);
        let c_down = effective_budget(self.cfg.budget, b_down, self.cfg.budget_safety);
        let down_bits = shard::broadcast(
            &self.plan,
            &self.down_selector,
            &self.cfg.layers,
            c_down,
            &self.x,
            &mut self.x_hat,
            &mut self.diff,
            &mut self.bcast,
            self.plan.n_shards() > 1,
        );

        // Downlink transfers, seat (= client-ascending) order — the
        // dense begin_chain loop over workers 0..M.
        for s in self.seats.iter_mut() {
            let tr = self.net.transfer(s.state.id, Direction::Down, t0, down_bits as f64);
            self.down_monitors[s.state.id].observe(down_bits as f64, tr.seconds);
            s.down_seconds = tr.seconds;
            s.t_bd = t0 + tr.seconds;
        }

        // Gradient phase in the dense engine's BroadcastDone order:
        // (arrival time, client) ascending. The source is one mutable
        // resource, so this ordering is the only part of the event
        // drain that can affect state.
        self.order.clear();
        self.order.extend(0..q);
        {
            let seats = &self.seats;
            self.order.sort_by(|&a, &b| {
                seats[a]
                    .t_bd
                    .total_cmp(&seats[b].t_bd)
                    .then(seats[a].client.cmp(&seats[b].client))
            });
        }
        let base_t = self.source.t_comp();
        for idx in 0..q {
            let j = self.order[idx];
            let client = self.seats[j].client.expect("seated clients are assigned") as usize;
            let loss =
                self.source.update(client, k, &self.x_hat.value, &mut self.seats[j].state.u)?;
            let t_comp = self.cfg.compute.sample(base_t, client, k);
            let s = &mut self.seats[j];
            s.loss = loss;
            s.t_comp = t_comp;
            s.up_start = s.t_bd + t_comp;
        }

        // Upload legs: per-seat state is disjoint, so the batch rides
        // the scoped-thread pool exactly like the dense Sync batch
        // (chunking is bit-invariant).
        let n_threads = effective_threads(self.cfg.threads, q, self.x.len(), self.thread_cap);
        let uctx = UploadCtx { cfg: &self.cfg, net: &self.net, up_selector: &self.up_selector };
        if n_threads <= 1 {
            for s in self.seats.iter_mut() {
                s.leg = upload_leg(&uctx, &mut s.state, s.up_start);
            }
        } else {
            let chunk = q.div_ceil(n_threads);
            let seats = &mut self.seats;
            let uctx = &uctx;
            std::thread::scope(|sc| {
                let handles: Vec<_> = seats
                    .chunks_mut(chunk)
                    .map(|ss| {
                        sc.spawn(move || {
                            for s in ss.iter_mut() {
                                s.leg = upload_leg(uctx, &mut s.state, s.up_start);
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().expect("upload leg thread panicked");
                }
            });
        }

        // The barrier: every seat's upload lands; mirror deliveries are
        // per-seat disjoint, so seat order ≡ the dense arrival order.
        for (j, s) in self.seats.iter().enumerate() {
            deliver_upload(&mut self.u_hats[j], &self.cfg.layers, &s.state.msgs);
        }

        // Records, reductions and the step, in seat (client) order.
        let worker_rounds: Vec<WorkerRound> = self
            .seats
            .iter()
            .map(|s| WorkerRound {
                worker: s.client.expect("seated clients are assigned") as usize,
                up_bits: s.leg.up_bits,
                up_seconds: s.leg.up_seconds,
                down_seconds: s.down_seconds,
                loss: s.loss,
                compression_error: s.leg.compression_error,
                est_up_bps: s.leg.est_up_bps,
                true_up_bps: s.leg.true_up_bps,
                arrival_lag: s.down_seconds + s.t_comp + s.leg.up_seconds,
                staleness: 0,
            })
            .collect();
        // tidy:allow(float-reduce) -- serial fold in seat order, deterministic
        let loss_sum: f64 = self.seats.iter().map(|s| s.loss).sum();
        let mut duration = worker_rounds.iter().map(|w| w.arrival_lag).fold(0.0f64, f64::max);
        let total_up: u64 = worker_rounds.iter().map(|w| w.up_bits).sum();
        // Zero-information guard, as in the dense engine: never step on
        // unchanged estimators (outside the EF21 contraction regime).
        let agg_norm_sq = if total_up > 0 || k == 0 {
            let par = self.plan.n_shards() > 1;
            let n = shard::aggregate(&self.plan, &self.weights, &self.u_hats, &mut self.agg, par);
            shard::step(
                &self.plan,
                &self.cfg.optimizer,
                k as usize,
                1.0,
                &mut self.x,
                &self.agg,
                &self.cfg.layers,
                par,
            );
            n
        } else {
            0.0
        };
        if let Some(deadline) = self.cfg.round_deadline {
            duration = duration.max(deadline);
        }
        let f_x = self.source.objective(&self.x).unwrap_or(f64::NAN);
        self.clock = t0 + duration;
        self.step += 1;
        Ok(RoundRecord {
            step: k,
            t_start: t0,
            duration,
            down_bits,
            workers: worker_rounds,
            loss: loss_sum / q as f64,
            f_x,
            agg_norm_sq,
        })
    }

    /// Run `n` rounds, collecting the records.
    pub fn run(&mut self, n: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(self.round()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::bandwidth::ConstantTrace;
    use crate::coordinator::{ComputeModel, QuadraticSource, Simulation};
    use crate::kimad::{BudgetParams, CompressPolicy};
    use crate::netsim::Link;
    use crate::optim::{LayerwiseSgd, Schedule};
    use crate::quadratic::Quadratic;

    /// Heterogeneous constant links: worker/cohort i's bandwidth grows
    /// with i, so download times differ and the event order is
    /// non-trivial.
    fn hetero_net(n: usize, base: f64) -> NetSim {
        NetSim::new(
            (0..n)
                .map(|i| {
                    let bps = base * (1.0 + 0.37 * i as f64);
                    Link::new(
                        Arc::new(ConstantTrace::new(bps)),
                        Arc::new(ConstantTrace::new(bps * 1.5)),
                    )
                })
                .collect(),
        )
    }

    fn sim_cfg(m: usize, policy: CompressPolicy, bps: f64) -> SimConfig {
        let q = Quadratic::paper_instance(30);
        SimConfig {
            m,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: policy.clone(),
            down_policy: policy,
            optimizer: LayerwiseSgd::new(Schedule::Constant(0.02)),
            layers: q.layout(3).layers(),
            warm_start: true,
            prior_bps: bps,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
            threads: 1,
            mode: ExecMode::Sync,
            compute: ComputeModel::Profile { factors: vec![1.0, 2.5, 0.7] },
        }
    }

    fn quad_source() -> QuadraticSource {
        QuadraticSource::new(Quadratic::paper_instance(30), 0.01)
    }

    fn pop_sim(
        m: usize,
        participation: f64,
        cohorts: usize,
        policy: CompressPolicy,
    ) -> PopulationSim<QuadraticSource> {
        let cfg = sim_cfg(m, policy, 640.0);
        let pop = PopulationSpec { population: m, participation, cohorts, seed: 21 };
        PopulationSim::new(cfg, pop, hetero_net(cohorts, 640.0), quad_source(), vec![1.0f32; 30])
            .unwrap()
    }

    #[test]
    fn p1_full_cohorts_bit_identical_to_dense() {
        // THE tentpole invariant: p = 1 with C = M runs the exact dense
        // Sync round — every record bit-identical, on heterogeneous
        // links and straggler compute.
        for policy in [
            CompressPolicy::KimadUniform,
            CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
            CompressPolicy::FixedRatio { ratio: 0.3 },
        ] {
            for m in [1usize, 3, 5] {
                let mut dense = Simulation::new(
                    sim_cfg(m, policy.clone(), 640.0),
                    hetero_net(m, 640.0),
                    quad_source(),
                    vec![1.0f32; 30],
                );
                let mut pop = pop_sim(m, 1.0, m, policy.clone());
                let a = dense.run(25).unwrap();
                let b = pop.run(25).unwrap();
                assert_eq!(a, b, "{policy:?} m={m}: population p=1 diverged from dense");
                assert_eq!(dense.server.x, pop.x, "final models diverged");
            }
        }
    }

    #[test]
    fn sampler_is_deterministic_and_engine_knob_invariant() {
        // Same seed => identical participant schedule, whatever the
        // thread and shard knobs say — and identical records too.
        let mut a = pop_sim(1000, 0.01, 16, CompressPolicy::KimadUniform);
        let mut b = pop_sim(1000, 0.01, 16, CompressPolicy::KimadUniform);
        b.cfg.threads = 4;
        b.shards = 3;
        let ra = a.run(8).unwrap();
        let rb = b.run(8).unwrap();
        assert_eq!(a.sampled(), b.sampled(), "schedules diverged across knobs");
        assert_eq!(ra, rb, "thread/shard knobs changed population records");
        // And directly at the sampler level, across disjoint calls.
        let (mut s1, mut s2) = (Vec::new(), Vec::new());
        for round in 0..20 {
            sample_round(21, 1000, 10, round, &mut s1);
            sample_round(21, 1000, 10, round, &mut s2);
            assert_eq!(s1, s2);
            assert!(s1.windows(2).all(|w| w[0] < w[1]));
        }
        // Different rounds sample different sets (with overwhelming
        // probability for these sizes — this seed included).
        sample_round(21, 1000, 10, 0, &mut s1);
        sample_round(21, 1000, 10, 1, &mut s2);
        assert_ne!(s1, s2);
    }

    #[test]
    fn quorum_edge_cases() {
        // Quorum ceils to >= 1 even at vanishing participation.
        let spec =
            PopulationSpec { population: 1000, participation: 1e-9, cohorts: 4, seed: 1 };
        assert_eq!(spec.quorum(), 1);
        let mut s = pop_sim(1000, 1e-9, 4, CompressPolicy::KimadUniform);
        assert_eq!(s.quorum(), 1);
        let recs = s.run(5).unwrap();
        for r in &recs {
            assert_eq!(r.workers.len(), 1);
            assert!(r.f_x.is_finite());
        }
        // M = 1: the only client participates every round.
        let mut one = pop_sim(1, 0.5, 1, CompressPolicy::KimadUniform);
        let recs = one.run(4).unwrap();
        for r in &recs {
            assert_eq!(r.workers.len(), 1);
            assert_eq!(r.workers[0].worker, 0);
        }
        // p = 1 quorum is the whole population.
        assert_eq!(
            PopulationSpec { population: 7, participation: 1.0, cohorts: 7, seed: 1 }.quorum(),
            7
        );
    }

    #[test]
    fn million_population_runs_with_quorum_sized_state() {
        // The scaling contract: M = 1e6 with a 10-client quorum holds
        // 10 seats and C links, never M of anything dense.
        let mut s = pop_sim(1_000_000, 1e-5, 8, CompressPolicy::KimadUniform);
        assert_eq!(s.quorum(), 10);
        assert_eq!(s.down_monitors.len(), 8);
        assert_eq!(s.net.n_workers(), 8);
        let recs = s.run(3).unwrap();
        assert_eq!(recs.len(), 3);
        for r in &recs {
            assert_eq!(r.workers.len(), 10);
            assert!(r.f_x.is_finite());
            for w in &r.workers {
                assert!(w.worker < 1_000_000);
            }
        }
        assert_eq!(s.seats.len(), 10, "seat pool never grows past the quorum");
    }

    #[test]
    fn reassigned_seats_reset_returning_clients_persist() {
        let mut s = pop_sim(50, 0.1, 5, CompressPolicy::KimadUniform);
        let mut seen = std::collections::BTreeSet::new();
        let recs = s.run(30).unwrap();
        for (k, r) in recs.iter().enumerate() {
            // Every arrival is a sampled client of that round's draw.
            let mut expect = Vec::new();
            sample_round(21, 50, 5, k as u64, &mut expect);
            let got: Vec<u32> = r.workers.iter().map(|w| w.worker as u32).collect();
            assert_eq!(got, expect, "round {k} recorded the wrong participants");
            seen.extend(got);
        }
        // Churn actually happened (many distinct clients seated) while
        // the pool stayed at quorum size.
        assert!(seen.len() > 20, "only {} distinct clients in 30 rounds", seen.len());
        assert_eq!(s.seats.len(), 5);
        assert!(recs.last().unwrap().f_x.is_finite());
    }

    #[test]
    fn rejects_non_sync_modes_and_bad_specs() {
        let mut cfg = sim_cfg(10, CompressPolicy::KimadUniform, 640.0);
        cfg.mode = ExecMode::SemiSync { quorum: 2 };
        let pop = PopulationSpec { population: 10, participation: 0.5, cohorts: 2, seed: 1 };
        assert!(PopulationSim::new(
            cfg,
            pop,
            hetero_net(2, 640.0),
            quad_source(),
            vec![1.0f32; 30]
        )
        .is_err());
        // Cohorts must match the netsim's link count.
        let cfg = sim_cfg(10, CompressPolicy::KimadUniform, 640.0);
        assert!(PopulationSim::new(
            cfg,
            pop,
            hetero_net(3, 640.0),
            quad_source(),
            vec![1.0f32; 30]
        )
        .is_err());
        // Participation and cohort ranges.
        let cfg = sim_cfg(10, CompressPolicy::KimadUniform, 640.0);
        let bad = PopulationSpec { population: 10, participation: 0.0, cohorts: 2, seed: 1 };
        assert!(PopulationSim::new(
            cfg,
            bad,
            hetero_net(2, 640.0),
            quad_source(),
            vec![1.0f32; 30]
        )
        .is_err());
        let cfg = sim_cfg(10, CompressPolicy::KimadUniform, 640.0);
        let bad = PopulationSpec { population: 10, participation: 0.5, cohorts: 11, seed: 1 };
        assert!(PopulationSim::new(
            cfg,
            bad,
            hetero_net(11, 640.0),
            quad_source(),
            vec![1.0f32; 30]
        )
        .is_err());
    }

    #[test]
    fn population_converges_under_sparse_participation() {
        // 1%-participation rounds still train the quadratic: the
        // sampled-quorum EF21 aggregate is a (1/q)-weighted descent
        // direction.
        let mut s = pop_sim(200, 0.05, 8, CompressPolicy::KimadUniform);
        let recs = s.run(150).unwrap();
        let first = recs[0].f_x;
        let last = recs.last().unwrap().f_x;
        assert!(last < first * 0.5, "f0={first} fK={last}");
    }
}
