//! Per-round records: everything Figs. 7–9 and Tables 1–2 read.
//!
//! One [`RoundRecord`] is one *server* round: all M workers in `Sync`
//! mode, the first-K quorum of arrivals in `SemiSync` mode, and a
//! single arrival in `Async` mode — `workers` holds exactly the
//! arrivals the server aggregated over when closing the round.

/// One worker's view of one communication round.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerRound {
    /// Worker index this entry belongs to (semi-sync/async records hold
    /// a subset of workers, so the position is not the identity).
    pub worker: usize,
    /// Bits actually sent on the uplink this round.
    pub up_bits: u64,
    /// Uplink transfer seconds.
    pub up_seconds: f64,
    /// Downlink (broadcast) transfer seconds for this worker.
    pub down_seconds: f64,
    /// Worker's training loss at the round's model estimate.
    pub loss: f64,
    /// Compression error ||û_m − u_m||² after the round (Fig. 9).
    pub compression_error: f64,
    /// The uplink bandwidth estimate the worker budgeted with.
    pub est_up_bps: f64,
    /// Ground-truth uplink bandwidth at round start (plots only).
    pub true_up_bps: f64,
    /// Seconds from the round's start until this worker's upload
    /// arrived at the server (straggler lag; 0 for arrivals that landed
    /// while the server idled at a round deadline).
    pub arrival_lag: f64,
    /// Server rounds completed between this worker's model snapshot and
    /// its upload arrival: 0 in `Sync`, > 0 for late semi-sync arrivals
    /// and asynchronous updates.
    pub staleness: u64,
}

/// One full communication round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub step: u64,
    /// Virtual time at the START of the round.
    pub t_start: f64,
    /// Wall (virtual) duration of the round: max over workers of
    /// down + compute + up (sync), time to the K-th arrival (semi-sync)
    /// or to the triggering arrival (async).
    pub duration: f64,
    /// Bits broadcast on the downlink during this round (same message
    /// to every worker in sync/semi-sync; in async, the triggering
    /// worker's per-channel refresh — plus every worker's bootstrap
    /// message on the first round, summed).
    pub down_bits: u64,
    /// The arrivals this round aggregated over, in worker-index order.
    pub workers: Vec<WorkerRound>,
    /// Mean worker loss (over the arrivals).
    pub loss: f64,
    /// Objective value at the server's model x (when the source can
    /// evaluate it; NaN otherwise).
    pub f_x: f64,
    /// Squared gradient-norm proxy: ||Σ w_m û_m||² (descent tracking).
    pub agg_norm_sq: f64,
}

impl RoundRecord {
    pub fn t_end(&self) -> f64 {
        self.t_start + self.duration
    }

    pub fn total_up_bits(&self) -> u64 {
        self.workers.iter().map(|w| w.up_bits).sum()
    }

    /// Number of uploads the server aggregated over this round (M in
    /// sync, the quorum K in semi-sync, 1 in async).
    pub fn n_arrivals(&self) -> usize {
        self.workers.len()
    }

    /// Largest arrival lag this round (the straggler tail).
    pub fn max_arrival_lag(&self) -> f64 {
        self.workers.iter().map(|w| w.arrival_lag).fold(0.0f64, f64::max)
    }

    /// Largest staleness among this round's arrivals.
    pub fn max_staleness(&self) -> u64 {
        self.workers.iter().map(|w| w.staleness).max().unwrap_or(0)
    }

    pub fn mean_compression_error(&self) -> f64 {
        if self.workers.is_empty() {
            return 0.0;
        }
        // tidy:allow(float-reduce) -- serial fold in worker order, deterministic
        self.workers.iter().map(|w| w.compression_error).sum::<f64>()
            / self.workers.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wr(worker: usize, bits: u64, err: f64, lag: f64, staleness: u64) -> WorkerRound {
        WorkerRound {
            worker,
            up_bits: bits,
            up_seconds: 1.0,
            down_seconds: 0.5,
            loss: 2.0,
            compression_error: err,
            est_up_bps: 1.0,
            true_up_bps: 1.0,
            arrival_lag: lag,
            staleness,
        }
    }

    #[test]
    fn aggregates() {
        let r = RoundRecord {
            step: 3,
            t_start: 10.0,
            duration: 2.5,
            down_bits: 64,
            workers: vec![wr(0, 100, 1.0, 1.5, 0), wr(1, 50, 3.0, 2.5, 2)],
            loss: 2.0,
            f_x: f64::NAN,
            agg_norm_sq: 0.0,
        };
        assert_eq!(r.t_end(), 12.5);
        assert_eq!(r.total_up_bits(), 150);
        assert_eq!(r.n_arrivals(), 2);
        assert_eq!(r.max_arrival_lag(), 2.5);
        assert_eq!(r.max_staleness(), 2);
        assert!((r.mean_compression_error() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_round_degenerates_gracefully() {
        let r = RoundRecord {
            step: 0,
            t_start: 0.0,
            duration: 1.0,
            down_bits: 0,
            workers: vec![],
            loss: 0.0,
            f_x: 0.0,
            agg_norm_sq: 0.0,
        };
        assert_eq!(r.n_arrivals(), 0);
        assert_eq!(r.max_arrival_lag(), 0.0);
        assert_eq!(r.max_staleness(), 0);
        assert_eq!(r.mean_compression_error(), 0.0);
    }
}
