//! Per-round records: everything Figs. 7–9 and Tables 1–2 read.

/// One worker's view of one communication round.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerRound {
    /// Bits actually sent on the uplink this round.
    pub up_bits: u64,
    /// Uplink transfer seconds.
    pub up_seconds: f64,
    /// Downlink (broadcast) transfer seconds for this worker.
    pub down_seconds: f64,
    /// Worker's training loss at the round's model estimate.
    pub loss: f64,
    /// Compression error ||û_m − u_m||² after the round (Fig. 9).
    pub compression_error: f64,
    /// The uplink bandwidth estimate the worker budgeted with.
    pub est_up_bps: f64,
    /// Ground-truth uplink bandwidth at round start (plots only).
    pub true_up_bps: f64,
}

/// One full communication round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub step: u64,
    /// Virtual time at the START of the round.
    pub t_start: f64,
    /// Wall (virtual) duration of the round: max over workers of
    /// down + compute + up.
    pub duration: f64,
    /// Bits broadcast on the downlink (same message to every worker).
    pub down_bits: u64,
    pub workers: Vec<WorkerRound>,
    /// Mean worker loss.
    pub loss: f64,
    /// Objective value at the server's model x (when the source can
    /// evaluate it; NaN otherwise).
    pub f_x: f64,
    /// Squared gradient-norm proxy: ||Σ w_m û_m||² (descent tracking).
    pub agg_norm_sq: f64,
}

impl RoundRecord {
    pub fn t_end(&self) -> f64 {
        self.t_start + self.duration
    }

    pub fn total_up_bits(&self) -> u64 {
        self.workers.iter().map(|w| w.up_bits).sum()
    }

    pub fn mean_compression_error(&self) -> f64 {
        if self.workers.is_empty() {
            return 0.0;
        }
        self.workers.iter().map(|w| w.compression_error).sum::<f64>()
            / self.workers.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wr(bits: u64, err: f64) -> WorkerRound {
        WorkerRound {
            up_bits: bits,
            up_seconds: 1.0,
            down_seconds: 0.5,
            loss: 2.0,
            compression_error: err,
            est_up_bps: 1.0,
            true_up_bps: 1.0,
        }
    }

    #[test]
    fn aggregates() {
        let r = RoundRecord {
            step: 3,
            t_start: 10.0,
            duration: 2.5,
            down_bits: 64,
            workers: vec![wr(100, 1.0), wr(50, 3.0)],
            loss: 2.0,
            f_x: f64::NAN,
            agg_norm_sq: 0.0,
        };
        assert_eq!(r.t_end(), 12.5);
        assert_eq!(r.total_up_bits(), 150);
        assert!((r.mean_compression_error() - 2.0).abs() < 1e-12);
    }
}
