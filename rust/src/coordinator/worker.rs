//! Worker-side state and the gradient computation abstraction.

use crate::bandwidth::{BandwidthMonitor, EwmaMonitor};
use crate::compress::Compressed;
use crate::ef21::Estimator;

/// Where update vectors come from. The quadratic workload implements
/// this in pure rust; the deep model implements it over the PJRT
/// runtime (`runtime::PjrtModelSource`) — the coordinator cannot tell
/// the difference, which is what keeps Python off the hot path.
pub trait GradientSource {
    /// Flat model dimension.
    fn dim(&self) -> usize;

    /// Compute worker `m`'s update u_m^k at the model estimate `x_hat`,
    /// writing it into `out` (len == dim). Returns the training loss at
    /// `x_hat` (NaN if the source has no loss notion).
    fn update(
        &mut self,
        worker: usize,
        step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64>;

    /// Virtual seconds one update computation takes (T_comp). The paper
    /// abstracts this as constant per task (§3.1).
    fn t_comp(&self) -> f64;

    /// Objective value at a model point, if computable (quadratic: f(x);
    /// deep model: None — loss is per-batch).
    fn objective(&self, _x: &[f32]) -> Option<f64> {
        None
    }
}

/// The paper's §4.1 synthetic source: full-batch gradient of the
/// quadratic, identical data on every worker (M=1 in the paper's
/// synthetic runs; with M>1 all workers agree, which keeps the
/// aggregation semantics intact).
pub struct QuadraticSource {
    pub q: crate::quadratic::Quadratic,
    pub t_comp: f64,
}

impl QuadraticSource {
    pub fn new(q: crate::quadratic::Quadratic, t_comp: f64) -> Self {
        Self { q, t_comp }
    }
}

impl GradientSource for QuadraticSource {
    fn dim(&self) -> usize {
        self.q.dim()
    }

    fn update(
        &mut self,
        _worker: usize,
        _step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64> {
        self.q.grad_into(x_hat, out);
        Ok(self.q.value(x_hat))
    }

    fn t_comp(&self) -> f64 {
        self.t_comp
    }

    fn objective(&self, x: &[f32]) -> Option<f64> {
        Some(self.q.value(x))
    }
}

/// Per-worker mutable state: the EF21 uplink estimator û_m, the local
/// mirror of x̂, the uplink bandwidth monitor, and scratch buffers
/// (allocation-free round loop — see EXPERIMENTS.md §Perf).
pub struct WorkerState {
    pub id: usize,
    pub u_hat: Estimator,
    pub monitor: Box<dyn BandwidthMonitor>,
    /// Scratch: the update vector u_m^k.
    pub u: Vec<f32>,
    /// Scratch: per-layer difference buffer.
    pub scratch: Vec<f32>,
    /// Scratch: full-dimension EF21 difference `u − û` — one per worker
    /// so the parallel round phase never shares mutable buffers.
    pub diff: Vec<f32>,
    /// Reusable compressed-message buffer (allocation-free rounds).
    pub msg: Compressed,
}

impl WorkerState {
    pub fn new(id: usize, dim: usize) -> Self {
        Self {
            id,
            u_hat: Estimator::zeros(dim),
            monitor: Box::new(EwmaMonitor::new(0.7)),
            u: vec![0.0; dim],
            scratch: Vec::with_capacity(dim),
            diff: vec![0.0; dim],
            msg: Compressed::default(),
        }
    }

    pub fn with_monitor(mut self, m: Box<dyn BandwidthMonitor>) -> Self {
        self.monitor = m;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quadratic::Quadratic;

    #[test]
    fn quadratic_source_grad_and_loss() {
        let mut src = QuadraticSource::new(Quadratic::new(vec![2.0, 4.0]), 0.1);
        let mut out = vec![0.0f32; 2];
        let loss = src.update(0, 0, &[1.0, 1.0], &mut out).unwrap();
        assert_eq!(out, vec![2.0, 4.0]);
        assert!((loss - 3.0).abs() < 1e-9);
        assert_eq!(src.t_comp(), 0.1);
        assert_eq!(src.objective(&[1.0, 1.0]), Some(3.0));
    }

    #[test]
    fn worker_state_dims() {
        let w = WorkerState::new(3, 10);
        assert_eq!(w.u_hat.dim(), 10);
        assert_eq!(w.u.len(), 10);
        assert_eq!(w.id, 3);
    }
}
