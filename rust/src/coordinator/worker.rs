//! Worker-side state, the gradient computation abstraction, and the
//! per-worker compute-time models (straggler profiles).

use crate::bandwidth::{BandwidthMonitor, EwmaMonitor};
use crate::compress::Compressed;
use crate::ef21::Estimator;
use crate::kimad::{SelectScratch, Selection};
use crate::util::rng::Rng;

/// How long one gradient computation takes on a given worker, as a
/// transformation of the workload's base `T_comp` (§3.1). Sampling is a
/// pure function of `(worker, round)`, so simulations stay
/// bit-reproducible regardless of event or thread order.
#[derive(Debug, Clone, PartialEq)]
pub enum ComputeModel {
    /// Every worker takes the base `T_comp` every round (the paper's
    /// homogeneous setting).
    Constant,
    /// Multiplicative lognormal jitter per `(worker, round)`:
    /// `T_comp · exp(σ z − σ²/2)` with `z ~ N(0,1)` — mean-preserving,
    /// so the average compute time stays the workload's `T_comp`.
    Lognormal { sigma: f64, seed: u64 },
    /// Trace-driven straggler profile: worker `m` always takes
    /// `T_comp · factors[m % len]`. An empty profile means no slowdown.
    Profile { factors: Vec<f64> },
}

impl ComputeModel {
    /// Virtual seconds worker `worker`'s computation takes in `round`.
    pub fn sample(&self, base: f64, worker: usize, round: u64) -> f64 {
        match self {
            ComputeModel::Constant => base,
            ComputeModel::Lognormal { sigma, seed } => {
                let mut rng = Rng::seed_from_u64(*seed)
                    .derive(worker as u64)
                    .derive(round.wrapping_add(1));
                let z = rng.normal();
                base * (sigma * z - 0.5 * sigma * sigma).exp()
            }
            ComputeModel::Profile { factors } => {
                if factors.is_empty() {
                    base
                } else {
                    base * factors[worker % factors.len()]
                }
            }
        }
    }
}

/// Where update vectors come from. The quadratic workload implements
/// this in pure rust; the deep model implements it over the PJRT
/// runtime (`runtime::PjrtModelSource`) — the coordinator cannot tell
/// the difference, which is what keeps Python off the hot path.
pub trait GradientSource {
    /// Flat model dimension.
    fn dim(&self) -> usize;

    /// Compute worker `m`'s update u_m^k at the model estimate `x_hat`,
    /// writing it into `out` (len == dim). Returns the training loss at
    /// `x_hat` (NaN if the source has no loss notion).
    fn update(
        &mut self,
        worker: usize,
        step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64>;

    /// Virtual seconds one update computation takes (T_comp). The paper
    /// abstracts this as constant per task (§3.1).
    fn t_comp(&self) -> f64;

    /// Objective value at a model point, if computable (quadratic: f(x);
    /// deep model: None — loss is per-batch).
    fn objective(&self, _x: &[f32]) -> Option<f64> {
        None
    }
}

/// The paper's §4.1 synthetic source: full-batch gradient of the
/// quadratic, identical data on every worker (M=1 in the paper's
/// synthetic runs; with M>1 all workers agree, which keeps the
/// aggregation semantics intact).
pub struct QuadraticSource {
    pub q: crate::quadratic::Quadratic,
    pub t_comp: f64,
}

impl QuadraticSource {
    pub fn new(q: crate::quadratic::Quadratic, t_comp: f64) -> Self {
        Self { q, t_comp }
    }
}

impl GradientSource for QuadraticSource {
    fn dim(&self) -> usize {
        self.q.dim()
    }

    fn update(
        &mut self,
        _worker: usize,
        _step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64> {
        self.q.grad_into(x_hat, out);
        Ok(self.q.value(x_hat))
    }

    fn t_comp(&self) -> f64 {
        self.t_comp
    }

    fn objective(&self, x: &[f32]) -> Option<f64> {
        Some(self.q.value(x))
    }
}

/// Per-worker mutable state: the EF21 uplink estimator û_m, the local
/// mirror of x̂, the uplink bandwidth monitor, and scratch buffers
/// (allocation-free round loop — see EXPERIMENTS.md §Perf).
pub struct WorkerState {
    pub id: usize,
    pub u_hat: Estimator,
    pub monitor: Box<dyn BandwidthMonitor>,
    /// Scratch: the update vector u_m^k.
    pub u: Vec<f32>,
    /// Scratch: per-layer difference buffer.
    pub scratch: Vec<f32>,
    /// Scratch: full-dimension EF21 difference `u − û` — one per worker
    /// so the parallel round phase never shares mutable buffers.
    pub diff: Vec<f32>,
    /// Reusable per-layer compressed-message buffers (allocation-free
    /// rounds). A worker has one upload in flight at a time, so these
    /// hold the wire content from compression (`ComputeDone`) until the
    /// server applies it on arrival (`UploadDone`).
    pub msgs: Vec<Compressed>,
    /// Reusable `A^compress` selection scratch for the uplink leg —
    /// per-worker, so the parallel worker phase never shares it.
    pub sel_scratch: SelectScratch,
    /// Reusable uplink selection result (paired with `sel_scratch`).
    pub sel: Selection,
}

impl WorkerState {
    pub fn new(id: usize, dim: usize) -> Self {
        Self {
            id,
            u_hat: Estimator::zeros(dim),
            monitor: Box::new(EwmaMonitor::new(0.7)),
            u: vec![0.0; dim],
            scratch: Vec::with_capacity(dim),
            diff: vec![0.0; dim],
            msgs: Vec::new(),
            sel_scratch: SelectScratch::default(),
            sel: Selection::default(),
        }
    }

    pub fn with_monitor(mut self, m: Box<dyn BandwidthMonitor>) -> Self {
        self.monitor = m;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quadratic::Quadratic;

    #[test]
    fn quadratic_source_grad_and_loss() {
        let mut src = QuadraticSource::new(Quadratic::new(vec![2.0, 4.0]), 0.1);
        let mut out = vec![0.0f32; 2];
        let loss = src.update(0, 0, &[1.0, 1.0], &mut out).unwrap();
        assert_eq!(out, vec![2.0, 4.0]);
        assert!((loss - 3.0).abs() < 1e-9);
        assert_eq!(src.t_comp(), 0.1);
        assert_eq!(src.objective(&[1.0, 1.0]), Some(3.0));
    }

    #[test]
    fn worker_state_dims() {
        let w = WorkerState::new(3, 10);
        assert_eq!(w.u_hat.dim(), 10);
        assert_eq!(w.u.len(), 10);
        assert_eq!(w.id, 3);
    }

    #[test]
    fn constant_model_is_identity() {
        let m = ComputeModel::Constant;
        assert_eq!(m.sample(0.25, 0, 0), 0.25);
        assert_eq!(m.sample(0.25, 7, 99), 0.25);
    }

    #[test]
    fn lognormal_model_is_deterministic_and_positive() {
        let m = ComputeModel::Lognormal { sigma: 0.4, seed: 11 };
        for w in 0..4 {
            for k in 0..8u64 {
                let a = m.sample(0.5, w, k);
                assert_eq!(a, m.sample(0.5, w, k), "pure in (worker, round)");
                assert!(a > 0.0);
            }
        }
        // Different (worker, round) pairs draw different jitter.
        assert_ne!(m.sample(0.5, 0, 0), m.sample(0.5, 1, 0));
        assert_ne!(m.sample(0.5, 0, 0), m.sample(0.5, 0, 1));
        // Mean-preserving within a loose sampling tolerance.
        let n = 2000;
        let mean: f64 =
            (0..n).map(|k| m.sample(1.0, 0, k as u64)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn profile_model_cycles_factors() {
        let m = ComputeModel::Profile { factors: vec![1.0, 4.0] };
        assert_eq!(m.sample(0.1, 0, 5), 0.1);
        assert!((m.sample(0.1, 1, 5) - 0.4).abs() < 1e-12);
        assert_eq!(m.sample(0.1, 2, 5), 0.1);
        let empty = ComputeModel::Profile { factors: vec![] };
        assert_eq!(empty.sample(0.1, 3, 0), 0.1);
    }
}
