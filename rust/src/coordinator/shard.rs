//! Layer-sharded server kernels: aggregation and broadcast.
//!
//! The server's per-round work — applying arrived uploads to the û_m
//! mirrors, reducing Σ w_m û_m, stepping the model, and the broadcast
//! compression phase (diff x − x̂, `A^compress` selection,
//! EF21 compress-advance) — is a per-coordinate pipeline over the flat
//! parameter vector. A [`ShardPlan`] partitions the model's
//! compression layers into contiguous *shards* (disjoint coordinate
//! spans), so that work fans out across scoped threads: each shard is
//! owned by exactly one thread for the duration of a batch, and no two
//! shards overlap.
//!
//! Shards are **views, not owners**: the flat vectors (`x`, `agg`, each
//! `Estimator::value`) stay contiguous — the gradient source and the
//! compressors need whole-model slices — and the plan hands out
//! disjoint `&mut [f32]` spans via `split_at_mut`.
//!
//! # Determinism
//!
//! Sharding never changes results, bit for bit, for any shard count:
//!
//! * every coordinate belongs to exactly one shard, and within a shard
//!   the per-coordinate operation order (zero, then worker 0's add,
//!   worker 1's add, …) is identical to the serialized loop;
//! * the reduction Σ w_m û_m runs in worker-index order inside every
//!   shard, so no floating-point sum is ever re-associated;
//! * scalar reductions that span shards (the aggregate's squared norm)
//!   are computed in a single ordered pass over the full vector *after*
//!   the parallel fill, never as per-shard partials — re-associating a
//!   non-associative f64 sum across a shard boundary would leak the
//!   shard count into the last bits;
//! * cross-layer *selection* passes in the broadcast kernel (the
//!   Kimad+ knapsack over per-layer error curves, the whole-model TopK
//!   quickselect) run as one ordered pass over the full difference
//!   vector / the full per-layer option table — only the per-layer
//!   work feeding them (curve builds) and following them
//!   (compress-advance) fans out, and the wire-bit total is an exact
//!   integer sum, associative under any regrouping.
//!
//! The serialized path (`parallel == false`, or one shard) performs the
//! exact same operations with zero heap allocations — the hot-path
//! bench guards this with a counting allocator. The parallel fan-out
//! allocates only its thread scope and per-shard slice lists, the same
//! class of cost the Sync upload batch already pays.

use crate::compress::{Compressed, Identity, TopK};
use crate::ef21::{compress_advance_span, Estimator};
use crate::kimad::{ErrorCurve, SelectScratch, Selection, Selector};
use crate::model::Layer;
use crate::netsim::Event;
use crate::optim::LayerwiseSgd;

use super::worker::WorkerState;

/// One shard: a contiguous run of layers and the coordinate span they
/// cover.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpan {
    /// First layer index (into the simulation's layer list).
    pub layer_lo: usize,
    /// One past the last layer index.
    pub layer_hi: usize,
    /// First flat-vector coordinate.
    pub coord_lo: usize,
    /// One past the last flat-vector coordinate.
    pub coord_hi: usize,
}

/// A partition of the model's layers into contiguous, size-balanced
/// shards (see the module docs for the determinism contract).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    spans: Vec<ShardSpan>,
    dim: usize,
}

impl ShardPlan {
    /// Partition `layers` into at most `n_shards` contiguous shards,
    /// greedily balanced by coordinate count (a shard never splits a
    /// layer — layers are the unit the compressed messages address).
    ///
    /// Layers must tile `[0, dim)` contiguously in order, which is what
    /// [`crate::model::ModelLayout`] produces.
    pub fn build(layers: &[Layer], n_shards: usize) -> Self {
        if layers.is_empty() {
            return Self { spans: Vec::new(), dim: 0 };
        }
        let mut off = 0usize;
        for l in layers {
            assert_eq!(l.offset, off, "layer '{}' breaks the contiguous tiling", l.name);
            off += l.size;
        }
        let dim = off;
        let n = n_shards.clamp(1, layers.len());
        let mut spans = Vec::with_capacity(n);
        let mut layer_lo = 0usize;
        let mut coord_lo = 0usize;
        for s in 0..n {
            // Remaining work split evenly over the remaining shards;
            // close this shard at the first layer boundary that reaches
            // its share (always at least one layer per shard).
            let remaining_shards = n - s;
            let target = (dim - coord_lo).div_ceil(remaining_shards);
            let mut layer_hi = layer_lo + 1;
            let mut coord_hi = layers[layer_lo].offset + layers[layer_lo].size;
            while layer_hi < layers.len()
                && layers.len() - layer_hi >= remaining_shards
                && coord_hi - coord_lo < target
            {
                coord_hi += layers[layer_hi].size;
                layer_hi += 1;
            }
            spans.push(ShardSpan { layer_lo, layer_hi, coord_lo, coord_hi });
            layer_lo = layer_hi;
            coord_lo = coord_hi;
        }
        debug_assert_eq!(spans.last().map(|s| s.coord_hi), Some(dim));
        debug_assert_eq!(spans.last().map(|s| s.layer_hi), Some(layers.len()));
        Self { spans, dim }
    }

    pub fn n_shards(&self) -> usize {
        self.spans.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn spans(&self) -> &[ShardSpan] {
        &self.spans
    }
}

/// Apply one worker's in-flight per-layer messages for the layers of
/// one shard to the matching span of its mirror. `mirror_span` is the
/// shard's slice of the estimator (starting at `span.coord_lo`).
fn apply_span(span: &ShardSpan, layers: &[Layer], msgs: &[Compressed], mirror_span: &mut [f32]) {
    let hi = span.layer_hi.min(msgs.len());
    if hi <= span.layer_lo {
        return;
    }
    for (l, msg) in layers[span.layer_lo..hi].iter().zip(&msgs[span.layer_lo..hi]) {
        let lo = l.offset - span.coord_lo;
        msg.add_into(&mut mirror_span[lo..lo + l.size]);
    }
}

/// Deliver a batch of upload arrivals (one [`Event`] per arriving
/// worker, worker-ascending) to the server's û_m mirrors, fanning the
/// per-layer applies across shards.
///
/// Mirrors of different workers are disjoint and each coordinate is
/// touched by at most one message, so serialized and sharded delivery
/// are bit-identical in any order; the batch exists so one scope
/// covers every apply of a timestamp.
pub fn deliver_batch(
    plan: &ShardPlan,
    layers: &[Layer],
    u_hats: &mut [Estimator],
    workers: &[WorkerState],
    batch: &[Event],
    parallel: bool,
) {
    debug_assert!(batch.windows(2).all(|w| w[0].worker < w[1].worker));
    if !parallel || plan.n_shards() <= 1 || batch.is_empty() {
        // Serialized path: allocation-free (hot-path bench guard).
        for ev in batch {
            let msgs = &workers[ev.worker].msgs;
            let mirror = &mut u_hats[ev.worker].value;
            for span in plan.spans() {
                apply_span(span, layers, msgs, &mut mirror[span.coord_lo..span.coord_hi]);
            }
        }
        return;
    }

    // Parallel fan-out: per shard, the list of (msgs, mirror span)
    // pairs of every batch worker; shards own disjoint spans, so the
    // scoped threads never alias.
    type ShardItems<'a> = Vec<(&'a [Compressed], &'a mut [f32])>;
    let n = plan.n_shards();
    let mut per_shard: Vec<ShardItems<'_>> =
        (0..n).map(|_| Vec::with_capacity(batch.len())).collect();
    let mut bi = 0usize;
    for (w, est) in u_hats.iter_mut().enumerate() {
        if bi >= batch.len() {
            break;
        }
        if batch[bi].worker != w {
            continue;
        }
        bi += 1;
        let msgs: &[Compressed] = &workers[w].msgs;
        let mut rest: &mut [f32] = &mut est.value;
        let mut prev = 0usize;
        for (si, span) in plan.spans().iter().enumerate() {
            let (head, tail) = rest.split_at_mut(span.coord_hi - prev);
            rest = tail;
            prev = span.coord_hi;
            per_shard[si].push((msgs, head));
        }
    }
    debug_assert_eq!(bi, batch.len(), "batch workers must exist in u_hats");
    std::thread::scope(|s| {
        let handles: Vec<_> = per_shard
            .into_iter()
            .enumerate()
            .map(|(si, items)| {
                let span = plan.spans()[si];
                s.spawn(move || {
                    for (msgs, mirror_span) in items {
                        apply_span(&span, layers, msgs, mirror_span);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("shard delivery thread panicked");
        }
    });
}

/// Fill `agg` with Σ w_m û_m shard by shard and return ‖agg‖²
/// (Algorithm 3 line 15's direction and the descent-tracking norm).
///
/// Within every shard the worker loop runs in index order — the same
/// per-coordinate operation sequence as the serialized reduction — and
/// the squared norm is a single ordered pass over the filled vector,
/// so the result is bit-identical for every shard count and for both
/// the serialized and parallel paths.
// tidy:alloc-free(aggregate)
pub fn aggregate(
    plan: &ShardPlan,
    weights: &[f64],
    u_hats: &[Estimator],
    agg: &mut [f32],
    parallel: bool,
) -> f64 {
    debug_assert_eq!(weights.len(), u_hats.len());
    debug_assert_eq!(agg.len(), plan.dim());
    let fill_span = |span: &ShardSpan, agg_span: &mut [f32]| {
        agg_span.iter_mut().for_each(|v| *v = 0.0);
        for (w, u_hat) in weights.iter().zip(u_hats) {
            let w = *w as f32;
            let src = &u_hat.value[span.coord_lo..span.coord_hi];
            for (a, &u) in agg_span.iter_mut().zip(src) {
                *a += w * u;
            }
        }
    };
    if !parallel || plan.n_shards() <= 1 {
        for span in plan.spans() {
            fill_span(span, &mut agg[span.coord_lo..span.coord_hi]);
        }
    } else {
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = &mut *agg;
            let mut prev = 0usize;
            let mut handles = Vec::with_capacity(plan.n_shards());
            for span in plan.spans() {
                let (head, tail) = rest.split_at_mut(span.coord_hi - prev);
                rest = tail;
                prev = span.coord_hi;
                let fill = &fill_span;
                handles.push(s.spawn(move || fill(span, head)));
            }
            for h in handles {
                h.join().expect("shard aggregate thread panicked");
            }
        });
    }
    // tidy:allow(float-reduce) -- serial fold in coordinate order, deterministic
    agg.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Step the model `x ← x − γ_i^k·scale · agg` layer by layer, fanned
/// across shards. Per-coordinate updates are independent, so sharding
/// is bit-identical to [`LayerwiseSgd::step_scaled`].
#[allow(clippy::too_many_arguments)] // mirrors step_scaled + (plan, parallel)
pub fn step(
    plan: &ShardPlan,
    opt: &LayerwiseSgd,
    k: usize,
    scale: f64,
    x: &mut [f32],
    agg: &[f32],
    layers: &[Layer],
    parallel: bool,
) {
    debug_assert_eq!(x.len(), agg.len());
    let step_span = |span: &ShardSpan, x_span: &mut [f32]| {
        for l in &layers[span.layer_lo..span.layer_hi] {
            let lo = l.offset - span.coord_lo;
            opt.step_layer(
                k,
                scale,
                l.id,
                &mut x_span[lo..lo + l.size],
                &agg[l.offset..l.offset + l.size],
            );
        }
    };
    if !parallel || plan.n_shards() <= 1 {
        for span in plan.spans() {
            step_span(span, &mut x[span.coord_lo..span.coord_hi]);
        }
    } else {
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = &mut *x;
            let mut prev = 0usize;
            let mut handles = Vec::with_capacity(plan.n_shards());
            for span in plan.spans() {
                let (head, tail) = rest.split_at_mut(span.coord_hi - prev);
                rest = tail;
                prev = span.coord_hi;
                let st = &step_span;
                handles.push(s.spawn(move || st(span, head)));
            }
            for h in handles {
                h.join().expect("shard step thread panicked");
            }
        });
    }
}

/// One reusable broadcast lane: the per-shard buffers the EF21
/// compress-advance needs (layer difference scratch + wire message).
/// One lane per shard, so the parallel fan-out never shares a mutable
/// buffer between threads.
#[derive(Debug, Clone, Default)]
struct BroadcastLane {
    scratch: Vec<f32>,
    msg: Compressed,
}

/// Reusable state of the sharded [`broadcast`] kernel: one lane per
/// shard plus the selection scratch. Owned by the simulation so
/// steady-state rounds are allocation-free on the serialized path (the
/// hot-path bench guards this; the parallel fan-out pays its thread
/// scopes, the same cost class as the other shard kernels).
#[derive(Debug, Clone, Default)]
pub struct BroadcastScratch {
    lanes: Vec<BroadcastLane>,
    select: SelectScratch,
    sel: Selection,
}

impl BroadcastScratch {
    /// Grow the lane set to cover `n_shards` (never shrinks — a plan
    /// oscillating between shard counts should not churn buffers).
    fn ensure(&mut self, n_shards: usize) {
        let want = n_shards.max(1);
        if self.lanes.len() < want {
            self.lanes.resize_with(want, BroadcastLane::default);
        }
    }
}

/// The server broadcast compression phase, fanned across layer shards:
/// fill `diff = x − x̂`, run the `A^compress` selection over `diff`
/// under the bit budget `c_down`, compress-advance the estimator layer
/// by layer, and return the total wire bits.
///
/// Both the shared-x̂ broadcast and the async per-worker x̂_m refresh
/// delegate here (with the worker's own mirror as `x_hat`), so the
/// broadcast path can never diverge between modes.
///
/// Sharding is bit-invariant, exactly like [`deliver_batch`] /
/// [`aggregate`] / [`step`]:
///
/// * the diff fill and the per-layer compress-advance touch each
///   coordinate with the same operation sequence as the serialized
///   loop (shards own disjoint spans and layers);
/// * the per-layer error curves (`KimadPlus`) are pure functions of
///   shard-local diff spans, so they ride the same fan-out, while the
///   cross-layer knapsack itself — like the whole-model TopK
///   quickselect — stays one ordered serial pass;
/// * the wire-bit total is a u64 sum over per-shard partials joined in
///   shard order — integer addition, exact under any grouping.
#[allow(clippy::too_many_arguments)] // the flattened borrow set of one broadcast
pub fn broadcast(
    plan: &ShardPlan,
    selector: &Selector,
    layers: &[Layer],
    c_down: u64,
    x: &[f32],
    x_hat: &mut Estimator,
    diff: &mut [f32],
    scratch: &mut BroadcastScratch,
    parallel: bool,
) -> u64 {
    broadcast_tapped(plan, selector, layers, c_down, x, x_hat, diff, scratch, parallel, None)
}

/// [`broadcast`] with an optional wire tap: when `tap` is `Some`, the
/// per-layer compress-advance messages are appended to it in layer
/// order — the transport layer's capture point for broadcast payload
/// bytes (the lane buffers are otherwise overwritten layer by layer).
/// A tapped call runs the serialized pass, which is bit-identical to
/// the sharded fan-out by the module determinism contract, so tapping
/// never changes results.
// tidy:alloc-free(broadcast)
#[allow(clippy::too_many_arguments)] // the flattened borrow set of one broadcast
pub fn broadcast_tapped(
    plan: &ShardPlan,
    selector: &Selector,
    layers: &[Layer],
    c_down: u64,
    x: &[f32],
    x_hat: &mut Estimator,
    diff: &mut [f32],
    scratch: &mut BroadcastScratch,
    parallel: bool,
    mut tap: Option<&mut Vec<Compressed>>,
) -> u64 {
    scratch.ensure(plan.n_shards());
    let BroadcastScratch { lanes, select, sel } = scratch;
    let par = parallel && plan.n_shards() > 1 && plan.dim() == diff.len() && tap.is_none();

    // ---- Phase 1: diff = x − x̂ (and, for curve-driven policies, the
    // per-layer error curves — shard-local work, same fan-out).
    if !par {
        // Chunked elementwise diff (bit-identical — util::chunk docs;
        // like the zip loop it replaces, it stops at the shortest
        // slice, which is what makes the `par` dim guard above safe).
        crate::util::chunk::diff_into(diff, x, &x_hat.value);
        // Curves (if any) build inside select_into, serially.
    } else {
        let mut curve_rest: Option<&mut [ErrorCurve]> = if selector.needs_curves() {
            Some(select.curves_mut(layers.len()))
        } else {
            None
        };
        std::thread::scope(|s| {
            let mut diff_rest: &mut [f32] = diff;
            let mut prev = 0usize;
            for span in plan.spans() {
                let (dhead, dtail) = diff_rest.split_at_mut(span.coord_hi - prev);
                diff_rest = dtail;
                prev = span.coord_hi;
                let chead = match curve_rest.take() {
                    None => None,
                    Some(c) => {
                        let (h, t) = c.split_at_mut(span.layer_hi - span.layer_lo);
                        curve_rest = Some(t);
                        Some(h)
                    }
                };
                let xs = &x[span.coord_lo..span.coord_hi];
                let xhs = &x_hat.value[span.coord_lo..span.coord_hi];
                let ls = &layers[span.layer_lo..span.layer_hi];
                let coord_lo = span.coord_lo;
                s.spawn(move || {
                    crate::util::chunk::diff_into(dhead, xs, xhs);
                    if let Some(curves) = chead {
                        for (l, slot) in ls.iter().zip(curves.iter_mut()) {
                            let lo = l.offset - coord_lo;
                            *slot = ErrorCurve::build(&dhead[lo..lo + l.size]);
                        }
                    }
                });
            }
        });
        if selector.needs_curves() {
            select.set_curves_ready();
        }
    }

    // ---- Phase 2: A^compress selection — cross-layer, one ordered
    // pass (see the module determinism contract).
    selector.select_into(diff, layers, c_down, select, sel);

    // ---- Phase 3: per-layer EF21 compress-advance, fanned across
    // shards; wire bits summed in shard order.
    let mut down_bits = 0u64;
    if !par {
        let lane = &mut lanes[0];
        for (l, &kk) in layers.iter().zip(&sel.k_per_layer) {
            let target = &x[l.offset..l.offset + l.size];
            let est = &mut x_hat.value[l.offset..l.offset + l.size];
            if kk >= l.size {
                compress_advance_span(&Identity, target, est, &mut lane.scratch, &mut lane.msg);
            } else {
                compress_advance_span(
                    &TopK::new(kk),
                    target,
                    est,
                    &mut lane.scratch,
                    &mut lane.msg,
                );
            }
            down_bits += lane.msg.wire_bits();
            if let Some(sink) = tap.as_deref_mut() {
                // tidy:allow(alloc-free) -- the wire tap copies messages off the hot path
                sink.push(lane.msg.clone());
            }
        }
    } else {
        std::thread::scope(|s| {
            let sel = &*sel;
            let mut est_rest: &mut [f32] = &mut x_hat.value;
            let mut prev = 0usize;
            let mut handles = Vec::with_capacity(plan.n_shards());
            for (span, lane) in plan.spans().iter().zip(lanes.iter_mut()) {
                let (head, tail) = est_rest.split_at_mut(span.coord_hi - prev);
                est_rest = tail;
                prev = span.coord_hi;
                let ls = &layers[span.layer_lo..span.layer_hi];
                let ks = &sel.k_per_layer[span.layer_lo..span.layer_hi];
                let span = *span;
                handles.push(s.spawn(move || {
                    let mut bits = 0u64;
                    for (l, &kk) in ls.iter().zip(ks) {
                        let target = &x[l.offset..l.offset + l.size];
                        let lo = l.offset - span.coord_lo;
                        let est = &mut head[lo..lo + l.size];
                        if kk >= l.size {
                            compress_advance_span(
                                &Identity,
                                target,
                                est,
                                &mut lane.scratch,
                                &mut lane.msg,
                            );
                        } else {
                            compress_advance_span(
                                &TopK::new(kk),
                                target,
                                est,
                                &mut lane.scratch,
                                &mut lane.msg,
                            );
                        }
                        bits += lane.msg.wire_bits();
                    }
                    bits
                }));
            }
            for h in handles {
                down_bits += h.join().expect("shard broadcast thread panicked");
            }
        });
    }
    down_bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelLayout;
    use crate::netsim::EventKind;
    use crate::optim::Schedule;

    fn layers(sizes: &[usize]) -> Vec<Layer> {
        ModelLayout::synthetic(sizes).layers()
    }

    #[test]
    fn plan_tiles_the_model() {
        let ls = layers(&[10, 30, 20, 40]);
        for n in 1..=6 {
            let plan = ShardPlan::build(&ls, n);
            assert_eq!(plan.dim(), 100);
            assert_eq!(plan.n_shards(), n.min(4));
            let spans = plan.spans();
            assert_eq!(spans[0].coord_lo, 0);
            assert_eq!(spans.last().unwrap().coord_hi, 100);
            for pair in spans.windows(2) {
                assert_eq!(pair[0].coord_hi, pair[1].coord_lo);
                assert_eq!(pair[0].layer_hi, pair[1].layer_lo);
            }
            for s in spans {
                assert!(s.layer_hi > s.layer_lo, "every shard owns >= 1 layer");
            }
        }
    }

    #[test]
    fn plan_balances_by_coordinates() {
        // 4 equal layers over 2 shards: 2 + 2 layers.
        let plan = ShardPlan::build(&layers(&[25, 25, 25, 25]), 2);
        assert_eq!(plan.n_shards(), 2);
        assert_eq!(plan.spans()[0].coord_hi, 50);
        // One huge head layer: it fills shard 0 alone.
        let plan = ShardPlan::build(&layers(&[90, 5, 5]), 2);
        assert_eq!(plan.spans()[0].layer_hi, 1);
        assert_eq!(plan.spans()[1].layer_lo, 1);
    }

    #[test]
    fn plan_clamps_and_handles_empty() {
        assert_eq!(ShardPlan::build(&layers(&[4, 4]), 99).n_shards(), 2);
        assert_eq!(ShardPlan::build(&layers(&[4, 4]), 0).n_shards(), 1);
        let empty = ShardPlan::build(&[], 4);
        assert_eq!(empty.n_shards(), 0);
        assert_eq!(empty.dim(), 0);
    }

    #[test]
    fn aggregate_matches_server_state_bitwise() {
        let ls = layers(&[7, 13, 9]);
        let dim = 29;
        let mut u_hats: Vec<Estimator> = (0..3).map(|_| Estimator::zeros(dim)).collect();
        for (wi, uh) in u_hats.iter_mut().enumerate() {
            for (i, v) in uh.value.iter_mut().enumerate() {
                *v = ((i * 31 + wi * 7) % 17) as f32 / 3.0 - 2.0;
            }
        }
        let weights = [0.5, 0.3, 0.2];
        let mut server = crate::coordinator::ServerState::new(vec![0.0; dim], 3);
        server.u_hats = u_hats.clone();
        let want_norm = server.aggregate(&weights);
        for n in [1usize, 2, 3] {
            for par in [false, true] {
                let plan = ShardPlan::build(&ls, n);
                let mut agg = vec![f32::NAN; dim];
                let norm = aggregate(&plan, &weights, &u_hats, &mut agg, par);
                assert_eq!(agg, server.agg, "shards={n} par={par}");
                assert_eq!(norm.to_bits(), want_norm.to_bits(), "shards={n} par={par}");
            }
        }
    }

    #[test]
    fn step_matches_layerwise_sgd_bitwise() {
        let ls = layers(&[8, 8, 8]);
        let opt = LayerwiseSgd::new(Schedule::Constant(0.05)).with_layer_weights(vec![1.0, 0.5]);
        let agg: Vec<f32> = (0..24).map(|i| (i as f32 - 12.0) / 5.0).collect();
        let mut want: Vec<f32> = vec![1.0; 24];
        opt.step_scaled(3, 0.7, &mut want, &agg, &ls);
        for n in [1usize, 2, 3] {
            for par in [false, true] {
                let plan = ShardPlan::build(&ls, n);
                let mut x = vec![1.0f32; 24];
                step(&plan, &opt, 3, 0.7, &mut x, &agg, &ls, par);
                assert_eq!(x, want, "shards={n} par={par}");
            }
        }
    }

    #[test]
    fn broadcast_matches_serialized_for_every_policy_and_shard_count() {
        use crate::kimad::CompressPolicy;
        let ls = layers(&[7, 13, 9, 11]);
        let dim = 40usize;
        let x: Vec<f32> = (0..dim).map(|i| ((i * 13 % 23) as f32) / 4.0 - 2.0).collect();
        for policy in [
            CompressPolicy::FixedRatio { ratio: 0.4 },
            CompressPolicy::KimadUniform,
            CompressPolicy::KimadPlus { discretization: 400, ratios: vec![] },
            CompressPolicy::WholeModelTopK,
        ] {
            let selector = Selector::new(policy.clone());
            for budget_k in [0u64, 5, 17, 100] {
                let c_down = budget_k * crate::kimad::select::SPARSE_COORD_BITS;
                // Serialized reference (1 shard, parallel off). Run two
                // rounds so the estimator state itself round-trips.
                let ref_plan = ShardPlan::build(&ls, 1);
                let mut want_hat = Estimator::zeros(dim);
                let mut diff = vec![0.0f32; dim];
                let mut scr = BroadcastScratch::default();
                let mut want_bits = Vec::new();
                for _ in 0..2 {
                    want_bits.push(broadcast(
                        &ref_plan,
                        &selector,
                        &ls,
                        c_down,
                        &x,
                        &mut want_hat,
                        &mut diff,
                        &mut scr,
                        false,
                    ));
                }
                for n in [2usize, 3, 4] {
                    for par in [false, true] {
                        let plan = ShardPlan::build(&ls, n);
                        let mut hat = Estimator::zeros(dim);
                        let mut diff = vec![0.0f32; dim];
                        let mut scr = BroadcastScratch::default();
                        let mut bits = Vec::new();
                        for _ in 0..2 {
                            bits.push(broadcast(
                                &plan,
                                &selector,
                                &ls,
                                c_down,
                                &x,
                                &mut hat,
                                &mut diff,
                                &mut scr,
                                par,
                            ));
                        }
                        assert_eq!(
                            bits, want_bits,
                            "{policy:?} budget_k={budget_k} shards={n} par={par}: bits"
                        );
                        assert_eq!(
                            hat.value, want_hat.value,
                            "{policy:?} budget_k={budget_k} shards={n} par={par}: x̂"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn deliver_batch_matches_serial_apply() {
        let ls = layers(&[4, 6, 5]);
        let dim = 15;
        let mk_workers = || -> Vec<WorkerState> {
            (0..3)
                .map(|w| {
                    let mut ws = WorkerState::new(w, dim);
                    ws.msgs = ls
                        .iter()
                        .map(|l| Compressed::Sparse {
                            dim: l.size,
                            idx: vec![0, (l.size - 1) as u32],
                            val: vec![w as f32 + 1.0, -(w as f32) - 0.5],
                        })
                        .collect();
                    ws
                })
                .collect()
        };
        let workers = mk_workers();
        let batch: Vec<Event> = [0usize, 2]
            .iter()
            .map(|&w| Event { time: 1.0, worker: w, kind: EventKind::UploadDone, round: 0 })
            .collect();
        // Serialized reference via Estimator::apply.
        let mut want: Vec<Estimator> = (0..3).map(|_| Estimator::zeros(dim)).collect();
        for ev in &batch {
            for (l, msg) in ls.iter().zip(&workers[ev.worker].msgs) {
                want[ev.worker].apply(msg, l);
            }
        }
        for n in [1usize, 2, 3] {
            for par in [false, true] {
                let plan = ShardPlan::build(&ls, n);
                let mut u_hats: Vec<Estimator> = (0..3).map(|_| Estimator::zeros(dim)).collect();
                deliver_batch(&plan, &ls, &mut u_hats, &workers, &batch, par);
                for (got, want) in u_hats.iter().zip(&want) {
                    assert_eq!(got.value, want.value, "shards={n} par={par}");
                }
            }
        }
    }
}
