//! Server-side state: the model x, the broadcast estimator x̂, and the
//! server's mirrors of every worker's û_m (Algorithm 3 line 14).

use crate::bandwidth::{BandwidthMonitor, EwmaMonitor};
use crate::ef21::Estimator;

pub struct ServerState {
    /// The global model x^k — only the server stores it (§3).
    pub x: Vec<f32>,
    /// Broadcast estimator x̂ (identical on server and all workers: it
    /// advances only by the broadcast compressed message, so one copy
    /// stands for both sides; the sync is asserted in tests).
    pub x_hat: Estimator,
    /// Per-worker broadcast mirrors x̂_m — populated only when the
    /// engine runs true per-worker broadcast channels (async mode, via
    /// [`with_per_worker_mirrors`](Self::with_per_worker_mirrors)).
    /// Empty = every worker shares `x_hat` (sync / semi-sync).
    pub x_hats: Vec<Estimator>,
    /// Server-side mirrors of the worker update estimators û_m.
    pub u_hats: Vec<Estimator>,
    /// Downlink bandwidth monitors, one per worker link.
    pub down_monitors: Vec<Box<dyn BandwidthMonitor>>,
    /// Scratch: aggregated direction Σ w_m û_m.
    pub agg: Vec<f32>,
    /// Scratch: compression difference buffer (warm-start exchanges;
    /// steady-state broadcasts use the shard kernel's per-shard lanes).
    pub scratch: Vec<f32>,
}

impl ServerState {
    pub fn new(x0: Vec<f32>, m: usize) -> Self {
        let dim = x0.len();
        Self {
            x: x0,
            x_hat: Estimator::zeros(dim),
            x_hats: Vec::new(),
            u_hats: (0..m).map(|_| Estimator::zeros(dim)).collect(),
            down_monitors: (0..m)
                .map(|_| Box::new(EwmaMonitor::new(0.7)) as Box<dyn BandwidthMonitor>)
                .collect(),
            agg: vec![0.0; dim],
            scratch: Vec::with_capacity(dim),
        }
    }

    /// Give every worker its own broadcast mirror x̂_m (the async
    /// engine's honest per-worker channel: each worker only ever sees
    /// what was actually compressed onto *its* downlink, instead of the
    /// shared-broadcast-channel abstraction where one x̂ stood for all).
    ///
    /// The mirrors start as dim-0 **copy-on-write placeholders**: until
    /// a worker's first broadcast, its channel is indistinguishable
    /// from the shared x̂ ([`model_estimate`](Self::model_estimate)
    /// falls back to it), so allocating M dense copies up front would
    /// buy nothing. [`materialize_mirror`](Self::materialize_mirror)
    /// clones the shared estimator into a slot on first use — O(active
    /// workers · d) instead of O(M · d).
    pub fn with_per_worker_mirrors(mut self) -> Self {
        self.x_hats = (0..self.u_hats.len()).map(|_| Estimator::zeros(0)).collect();
        self
    }

    /// The model estimate worker `worker` computes gradients at: its
    /// own mirror when per-worker channels are on *and* the mirror has
    /// been materialized, the shared broadcast estimator otherwise
    /// (empty-placeholder slots are copy-on-write views of x̂).
    pub fn model_estimate(&self, worker: usize) -> &[f32] {
        match self.x_hats.get(worker) {
            Some(xh) if !xh.value.is_empty() => &xh.value,
            _ => &self.x_hat.value,
        }
    }

    /// Materialize worker `worker`'s copy-on-write mirror: clone the
    /// shared x̂ into its slot iff it is still a dim-0 placeholder.
    /// Bit-identical to eager allocation because the shared estimator
    /// is static between mirror creation and first use (async rounds
    /// only ever advance the per-worker channels).
    pub fn materialize_mirror(&mut self, worker: usize) {
        if self.x_hats[worker].value.is_empty() {
            self.x_hats[worker] = self.x_hat.clone();
        }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    pub fn n_workers(&self) -> usize {
        self.u_hats.len()
    }

    /// Aggregate Σ w_m û_m into the scratch direction buffer and return
    /// its squared norm (Algorithm 3 line 15's direction).
    pub fn aggregate(&mut self, weights: &[f64]) -> f64 {
        debug_assert_eq!(weights.len(), self.u_hats.len());
        self.agg.iter_mut().for_each(|v| *v = 0.0);
        for (w, u_hat) in weights.iter().zip(&self.u_hats) {
            let w = *w as f32;
            for (a, &u) in self.agg.iter_mut().zip(&u_hat.value) {
                *a += w * u;
            }
        }
        // tidy:allow(float-reduce) -- serial fold in coordinate order, deterministic
        self.agg.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Conservative broadcast bandwidth estimate: the slowest worker's
    /// downlink (the broadcast is done when the last worker has it).
    pub fn broadcast_estimate(&self, prior: f64) -> f64 {
        self.down_monitors
            .iter()
            .map(|m| m.estimate_or(prior))
            .fold(f64::INFINITY, f64::min)
    }

    /// One worker's downlink estimate — what the async engine budgets a
    /// per-worker model refresh with (no other link is involved).
    pub fn down_estimate(&self, worker: usize, prior: f64) -> f64 {
        self.down_monitors[worker].estimate_or(prior)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_weighted() {
        let mut s = ServerState::new(vec![0.0; 2], 2);
        s.u_hats[0].value = vec![1.0, 0.0];
        s.u_hats[1].value = vec![0.0, 2.0];
        let norm = s.aggregate(&[0.5, 0.5]);
        assert_eq!(s.agg, vec![0.5, 1.0]);
        assert!((norm - 1.25).abs() < 1e-9);
    }

    #[test]
    fn broadcast_estimate_is_min() {
        let mut s = ServerState::new(vec![0.0; 1], 2);
        s.down_monitors[0].observe(100.0, 1.0);
        s.down_monitors[1].observe(10.0, 1.0);
        assert_eq!(s.broadcast_estimate(999.0), 10.0);
    }

    #[test]
    fn cold_start_uses_prior() {
        let s = ServerState::new(vec![0.0; 1], 2);
        assert_eq!(s.broadcast_estimate(42.0), 42.0);
    }

    #[test]
    fn model_estimate_prefers_per_worker_mirrors() {
        let shared = ServerState::new(vec![0.0; 2], 2);
        assert!(shared.x_hats.is_empty());
        assert_eq!(shared.model_estimate(1), shared.x_hat.value.as_slice());
        let mut per = ServerState::new(vec![0.0; 2], 2).with_per_worker_mirrors();
        assert_eq!(per.x_hats.len(), 2);
        per.x_hats[1].value = vec![3.0, 4.0];
        assert_eq!(per.model_estimate(0), &[0.0, 0.0]);
        assert_eq!(per.model_estimate(1), &[3.0, 4.0]);
    }

    #[test]
    fn mirrors_are_copy_on_write_placeholders() {
        // Creation costs O(M) slots, not O(M·d) floats: every slot is a
        // dim-0 placeholder until materialized.
        let mut s = ServerState::new(vec![0.0; 4], 3).with_per_worker_mirrors();
        assert!(s.x_hats.iter().all(|xh| xh.value.is_empty()));
        // Placeholder slots read through to the shared estimator.
        s.x_hat.value = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(s.model_estimate(2), &[1.0, 2.0, 3.0, 4.0]);
        // First use clones the shared channel; later materializations
        // are no-ops (the mirror now evolves independently).
        s.materialize_mirror(2);
        assert_eq!(s.x_hats[2].value, vec![1.0, 2.0, 3.0, 4.0]);
        s.x_hats[2].value[0] = 9.0;
        s.materialize_mirror(2);
        assert_eq!(s.x_hats[2].value, vec![9.0, 2.0, 3.0, 4.0]);
        // Untouched slots stay placeholders.
        assert!(s.x_hats[0].value.is_empty() && s.x_hats[1].value.is_empty());
    }

    #[test]
    fn down_estimate_is_per_link() {
        let mut s = ServerState::new(vec![0.0; 1], 2);
        s.down_monitors[0].observe(100.0, 1.0);
        assert_eq!(s.down_estimate(0, 7.0), 100.0);
        assert_eq!(s.down_estimate(1, 7.0), 7.0, "cold link falls back to the prior");
    }
}
