//! The Parameter-Server coordinator: Algorithm 3 over the netsim.
//!
//! This is the paper's system contribution wired together: per-endpoint
//! bandwidth monitors feed Eq. (2) budgets, `A^compress` picks
//! compressors, bidirectional EF21 estimators advance by compressed
//! differences, and the virtual clock advances by the max per-worker
//! round time (synchronous PS).
//!
//! Layer map:
//!   server.rs — server-side state (model x, x̂, û_m mirrors)
//!   worker.rs — worker-side state + the GradientSource abstraction
//!   round.rs  — per-round records the figures/tables read
//!   sim.rs    — the round loop itself

pub mod round;
pub mod server;
pub mod sim;
pub mod worker;

pub use round::{RoundRecord, WorkerRound};
pub use server::ServerState;
pub use sim::{SimConfig, Simulation};
pub use worker::{GradientSource, QuadraticSource, WorkerState};
