//! The Parameter-Server coordinator: Algorithm 3 over the netsim.
//!
//! This is the paper's system contribution wired together: per-endpoint
//! bandwidth monitors feed Eq. (2) budgets, `A^compress` picks
//! compressors, bidirectional EF21 estimators advance by compressed
//! differences, and the virtual clock advances event by event on the
//! netsim's deterministic queue — lockstep (`Sync`), first-K quorum
//! (`SemiSync`) or one step per arrival (`Async`); see
//! [`sim::ExecMode`].
//!
//! Layer map:
//!   server.rs     — server-side state (model x, x̂ / per-worker x̂_m
//!                   mirrors, û_m mirrors)
//!   worker.rs     — worker-side state, GradientSource, compute models
//!   shard.rs      — layer-sharded server kernels (ShardPlan + the
//!                   deliver/aggregate/step/broadcast kernels)
//!   round.rs      — per-round records the figures/tables read
//!   sim.rs        — the event-driven round engine (dense: every worker
//!                   materialized)
//!   population.rs — the population/cohort engine (M described, only the
//!                   sampled quorum materialized; O(quorum + cohorts)
//!                   state)
//!
//! See `docs/ARCHITECTURE.md` for the full data-flow walkthrough.

pub mod population;
pub mod round;
pub mod server;
pub mod shard;
pub mod sim;
pub mod worker;

pub use population::{sample_round, PopulationSim, PopulationSpec};
pub use round::{RoundRecord, WorkerRound};
pub use server::ServerState;
pub use shard::{BroadcastScratch, ShardPlan, ShardSpan};
pub use sim::{ExecMode, RoundWire, SimConfig, Simulation};
pub use worker::{ComputeModel, GradientSource, QuadraticSource, WorkerState};
