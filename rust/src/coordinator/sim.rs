//! The synchronous PS round loop (Algorithm 3) over virtual time.
//!
//! Round structure (M workers):
//!
//! 1. probe + broadcast selection + x̂ advance — serial (server state);
//! 2. gradient computation per worker — serial (the [`GradientSource`]
//!    is one mutable resource; PJRT executables are not re-entrant);
//! 3. **parallel worker phase** — each worker's downlink timing, uplink
//!    budget read, `A^compress` selection, EF21 compress-advance and
//!    uplink transfer run on a scoped thread pool. Every buffer the
//!    phase touches (monitor, û_m, the server's û_m mirror, diff/msg
//!    scratch) is owned per worker, so the phase is data-race-free by
//!    construction and bit-deterministic regardless of thread count;
//! 4. aggregation + optimizer step — serial, in worker-index order, so
//!    the f32 reduction order never depends on scheduling.

use crate::bandwidth::BandwidthMonitor;
use crate::compress::{Identity, TopK};
use crate::ef21::Estimator;
use crate::kimad::{compression_budget, BudgetParams, CompressPolicy, Selector};
use crate::model::Layer;
use crate::netsim::{Direction, NetSim};
use crate::optim::LayerwiseSgd;

use super::round::{RoundRecord, WorkerRound};
use super::server::ServerState;
use super::worker::{GradientSource, WorkerState};

/// Synthetic NIC-counter probe: bits/window observed by the continuous
/// bandwidth monitor each round (§2.4, §3).
const PROBE_BITS: f64 = 1.0e4;
const PROBE_WINDOW: f64 = 0.5;

/// Full experiment configuration for one simulated training run.
pub struct SimConfig {
    /// Number of workers M.
    pub m: usize,
    /// Aggregation weights w_m (empty = uniform 1/M).
    pub weights: Vec<f64>,
    /// Eq. (2) parameters (time budget).
    pub budget: BudgetParams,
    /// `A^compress` policy for worker→server messages.
    pub up_policy: CompressPolicy,
    /// `A^compress` policy for the server broadcast.
    pub down_policy: CompressPolicy,
    /// Server-side optimizer (γ^k, optional layer weights).
    pub optimizer: LayerwiseSgd,
    /// Compression layers (Kimad+ granularity).
    pub layers: Vec<Layer>,
    /// Initialize estimators from the first uncompressed round (the
    /// paper's §4.2 warmup) instead of zeros.
    pub warm_start: bool,
    /// Bandwidth prior for cold-start rounds (bits/s).
    pub prior_bps: f64,
    /// Synchronized round schedule: every round lasts at least this
    /// long (the user's time budget t — rounds are *scheduled* at this
    /// cadence: stragglers overrun it, fast rounds wait for it). None =
    /// free-running rounds.
    pub round_deadline: Option<f64>,
    /// Safety factor on the Eq. (2) budget (DC2-style conservatism):
    /// the bandwidth estimate is a trailing average, so budgeting at
    /// 100% of it overruns the deadline whenever bandwidth is falling.
    /// 1.0 = trust the estimate fully.
    pub budget_safety: f64,
    /// Worker-phase thread count: 0 = one thread per worker up to the
    /// machine's parallelism, 1 = serial, n = at most n threads. The
    /// simulation is bit-identical for every setting.
    pub threads: usize,
}

impl SimConfig {
    pub fn weights_or_uniform(&self) -> Vec<f64> {
        if self.weights.is_empty() {
            vec![1.0 / self.m as f64; self.m]
        } else {
            assert_eq!(self.weights.len(), self.m);
            self.weights.clone()
        }
    }
}

/// Auto mode (`threads == 0`) only goes parallel when the per-round
/// work amortizes the scoped-thread spawn cost (~tens of µs) — below
/// this many worker-elements the serial path is faster and keeps the
/// per-thread TopK scratch warm. An explicit `threads = n` always wins.
const PARALLEL_MIN_WORK: usize = 1 << 16;

fn effective_threads(requested: usize, m: usize, dim: usize) -> usize {
    let m = m.max(1);
    if requested != 0 {
        return requested.min(m);
    }
    if m < 2 || dim.saturating_mul(m) < PARALLEL_MIN_WORK {
        return 1;
    }
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    auto.min(m)
}

/// A running simulation: server + M workers + network + source.
pub struct Simulation<S: GradientSource> {
    pub cfg: SimConfig,
    pub net: NetSim,
    pub source: S,
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
    pub clock: f64,
    pub step: u64,
    weights: Vec<f64>,
    up_selector: Selector,
    down_selector: Selector,
    /// Reusable broadcast difference buffer (allocation-free rounds).
    diff: Vec<f32>,
    warmed: bool,
}

/// Shared, immutable inputs of one round's parallel worker phase.
struct RoundCtx<'a> {
    cfg: &'a SimConfig,
    net: &'a NetSim,
    up_selector: &'a Selector,
    t0: f64,
    t_comp: f64,
    down_bits: u64,
}

/// One worker's communication round: downlink timing, uplink budget
/// read "when communication is triggered" (§3.1), `A^compress`
/// selection, EF21 compress-advance mirrored onto the server, and the
/// uplink transfer. Touches only per-worker state (plus the read-only
/// [`RoundCtx`]), so workers run concurrently and deterministically.
fn worker_phase(
    ctx: &RoundCtx<'_>,
    loss: f64,
    w: &mut WorkerState,
    u_hat_mirror: &mut Estimator,
    down_monitor: &mut dyn BandwidthMonitor,
) -> WorkerRound {
    let down_tr = ctx
        .net
        .transfer(w.id, Direction::Down, ctx.t0, ctx.down_bits as f64);
    down_monitor.observe(ctx.down_bits as f64, down_tr.seconds);

    // Uplink budget read at upload time, after download and compute.
    let up_start = ctx.t0 + down_tr.seconds + ctx.t_comp;
    let b_probe = ctx
        .net
        .window_bps(w.id, Direction::Up, up_start, PROBE_WINDOW);
    w.monitor.observe(PROBE_BITS, PROBE_BITS / b_probe.max(1e-9));
    let true_up = ctx.net.true_bps(w.id, Direction::Up, up_start);
    let b_up = w.monitor.estimate_or(ctx.cfg.prior_bps);
    let c_up =
        (compression_budget(ctx.cfg.budget, b_up) as f64 * ctx.cfg.budget_safety) as u64;
    for (d, (&u, &uh)) in w.diff.iter_mut().zip(w.u.iter().zip(&w.u_hat.value)) {
        *d = u - uh;
    }
    let sel_up = ctx.up_selector.select(&w.diff, &ctx.cfg.layers, c_up);

    // Compress-advance û_m layer by layer, mirroring on the server.
    let mut up_bits = 0u64;
    for (l, &kk) in ctx.cfg.layers.iter().zip(&sel_up.k_per_layer) {
        let target = &w.u[l.offset..l.offset + l.size];
        if kk >= l.size {
            w.u_hat
                .compress_advance_into(&Identity, target, l, &mut w.scratch, &mut w.msg);
        } else {
            w.u_hat.compress_advance_into(
                &TopK::new(kk),
                target,
                l,
                &mut w.scratch,
                &mut w.msg,
            );
        }
        u_hat_mirror.apply(&w.msg, l);
        up_bits += w.msg.wire_bits();
    }

    let up_tr = ctx.net.transfer(w.id, Direction::Up, up_start, up_bits as f64);
    w.monitor.observe(up_bits as f64, up_tr.seconds);

    // Compression error ||û_m − u_m||² after the round (Fig. 9).
    let comp_err: f64 = w
        .u
        .iter()
        .zip(&w.u_hat.value)
        .map(|(&u, &uh)| ((u - uh) as f64).powi(2))
        .sum();

    WorkerRound {
        up_bits,
        up_seconds: up_tr.seconds,
        down_seconds: down_tr.seconds,
        loss,
        compression_error: comp_err,
        est_up_bps: b_up,
        true_up_bps: true_up,
    }
}

impl<S: GradientSource> Simulation<S> {
    pub fn new(cfg: SimConfig, net: NetSim, source: S, x0: Vec<f32>) -> Self {
        assert_eq!(net.n_workers(), cfg.m, "netsim links != M");
        assert_eq!(x0.len(), source.dim(), "x0 dim != source dim");
        let dim = x0.len();
        let weights = cfg.weights_or_uniform();
        let up_selector = Selector::new(cfg.up_policy.clone());
        let down_selector = Selector::new(cfg.down_policy.clone());
        let server = ServerState::new(x0, cfg.m);
        let workers = (0..cfg.m).map(|i| WorkerState::new(i, dim)).collect();
        Self {
            cfg,
            net,
            source,
            server,
            workers,
            clock: 0.0,
            step: 0,
            weights,
            up_selector,
            down_selector,
            diff: vec![0.0; dim],
            warmed: false,
        }
    }

    /// The warmup initialization (§4.2): one uncompressed exchange so
    /// x̂ = x⁰ and û_m = u_m⁰. Costs no virtual time (the paper runs 5
    /// warmup epochs outside the timed window).
    fn warm_start(&mut self) -> anyhow::Result<()> {
        let id = Identity;
        let layers = self.cfg.layers.clone();
        for l in &layers {
            let target = &self.server.x[l.offset..l.offset + l.size];
            self.server
                .x_hat
                .compress_advance(&id, target, l, &mut self.server.scratch);
        }
        for w in &mut self.workers {
            self.source
                .update(w.id, 0, &self.server.x_hat.value, &mut w.u)?;
            for l in &layers {
                let target = &w.u[l.offset..l.offset + l.size];
                let msg = w.u_hat.compress_advance(&id, target, l, &mut w.scratch);
                self.server.u_hats[w.id].apply(&msg, l);
            }
        }
        Ok(())
    }

    /// Execute one full communication round; returns its record.
    pub fn round(&mut self) -> anyhow::Result<RoundRecord> {
        if self.cfg.warm_start && !self.warmed {
            self.warm_start()?;
            self.warmed = true;
        }
        let k = self.step;
        let t0 = self.clock;
        let t_comp = self.source.t_comp();

        // ---- Continuous bandwidth monitoring (§2.4, §3): the monitor
        // samples the link each round (NIC-counter style), independent
        // of training traffic — without this, a zero-bit round would
        // starve the estimator at trough level forever. The observation
        // is the instantaneous rate at round start; the EWMA smooths it.
        for (i, mon) in self.server.down_monitors.iter_mut().enumerate() {
            let bd = self.net.window_bps(i, Direction::Down, t0, PROBE_WINDOW);
            mon.observe(PROBE_BITS, PROBE_BITS / bd.max(1e-9));
        }

        // ---- Server: select broadcast compressor under Eq. (2) budget.
        let b_down = self.server.broadcast_estimate(self.cfg.prior_bps);
        let c_down =
            (compression_budget(self.cfg.budget, b_down) as f64 * self.cfg.budget_safety) as u64;
        for (d, (&x, &xh)) in self
            .diff
            .iter_mut()
            .zip(self.server.x.iter().zip(&self.server.x_hat.value))
        {
            *d = x - xh;
        }
        let sel_down = self.down_selector.select(&self.diff, &self.cfg.layers, c_down);

        // ---- Server: compress-advance x̂ and measure the wire size.
        let mut down_bits = 0u64;
        for (l, &kk) in self.cfg.layers.iter().zip(&sel_down.k_per_layer) {
            let target = &self.server.x[l.offset..l.offset + l.size];
            if kk >= l.size {
                self.server.x_hat.compress_advance_into(
                    &Identity,
                    target,
                    l,
                    &mut self.server.scratch,
                    &mut self.server.msg,
                );
            } else {
                self.server.x_hat.compress_advance_into(
                    &TopK::new(kk),
                    target,
                    l,
                    &mut self.server.scratch,
                    &mut self.server.msg,
                );
            }
            down_bits += self.server.msg.wire_bits();
        }

        // ---- Gradient phase (serial: the source is one mutable
        // resource). Every worker computes at the same broadcast x̂.
        let mut losses = Vec::with_capacity(self.cfg.m);
        for w in &mut self.workers {
            let loss = self
                .source
                .update(w.id, k, &self.server.x_hat.value, &mut w.u)?;
            losses.push(loss);
        }

        // ---- Parallel worker phase: timing, budgets, selection, EF21.
        let n_threads = effective_threads(self.cfg.threads, self.cfg.m, self.server.dim());
        let ctx = RoundCtx {
            cfg: &self.cfg,
            net: &self.net,
            up_selector: &self.up_selector,
            t0,
            t_comp,
            down_bits,
        };
        let worker_rounds: Vec<WorkerRound> = if n_threads <= 1 {
            self.workers
                .iter_mut()
                .zip(self.server.u_hats.iter_mut())
                .zip(self.server.down_monitors.iter_mut())
                .zip(&losses)
                .map(|(((w, uh), dm), &loss)| worker_phase(&ctx, loss, w, uh, dm.as_mut()))
                .collect()
        } else {
            let chunk = self.cfg.m.div_ceil(n_threads);
            let workers = &mut self.workers;
            let u_hats = &mut self.server.u_hats;
            let down_monitors = &mut self.server.down_monitors;
            let ctx = &ctx;
            std::thread::scope(|s| {
                let handles: Vec<_> = workers
                    .chunks_mut(chunk)
                    .zip(u_hats.chunks_mut(chunk))
                    .zip(down_monitors.chunks_mut(chunk))
                    .zip(losses.chunks(chunk))
                    .map(|(((ws, us), ds), ls)| {
                        s.spawn(move || {
                            ws.iter_mut()
                                .zip(us.iter_mut())
                                .zip(ds.iter_mut())
                                .zip(ls)
                                .map(|(((w, uh), dm), &loss)| {
                                    worker_phase(ctx, loss, w, uh, dm.as_mut())
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                // Chunks rejoin in spawn order, so the concatenation is
                // exactly worker-index order — aggregation stays
                // deterministic no matter how the threads interleave.
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("worker phase thread panicked"))
                    .collect()
            })
        };
        let loss_sum: f64 = losses.iter().sum();
        let mut duration = worker_rounds
            .iter()
            .map(|w| w.down_seconds + t_comp + w.up_seconds)
            .fold(0.0f64, f64::max);

        // ---- Server: aggregate and step (Algorithm 3 line 15).
        // Zero-information rounds (every worker's budget rounded to no
        // coordinates) are deadline-preserving no-ops: stepping again on
        // the unchanged, stale estimators is outside the EF21 regime —
        // Theorem 1 requires contraction alpha_i > 0 — and measurably
        // destabilizes the quadratic workload during bandwidth troughs.
        let total_up: u64 = worker_rounds.iter().map(|w| w.up_bits).sum();
        let agg_norm_sq = if total_up > 0 || k == 0 {
            let n = self.server.aggregate(&self.weights);
            self.cfg.optimizer.step(
                k as usize,
                &mut self.server.x,
                &self.server.agg,
                &self.cfg.layers,
            );
            n
        } else {
            0.0
        };

        // Synchronized schedule: fast rounds wait for the deadline.
        if let Some(deadline) = self.cfg.round_deadline {
            duration = duration.max(deadline);
        }

        let f_x = self.source.objective(&self.server.x).unwrap_or(f64::NAN);
        self.clock = t0 + duration;
        self.step += 1;
        Ok(RoundRecord {
            step: k,
            t_start: t0,
            duration,
            down_bits,
            workers: worker_rounds,
            loss: loss_sum / self.cfg.m as f64,
            f_x,
            agg_norm_sq,
        })
    }

    /// Run `n` rounds, collecting the records.
    pub fn run(&mut self, n: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(self.round()?);
        }
        Ok(out)
    }

    /// Run until virtual time exceeds `deadline` seconds (or `max`
    /// rounds as a backstop).
    pub fn run_until(&mut self, deadline: f64, max: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::new();
        while self.clock < deadline && (out.len() as u64) < max {
            out.push(self.round()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::ConstantTrace;
    use crate::kimad::BudgetParams;
    use crate::netsim::Link;
    use crate::optim::{LayerwiseSgd, Schedule};
    use crate::quadratic::Quadratic;

    fn constant_net(m: usize, bps: f64) -> NetSim {
        NetSim::new(
            (0..m)
                .map(|_| {
                    Link::new(
                        Box::new(ConstantTrace::new(bps)),
                        Box::new(ConstantTrace::new(bps)),
                    )
                })
                .collect(),
        )
    }

    fn sim(
        m: usize,
        bps: f64,
        policy: CompressPolicy,
        gamma: f64,
    ) -> Simulation<crate::coordinator::QuadraticSource> {
        let q = Quadratic::paper_instance(30);
        let layout = q.layout(3);
        let layers = layout.layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: policy.clone(),
            down_policy: policy,
            optimizer: LayerwiseSgd::new(Schedule::Constant(gamma)),
            layers,
            warm_start: true,
            prior_bps: bps,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
            threads: 1,
        };
        Simulation::new(cfg, constant_net(m, bps), src, vec![1.0f32; 30])
    }

    #[test]
    fn identity_policy_matches_gd() {
        // Enough bandwidth for uncompressed rounds: Kimad = plain GD.
        let mut s = sim(2, 1e9, CompressPolicy::KimadUniform, 0.05);
        let recs = s.run(50).unwrap();
        assert!(recs.last().unwrap().f_x < 1e-3 * recs[0].f_x);
        // All coordinates kept: wire bits = dense encoding.
        assert_eq!(recs[5].down_bits, 30 * 32 + 3 * 32);
    }

    #[test]
    fn kimad_converges_under_tight_budget() {
        let mut s = sim(2, 64.0 * 8.0, CompressPolicy::KimadUniform, 0.02);
        let recs = s.run(400).unwrap();
        let first = recs[0].f_x;
        let last = recs.last().unwrap().f_x;
        assert!(last < first * 0.05, "f0={first} fK={last}");
    }

    #[test]
    fn budget_never_exceeded_by_uplink() {
        let bps = 64.0 * 4.0;
        let mut s = sim(3, bps, CompressPolicy::KimadUniform, 0.02);
        let recs = s.run(20).unwrap();
        for r in recs.iter().skip(1) {
            for w in &r.workers {
                // planned <= budget = t_comm * B (cold start skipped).
                assert!(w.up_bits as f64 <= bps * 1.0 + 64.0, "{}", w.up_bits);
            }
        }
    }

    #[test]
    fn round_time_includes_all_phases() {
        let mut s = sim(1, 1000.0, CompressPolicy::KimadUniform, 0.01);
        let r = s.round().unwrap();
        let w = &r.workers[0];
        let phases = w.down_seconds + 0.01 + w.up_seconds;
        // Deadline-scheduled: duration = max(phases, deadline).
        assert!((r.duration - phases.max(1.0)).abs() < 1e-12);
        assert!(r.t_start == 0.0 && s.clock == r.duration);
    }

    #[test]
    fn zero_budget_rounds_still_advance_clock() {
        // Near-zero bandwidth: Kimad sends ~nothing but the round still
        // takes the time budget (no zero-duration spinning).
        let mut s = sim(1, 2.0, CompressPolicy::KimadUniform, 0.01);
        let recs = s.run(5).unwrap();
        for r in &recs {
            assert!(r.duration >= 1.0);
        }
        assert!(s.clock >= 5.0);
        // And the model was not destabilized by the empty rounds.
        assert!(recs.last().unwrap().f_x.is_finite());
    }

    #[test]
    fn fixed_ratio_baseline_constant_bits() {
        let mut s = sim(2, 500.0, CompressPolicy::FixedRatio { ratio: 0.2 }, 0.02);
        let recs = s.run(5).unwrap();
        let bits0 = recs[1].workers[0].up_bits;
        for r in recs.iter().skip(1) {
            assert_eq!(r.workers[0].up_bits, bits0);
        }
    }

    #[test]
    fn kimad_plus_runs_and_converges() {
        let mut s = sim(
            2,
            64.0 * 8.0,
            CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
            0.02,
        );
        let recs = s.run(300).unwrap();
        assert!(recs.last().unwrap().f_x < recs[0].f_x * 0.1);
    }

    #[test]
    fn parallel_rounds_bit_match_serial() {
        // The tentpole guarantee: thread count never changes results.
        for policy in [
            CompressPolicy::KimadUniform,
            CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
            CompressPolicy::WholeModelTopK,
        ] {
            let mut serial = sim(4, 640.0, policy.clone(), 0.02);
            serial.cfg.threads = 1;
            let mut par2 = sim(4, 640.0, policy.clone(), 0.02);
            par2.cfg.threads = 2;
            let mut par_auto = sim(4, 640.0, policy.clone(), 0.02);
            par_auto.cfg.threads = 0;
            let a = serial.run(25).unwrap();
            let b = par2.run(25).unwrap();
            let c = par_auto.run(25).unwrap();
            assert_eq!(a, b, "{policy:?}: threads=2 diverged");
            assert_eq!(a, c, "{policy:?}: threads=auto diverged");
        }
    }

    #[test]
    fn thread_count_clamps() {
        // Explicit thread counts win regardless of work size.
        assert_eq!(effective_threads(1, 8, 30), 1);
        assert_eq!(effective_threads(16, 3, 30), 3);
        // Auto mode: small rounds stay serial, big ones parallelize.
        assert_eq!(effective_threads(0, 4, 30), 1);
        assert_eq!(effective_threads(0, 1, 10_000_000), 1);
        let big = effective_threads(0, 64, 1_000_000);
        assert!((1..=64).contains(&big));
    }

    #[test]
    fn ef21_estimator_error_shrinks_on_static_target() {
        // With a tiny learning rate the gradient barely moves, so the
        // EF21 error must contract round over round. Cold estimators
        // (no warmup) so the error starts large.
        let q = Quadratic::paper_instance(30);
        let layers = q.layout(3).layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m: 1,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadUniform,
            down_policy: CompressPolicy::FixedRatio { ratio: 1.0 },
            optimizer: LayerwiseSgd::new(Schedule::Constant(1e-6)),
            layers,
            warm_start: false,
            prior_bps: 128.0,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
            threads: 1,
        };
        let mut s = Simulation::new(cfg, constant_net(1, 128.0), src, vec![1.0f32; 30]);
        let recs = s.run(30).unwrap();
        let first = recs[2].workers[0].compression_error;
        let last = recs.last().unwrap().workers[0].compression_error;
        assert!(last < first, "first={first} last={last}");
    }
}
