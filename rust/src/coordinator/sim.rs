//! The Parameter-Server round engine (Algorithm 3) over virtual time,
//! event-driven.
//!
//! The engine schedules per-worker pipeline milestones — `BroadcastDone`
//! → `ComputeDone` → `UploadDone` — on the deterministic
//! [`EventQueue`](crate::netsim::EventQueue) and supports three
//! execution modes ([`ExecMode`]):
//!
//! * **Sync** — the paper's lockstep loop: every round barriers on all
//!   M uploads. Bit-identical to the pre-refactor loop for
//!   [`ComputeModel::Constant`] (proven against [`Simulation::round_reference`]
//!   in `tests/mode_matrix.rs`); with a straggler compute model the
//!   barrier waits for the slowest worker.
//! * **SemiSync** — the server closes a round after the first `quorum`
//!   upload arrivals; stragglers keep flying and their late uploads
//!   advance the server's EF21 mirrors when they land, carrying into
//!   the next round's aggregate.
//! * **Async** — the server steps on every upload arrival with a
//!   staleness-damped step size and immediately re-broadcasts the fresh
//!   model estimate to the triggering worker.
//!
//! # Determinism
//!
//! Every mode is bit-reproducible: the event queue's pop order is a
//! total order (time, kind, worker index), compute-time models are pure
//! functions of `(worker, round)`, and all floating-point reductions
//! run in worker-index order. The `threads` knob parallelizes the
//! Sync-mode upload batch (per-worker state is disjoint, so chunk
//! scheduling cannot change results).
//!
//! # Sharded server path
//!
//! Semi-sync and async rounds drain the event queue in **batches of
//! same-timestamp arrivals** ([`EventQueue::pop_batch_into`]) and fan
//! the server-side work — mirror delivery, the Σ w_m û_m reduction and
//! the optimizer step — across layer shards
//! ([`shard::ShardPlan`](super::shard)), so the aggregation path scales
//! with cores the way the Sync upload batch already does. The
//! **broadcast compression phase** (diff x − x̂, layer-wise budgeted
//! selection, EF21 compress-advance) rides the same shards
//! ([`shard::broadcast`](super::shard::broadcast)) in every mode,
//! including the async per-worker x̂_m refreshes. The `shards` knob on
//! [`Simulation`] (0 = auto) picks the shard count; results are
//! bit-identical for every shard count and thread count (see the shard
//! module's determinism contract and `tests/shard_matrix.rs`).
//!
//! Auto thread and shard resolution respects the cooperative
//! [`Simulation::thread_cap`] budget, so an outer pool (the scenario
//! matrix) can hand each simulation a slice of the machine instead of
//! every auto knob grabbing all cores at once.

use crate::bandwidth::BandwidthMonitor;
use crate::compress::{Compressed, Identity, TopK};
use crate::ef21::Estimator;
use crate::kimad::{effective_budget, BudgetParams, CompressPolicy, Selector};
use crate::model::Layer;
use crate::netsim::{Direction, Event, EventKind, EventQueue, NetSim};
use crate::optim::LayerwiseSgd;

use super::round::{RoundRecord, WorkerRound};
use super::server::ServerState;
use super::shard::{self, ShardPlan};
use super::worker::{ComputeModel, GradientSource, WorkerState};

/// Synthetic NIC-counter probe: bits/window observed by the continuous
/// bandwidth monitor each round (§2.4, §3). Crate-visible so the
/// population engine ([`super::population`]) probes identically.
pub(crate) const PROBE_BITS: f64 = 1.0e4;
pub(crate) const PROBE_WINDOW: f64 = 0.5;

/// Execution mode of the round engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExecMode {
    /// Lockstep rounds: every round aggregates all M uploads (the
    /// paper's synchronous loop).
    Sync,
    /// Partial participation: the server aggregates after the first
    /// `quorum` of M upload arrivals per round (clamped to `[1, M]`);
    /// late uploads advance the EF21 mirrors when they land.
    SemiSync { quorum: usize },
    /// Fully asynchronous: one server step per upload arrival, with the
    /// step size damped by `damping^staleness` (`damping` in `(0, 1]`;
    /// 1.0 = undamped). Ignores `round_deadline` — rounds are
    /// arrival-paced.
    Async { damping: f64 },
}

/// Full experiment configuration for one simulated training run.
pub struct SimConfig {
    /// Number of workers M.
    pub m: usize,
    /// Aggregation weights w_m (empty = uniform 1/M).
    pub weights: Vec<f64>,
    /// Eq. (2) parameters (time budget).
    pub budget: BudgetParams,
    /// `A^compress` policy for worker→server messages.
    pub up_policy: CompressPolicy,
    /// `A^compress` policy for the server broadcast.
    pub down_policy: CompressPolicy,
    /// Server-side optimizer (γ^k, optional layer weights).
    pub optimizer: LayerwiseSgd,
    /// Compression layers (Kimad+ granularity).
    pub layers: Vec<Layer>,
    /// Initialize estimators from the first uncompressed round (the
    /// paper's §4.2 warmup) instead of zeros.
    pub warm_start: bool,
    /// Bandwidth prior for cold-start rounds (bits/s).
    pub prior_bps: f64,
    /// Synchronized round schedule: every round lasts at least this
    /// long (the user's time budget t — rounds are *scheduled* at this
    /// cadence: stragglers overrun it, fast rounds wait for it). None =
    /// free-running rounds. Async mode ignores it.
    pub round_deadline: Option<f64>,
    /// Safety factor on the Eq. (2) budget (DC2-style conservatism):
    /// the bandwidth estimate is a trailing average, so budgeting at
    /// 100% of it overruns the deadline whenever bandwidth is falling.
    /// 1.0 = trust the estimate fully.
    pub budget_safety: f64,
    /// Sync-mode upload-batch thread count: 0 = one thread per worker
    /// up to the machine's parallelism, 1 = serial, n = at most n
    /// threads. Results are bit-identical for every setting and mode.
    pub threads: usize,
    /// Round-engine execution mode.
    pub mode: ExecMode,
    /// Per-worker compute-time model (straggler profiles).
    pub compute: ComputeModel,
}

impl SimConfig {
    pub fn weights_or_uniform(&self) -> Vec<f64> {
        if self.weights.is_empty() {
            vec![1.0 / self.m as f64; self.m]
        } else {
            assert_eq!(self.weights.len(), self.m);
            self.weights.clone()
        }
    }
}

/// Auto mode (`threads == 0`) only goes parallel when the per-round
/// work amortizes the scoped-thread spawn cost (~tens of µs) — below
/// this many worker-elements the serial path is faster and keeps the
/// per-thread TopK scratch warm. An explicit `threads = n` always wins.
const PARALLEL_MIN_WORK: usize = 1 << 16;

/// What "available parallelism" means under a cooperative thread
/// budget: the machine, bounded by `cap` when one is set (`cap == 0` =
/// uncapped). The scenario matrix hands every cell a cap so
/// matrix workers × per-cell auto threads never oversubscribes the box
/// (the pre-PR-4 bug: nested auto pools spawned up to N×N threads).
fn avail_within(cap: usize) -> usize {
    let machine = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cap == 0 {
        machine
    } else {
        cap.min(machine)
    }
}

pub(crate) fn effective_threads(requested: usize, m: usize, dim: usize, cap: usize) -> usize {
    let m = m.max(1);
    if requested != 0 {
        return requested.min(m);
    }
    if m < 2 || dim.saturating_mul(m) < PARALLEL_MIN_WORK {
        return 1;
    }
    avail_within(cap).min(m)
}

/// Auto shard count (`shards == 0`): one shard below the work floor
/// (per-round scoped-thread spawns only amortize on big models), else
/// up to one shard per core — bounded by the thread cap, never more
/// than one per layer. An explicit `shards = n` always wins (clamped
/// to the layer count) — results are bit-identical either way, so
/// forcing small-model runs parallel is purely a testing device.
pub(crate) fn effective_shards(requested: usize, n_layers: usize, dim: usize, cap: usize) -> usize {
    let layer_cap = n_layers.max(1);
    if requested != 0 {
        return requested.min(layer_cap);
    }
    if n_layers < 2 || dim < PARALLEL_MIN_WORK {
        return 1;
    }
    avail_within(cap).min(layer_cap)
}

/// Shared, immutable inputs of a worker upload leg. Crate-visible so
/// the population engine ([`super::population`]) reuses the exact same
/// leg kernel (bit-identity at p = 1 is by construction, not by test
/// alone).
pub(crate) struct UploadCtx<'a> {
    pub(crate) cfg: &'a SimConfig,
    pub(crate) net: &'a NetSim,
    pub(crate) up_selector: &'a Selector,
}

/// What one upload leg produced (recorded when the upload arrives).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct UploadLeg {
    pub(crate) up_bits: u64,
    pub(crate) up_seconds: f64,
    pub(crate) est_up_bps: f64,
    pub(crate) true_up_bps: f64,
    pub(crate) compression_error: f64,
}

/// One worker's uplink leg at `up_start` ("when communication is
/// triggered", §3.1): bandwidth probe, Eq. (2) budget read,
/// `A^compress` selection, EF21 compress-advance into the worker's
/// in-flight per-layer message buffers, and the uplink transfer
/// timing. Touches only per-worker state, so legs run concurrently and
/// deterministically. The server's û_m mirror is NOT advanced here —
/// the wire content stays in `w.msgs` until the upload *arrives*
/// ([`deliver_upload`]), which is what makes async aggregation honest
/// about in-flight data.
pub(crate) fn upload_leg(ctx: &UploadCtx<'_>, w: &mut WorkerState, up_start: f64) -> UploadLeg {
    let b_probe = ctx.net.window_bps(w.id, Direction::Up, up_start, PROBE_WINDOW);
    w.monitor.observe(PROBE_BITS, PROBE_BITS / b_probe.max(1e-9));
    let true_up = ctx.net.true_bps(w.id, Direction::Up, up_start);
    let b_up = w.monitor.estimate_or(ctx.cfg.prior_bps);
    let c_up = effective_budget(ctx.cfg.budget, b_up, ctx.cfg.budget_safety);
    // Chunked elementwise diff (bit-identical — util::chunk docs).
    crate::util::chunk::diff_into(&mut w.diff, &w.u, &w.u_hat.value);
    // Allocation-free selection into the worker's reusable scratch
    // (bit-identical to `select` — it IS `select` minus the builds).
    ctx.up_selector
        .select_into(&w.diff, &ctx.cfg.layers, c_up, &mut w.sel_scratch, &mut w.sel);

    if w.msgs.len() < ctx.cfg.layers.len() {
        w.msgs.resize_with(ctx.cfg.layers.len(), Compressed::default);
    }
    let mut up_bits = 0u64;
    for (i, (l, &kk)) in ctx.cfg.layers.iter().zip(&w.sel.k_per_layer).enumerate() {
        let target = &w.u[l.offset..l.offset + l.size];
        if kk >= l.size {
            w.u_hat.compress_advance_into(&Identity, target, l, &mut w.scratch, &mut w.msgs[i]);
        } else {
            w.u_hat.compress_advance_into(
                &TopK::new(kk),
                target,
                l,
                &mut w.scratch,
                &mut w.msgs[i],
            );
        }
        up_bits += w.msgs[i].wire_bits();
    }

    let up_tr = ctx.net.transfer(w.id, Direction::Up, up_start, up_bits as f64);
    w.monitor.observe(up_bits as f64, up_tr.seconds);

    // Compression error ||û_m − u_m||² after the round (Fig. 9).
    let comp_err: f64 = w
        .u
        .iter()
        .zip(&w.u_hat.value)
        .map(|(&u, &uh)| ((u - uh) as f64).powi(2))
        // tidy:allow(float-reduce) -- serial fold in coordinate order, deterministic
        .sum();

    UploadLeg {
        up_bits,
        up_seconds: up_tr.seconds,
        est_up_bps: b_up,
        true_up_bps: true_up,
        compression_error: comp_err,
    }
}

/// Server side of an upload arrival: advance the û_m mirror by the
/// worker's in-flight per-layer messages.
pub(crate) fn deliver_upload(mirror: &mut Estimator, layers: &[Layer], msgs: &[Compressed]) {
    for (l, msg) in layers.iter().zip(msgs) {
        mirror.apply(msg, l);
    }
}

/// Shared, immutable inputs of one reference round's parallel worker
/// phase (the frozen pre-refactor loop).
struct RoundCtx<'a> {
    up: UploadCtx<'a>,
    t0: f64,
    t_comp: f64,
    down_bits: u64,
}

/// One worker's communication round in the frozen pre-refactor loop:
/// downlink timing, uplink leg, immediate mirror delivery (the
/// synchronous barrier makes delivery time irrelevant).
fn worker_phase(
    ctx: &RoundCtx<'_>,
    loss: f64,
    w: &mut WorkerState,
    u_hat_mirror: &mut Estimator,
    down_monitor: &mut dyn BandwidthMonitor,
) -> WorkerRound {
    let down_tr = ctx.up.net.transfer(w.id, Direction::Down, ctx.t0, ctx.down_bits as f64);
    down_monitor.observe(ctx.down_bits as f64, down_tr.seconds);

    // Uplink budget read at upload time, after download and compute.
    let up_start = ctx.t0 + down_tr.seconds + ctx.t_comp;
    let leg = upload_leg(&ctx.up, w, up_start);
    deliver_upload(u_hat_mirror, &ctx.up.cfg.layers, &w.msgs);

    WorkerRound {
        worker: w.id,
        up_bits: leg.up_bits,
        up_seconds: leg.up_seconds,
        down_seconds: down_tr.seconds,
        loss,
        compression_error: leg.compression_error,
        est_up_bps: leg.est_up_bps,
        true_up_bps: leg.true_up_bps,
        arrival_lag: down_tr.seconds + ctx.t_comp + leg.up_seconds,
        staleness: 0,
    }
}

/// Per-worker in-flight pipeline bookkeeping (event engine).
#[derive(Debug, Clone, Copy, Default)]
struct Chain {
    busy: bool,
    /// Server rounds completed when the gradient snapshot was taken.
    snapshot_step: u64,
    down_seconds: f64,
    t_comp: f64,
    /// ComputeDone time: chain start + down + compute (the upload
    /// trigger).
    up_start: f64,
    loss: f64,
    leg: UploadLeg,
}

/// A running simulation: server + M workers + network + source.
pub struct Simulation<S: GradientSource> {
    pub cfg: SimConfig,
    pub net: NetSim,
    pub source: S,
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
    pub clock: f64,
    pub step: u64,
    /// Server-shard count for the aggregation and broadcast paths: 0 =
    /// auto (one shard per core on big models, serial otherwise), n =
    /// at most n shards (clamped to the layer count). Results are
    /// bit-identical for every setting — the knob only trades spawn
    /// overhead for parallelism (see [`super::shard`]).
    pub shards: usize,
    /// Cooperative thread budget: an upper bound on what the *auto*
    /// knobs (`threads == 0`, `shards == 0`) may resolve to (0 = the
    /// machine's parallelism). Set per cell by the scenario matrix so
    /// matrix workers × per-cell threads never exceeds the box;
    /// results are unaffected (thread and shard counts are
    /// bit-invariant).
    pub thread_cap: usize,
    weights: Vec<f64>,
    up_selector: Selector,
    down_selector: Selector,
    /// Reusable broadcast difference buffer (allocation-free rounds).
    diff: Vec<f32>,
    warmed: bool,
    queue: EventQueue,
    chains: Vec<Chain>,
    /// Layer-shard partition of the server path, rebuilt only when the
    /// `shards` knob changes (allocation-free steady state).
    plan: ShardPlan,
    /// Reusable sharded-broadcast scratch (per-shard lanes + selection
    /// buffers — allocation-free steady state on the serialized path).
    bcast: shard::BroadcastScratch,
    /// Reusable same-timestamp event batch buffer.
    batch: Vec<Event>,
    /// When set, every Sync round stores its wire-visible messages in
    /// `last_wire` for the transport layer ([`Self::take_wire`]).
    /// Results are unaffected: the tap only copies messages the round
    /// already produced.
    pub wire_tap: bool,
    /// Scratch the tapped broadcast kernel appends per-layer messages
    /// to (drained into `last_wire` at the end of the round).
    wire_bcast: Vec<Compressed>,
    last_wire: Option<RoundWire>,
}

/// One Sync round's wire-visible content, captured when
/// [`Simulation::wire_tap`] is set: exactly the bytes that cross a
/// real wire in the multi-process transport, excluding timestamps.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RoundWire {
    /// The round index the capture belongs to.
    pub step: u64,
    /// Per-layer broadcast messages, in layer order (identical for
    /// every worker in Sync mode — the server state is shared).
    pub broadcast: Vec<Compressed>,
    /// Per-worker upload messages (`uploads[m][l]` = worker m, layer
    /// l), in worker-index order.
    pub uploads: Vec<Vec<Compressed>>,
}

impl<S: GradientSource> Simulation<S> {
    pub fn new(cfg: SimConfig, net: NetSim, source: S, x0: Vec<f32>) -> Self {
        assert_eq!(net.n_workers(), cfg.m, "netsim links != M");
        assert_eq!(x0.len(), source.dim(), "x0 dim != source dim");
        if let ExecMode::Async { damping } = cfg.mode {
            assert!(
                damping > 0.0 && damping <= 1.0,
                "async staleness damping must be in (0, 1], got {damping}"
            );
        }
        let dim = x0.len();
        let weights = cfg.weights_or_uniform();
        let up_selector = Selector::new(cfg.up_policy.clone());
        let down_selector = Selector::new(cfg.down_policy.clone());
        let server = if matches!(cfg.mode, ExecMode::Async { .. }) {
            // Async gets honest per-worker broadcast channels.
            ServerState::new(x0, cfg.m).with_per_worker_mirrors()
        } else {
            ServerState::new(x0, cfg.m)
        };
        let workers = (0..cfg.m).map(|i| WorkerState::new(i, dim)).collect();
        let chains = vec![Chain::default(); cfg.m];
        let plan = ShardPlan::build(&cfg.layers, effective_shards(0, cfg.layers.len(), dim, 0));
        Self {
            cfg,
            net,
            source,
            server,
            workers,
            clock: 0.0,
            step: 0,
            shards: 0,
            thread_cap: 0,
            weights,
            up_selector,
            down_selector,
            diff: vec![0.0; dim],
            warmed: false,
            queue: EventQueue::new(),
            chains,
            plan,
            bcast: shard::BroadcastScratch::default(),
            batch: Vec::new(),
            wire_tap: false,
            wire_bcast: Vec::new(),
            last_wire: None,
        }
    }

    /// Take the last Sync round's captured wire content. `None` when
    /// the tap is off or no round has run since the last take.
    pub fn take_wire(&mut self) -> Option<RoundWire> {
        self.last_wire.take()
    }

    /// Rebuild the shard plan iff the `shards` knob changed since the
    /// last round (steady-state rounds never allocate here).
    fn ensure_plan(&mut self) {
        let n = effective_shards(
            self.shards,
            self.cfg.layers.len(),
            self.server.dim(),
            self.thread_cap,
        );
        if self.plan.n_shards() != n && !self.cfg.layers.is_empty() {
            self.plan = ShardPlan::build(&self.cfg.layers, n);
        }
    }

    /// The warmup initialization (§4.2): one uncompressed exchange so
    /// x̂ = x⁰ and û_m = u_m⁰. Costs no virtual time (the paper runs 5
    /// warmup epochs outside the timed window).
    fn warm_start(&mut self) -> anyhow::Result<()> {
        let id = Identity;
        let layers = self.cfg.layers.clone();
        for l in &layers {
            let target = &self.server.x[l.offset..l.offset + l.size];
            self.server
                .x_hat
                .compress_advance(&id, target, l, &mut self.server.scratch);
        }
        for w in &mut self.workers {
            self.source
                .update(w.id, 0, &self.server.x_hat.value, &mut w.u)?;
            for l in &layers {
                let target = &w.u[l.offset..l.offset + l.size];
                let msg = w.u_hat.compress_advance(&id, target, l, &mut w.scratch);
                self.server.u_hats[w.id].apply(&msg, l);
            }
        }
        // Per-worker broadcast mirrors (async channels) warm to the
        // same x⁰ as the shared estimator. Copy-on-write placeholders
        // (dim 0) stay untouched: they already read through to the
        // freshly-warmed shared x̂ and will clone it on first use.
        let ServerState { x, x_hats, scratch, .. } = &mut self.server;
        for xh in x_hats.iter_mut().filter(|xh| !xh.value.is_empty()) {
            for l in &layers {
                let target = &x[l.offset..l.offset + l.size];
                xh.compress_advance(&id, target, l, scratch);
            }
        }
        Ok(())
    }

    /// Continuous bandwidth monitoring (§2.4, §3): sample every
    /// downlink each round (NIC-counter style), independent of training
    /// traffic — without this, a zero-bit round would starve the
    /// estimator at trough level forever.
    fn probe_down_monitors(&mut self, t0: f64) {
        for (i, mon) in self.server.down_monitors.iter_mut().enumerate() {
            let bd = self.net.window_bps(i, Direction::Down, t0, PROBE_WINDOW);
            mon.observe(PROBE_BITS, PROBE_BITS / bd.max(1e-9));
        }
    }

    /// Server broadcast phase: Eq. (2) budget at bandwidth estimate
    /// `b_down`, `A^compress` selection over x − x̂, compress-advance of
    /// the shared x̂ — fanned across the layer shards
    /// ([`shard::broadcast`], bit-identical to the serialized pass for
    /// any shard count). Returns the wire size of the broadcast
    /// message.
    fn broadcast_phase(&mut self, b_down: f64) -> u64 {
        let c_down = effective_budget(self.cfg.budget, b_down, self.cfg.budget_safety);
        self.wire_bcast.clear();
        let tap = if self.wire_tap { Some(&mut self.wire_bcast) } else { None };
        let ServerState { x, x_hat, .. } = &mut self.server;
        shard::broadcast_tapped(
            &self.plan,
            &self.down_selector,
            &self.cfg.layers,
            c_down,
            x,
            x_hat,
            &mut self.diff,
            &mut self.bcast,
            self.plan.n_shards() > 1,
            tap,
        )
    }

    /// [`broadcast_phase`](Self::broadcast_phase) for one worker's own
    /// channel: diff and compress-advance against that worker's x̂_m
    /// mirror under that link's budget (async per-worker channels) —
    /// through the same sharded kernel.
    fn broadcast_phase_for(&mut self, worker: usize, b_down: f64) -> u64 {
        let c_down = effective_budget(self.cfg.budget, b_down, self.cfg.budget_safety);
        // First broadcast on this channel: materialize the worker's
        // copy-on-write mirror from the shared estimator (bit-identical
        // to eager allocation — x̂ is static while mirrors are in play).
        self.server.materialize_mirror(worker);
        let ServerState { x, x_hats, .. } = &mut self.server;
        shard::broadcast(
            &self.plan,
            &self.down_selector,
            &self.cfg.layers,
            c_down,
            x,
            &mut x_hats[worker],
            &mut self.diff,
            &mut self.bcast,
            self.plan.n_shards() > 1,
        )
    }

    /// Start one worker's pipeline chain: the broadcast transfer on its
    /// downlink, ending in a `BroadcastDone` event.
    fn begin_chain(&mut self, w: usize, t: f64, down_bits: u64, round: u64) {
        let tr = self.net.transfer(w, Direction::Down, t, down_bits as f64);
        self.server.down_monitors[w].observe(down_bits as f64, tr.seconds);
        self.chains[w] = Chain {
            busy: true,
            snapshot_step: self.step,
            down_seconds: tr.seconds,
            t_comp: 0.0,
            up_start: 0.0,
            loss: f64::NAN,
            leg: UploadLeg::default(),
        };
        self.queue.push(Event {
            time: t + tr.seconds,
            worker: w,
            kind: EventKind::BroadcastDone,
            round,
        });
    }

    /// `BroadcastDone`: snapshot the model estimate (the worker's own
    /// mirror under async per-worker channels, the shared x̂ otherwise),
    /// compute the gradient (the source is one mutable resource —
    /// handlers run serially in deterministic event order), schedule
    /// `ComputeDone`.
    fn on_broadcast_done(&mut self, ev: &Event) -> anyhow::Result<()> {
        let w = ev.worker;
        self.chains[w].snapshot_step = self.step;
        let loss = self
            .source
            .update(w, ev.round, self.server.model_estimate(w), &mut self.workers[w].u)?;
        let t_comp = self.cfg.compute.sample(self.source.t_comp(), w, ev.round);
        self.chains[w].loss = loss;
        self.chains[w].t_comp = t_comp;
        self.queue.push(Event {
            time: ev.time + t_comp,
            worker: w,
            kind: EventKind::ComputeDone,
            round: ev.round,
        });
        Ok(())
    }

    /// `ComputeDone`: run the uplink leg and schedule `UploadDone`.
    fn on_compute_done(&mut self, ev: &Event) {
        let w = ev.worker;
        let uctx = UploadCtx { cfg: &self.cfg, net: &self.net, up_selector: &self.up_selector };
        let leg = upload_leg(&uctx, &mut self.workers[w], ev.time);
        self.chains[w].up_start = ev.time;
        self.chains[w].leg = leg;
        self.queue.push(Event {
            time: ev.time + leg.up_seconds,
            worker: w,
            kind: EventKind::UploadDone,
            round: ev.round,
        });
    }

    /// Close the chain of an upload that just landed and produce its
    /// record entry (mirror delivery happens separately, batched and
    /// sharded — [`Self::deliver_arrivals`]). `t0` is the current
    /// round's start (for the arrival-lag column).
    fn record_arrival(&mut self, ev: &Event, t0: f64) -> WorkerRound {
        let c = &mut self.chains[ev.worker];
        c.busy = false;
        WorkerRound {
            worker: ev.worker,
            up_bits: c.leg.up_bits,
            up_seconds: c.leg.up_seconds,
            down_seconds: c.down_seconds,
            loss: c.loss,
            compression_error: c.leg.compression_error,
            est_up_bps: c.leg.est_up_bps,
            true_up_bps: c.leg.true_up_bps,
            arrival_lag: (ev.time - t0).max(0.0),
            staleness: self.step - c.snapshot_step,
        }
    }

    /// Deliver a batch of same-timestamp upload arrivals to the û_m
    /// mirrors, fanned across the layer shards.
    fn deliver_arrivals(&mut self, batch: &[Event]) {
        shard::deliver_batch(
            &self.plan,
            &self.cfg.layers,
            &mut self.server.u_hats,
            &self.workers,
            batch,
            self.plan.n_shards() > 1,
        );
    }

    /// Pop the earliest same-`(time, kind)` event batch and handle it:
    /// gradient and compute milestones run serially in event order (the
    /// source is one mutable resource), upload batches fan their
    /// per-layer mirror deliveries across shards and append their
    /// arrival records (worker-ascending) to `arrivals`.
    fn drain_batch(
        &mut self,
        t0: f64,
        arrivals: &mut Vec<WorkerRound>,
        t_last: &mut f64,
    ) -> anyhow::Result<()> {
        let mut batch = std::mem::take(&mut self.batch);
        self.queue.pop_batch_into(&mut batch);
        let kind = batch.first().map(|ev| ev.kind);
        match kind {
            None => unreachable!("drain_batch requires a non-empty queue"),
            Some(EventKind::BroadcastDone) => {
                for ev in &batch {
                    self.on_broadcast_done(ev)?;
                }
            }
            Some(EventKind::ComputeDone) => {
                for ev in &batch {
                    self.on_compute_done(ev);
                }
            }
            Some(EventKind::UploadDone) => {
                self.deliver_arrivals(&batch);
                for ev in &batch {
                    arrivals.push(self.record_arrival(ev, t0));
                    *t_last = t_last.max(ev.time);
                }
            }
        }
        self.batch = batch;
        Ok(())
    }

    /// Aggregate Σ w_m û_m and step the optimizer — both fanned across
    /// the layer shards (bit-identical to the serialized path for any
    /// shard count) — honoring the zero-information guard: stepping
    /// again on unchanged, stale estimators is outside the EF21 regime
    /// — Theorem 1 requires contraction alpha_i > 0 — and measurably
    /// destabilizes the quadratic workload during bandwidth troughs.
    fn aggregate_and_step(&mut self, k: u64, total_up: u64, gamma_scale: f64) -> f64 {
        if total_up > 0 || k == 0 {
            let par = self.plan.n_shards() > 1;
            let n = shard::aggregate(
                &self.plan,
                &self.weights,
                &self.server.u_hats,
                &mut self.server.agg,
                par,
            );
            shard::step(
                &self.plan,
                &self.cfg.optimizer,
                k as usize,
                gamma_scale,
                &mut self.server.x,
                &self.server.agg,
                &self.cfg.layers,
                par,
            );
            n
        } else {
            0.0
        }
    }

    /// Execute one full communication round; returns its record.
    pub fn round(&mut self) -> anyhow::Result<RoundRecord> {
        self.ensure_plan();
        if self.cfg.warm_start && !self.warmed {
            self.warm_start()?;
            self.warmed = true;
        }
        match self.cfg.mode {
            ExecMode::Sync => self.round_sync(),
            ExecMode::SemiSync { quorum } => self.round_semisync(quorum),
            ExecMode::Async { damping } => self.round_async(damping),
        }
    }

    /// Sync mode on the event engine: schedule all M chains, drain the
    /// gradient milestones in event order, run the M independent upload
    /// legs on the scoped-thread pool (exactly the pre-refactor
    /// parallel worker phase), then barrier on the M arrivals.
    fn round_sync(&mut self) -> anyhow::Result<RoundRecord> {
        let k = self.step;
        let t0 = self.clock;
        let m = self.cfg.m;
        debug_assert!(self.queue.is_empty(), "sync rounds drain the queue fully");

        self.probe_down_monitors(t0);
        let b_down = self.server.broadcast_estimate(self.cfg.prior_bps);
        let down_bits = self.broadcast_phase(b_down);
        for w in 0..m {
            self.begin_chain(w, t0, down_bits, k);
        }

        // Drain the gradient and compute milestones in event order.
        // With heterogeneous downlinks a fast worker's ComputeDone can
        // precede a slow worker's BroadcastDone, so the kinds interleave
        // — dispatch explicitly until every worker has computed.
        // Gradients stay serial (the source is one mutable resource);
        // the M upload legs are deferred so they can batch onto the
        // thread pool below (bit-deterministic for any chunking).
        let mut computed = 0;
        while computed < m {
            let ev = self.queue.pop().expect("sync chains schedule 2M milestones");
            match ev.kind {
                EventKind::BroadcastDone => self.on_broadcast_done(&ev)?,
                EventKind::ComputeDone => {
                    self.chains[ev.worker].up_start = ev.time;
                    computed += 1;
                }
                EventKind::UploadDone => {
                    unreachable!("sync uploads are scheduled only after the compute batch")
                }
            }
        }
        debug_assert!(self.queue.is_empty());
        let n_threads = effective_threads(self.cfg.threads, m, self.server.dim(), self.thread_cap);
        let uctx = UploadCtx { cfg: &self.cfg, net: &self.net, up_selector: &self.up_selector };
        if n_threads <= 1 {
            for (w, c) in self.workers.iter_mut().zip(self.chains.iter_mut()) {
                c.leg = upload_leg(&uctx, w, c.up_start);
            }
        } else {
            let chunk = m.div_ceil(n_threads);
            let workers = &mut self.workers;
            let chains = &mut self.chains;
            let uctx = &uctx;
            std::thread::scope(|s| {
                let handles: Vec<_> = workers
                    .chunks_mut(chunk)
                    .zip(chains.chunks_mut(chunk))
                    .map(|(ws, cs)| {
                        s.spawn(move || {
                            for (w, c) in ws.iter_mut().zip(cs.iter_mut()) {
                                c.leg = upload_leg(uctx, w, c.up_start);
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().expect("upload leg thread panicked");
                }
            });
        }
        for (w, c) in self.chains.iter().enumerate() {
            self.queue.push(Event {
                time: c.up_start + c.leg.up_seconds,
                worker: w,
                kind: EventKind::UploadDone,
                round: k,
            });
        }

        // The barrier: all M arrivals land before aggregation.
        for _ in 0..m {
            let ev = self.queue.pop().expect("one UploadDone per worker");
            debug_assert_eq!(ev.kind, EventKind::UploadDone);
            let w = ev.worker;
            deliver_upload(&mut self.server.u_hats[w], &self.cfg.layers, &self.workers[w].msgs);
            self.chains[w].busy = false;
        }
        debug_assert!(self.queue.is_empty());

        // Wire tap: after the barrier every worker's `msgs` holds this
        // round's upload exactly as delivered; `wire_bcast` holds the
        // broadcast the round opened with.
        if self.wire_tap {
            let nl = self.cfg.layers.len();
            self.last_wire = Some(RoundWire {
                step: k,
                broadcast: std::mem::take(&mut self.wire_bcast),
                uploads: self
                    .workers
                    .iter()
                    .map(|w| w.msgs[..nl.min(w.msgs.len())].to_vec())
                    .collect(),
            });
        }

        // Records, reductions and the step, all in worker-index order.
        let worker_rounds: Vec<WorkerRound> = self
            .chains
            .iter()
            .enumerate()
            .map(|(w, c)| WorkerRound {
                worker: w,
                up_bits: c.leg.up_bits,
                up_seconds: c.leg.up_seconds,
                down_seconds: c.down_seconds,
                loss: c.loss,
                compression_error: c.leg.compression_error,
                est_up_bps: c.leg.est_up_bps,
                true_up_bps: c.leg.true_up_bps,
                arrival_lag: c.down_seconds + c.t_comp + c.leg.up_seconds,
                staleness: 0,
            })
            .collect();
        // tidy:allow(float-reduce) -- serial fold in chain order, deterministic
        let loss_sum: f64 = self.chains.iter().map(|c| c.loss).sum();
        let mut duration =
            worker_rounds.iter().map(|w| w.arrival_lag).fold(0.0f64, f64::max);
        let total_up: u64 = worker_rounds.iter().map(|w| w.up_bits).sum();
        let agg_norm_sq = self.aggregate_and_step(k, total_up, 1.0);

        // Synchronized schedule: fast rounds wait for the deadline.
        if let Some(deadline) = self.cfg.round_deadline {
            duration = duration.max(deadline);
        }

        let f_x = self.source.objective(&self.server.x).unwrap_or(f64::NAN);
        self.clock = t0 + duration;
        self.step += 1;
        Ok(RoundRecord {
            step: k,
            t_start: t0,
            duration,
            down_bits,
            workers: worker_rounds,
            loss: loss_sum / m as f64,
            f_x,
            agg_norm_sq,
        })
    }

    /// Semi-sync mode: broadcast to every idle worker, pump the event
    /// queue — batch by same-timestamp batch — until `quorum` uploads
    /// have arrived, aggregate, step. Stragglers' chains span rounds;
    /// their arrivals count toward whatever round is open when they
    /// land, and arrivals sharing the quorum-closing timestamp join the
    /// closing round (the server cannot distinguish simultaneous
    /// landings, so it aggregates everything on the floor).
    fn round_semisync(&mut self, quorum: usize) -> anyhow::Result<RoundRecord> {
        let k = self.step;
        let t0 = self.clock;
        let quorum = quorum.clamp(1, self.cfg.m);

        // Arrivals that landed while the server idled at the previous
        // round's deadline join this round immediately (lag 0).
        let mut arrivals: Vec<WorkerRound> = Vec::new();
        let mut t_last = t0;
        while self.queue.peek().is_some_and(|ev| ev.time <= t0) {
            // Pre-deadline landings never stretch the round: their
            // times are <= t0, so the t_last max is a no-op here.
            self.drain_batch(t0, &mut arrivals, &mut t_last)?;
        }

        // Broadcast to every idle worker (stragglers keep flying).
        self.probe_down_monitors(t0);
        let b_down = self.server.broadcast_estimate(self.cfg.prior_bps);
        let down_bits = self.broadcast_phase(b_down);
        for w in 0..self.cfg.m {
            if !self.chains[w].busy {
                self.begin_chain(w, t0, down_bits, k);
            }
        }

        // Pump event batches until the quorum is met. Every worker is
        // busy at this point, so the queue cannot starve before the
        // quorum.
        while arrivals.len() < quorum {
            debug_assert!(!self.queue.is_empty(), "semisync: busy workers imply pending events");
            self.drain_batch(t0, &mut arrivals, &mut t_last)?;
        }

        arrivals.sort_by_key(|w| w.worker);
        let total_up: u64 = arrivals.iter().map(|w| w.up_bits).sum();
        let agg_norm_sq = self.aggregate_and_step(k, total_up, 1.0);
        let mut duration = t_last - t0;
        if let Some(deadline) = self.cfg.round_deadline {
            duration = duration.max(deadline);
        }
        // tidy:allow(float-reduce) -- serial fold over sorted arrivals, deterministic
        let loss = arrivals.iter().map(|w| w.loss).sum::<f64>() / arrivals.len() as f64;
        let f_x = self.source.objective(&self.server.x).unwrap_or(f64::NAN);
        self.clock = t0 + duration;
        self.step += 1;
        Ok(RoundRecord {
            step: k,
            t_start: t0,
            duration,
            down_bits,
            workers: arrivals,
            loss,
            f_x,
            agg_norm_sq,
        })
    }

    /// Async mode: one server round per upload arrival. The aggregate
    /// still spans all û_m mirrors (EF21 memory: absent workers
    /// contribute their last delivered estimate), the step size is
    /// damped by `damping^staleness`, and the triggering worker is
    /// immediately re-broadcast a fresh model estimate **on its own
    /// channel**: every worker owns a true x̂_m mirror that advances
    /// only by messages actually compressed for its downlink (budgeted
    /// from that link's own monitor) — the honest replacement for the
    /// earlier shared-broadcast-channel abstraction, where one x̂ stood
    /// for all workers and silently leaked other workers' refreshes.
    /// Mirror delivery, the aggregate and the step fan across the
    /// layer shards.
    fn round_async(&mut self, damping: f64) -> anyhow::Result<RoundRecord> {
        let k = self.step;
        let t0 = self.clock;
        let mut down_bits = 0u64;

        // `cfg.mode` is public, so a simulation built for another mode
        // can be switched to Async mid-run: create per-worker mirror
        // *slots* lazily. Each slot is a dim-0 copy-on-write placeholder
        // that reads through to the shared estimator every worker was
        // tracking until now and clones it on the worker's first
        // broadcast — O(M) slots instead of the old O(M·d) eager copy.
        if self.server.x_hats.is_empty() {
            self.server.x_hats = (0..self.cfg.m).map(|_| Estimator::zeros(0)).collect();
        }

        // Bootstrap (first round, or every worker idle): broadcast to
        // every worker on its own channel, each message budgeted and
        // compressed against that worker's mirror.
        if self.chains.iter().all(|c| !c.busy) {
            self.probe_down_monitors(t0);
            for w in 0..self.cfg.m {
                let b_down = self.server.down_estimate(w, self.cfg.prior_bps);
                let bits = self.broadcast_phase_for(w, b_down);
                self.begin_chain(w, t0, bits, k);
                down_bits += bits;
            }
        }

        loop {
            let ev = self.queue.pop().expect("async: busy workers imply pending events");
            match ev.kind {
                EventKind::BroadcastDone => {
                    self.on_broadcast_done(&ev)?;
                    continue;
                }
                EventKind::ComputeDone => {
                    self.on_compute_done(&ev);
                    continue;
                }
                EventKind::UploadDone => {}
            }
            let w = ev.worker;
            self.deliver_arrivals(std::slice::from_ref(&ev));
            let wr = self.record_arrival(&ev, t0);
            let scale = damping.powi(wr.staleness as i32);
            let agg_norm_sq = self.aggregate_and_step(k, wr.up_bits, scale);

            // Refresh the triggering worker: probe its downlink, budget
            // from its own monitor, compress-advance its x̂_m mirror.
            let bd = self.net.window_bps(w, Direction::Down, ev.time, PROBE_WINDOW);
            self.server.down_monitors[w].observe(PROBE_BITS, PROBE_BITS / bd.max(1e-9));
            let b_down = self.server.down_estimate(w, self.cfg.prior_bps);
            let refresh_bits = self.broadcast_phase_for(w, b_down);
            self.step += 1;
            self.begin_chain(w, ev.time, refresh_bits, self.step);
            down_bits += refresh_bits;

            let loss = wr.loss;
            let f_x = self.source.objective(&self.server.x).unwrap_or(f64::NAN);
            self.clock = ev.time;
            return Ok(RoundRecord {
                step: k,
                t_start: t0,
                duration: ev.time - t0,
                down_bits,
                workers: vec![wr],
                loss,
                f_x,
                agg_norm_sq,
            });
        }
    }

    /// The pre-refactor synchronous loop, frozen as the bit-identity
    /// oracle for `ExecMode::Sync` on the event engine (asserted by the
    /// golden test in `tests/mode_matrix.rs`). Only meaningful for
    /// `Sync` mode with homogeneous compute.
    pub fn round_reference(&mut self) -> anyhow::Result<RoundRecord> {
        anyhow::ensure!(
            matches!(self.cfg.mode, ExecMode::Sync),
            "round_reference is the Sync-mode oracle"
        );
        anyhow::ensure!(
            matches!(self.cfg.compute, ComputeModel::Constant),
            "round_reference models homogeneous compute only"
        );
        self.ensure_plan();
        if self.cfg.warm_start && !self.warmed {
            self.warm_start()?;
            self.warmed = true;
        }
        let k = self.step;
        let t0 = self.clock;
        let t_comp = self.source.t_comp();

        self.probe_down_monitors(t0);
        let b_down = self.server.broadcast_estimate(self.cfg.prior_bps);
        let down_bits = self.broadcast_phase(b_down);

        // ---- Gradient phase (serial: the source is one mutable
        // resource). Every worker computes at the same broadcast x̂.
        let mut losses = Vec::with_capacity(self.cfg.m);
        for w in &mut self.workers {
            let loss = self
                .source
                .update(w.id, k, &self.server.x_hat.value, &mut w.u)?;
            losses.push(loss);
        }

        // ---- Parallel worker phase: timing, budgets, selection, EF21.
        let n_threads =
            effective_threads(self.cfg.threads, self.cfg.m, self.server.dim(), self.thread_cap);
        let ctx = RoundCtx {
            up: UploadCtx { cfg: &self.cfg, net: &self.net, up_selector: &self.up_selector },
            t0,
            t_comp,
            down_bits,
        };
        let worker_rounds: Vec<WorkerRound> = if n_threads <= 1 {
            self.workers
                .iter_mut()
                .zip(self.server.u_hats.iter_mut())
                .zip(self.server.down_monitors.iter_mut())
                .zip(&losses)
                .map(|(((w, uh), dm), &loss)| worker_phase(&ctx, loss, w, uh, dm.as_mut()))
                .collect()
        } else {
            let chunk = self.cfg.m.div_ceil(n_threads);
            let workers = &mut self.workers;
            let u_hats = &mut self.server.u_hats;
            let down_monitors = &mut self.server.down_monitors;
            let ctx = &ctx;
            std::thread::scope(|s| {
                let handles: Vec<_> = workers
                    .chunks_mut(chunk)
                    .zip(u_hats.chunks_mut(chunk))
                    .zip(down_monitors.chunks_mut(chunk))
                    .zip(losses.chunks(chunk))
                    .map(|(((ws, us), ds), ls)| {
                        s.spawn(move || {
                            ws.iter_mut()
                                .zip(us.iter_mut())
                                .zip(ds.iter_mut())
                                .zip(ls)
                                .map(|(((w, uh), dm), &loss)| {
                                    worker_phase(ctx, loss, w, uh, dm.as_mut())
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                // Chunks rejoin in spawn order, so the concatenation is
                // exactly worker-index order — aggregation stays
                // deterministic no matter how the threads interleave.
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("worker phase thread panicked"))
                    .collect()
            })
        };
        // tidy:allow(float-reduce) -- serial fold in worker order, deterministic
        let loss_sum: f64 = losses.iter().sum();
        let mut duration =
            worker_rounds.iter().map(|w| w.arrival_lag).fold(0.0f64, f64::max);

        // ---- Server: aggregate and step (Algorithm 3 line 15).
        let total_up: u64 = worker_rounds.iter().map(|w| w.up_bits).sum();
        let agg_norm_sq = self.aggregate_and_step(k, total_up, 1.0);

        // Synchronized schedule: fast rounds wait for the deadline.
        if let Some(deadline) = self.cfg.round_deadline {
            duration = duration.max(deadline);
        }

        let f_x = self.source.objective(&self.server.x).unwrap_or(f64::NAN);
        self.clock = t0 + duration;
        self.step += 1;
        Ok(RoundRecord {
            step: k,
            t_start: t0,
            duration,
            down_bits,
            workers: worker_rounds,
            loss: loss_sum / self.cfg.m as f64,
            f_x,
            agg_norm_sq,
        })
    }

    /// Run `n` rounds, collecting the records.
    pub fn run(&mut self, n: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(self.round()?);
        }
        Ok(out)
    }

    /// Run until virtual time exceeds `deadline` seconds (or `max`
    /// rounds as a backstop).
    pub fn run_until(&mut self, deadline: f64, max: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::new();
        while self.clock < deadline && (out.len() as u64) < max {
            out.push(self.round()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::bandwidth::ConstantTrace;
    use crate::kimad::BudgetParams;
    use crate::netsim::Link;
    use crate::optim::{LayerwiseSgd, Schedule};
    use crate::quadratic::Quadratic;

    fn constant_net(m: usize, bps: f64) -> NetSim {
        NetSim::new(
            (0..m)
                .map(|_| {
                    Link::new(
                        Arc::new(ConstantTrace::new(bps)),
                        Arc::new(ConstantTrace::new(bps)),
                    )
                })
                .collect(),
        )
    }

    fn sim(
        m: usize,
        bps: f64,
        policy: CompressPolicy,
        gamma: f64,
    ) -> Simulation<crate::coordinator::QuadraticSource> {
        let q = Quadratic::paper_instance(30);
        let layout = q.layout(3);
        let layers = layout.layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: policy.clone(),
            down_policy: policy,
            optimizer: LayerwiseSgd::new(Schedule::Constant(gamma)),
            layers,
            warm_start: true,
            prior_bps: bps,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
            threads: 1,
            mode: ExecMode::Sync,
            compute: ComputeModel::Constant,
        };
        Simulation::new(cfg, constant_net(m, bps), src, vec![1.0f32; 30])
    }

    #[test]
    fn identity_policy_matches_gd() {
        // Enough bandwidth for uncompressed rounds: Kimad = plain GD.
        let mut s = sim(2, 1e9, CompressPolicy::KimadUniform, 0.05);
        let recs = s.run(50).unwrap();
        assert!(recs.last().unwrap().f_x < 1e-3 * recs[0].f_x);
        // All coordinates kept: wire bits = dense encoding.
        assert_eq!(recs[5].down_bits, 30 * 32 + 3 * 32);
    }

    #[test]
    fn kimad_converges_under_tight_budget() {
        let mut s = sim(2, 64.0 * 8.0, CompressPolicy::KimadUniform, 0.02);
        let recs = s.run(400).unwrap();
        let first = recs[0].f_x;
        let last = recs.last().unwrap().f_x;
        assert!(last < first * 0.05, "f0={first} fK={last}");
    }

    #[test]
    fn budget_never_exceeded_by_uplink() {
        let bps = 64.0 * 4.0;
        let mut s = sim(3, bps, CompressPolicy::KimadUniform, 0.02);
        let recs = s.run(20).unwrap();
        for r in recs.iter().skip(1) {
            for w in &r.workers {
                // planned <= budget = t_comm * B (cold start skipped).
                assert!(w.up_bits as f64 <= bps * 1.0 + 64.0, "{}", w.up_bits);
            }
        }
    }

    #[test]
    fn round_time_includes_all_phases() {
        let mut s = sim(1, 1000.0, CompressPolicy::KimadUniform, 0.01);
        let r = s.round().unwrap();
        let w = &r.workers[0];
        let phases = w.down_seconds + 0.01 + w.up_seconds;
        // Deadline-scheduled: duration = max(phases, deadline).
        assert!((r.duration - phases.max(1.0)).abs() < 1e-12);
        assert!(r.t_start == 0.0 && s.clock == r.duration);
        // Sync rounds: lag = down + compute + up, staleness 0.
        assert!((w.arrival_lag - phases).abs() < 1e-12);
        assert_eq!(w.staleness, 0);
        assert_eq!(w.worker, 0);
    }

    #[test]
    fn zero_budget_rounds_still_advance_clock() {
        // Near-zero bandwidth: Kimad sends ~nothing but the round still
        // takes the time budget (no zero-duration spinning).
        let mut s = sim(1, 2.0, CompressPolicy::KimadUniform, 0.01);
        let recs = s.run(5).unwrap();
        for r in &recs {
            assert!(r.duration >= 1.0);
        }
        assert!(s.clock >= 5.0);
        // And the model was not destabilized by the empty rounds.
        assert!(recs.last().unwrap().f_x.is_finite());
    }

    #[test]
    fn fixed_ratio_baseline_constant_bits() {
        let mut s = sim(2, 500.0, CompressPolicy::FixedRatio { ratio: 0.2 }, 0.02);
        let recs = s.run(5).unwrap();
        let bits0 = recs[1].workers[0].up_bits;
        for r in recs.iter().skip(1) {
            assert_eq!(r.workers[0].up_bits, bits0);
        }
    }

    #[test]
    fn kimad_plus_runs_and_converges() {
        let mut s = sim(
            2,
            64.0 * 8.0,
            CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
            0.02,
        );
        let recs = s.run(300).unwrap();
        assert!(recs.last().unwrap().f_x < recs[0].f_x * 0.1);
    }

    #[test]
    fn parallel_rounds_bit_match_serial() {
        // The engine guarantee: thread count never changes results.
        for policy in [
            CompressPolicy::KimadUniform,
            CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
            CompressPolicy::WholeModelTopK,
        ] {
            let mut serial = sim(4, 640.0, policy.clone(), 0.02);
            serial.cfg.threads = 1;
            let mut par2 = sim(4, 640.0, policy.clone(), 0.02);
            par2.cfg.threads = 2;
            let mut par_auto = sim(4, 640.0, policy.clone(), 0.02);
            par_auto.cfg.threads = 0;
            let a = serial.run(25).unwrap();
            let b = par2.run(25).unwrap();
            let c = par_auto.run(25).unwrap();
            assert_eq!(a, b, "{policy:?}: threads=2 diverged");
            assert_eq!(a, c, "{policy:?}: threads=auto diverged");
        }
    }

    #[test]
    fn thread_count_clamps() {
        // Explicit thread counts win regardless of work size.
        assert_eq!(effective_threads(1, 8, 30, 0), 1);
        assert_eq!(effective_threads(16, 3, 30, 0), 3);
        // Auto mode: small rounds stay serial, big ones parallelize.
        assert_eq!(effective_threads(0, 4, 30, 0), 1);
        assert_eq!(effective_threads(0, 1, 10_000_000, 0), 1);
        let big = effective_threads(0, 64, 1_000_000, 0);
        assert!((1..=64).contains(&big));
    }

    #[test]
    fn shard_count_clamps() {
        // Explicit shard counts clamp to the layer count.
        assert_eq!(effective_shards(2, 8, 30, 0), 2);
        assert_eq!(effective_shards(16, 3, 30, 0), 3);
        // Auto mode: small models stay serialized, big ones shard.
        assert_eq!(effective_shards(0, 10, 30, 0), 1);
        assert_eq!(effective_shards(0, 1, 10_000_000, 0), 1);
        let big = effective_shards(0, 64, 10_000_000, 0);
        assert!((1..=64).contains(&big));
    }

    #[test]
    fn thread_cap_bounds_auto_but_not_explicit() {
        // The cooperative budget: auto resolution never exceeds the
        // cap, while explicit knobs remain the caller's business (the
        // scenario layer clamps those before they get here).
        assert_eq!(effective_threads(0, 64, 10_000_000, 1), 1);
        assert!(effective_threads(0, 64, 10_000_000, 2) <= 2);
        assert_eq!(effective_threads(5, 64, 10_000_000, 1), 5);
        assert_eq!(effective_shards(0, 64, 10_000_000, 1), 1);
        assert!(effective_shards(0, 64, 10_000_000, 3) <= 3);
        assert_eq!(effective_shards(4, 64, 10_000_000, 1), 4);
        // Cap 0 = uncapped (the machine).
        assert_eq!(avail_within(0), avail_within(usize::MAX));
        assert_eq!(avail_within(1), 1);
    }

    #[test]
    fn forced_shards_do_not_change_sync_results() {
        // The shard-count analogue of parallel_rounds_bit_match_serial:
        // the engine guarantee is that sharding never changes bits.
        let mut base = sim(3, 640.0, CompressPolicy::KimadUniform, 0.02);
        let a = base.run(20).unwrap();
        for shards in [2usize, 3] {
            let mut s = sim(3, 640.0, CompressPolicy::KimadUniform, 0.02);
            s.shards = shards;
            let b = s.run(20).unwrap();
            assert_eq!(a, b, "shards={shards} diverged");
        }
    }

    #[test]
    fn async_workers_get_private_broadcast_mirrors() {
        let mut proto = sim(2, 64.0 * 8.0, CompressPolicy::KimadUniform, 0.02);
        proto.cfg.mode = ExecMode::Async { damping: 0.7 };
        proto.cfg.round_deadline = None;
        // Rebuild: the constructor decides mirrors from the mode.
        let cfg = proto.cfg;
        let mut s = Simulation::new(
            cfg,
            constant_net(2, 64.0 * 8.0),
            crate::coordinator::QuadraticSource::new(Quadratic::paper_instance(30), 0.01),
            vec![1.0f32; 30],
        );
        assert_eq!(s.server.x_hats.len(), 2, "async mode owns per-worker mirrors");
        s.run(40).unwrap();
        // Each worker's channel tracks the model independently; both
        // mirrors converge toward x without being identical objects.
        for xh in &s.server.x_hats {
            assert!(xh.value.iter().all(|v| v.is_finite()));
        }
        // Sync mode keeps the shared channel only.
        let sync = sim(2, 640.0, CompressPolicy::KimadUniform, 0.02);
        assert!(sync.server.x_hats.is_empty());

        // Switching a constructed simulation to Async mid-run creates
        // the mirrors lazily (cfg.mode is public) instead of indexing
        // out of bounds.
        let mut switched = sim(2, 640.0, CompressPolicy::KimadUniform, 0.02);
        switched.cfg.mode = ExecMode::Async { damping: 0.7 };
        switched.cfg.round_deadline = None;
        switched.run(3).unwrap();
        assert_eq!(switched.server.x_hats.len(), 2, "lazy per-worker mirrors");
    }

    #[test]
    fn async_mirrors_materialize_lazily_not_eagerly() {
        // The COW contract: constructing an async simulation allocates
        // M placeholder slots, zero mirror floats; a worker's mirror
        // densifies only on its first broadcast.
        let mut proto = sim(2, 640.0, CompressPolicy::KimadUniform, 0.02);
        proto.cfg.mode = ExecMode::Async { damping: 0.7 };
        proto.cfg.round_deadline = None;
        let cfg = proto.cfg;
        let mut s = Simulation::new(
            cfg,
            constant_net(2, 640.0),
            crate::coordinator::QuadraticSource::new(Quadratic::paper_instance(30), 0.01),
            vec![1.0f32; 30],
        );
        assert!(s.server.x_hats.iter().all(|xh| xh.value.is_empty()));
        // Until then every worker reads the shared channel.
        assert_eq!(s.server.model_estimate(1), s.server.x_hat.value.as_slice());
        // The bootstrap round broadcasts to everyone: all materialize.
        s.round().unwrap();
        assert!(s.server.x_hats.iter().all(|xh| xh.value.len() == 30));
    }

    #[test]
    fn ef21_estimator_error_shrinks_on_static_target() {
        // With a tiny learning rate the gradient barely moves, so the
        // EF21 error must contract round over round. Cold estimators
        // (no warmup) so the error starts large.
        let q = Quadratic::paper_instance(30);
        let layers = q.layout(3).layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m: 1,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadUniform,
            down_policy: CompressPolicy::FixedRatio { ratio: 1.0 },
            optimizer: LayerwiseSgd::new(Schedule::Constant(1e-6)),
            layers,
            warm_start: false,
            prior_bps: 128.0,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
            threads: 1,
            mode: ExecMode::Sync,
            compute: ComputeModel::Constant,
        };
        let mut s = Simulation::new(cfg, constant_net(1, 128.0), src, vec![1.0f32; 30]);
        let recs = s.run(30).unwrap();
        let first = recs[2].workers[0].compression_error;
        let last = recs.last().unwrap().workers[0].compression_error;
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn semisync_aggregates_first_quorum() {
        // Worker 1 is a 10x compute straggler: every round closes on
        // worker 0's arrival alone, and the straggler's late uploads
        // land in later rounds with positive staleness.
        let mut s = sim(2, 2000.0, CompressPolicy::FixedRatio { ratio: 0.5 }, 0.02);
        s.cfg.mode = ExecMode::SemiSync { quorum: 1 };
        s.cfg.compute = ComputeModel::Profile { factors: vec![1.0, 10.0] };
        let recs = s.run(30).unwrap();
        for r in &recs {
            assert!(!r.workers.is_empty() && r.workers.len() <= 2);
        }
        // The straggler did land eventually, stale.
        let late: Vec<_> = recs
            .iter()
            .flat_map(|r| &r.workers)
            .filter(|w| w.worker == 1)
            .collect();
        assert!(!late.is_empty(), "straggler uploads must still arrive");
        assert!(late.iter().any(|w| w.staleness > 0));
        assert!(recs.last().unwrap().f_x.is_finite());
    }

    #[test]
    fn semisync_full_quorum_waits_for_everyone() {
        let mut s = sim(3, 2000.0, CompressPolicy::FixedRatio { ratio: 0.5 }, 0.02);
        s.cfg.mode = ExecMode::SemiSync { quorum: 3 };
        let recs = s.run(10).unwrap();
        for r in &recs {
            assert_eq!(r.n_arrivals(), 3, "full quorum = every worker, every round");
            assert_eq!(r.max_staleness(), 0);
        }
    }

    #[test]
    fn async_steps_on_every_arrival_and_converges() {
        let mut s = sim(2, 64.0 * 8.0, CompressPolicy::KimadUniform, 0.02);
        s.cfg.mode = ExecMode::Async { damping: 0.7 };
        s.cfg.round_deadline = None;
        let recs = s.run(400).unwrap();
        for r in &recs {
            assert_eq!(r.n_arrivals(), 1, "async rounds are single arrivals");
        }
        // Virtual time is monotone and the model trains.
        for pair in recs.windows(2) {
            assert!(pair[1].t_start >= pair[0].t_start);
        }
        assert!(recs.last().unwrap().f_x < recs[0].f_x * 0.2);
    }

    #[test]
    #[should_panic(expected = "damping")]
    fn async_rejects_bad_damping() {
        let q = Quadratic::paper_instance(30);
        let layers = q.layout(3).layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m: 1,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadUniform,
            down_policy: CompressPolicy::KimadUniform,
            optimizer: LayerwiseSgd::new(Schedule::Constant(0.02)),
            layers,
            warm_start: true,
            prior_bps: 100.0,
            round_deadline: None,
            budget_safety: 1.0,
            threads: 1,
            mode: ExecMode::Async { damping: 0.0 },
            compute: ComputeModel::Constant,
        };
        let _ = Simulation::new(cfg, constant_net(1, 100.0), src, vec![1.0f32; 30]);
    }
}
