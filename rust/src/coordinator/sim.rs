//! The synchronous PS round loop (Algorithm 3) over virtual time.

use crate::compress::{Identity, TopK};
use crate::kimad::{compression_budget, BudgetParams, CompressPolicy, Selector};
use crate::model::Layer;
use crate::netsim::{Direction, NetSim};
use crate::optim::LayerwiseSgd;

use super::round::{RoundRecord, WorkerRound};
use super::server::ServerState;
use super::worker::{GradientSource, WorkerState};

/// Full experiment configuration for one simulated training run.
pub struct SimConfig {
    /// Number of workers M.
    pub m: usize,
    /// Aggregation weights w_m (empty = uniform 1/M).
    pub weights: Vec<f64>,
    /// Eq. (2) parameters (time budget).
    pub budget: BudgetParams,
    /// `A^compress` policy for worker→server messages.
    pub up_policy: CompressPolicy,
    /// `A^compress` policy for the server broadcast.
    pub down_policy: CompressPolicy,
    /// Server-side optimizer (γ^k, optional layer weights).
    pub optimizer: LayerwiseSgd,
    /// Compression layers (Kimad+ granularity).
    pub layers: Vec<Layer>,
    /// Initialize estimators from the first uncompressed round (the
    /// paper's §4.2 warmup) instead of zeros.
    pub warm_start: bool,
    /// Bandwidth prior for cold-start rounds (bits/s).
    pub prior_bps: f64,
    /// Synchronized round schedule: every round lasts at least this
    /// long (the user's time budget t — rounds are *scheduled* at this
    /// cadence: stragglers overrun it, fast rounds wait for it). None =
    /// free-running rounds.
    pub round_deadline: Option<f64>,
    /// Safety factor on the Eq. (2) budget (DC2-style conservatism):
    /// the bandwidth estimate is a trailing average, so budgeting at
    /// 100% of it overruns the deadline whenever bandwidth is falling.
    /// 1.0 = trust the estimate fully.
    pub budget_safety: f64,
}

impl SimConfig {
    pub fn weights_or_uniform(&self) -> Vec<f64> {
        if self.weights.is_empty() {
            vec![1.0 / self.m as f64; self.m]
        } else {
            assert_eq!(self.weights.len(), self.m);
            self.weights.clone()
        }
    }
}

/// A running simulation: server + M workers + network + source.
pub struct Simulation<S: GradientSource> {
    pub cfg: SimConfig,
    pub net: NetSim,
    pub source: S,
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
    pub clock: f64,
    pub step: u64,
    weights: Vec<f64>,
    up_selector: Selector,
    down_selector: Selector,
    /// Reusable difference buffer (allocation-free rounds).
    diff: Vec<f32>,
    warmed: bool,
}

impl<S: GradientSource> Simulation<S> {
    pub fn new(cfg: SimConfig, net: NetSim, source: S, x0: Vec<f32>) -> Self {
        assert_eq!(net.n_workers(), cfg.m, "netsim links != M");
        assert_eq!(x0.len(), source.dim(), "x0 dim != source dim");
        let dim = x0.len();
        let weights = cfg.weights_or_uniform();
        let up_selector = Selector::new(cfg.up_policy.clone());
        let down_selector = Selector::new(cfg.down_policy.clone());
        let server = ServerState::new(x0, cfg.m);
        let workers = (0..cfg.m).map(|i| WorkerState::new(i, dim)).collect();
        Self {
            cfg,
            net,
            source,
            server,
            workers,
            clock: 0.0,
            step: 0,
            weights,
            up_selector,
            down_selector,
            diff: vec![0.0; dim],
            warmed: false,
        }
    }

    /// The warmup initialization (§4.2): one uncompressed exchange so
    /// x̂ = x⁰ and û_m = u_m⁰. Costs no virtual time (the paper runs 5
    /// warmup epochs outside the timed window).
    fn warm_start(&mut self) -> anyhow::Result<()> {
        let id = Identity;
        let layers = self.cfg.layers.clone();
        for l in &layers {
            let target = &self.server.x[l.offset..l.offset + l.size];
            self.server
                .x_hat
                .compress_advance(&id, target, l, &mut self.server.scratch);
        }
        for w in &mut self.workers {
            self.source
                .update(w.id, 0, &self.server.x_hat.value, &mut w.u)?;
            for l in &layers {
                let target = &w.u[l.offset..l.offset + l.size];
                let msg = w.u_hat.compress_advance(&id, target, l, &mut w.scratch);
                self.server.u_hats[w.id].apply(&msg, l);
            }
        }
        Ok(())
    }

    /// Execute one full communication round; returns its record.
    pub fn round(&mut self) -> anyhow::Result<RoundRecord> {
        if self.cfg.warm_start && !self.warmed {
            self.warm_start()?;
            self.warmed = true;
        }
        let k = self.step;
        let t0 = self.clock;
        let layers = &self.cfg.layers;
        let t_comp = self.source.t_comp();


        // ---- Continuous bandwidth monitoring (§2.4, §3): the monitor
        // samples the link each round (NIC-counter style), independent
        // of training traffic — without this, a zero-bit round would
        // starve the estimator at trough level forever. The observation
        // is the instantaneous rate at round start; the EWMA smooths it.
        const PROBE_BITS: f64 = 1.0e4;
        const PROBE_WINDOW: f64 = 0.5;
        for w in &mut self.workers {
            let bd = self.net.window_bps(w.id, Direction::Down, t0, PROBE_WINDOW);
            self.server.down_monitors[w.id].observe(PROBE_BITS, PROBE_BITS / bd.max(1e-9));
        }

        // ---- Server: select broadcast compressor under Eq. (2) budget.
        let b_down = self.server.broadcast_estimate(self.cfg.prior_bps);
        let c_down =
            (compression_budget(self.cfg.budget, b_down) as f64 * self.cfg.budget_safety) as u64;
        for (d, (&x, &xh)) in self
            .diff
            .iter_mut()
            .zip(self.server.x.iter().zip(&self.server.x_hat.value))
        {
            *d = x - xh;
        }
        let sel_down = self.down_selector.select(&self.diff, layers, c_down);

        // ---- Server: compress-advance x̂ and measure the wire size.
        let mut down_bits = 0u64;
        for (l, &kk) in layers.iter().zip(&sel_down.k_per_layer) {
            let target = &self.server.x[l.offset..l.offset + l.size];
            let msg = if kk >= l.size {
                self.server
                    .x_hat
                    .compress_advance(&Identity, target, l, &mut self.server.scratch)
            } else {
                self.server.x_hat.compress_advance(
                    &TopK::new(kk),
                    target,
                    l,
                    &mut self.server.scratch,
                )
            };
            down_bits += msg.wire_bits();
        }

        // ---- Broadcast to every worker (worker x̂ mirrors the server's
        // x̂ exactly — single-copy representation, sync asserted in
        // tests) and record per-worker transfer times.
        let mut worker_rounds = Vec::with_capacity(self.cfg.m);
        let mut loss_sum = 0.0;
        let mut duration = 0.0f64;
        for w in &mut self.workers {
            let down_tr = self
                .net
                .transfer(w.id, Direction::Down, t0, down_bits as f64);
            self.server.down_monitors[w.id].observe(down_bits as f64, down_tr.seconds);

            // ---- Worker: compute update at x̂.
            let loss = self
                .source
                .update(w.id, k, &self.server.x_hat.value, &mut w.u)?;
            loss_sum += loss;

            // ---- Worker: uplink budget read "when communication is
            // triggered" (§3.1) — i.e. at upload time, after download
            // and compute, not at round start.
            let up_start = t0 + down_tr.seconds + t_comp;
            let b_probe = self.net.window_bps(w.id, Direction::Up, up_start, PROBE_WINDOW);
            w.monitor.observe(PROBE_BITS, PROBE_BITS / b_probe.max(1e-9));
            let true_up = self.net.true_bps(w.id, Direction::Up, up_start);
            let b_up = w.monitor.estimate_or(self.cfg.prior_bps);
            let c_up =
                (compression_budget(self.cfg.budget, b_up) as f64 * self.cfg.budget_safety) as u64;
            for (d, (&u, &uh)) in self
                .diff
                .iter_mut()
                .zip(w.u.iter().zip(&w.u_hat.value))
            {
                *d = u - uh;
            }
            let sel_up = self.up_selector.select(&self.diff, layers, c_up);

            // ---- Worker: compress-advance û_m, mirror on the server.
            let mut up_bits = 0u64;
            for (l, &kk) in layers.iter().zip(&sel_up.k_per_layer) {
                let target = &w.u[l.offset..l.offset + l.size];
                let msg = if kk >= l.size {
                    w.u_hat.compress_advance(&Identity, target, l, &mut w.scratch)
                } else {
                    w.u_hat
                        .compress_advance(&TopK::new(kk), target, l, &mut w.scratch)
                };
                self.server.u_hats[w.id].apply(&msg, l);
                up_bits += msg.wire_bits();
            }

            let down_secs = down_tr.seconds;
            let up_tr = self.net.transfer(w.id, Direction::Up, up_start, up_bits as f64);
            w.monitor.observe(up_bits as f64, up_tr.seconds);
            let up_secs = up_tr.seconds;

            // Compression error ||û_m − u_m||² after the round (Fig. 9).
            let comp_err: f64 = w
                .u
                .iter()
                .zip(&w.u_hat.value)
                .map(|(&u, &uh)| ((u - uh) as f64).powi(2))
                .sum();

            duration = duration.max(down_secs + t_comp + up_secs);
            worker_rounds.push(WorkerRound {
                up_bits,
                up_seconds: up_secs,
                down_seconds: down_secs,
                loss,
                compression_error: comp_err,
                est_up_bps: b_up,
                true_up_bps: true_up,
            });
        }

        // ---- Server: aggregate and step (Algorithm 3 line 15).
        // Zero-information rounds (every worker's budget rounded to no
        // coordinates) are deadline-preserving no-ops: stepping again on
        // the unchanged, stale estimators is outside the EF21 regime —
        // Theorem 1 requires contraction alpha_i > 0 — and measurably
        // destabilizes the quadratic workload during bandwidth troughs.
        let total_up: u64 = worker_rounds.iter().map(|w| w.up_bits).sum();
        let agg_norm_sq = if total_up > 0 || k == 0 {
            let n = self.server.aggregate(&self.weights);
            self.cfg
                .optimizer
                .step(k as usize, &mut self.server.x, &self.server.agg, layers);
            n
        } else {
            0.0
        };

        // Synchronized schedule: fast rounds wait for the deadline.
        if let Some(deadline) = self.cfg.round_deadline {
            duration = duration.max(deadline);
        }

        let f_x = self.source.objective(&self.server.x).unwrap_or(f64::NAN);
        self.clock = t0 + duration;
        self.step += 1;
        Ok(RoundRecord {
            step: k,
            t_start: t0,
            duration,
            down_bits,
            workers: worker_rounds,
            loss: loss_sum / self.cfg.m as f64,
            f_x,
            agg_norm_sq,
        })
    }

    /// Run `n` rounds, collecting the records.
    pub fn run(&mut self, n: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(self.round()?);
        }
        Ok(out)
    }

    /// Run until virtual time exceeds `deadline` seconds (or `max`
    /// rounds as a backstop).
    pub fn run_until(&mut self, deadline: f64, max: u64) -> anyhow::Result<Vec<RoundRecord>> {
        let mut out = Vec::new();
        while self.clock < deadline && (out.len() as u64) < max {
            out.push(self.round()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::ConstantTrace;
    use crate::kimad::BudgetParams;
    use crate::netsim::Link;
    use crate::optim::{LayerwiseSgd, Schedule};
    use crate::quadratic::Quadratic;

    fn constant_net(m: usize, bps: f64) -> NetSim {
        NetSim::new(
            (0..m)
                .map(|_| {
                    Link::new(
                        Box::new(ConstantTrace::new(bps)),
                        Box::new(ConstantTrace::new(bps)),
                    )
                })
                .collect(),
        )
    }

    fn sim(
        m: usize,
        bps: f64,
        policy: CompressPolicy,
        gamma: f64,
    ) -> Simulation<crate::coordinator::QuadraticSource> {
        let q = Quadratic::paper_instance(30);
        let layout = q.layout(3);
        let layers = layout.layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: policy.clone(),
            down_policy: policy,
            optimizer: LayerwiseSgd::new(Schedule::Constant(gamma)),
            layers,
            warm_start: true,
            prior_bps: bps,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
        };
        Simulation::new(cfg, constant_net(m, bps), src, vec![1.0f32; 30])
    }

    #[test]
    fn identity_policy_matches_gd() {
        // Enough bandwidth for uncompressed rounds: Kimad = plain GD.
        let mut s = sim(2, 1e9, CompressPolicy::KimadUniform, 0.05);
        let recs = s.run(50).unwrap();
        assert!(recs.last().unwrap().f_x < 1e-3 * recs[0].f_x);
        // All coordinates kept: wire bits = dense encoding.
        assert_eq!(recs[5].down_bits, 30 * 32 + 3 * 32);
    }

    #[test]
    fn kimad_converges_under_tight_budget() {
        let mut s = sim(2, 64.0 * 8.0, CompressPolicy::KimadUniform, 0.02);
        let recs = s.run(400).unwrap();
        let first = recs[0].f_x;
        let last = recs.last().unwrap().f_x;
        assert!(last < first * 0.05, "f0={first} fK={last}");
    }

    #[test]
    fn budget_never_exceeded_by_uplink() {
        let bps = 64.0 * 4.0;
        let mut s = sim(3, bps, CompressPolicy::KimadUniform, 0.02);
        let recs = s.run(20).unwrap();
        for r in recs.iter().skip(1) {
            for w in &r.workers {
                // planned <= budget = t_comm * B (cold start skipped).
                assert!(w.up_bits as f64 <= bps * 1.0 + 64.0, "{}", w.up_bits);
            }
        }
    }

    #[test]
    fn round_time_includes_all_phases() {
        let mut s = sim(1, 1000.0, CompressPolicy::KimadUniform, 0.01);
        let r = s.round().unwrap();
        let w = &r.workers[0];
        let phases = w.down_seconds + 0.01 + w.up_seconds;
        // Deadline-scheduled: duration = max(phases, deadline).
        assert!((r.duration - phases.max(1.0)).abs() < 1e-12);
        assert!(r.t_start == 0.0 && s.clock == r.duration);
    }

    #[test]
    fn zero_budget_rounds_still_advance_clock() {
        // Near-zero bandwidth: Kimad sends ~nothing but the round still
        // takes the time budget (no zero-duration spinning).
        let mut s = sim(1, 2.0, CompressPolicy::KimadUniform, 0.01);
        let recs = s.run(5).unwrap();
        for r in &recs {
            assert!(r.duration >= 1.0);
        }
        assert!(s.clock >= 5.0);
        // And the model was not destabilized by the empty rounds.
        assert!(recs.last().unwrap().f_x.is_finite());
    }

    #[test]
    fn fixed_ratio_baseline_constant_bits() {
        let mut s = sim(2, 500.0, CompressPolicy::FixedRatio { ratio: 0.2 }, 0.02);
        let recs = s.run(5).unwrap();
        let bits0 = recs[1].workers[0].up_bits;
        for r in recs.iter().skip(1) {
            assert_eq!(r.workers[0].up_bits, bits0);
        }
    }

    #[test]
    fn kimad_plus_runs_and_converges() {
        let mut s = sim(
            2,
            64.0 * 8.0,
            CompressPolicy::KimadPlus { discretization: 200, ratios: vec![] },
            0.02,
        );
        let recs = s.run(300).unwrap();
        assert!(recs.last().unwrap().f_x < recs[0].f_x * 0.1);
    }

    #[test]
    fn ef21_estimator_error_shrinks_on_static_target() {
        // With a tiny learning rate the gradient barely moves, so the
        // EF21 error must contract round over round. Cold estimators
        // (no warmup) so the error starts large.
        let q = Quadratic::paper_instance(30);
        let layers = q.layout(3).layers();
        let src = crate::coordinator::QuadraticSource::new(q, 0.01);
        let cfg = SimConfig {
            m: 1,
            weights: vec![],
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadUniform,
            down_policy: CompressPolicy::FixedRatio { ratio: 1.0 },
            optimizer: LayerwiseSgd::new(Schedule::Constant(1e-6)),
            layers,
            warm_start: false,
            prior_bps: 128.0,
            round_deadline: Some(1.0),
            budget_safety: 1.0,
        };
        let mut s = Simulation::new(cfg, constant_net(1, 128.0), src, vec![1.0f32; 30]);
        let recs = s.run(30).unwrap();
        let first = recs[2].workers[0].compression_error;
        let last = recs.last().unwrap().workers[0].compression_error;
        assert!(last < first, "first={first} last={last}");
    }
}
