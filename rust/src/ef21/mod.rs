//! EF21 error feedback (§2.3, §3.3), layer-wise and bidirectional.
//!
//! Both endpoints of every link hold *estimators* that advance only by
//! compressed differences, so they stay bit-identical on both sides:
//!
//!   worker m uplink:  û_m^k = û_m^{k-1} + C_m^k(u_m^k − û_m^{k-1})
//!   server downlink:  x̂^k   = x̂^{k-1}  + C^k(x^k − x̂^{k-1})
//!
//! `theory` implements Theorem 1's constants (θ_i, β_i, the Eq. 9 step
//! size bound) used by tests and the synthetic experiments' tuning.
//!
//! # Example: sender and receiver advance in lockstep
//!
//! Only the compressed message crosses the wire, yet both mirrors stay
//! bit-identical — and the estimator converges to a fixed target in
//! `ceil(d / k)` rounds:
//!
//! ```
//! use kimad::compress::TopK;
//! use kimad::ef21::Estimator;
//! use kimad::model::Layer;
//!
//! let layer = Layer { id: 0, name: "l".into(), offset: 0, size: 4 };
//! let target = [4.0f32, 3.0, 2.0, 1.0];
//! let mut sender = Estimator::zeros(4);
//! let mut receiver = Estimator::zeros(4);
//! let mut scratch = Vec::new();
//! for _ in 0..2 {
//!     let msg = sender.compress_advance(&TopK::new(2), &target, &layer, &mut scratch);
//!     receiver.apply(&msg, &layer);
//! }
//! assert_eq!(sender.value, receiver.value);
//! assert_eq!(sender.value, target); // TopK(2) over 4 dims: 2 rounds
//! ```

pub mod theory;

use crate::compress::{Compressed, Compressor};
use crate::model::Layer;

/// One EF21 estimator over a flat vector (an `û_m` or the `x̂`).
#[derive(Debug, Clone, PartialEq)]
pub struct Estimator {
    pub value: Vec<f32>,
}

impl Estimator {
    pub fn zeros(dim: usize) -> Self {
        Self { value: vec![0.0; dim] }
    }

    /// Warm init from a concrete vector (the paper's §4.2 warmup:
    /// "û and x̂ are initialized as u^5 and x^5").
    pub fn from_vec(v: Vec<f32>) -> Self {
        Self { value: v }
    }

    pub fn dim(&self) -> usize {
        self.value.len()
    }

    /// Compress the difference `target − estimate` on one layer span and
    /// advance the estimator by the compressed message. Returns the
    /// message so the caller can "send" it (netsim wire accounting).
    pub fn compress_advance(
        &mut self,
        compressor: &dyn Compressor,
        target_layer: &[f32],
        layer: &Layer,
        scratch: &mut Vec<f32>,
    ) -> Compressed {
        let mut msg = Compressed::default();
        self.compress_advance_into(compressor, target_layer, layer, scratch, &mut msg);
        msg
    }

    /// [`compress_advance`](Self::compress_advance) into a caller-owned
    /// message buffer — the allocation-free form the round loop uses
    /// (the message's index/value vectors are reused across layers and
    /// rounds; see EXPERIMENTS.md §Perf).
    pub fn compress_advance_into(
        &mut self,
        compressor: &dyn Compressor,
        target_layer: &[f32],
        layer: &Layer,
        scratch: &mut Vec<f32>,
        msg: &mut Compressed,
    ) {
        let span = &mut self.value[layer.offset..layer.offset + layer.size];
        compress_advance_span(compressor, target_layer, span, scratch, msg);
    }

    /// Receiver side: advance by an already-received message.
    pub fn apply(&mut self, msg: &Compressed, layer: &Layer) {
        let span = &mut self.value[layer.offset..layer.offset + layer.size];
        msg.add_into(span);
    }

    /// Squared L2 distance to a target on one layer (compression error
    /// *after* the round — the Fig. 9 series).
    pub fn layer_error(&self, target_layer: &[f32], layer: &Layer) -> f64 {
        self.value[layer.offset..layer.offset + layer.size]
            .iter()
            .zip(target_layer)
            .map(|(&e, &t)| ((e - t) as f64).powi(2))
            // tidy:allow(float-reduce) -- serial fold in coordinate order, deterministic
            .sum()
    }
}

/// The span form of [`Estimator::compress_advance_into`]: advance an
/// explicit estimator span — the slice of `value` belonging to one
/// layer — instead of indexing into the whole estimator. This is what
/// the sharded broadcast kernel calls when the estimator's flat vector
/// is split across shard threads via `split_at_mut` (each thread owns
/// its shard's span, so `&mut self` on the whole estimator is
/// unavailable by design). `est_span` must be exactly
/// `value[layer.offset .. layer.offset + layer.size]`;
/// `compress_advance_into` delegates here, so the two forms can never
/// diverge.
// tidy:alloc-free(ef21_advance)
pub fn compress_advance_span(
    compressor: &dyn Compressor,
    target_layer: &[f32],
    est_span: &mut [f32],
    scratch: &mut Vec<f32>,
    msg: &mut Compressed,
) {
    // Chunked elementwise diff; `resize` on the just-cleared vec reuses
    // its capacity, so the warm path stays allocation-free.
    scratch.clear();
    scratch.resize(target_layer.len(), 0.0);
    crate::util::chunk::diff_into(scratch, target_layer, est_span);
    compressor.compress_into(scratch, msg);
    msg.add_into(est_span);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};
    use crate::model::ModelLayout;

    fn layer(dim: usize) -> Layer {
        Layer { id: 0, name: "l".into(), offset: 0, size: dim }
    }

    #[test]
    fn compress_advance_into_matches_allocating_path() {
        let mut a = Estimator::zeros(8);
        let mut b = Estimator::zeros(8);
        let target = [8.0f32, -7.0, 6.0, -5.0, 4.0, -3.0, 2.0, -1.0];
        let l = layer(8);
        let c = TopK::new(3);
        let mut scratch = Vec::new();
        let mut msg = Compressed::default();
        for _ in 0..4 {
            let want = a.compress_advance(&c, &target, &l, &mut scratch);
            b.compress_advance_into(&c, &target, &l, &mut scratch, &mut msg);
            assert_eq!(msg, want);
        }
        assert_eq!(a.value, b.value);
    }

    #[test]
    fn compress_advance_span_matches_whole_estimator_form() {
        // The sharded broadcast runs the span form on split_at_mut
        // slices; it must be bit-identical to the &mut Estimator form.
        let layout = ModelLayout::synthetic(&[3, 5]);
        let layers = layout.layers();
        let target = [4.0f32, -3.0, 2.0, -1.0, 0.5, 6.0, -2.5, 1.5];
        let c = TopK::new(2);
        let mut whole = Estimator::zeros(8);
        let mut split = Estimator::zeros(8);
        let mut scratch = Vec::new();
        let (mut msg_a, mut msg_b) = (Compressed::default(), Compressed::default());
        for _ in 0..3 {
            for l in &layers {
                let t = &target[l.offset..l.offset + l.size];
                whole.compress_advance_into(&c, t, l, &mut scratch, &mut msg_a);
                let (head, tail) = split.value.split_at_mut(layers[0].size);
                let span = if l.offset == 0 { head } else { tail };
                compress_advance_span(&c, t, span, &mut scratch, &mut msg_b);
                assert_eq!(msg_a, msg_b);
            }
        }
        assert_eq!(whole.value, split.value);
    }

    #[test]
    fn identity_compressor_converges_in_one_step() {
        let mut est = Estimator::zeros(4);
        let target = [1.0f32, -2.0, 3.0, 0.5];
        let l = layer(4);
        let mut scratch = Vec::new();
        let msg = est.compress_advance(&Identity, &target, &l, &mut scratch);
        assert_eq!(est.value, target.to_vec());
        assert_eq!(msg.wire_bits(), 4 * 32 + 32);
        assert_eq!(est.layer_error(&target, &l), 0.0);
    }

    #[test]
    fn topk_contracts_monotonically() {
        let mut est = Estimator::zeros(8);
        let target = [8.0f32, -7.0, 6.0, -5.0, 4.0, -3.0, 2.0, -1.0];
        let l = layer(8);
        let c = TopK::new(2);
        let mut scratch = Vec::new();
        let mut prev = f64::INFINITY;
        for _ in 0..10 {
            est.compress_advance(&c, &target, &l, &mut scratch);
            let err = est.layer_error(&target, &l);
            assert!(err <= prev + 1e-9, "EF21 error must not increase");
            prev = err;
        }
        assert!(prev < 1e-9, "TopK(2) over 8 dims converges in ceil(8/2) rounds");
    }

    #[test]
    fn sender_receiver_stay_in_sync() {
        let mut sender = Estimator::zeros(6);
        let mut receiver = Estimator::zeros(6);
        let layout = ModelLayout::synthetic(&[3, 3]);
        let layers = layout.layers();
        let target = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let c = TopK::new(1);
        let mut scratch = Vec::new();
        for _ in 0..5 {
            for l in &layers {
                let msg = sender.compress_advance(
                    &c,
                    &target[l.offset..l.offset + l.size],
                    l,
                    &mut scratch,
                );
                receiver.apply(&msg, l);
            }
        }
        assert_eq!(sender.value, receiver.value);
    }

    #[test]
    fn layerwise_independent_spans() {
        let mut est = Estimator::zeros(4);
        let layout = ModelLayout::synthetic(&[2, 2]);
        let layers = layout.layers();
        let target = [1.0f32, 1.0, 9.0, 9.0];
        let mut scratch = Vec::new();
        // Only advance layer 0.
        est.compress_advance(&Identity, &target[0..2], &layers[0], &mut scratch);
        assert_eq!(est.value, vec![1.0, 1.0, 0.0, 0.0]);
    }
}
