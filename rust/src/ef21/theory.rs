//! Theorem 1 constants: θ_i, β_i and the Eq. (9) step-size bound.
//!
//! θ_i := 1 − (1 − α_i)(1 + ζ_i),  β_i := (1 − α_i)(1 + ζ_i⁻¹)
//!
//! and γ must satisfy, for every layer i,
//!
//!   γ² · w_i (max_j w_j/δ_j)(max_j δ_j β_j) L² / θ  +  γ L_i w_i ≤ 1.
//!
//! Used by the synthetic experiments to pick provably-safe step sizes
//! and by property tests (Lyapunov descent on quadratics).

/// Per-layer EF21 constants for a compressor with contraction α.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerConsts {
    pub alpha: f64,
    pub zeta: f64,
    pub theta: f64,
    pub beta: f64,
}

/// Standard choice ζ_i s.t. (1−α)(1+ζ) < 1: ζ = α / (2(1−α)) giving
/// θ = α/2 (EF21 paper's canonical tuning), β = (1−α)(1+ζ⁻¹).
pub fn canonical_consts(alpha: f64) -> LayerConsts {
    let alpha = alpha.clamp(1e-12, 1.0);
    if alpha >= 1.0 {
        return LayerConsts { alpha: 1.0, zeta: 0.0, theta: 1.0, beta: 0.0 };
    }
    let zeta = alpha / (2.0 * (1.0 - alpha));
    let theta = 1.0 - (1.0 - alpha) * (1.0 + zeta);
    let beta = (1.0 - alpha) * (1.0 + 1.0 / zeta);
    LayerConsts { alpha, zeta, theta, beta }
}

/// Largest γ satisfying Eq. (9) for layer constants and weights.
///
/// * `alphas[i]` — compressor contraction per layer
/// * `l_layers[i]` — layer smoothness L_i (Assumption 1)
/// * `l_global` — global smoothness L (Assumption 2)
/// * `w[i]` — layer step-size weights (γ_i = γ w_i)
/// * `deltas[i]` — the δ_i > 0 of Definition (12); pass `None` for δ_i=1
pub fn max_gamma(
    alphas: &[f64],
    l_layers: &[f64],
    l_global: f64,
    w: &[f64],
    deltas: Option<&[f64]>,
) -> f64 {
    let ell = alphas.len();
    assert!(ell > 0 && l_layers.len() == ell && w.len() == ell);
    let ones = vec![1.0; ell];
    let deltas = deltas.unwrap_or(&ones);
    assert_eq!(deltas.len(), ell);

    let consts: Vec<LayerConsts> = alphas.iter().map(|&a| canonical_consts(a)).collect();
    let theta = consts
        .iter()
        .map(|c| c.theta)
        .fold(f64::INFINITY, f64::min)
        .max(1e-12);
    let max_w_over_delta = w
        .iter()
        .zip(deltas)
        .map(|(&wi, &di)| wi / di)
        .fold(0.0, f64::max);
    let max_delta_beta = consts
        .iter()
        .zip(deltas)
        .map(|(c, &di)| di * c.beta)
        .fold(0.0, f64::max);

    // Per-layer quadratic in γ: A w_i γ² + L_i w_i γ − 1 ≤ 0 with
    // A = max_w_over_delta * max_delta_beta * L² / θ.
    let a_coef = max_w_over_delta * max_delta_beta * l_global * l_global / theta;
    let mut gamma = f64::INFINITY;
    for i in 0..ell {
        let a = a_coef * w[i];
        let b = l_layers[i] * w[i];
        let g = if a < 1e-18 {
            if b < 1e-18 {
                f64::INFINITY
            } else {
                1.0 / b
            }
        } else {
            // γ = (−b + sqrt(b² + 4a)) / (2a)
            (-b + (b * b + 4.0 * a).sqrt()) / (2.0 * a)
        };
        gamma = gamma.min(g);
    }
    gamma
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_theta_is_half_alpha() {
        for &a in &[0.1, 0.3, 0.7, 0.99] {
            let c = canonical_consts(a);
            assert!((c.theta - a / 2.0).abs() < 1e-9, "alpha={a}");
            assert!((1.0 - c.alpha) * (1.0 + c.zeta) < 1.0);
        }
    }

    #[test]
    fn lossless_gives_gd_stepsize() {
        // α = 1 (no compression): θ = 1, β = 0 ⇒ γ ≤ 1/L_i (GD bound).
        let g = max_gamma(&[1.0], &[2.0], 2.0, &[1.0], None);
        assert!((g - 0.5).abs() < 1e-9);
    }

    #[test]
    fn smaller_alpha_smaller_gamma() {
        let g1 = max_gamma(&[0.5], &[1.0], 1.0, &[1.0], None);
        let g2 = max_gamma(&[0.05], &[1.0], 1.0, &[1.0], None);
        assert!(g2 < g1);
        assert!(g1 < 1.0); // always below the GD step
    }

    #[test]
    fn eq9_satisfied_at_max_gamma() {
        let alphas = [0.3, 0.6];
        let ls = [2.0, 5.0];
        let lg = 5.0;
        let w = [1.0, 0.5];
        let g = max_gamma(&alphas, &ls, lg, &w, None);
        let consts: Vec<_> = alphas.iter().map(|&a| canonical_consts(a)).collect();
        let theta = consts.iter().map(|c| c.theta).fold(f64::INFINITY, f64::min);
        let max_beta = consts.iter().map(|c| c.beta).fold(0.0, f64::max);
        let max_w = w.iter().cloned().fold(0.0, f64::max);
        for i in 0..2 {
            let lhs = g * g * w[i] * max_w * max_beta * lg * lg / theta + g * ls[i] * w[i];
            assert!(lhs <= 1.0 + 1e-6, "layer {i}: lhs={lhs}");
        }
    }

    #[test]
    fn weights_scale_inverse() {
        let g1 = max_gamma(&[0.5], &[1.0], 1.0, &[1.0], None);
        let g2 = max_gamma(&[0.5], &[1.0], 1.0, &[2.0], None);
        assert!(g2 < g1);
    }
}
