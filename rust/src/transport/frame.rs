//! Wire codec: length-prefixed, versioned, CRC-protected frames plus
//! the payload serialization for [`Compressed`] message vectors.
//!
//! ## Frame layout (all integers little-endian)
//!
//! | offset | size | field    | notes                                  |
//! |--------|------|----------|----------------------------------------|
//! | 0      | 4    | magic    | `b"KMAD"`                              |
//! | 4      | 2    | version  | wire protocol version, currently 1     |
//! | 6      | 1    | kind     | [`PayloadKind`] discriminant           |
//! | 7      | 1    | reserved | must encode as 0, ignored on decode    |
//! | 8      | 4    | worker   | worker id the frame is for / from      |
//! | 12     | 8    | round    | round index (or acked seq for `Ack`)   |
//! | 20     | 8    | seq      | per-connection stop-and-wait sequence  |
//! | 28     | 4    | len      | payload byte count, <= [`MAX_PAYLOAD`] |
//! | 32     | len  | payload  | kind-specific bytes                    |
//! | 32+len | 4    | crc      | CRC-32 (IEEE) over bytes `[0, 32+len)` |
//!
//! Decoding is total: malformed input yields a typed [`FrameError`],
//! never a panic, and `len` is validated against both [`MAX_PAYLOAD`]
//! and the buffer before any allocation happens.

use crate::compress::Compressed;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"KMAD";
/// Wire protocol version emitted (and the only one accepted).
pub const VERSION: u16 = 1;
/// Fixed header size in bytes (magic through len).
pub const HEADER_LEN: usize = 32;
/// CRC trailer size in bytes.
pub const TRAILER_LEN: usize = 4;
/// Hard payload ceiling (64 MiB): `len` fields above this are rejected
/// before any buffer is sized from them.
pub const MAX_PAYLOAD: u32 = 1 << 26;

/// What a frame carries; the `kind` byte on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadKind {
    /// Coordinator -> worker: the round's serialized broadcast messages.
    Broadcast = 0,
    /// Worker -> coordinator: the worker's serialized upload messages.
    Upload = 1,
    /// Worker -> coordinator handshake: `worker id u32 | m u32`.
    Probe = 2,
    /// Delivery acknowledgement for `round` = the acked sequence.
    Ack = 3,
    /// Coordinator -> worker: the run is over, close the connection.
    Shutdown = 4,
}

impl PayloadKind {
    /// The wire discriminant (inverse of [`PayloadKind::from_byte`]).
    fn byte(self) -> u8 {
        match self {
            PayloadKind::Broadcast => 0,
            PayloadKind::Upload => 1,
            PayloadKind::Probe => 2,
            PayloadKind::Ack => 3,
            PayloadKind::Shutdown => 4,
        }
    }

    fn from_byte(b: u8) -> Result<Self, FrameError> {
        Ok(match b {
            0 => PayloadKind::Broadcast,
            1 => PayloadKind::Upload,
            2 => PayloadKind::Probe,
            3 => PayloadKind::Ack,
            4 => PayloadKind::Shutdown,
            other => return Err(FrameError::BadKind(other)),
        })
    }
}

/// Typed decode failure. Every malformed input maps to one of these;
/// the codec never panics and never allocates from an unvalidated
/// length field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// The buffer ends before the frame (or a payload field) does.
    Truncated,
    /// The first four bytes are not [`MAGIC`].
    BadMagic,
    /// Unknown protocol version.
    BadVersion(u16),
    /// Unknown payload-kind byte.
    BadKind(u8),
    /// `len` exceeds [`MAX_PAYLOAD`].
    Oversize(u32),
    /// Header and length were plausible but the CRC trailer mismatched.
    BadCrc,
    /// A payload substructure (message vector) failed validation.
    Malformed(&'static str),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated => write!(f, "frame truncated"),
            FrameError::BadMagic => write!(f, "bad frame magic"),
            FrameError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            FrameError::BadKind(k) => write!(f, "unknown payload kind {k}"),
            FrameError::Oversize(n) => write!(f, "payload length {n} exceeds {MAX_PAYLOAD}"),
            FrameError::BadCrc => write!(f, "frame CRC mismatch"),
            FrameError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: PayloadKind,
    pub worker: u32,
    pub round: u64,
    pub seq: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: PayloadKind, worker: u32, round: u64, seq: u64, payload: Vec<u8>) -> Self {
        Frame { kind, worker, round, seq, payload }
    }

    /// Total encoded size in bytes.
    pub fn wire_len(&self) -> usize {
        HEADER_LEN + self.payload.len() + TRAILER_LEN
    }

    /// Serialize into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        self.encode_into(&mut out);
        out
    }

    /// Serialize, appending to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let len = u32::try_from(self.payload.len()).expect("payload exceeds u32 len field");
        assert!(len <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
        let start = out.len();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind.byte());
        out.push(0); // reserved
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out[start..]);
        out.extend_from_slice(&crc.to_le_bytes());
    }

    /// Strict decode of one frame from the start of `buf`. Returns the
    /// frame plus the number of bytes consumed (the caller may have
    /// more frames after it). All failures are typed; `Truncated`
    /// means "feed me more bytes", everything else means the prefix
    /// can never become a valid frame.
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
        let hdr_len = Self::decode_header(buf)?;
        let len = usize::try_from(hdr_len).map_err(|_| FrameError::Oversize(hdr_len))?;
        let total = HEADER_LEN + len + TRAILER_LEN;
        if buf.len() < total {
            return Err(FrameError::Truncated);
        }
        let body = buf.get(..HEADER_LEN + len).ok_or(FrameError::Truncated)?;
        let want = u32::from_le_bytes(le_bytes(buf, HEADER_LEN + len)?);
        if crc32(body) != want {
            return Err(FrameError::BadCrc);
        }
        let kind = PayloadKind::from_byte(*buf.get(6).ok_or(FrameError::Truncated)?)?;
        let worker = u32::from_le_bytes(le_bytes(buf, 8)?);
        let round = u64::from_le_bytes(le_bytes(buf, 12)?);
        let seq = u64::from_le_bytes(le_bytes(buf, 20)?);
        let payload = buf.get(HEADER_LEN..HEADER_LEN + len).ok_or(FrameError::Truncated)?.to_vec();
        Ok((Frame { kind, worker, round, seq, payload }, total))
    }

    /// Validate the fixed header and return the declared payload
    /// length. Never reads past `HEADER_LEN` bytes.
    fn decode_header(buf: &[u8]) -> Result<u32, FrameError> {
        if buf.len() < HEADER_LEN {
            return Err(FrameError::Truncated);
        }
        if le_bytes::<4>(buf, 0)? != MAGIC {
            return Err(FrameError::BadMagic);
        }
        let version = u16::from_le_bytes(le_bytes(buf, 4)?);
        if version != VERSION {
            return Err(FrameError::BadVersion(version));
        }
        PayloadKind::from_byte(*buf.get(6).ok_or(FrameError::Truncated)?)?;
        let len = u32::from_le_bytes(le_bytes(buf, 28)?);
        if len > MAX_PAYLOAD {
            return Err(FrameError::Oversize(len));
        }
        Ok(len)
    }
}

/// Bounds-checked fixed-width field read: the `N` bytes at `off`.
/// The only way decode paths touch raw buffer bytes — total by
/// construction, so no decode site ever indexes a slice directly.
fn le_bytes<const N: usize>(buf: &[u8], off: usize) -> Result<[u8; N], FrameError> {
    let end = off.checked_add(N).ok_or(FrameError::Truncated)?;
    let bytes = buf.get(off..end).ok_or(FrameError::Truncated)?;
    bytes.try_into().map_err(|_| FrameError::Truncated)
}

/// Outcome of one streaming decode step over a receive buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum Decoded {
    /// A complete valid frame; `usize` is the bytes to drain.
    Frame(Frame, usize),
    /// The buffer holds only a prefix; read more bytes.
    Incomplete,
    /// The prefix can never decode; drain `skip` bytes and resync.
    Corrupt { skip: usize, err: FrameError },
}

/// Streaming decode: classify the buffer prefix. A corrupt *body*
/// (CRC mismatch with a plausible header) skips the whole declared
/// frame; a corrupt *header* skips one byte so the scan can resync on
/// the next magic. The receiver relies on retransmission — corrupt
/// frames are dropped, never repaired.
pub fn decode_step(buf: &[u8]) -> Decoded {
    match Frame::decode(buf) {
        Ok((frame, used)) => Decoded::Frame(frame, used),
        Err(FrameError::Truncated) => Decoded::Incomplete,
        Err(FrameError::BadCrc) => {
            // Header was valid, so the declared extent is trustworthy
            // enough to skip past in one step. Re-derive it through the
            // total header parser rather than indexing the raw bytes.
            let skip = Frame::decode_header(buf)
                .ok()
                .and_then(|len| usize::try_from(len).ok())
                .map_or(1, |len| HEADER_LEN + len + TRAILER_LEN);
            Decoded::Corrupt { skip, err: FrameError::BadCrc }
        }
        Err(err) => Decoded::Corrupt { skip: 1, err },
    }
}

/// CRC-32 (IEEE 802.3, reflected, init/final 0xFFFFFFFF) — the same
/// polynomial as zlib. Table built at compile time; no dependency.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = u32::MAX;
    for &b in bytes {
        // tidy:allow(numeric-cast) -- provably masked 8-bit table index
        c = CRC_TABLE[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

static CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        // tidy:allow(numeric-cast) -- u32::try_from is not usable in a const fn
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

// ---------------------------------------------------------------------
// Payload codec for message vectors
// ---------------------------------------------------------------------

/// Per-message variant tags in [`encode_msgs`] payloads.
const TAG_SPARSE: u8 = 0;
const TAG_DENSE: u8 = 1;
const TAG_FACTORS: u8 = 2;

/// Serialize a per-layer message vector: `count u32`, then per message
/// a variant tag and its fields. Float values travel as raw IEEE-754
/// bits, so encode/decode is a bit-exact roundtrip (NaN included).
pub fn encode_msgs(msgs: &[Compressed]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&len_u32(msgs.len()));
    for msg in msgs {
        match msg {
            Compressed::Sparse { dim, idx, val } => {
                out.push(TAG_SPARSE);
                out.extend_from_slice(&len_u64(*dim));
                out.extend_from_slice(&len_u32(idx.len()));
                out.extend_from_slice(&len_u32(val.len()));
                for i in idx {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for v in val {
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            Compressed::Dense { val, bits_per_val } => {
                out.push(TAG_DENSE);
                out.extend_from_slice(&bits_per_val.to_le_bytes());
                out.extend_from_slice(&len_u32(val.len()));
                for v in val {
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            Compressed::Factors { rows, cols, u, v } => {
                out.push(TAG_FACTORS);
                out.extend_from_slice(&len_u64(*rows));
                out.extend_from_slice(&len_u64(*cols));
                out.extend_from_slice(&len_u32(u.len()));
                out.extend_from_slice(&len_u32(v.len()));
                for x in u {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
                for x in v {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
        }
    }
    out
}

/// Inverse of [`encode_msgs`]. Total: every count is validated against
/// the remaining bytes *before* any allocation is sized from it, so
/// arbitrary input can neither panic nor OOM.
pub fn decode_msgs(buf: &[u8]) -> Result<Vec<Compressed>, FrameError> {
    let mut r = Reader { buf, pos: 0 };
    let count = r.len()?;
    // A message is at least 1 tag byte: cheap sanity bound on `count`.
    if count > buf.len() {
        return Err(FrameError::Malformed("message count exceeds payload"));
    }
    let mut msgs = Vec::with_capacity(count);
    for _ in 0..count {
        let msg = match r.u8()? {
            TAG_SPARSE => {
                let dim = r.len64()?;
                let ni = r.len()?;
                let nv = r.len()?;
                let idx = r.u32_vec(ni)?;
                let val = r.f32_vec(nv)?;
                Compressed::Sparse { dim, idx, val }
            }
            TAG_DENSE => {
                let bits_per_val = r.u64()?;
                let n = r.len()?;
                Compressed::Dense { val: r.f32_vec(n)?, bits_per_val }
            }
            TAG_FACTORS => {
                let rows = r.len64()?;
                let cols = r.len64()?;
                let nu = r.len()?;
                let nv = r.len()?;
                let u = r.f32_vec(nu)?;
                let v = r.f32_vec(nv)?;
                Compressed::Factors { rows, cols, u, v }
            }
            _ => return Err(FrameError::Malformed("unknown message tag")),
        };
        msgs.push(msg);
    }
    if r.pos != buf.len() {
        return Err(FrameError::Malformed("trailing bytes after messages"));
    }
    Ok(msgs)
}

/// Bounds-checked little-endian cursor: every read is validated
/// against the remaining input, so element counts can never size an
/// allocation past the bytes that actually back them.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], FrameError> {
        let end = self.pos.checked_add(n).ok_or(FrameError::Truncated)?;
        let out = self.buf.get(self.pos..end).ok_or(FrameError::Truncated)?;
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        self.take(1)?.first().copied().ok_or(FrameError::Truncated)
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let pos = self.pos;
        let bytes = le_bytes(self.buf, pos)?;
        self.pos = pos + 4;
        Ok(u32::from_le_bytes(bytes))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let pos = self.pos;
        let bytes = le_bytes(self.buf, pos)?;
        self.pos = pos + 8;
        Ok(u64::from_le_bytes(bytes))
    }

    /// A `u32` count field converted to the `usize` it sizes.
    fn len(&mut self) -> Result<usize, FrameError> {
        usize::try_from(self.u32()?).map_err(|_| FrameError::Malformed("count exceeds usize"))
    }

    /// A `u64` dimension field converted to the `usize` it describes.
    fn len64(&mut self) -> Result<usize, FrameError> {
        usize::try_from(self.u64()?).map_err(|_| FrameError::Malformed("dimension exceeds usize"))
    }

    fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>, FrameError> {
        let bytes = self.take(n.checked_mul(4).ok_or(FrameError::Truncated)?)?;
        let mut out = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(4) {
            out.push(u32::from_le_bytes(chunk.try_into().map_err(|_| FrameError::Truncated)?));
        }
        Ok(out)
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, FrameError> {
        let bytes = self.take(n.checked_mul(4).ok_or(FrameError::Truncated)?)?;
        let mut out = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(4) {
            let bits = u32::from_le_bytes(chunk.try_into().map_err(|_| FrameError::Truncated)?);
            out.push(f32::from_bits(bits));
        }
        Ok(out)
    }
}

/// Encode a `usize` length as the `u32` count field used on the wire.
fn len_u32(n: usize) -> [u8; 4] {
    u32::try_from(n).expect("length exceeds u32 wire field").to_le_bytes()
}

/// Encode a `usize` dimension as the `u64` field used on the wire.
fn len_u64(n: usize) -> [u8; 8] {
    u64::try_from(n).expect("dimension exceeds u64 wire field").to_le_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn arbitrary_frame(rng: &mut Rng) -> Frame {
        let kinds = [
            PayloadKind::Broadcast,
            PayloadKind::Upload,
            PayloadKind::Probe,
            PayloadKind::Ack,
            PayloadKind::Shutdown,
        ];
        let n = rng.range_usize(0, 257);
        let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        Frame {
            kind: kinds[rng.range_usize(0, kinds.len())],
            worker: rng.next_u64() as u32,
            round: rng.next_u64(),
            seq: rng.next_u64(),
            payload,
        }
    }

    fn arbitrary_msgs(rng: &mut Rng) -> Vec<Compressed> {
        let n = rng.range_usize(0, 5);
        (0..n)
            .map(|_| match rng.range_usize(0, 3) {
                0 => {
                    let k = rng.range_usize(0, 17);
                    Compressed::Sparse {
                        dim: rng.range_usize(0, 1000),
                        idx: (0..k).map(|_| rng.next_u64() as u32).collect(),
                        val: (0..k).map(|_| rng.range_f32(-10.0, 10.0)).collect(),
                    }
                }
                1 => Compressed::Dense {
                    val: (0..rng.range_usize(0, 17)).map(|_| rng.next_f32()).collect(),
                    bits_per_val: rng.range_usize(1, 33) as u64,
                },
                _ => {
                    let (r, c, k) = (rng.range_usize(1, 5), rng.range_usize(1, 5), 2);
                    Compressed::Factors {
                        rows: r,
                        cols: c,
                        u: (0..r * k).map(|_| rng.next_f32()).collect(),
                        v: (0..c * k).map(|_| rng.next_f32()).collect(),
                    }
                }
            })
            .collect()
    }

    #[test]
    fn frame_roundtrip_identity() {
        prop::check("frame-roundtrip", 0xF0A1, 300, |rng| {
            let frame = arbitrary_frame(rng);
            let bytes = frame.encode();
            let (back, used) = Frame::decode(&bytes).expect("roundtrip decode");
            assert_eq!(used, bytes.len());
            assert_eq!(back, frame);
        });
    }

    #[test]
    fn truncated_prefix_is_typed_error() {
        prop::check("frame-truncated", 0xF0A2, 200, |rng| {
            let bytes = arbitrary_frame(rng).encode();
            let cut = rng.range_usize(0, bytes.len());
            assert_eq!(Frame::decode(&bytes[..cut]).unwrap_err(), FrameError::Truncated);
            assert_eq!(decode_step(&bytes[..cut]), Decoded::Incomplete);
        });
    }

    #[test]
    fn single_bit_flip_is_detected() {
        // CRC-32 detects every single-bit error, so any one-bit flip
        // anywhere in the frame must fail decode with a typed error.
        prop::check("frame-bitflip", 0xF0A3, 300, |rng| {
            let mut bytes = arbitrary_frame(rng).encode();
            let bit = rng.range_usize(0, bytes.len() * 8);
            bytes[bit / 8] ^= 1 << (bit % 8);
            assert!(Frame::decode(&bytes).is_err());
        });
    }

    #[test]
    fn arbitrary_bytes_never_panic() {
        prop::check("frame-fuzz", 0xF0A4, 500, |rng| {
            let n = rng.range_usize(0, 300);
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            // Half the cases get a real magic prefix so header parsing
            // is exercised past the first gate.
            if rng.next_f64() < 0.5 && bytes.len() >= 4 {
                bytes[..4].copy_from_slice(&MAGIC);
            }
            let _ = Frame::decode(&bytes);
            let _ = decode_step(&bytes);
        });
    }

    #[test]
    fn oversize_len_is_rejected_before_allocation() {
        let mut frame = Frame::new(PayloadKind::Probe, 0, 0, 0, vec![]).encode();
        frame[28..32].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Frame::decode(&frame).unwrap_err(), FrameError::Oversize(u32::MAX));
    }

    #[test]
    fn corrupt_body_skips_whole_frame() {
        let good = Frame::new(PayloadKind::Upload, 3, 7, 1, vec![9; 16]);
        let mut bytes = good.encode();
        let total = bytes.len();
        bytes[HEADER_LEN] ^= 0xFF; // corrupt payload, header stays valid
        let next = Frame::new(PayloadKind::Ack, 3, 1, 2, vec![]);
        next.encode_into(&mut bytes);
        match decode_step(&bytes) {
            Decoded::Corrupt { skip, err } => {
                assert_eq!(skip, total);
                assert_eq!(err, FrameError::BadCrc);
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let (resynced, _) = Frame::decode(&bytes[total..]).expect("resync on next frame");
        assert_eq!(resynced, next);
    }

    #[test]
    fn msgs_roundtrip_identity() {
        prop::check("msgs-roundtrip", 0xF0A5, 300, |rng| {
            let msgs = arbitrary_msgs(rng);
            let bytes = encode_msgs(&msgs);
            assert_eq!(decode_msgs(&bytes).expect("roundtrip"), msgs);
        });
    }

    #[test]
    fn msgs_decoder_never_panics() {
        prop::check("msgs-fuzz", 0xF0A6, 500, |rng| {
            let n = rng.range_usize(0, 200);
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let _ = decode_msgs(&bytes);
            // Truncations of a valid encoding must error, not panic.
            let valid = encode_msgs(&arbitrary_msgs(rng));
            let cut = rng.range_usize(0, valid.len());
            if cut < valid.len() {
                assert!(decode_msgs(&valid[..cut]).is_err());
            }
        });
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical zlib check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
