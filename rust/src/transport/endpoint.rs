//! Blocking-I/O endpoint layer: TCP / Unix-socket connections carrying
//! [`Frame`]s with per-peer ordered, acknowledged delivery.
//!
//! The protocol is stop-and-wait per connection and direction: each
//! data frame carries a sequence number; the receiver acks in-order
//! frames immediately, re-acks duplicates, and rejects gaps (a gap is
//! a protocol bug, not a network fault — TCP/UDS never reorder). The
//! sender retransmits on ack timeout with bounded exponential backoff.
//! An in-order *data* frame arriving while the sender awaits an ack is
//! an implicit acknowledgement: the lockstep protocol only lets a peer
//! send data after it has received ours, so the frame is stashed in a
//! one-slot pending buffer and the send completes.
//!
//! Connection setup retries with the same bounded exponential backoff
//! ([`backoff_delay`]), so workers may dial before the coordinator
//! finishes binding. After retry budgets are exhausted the endpoint
//! fails loudly — the run model is crash-stop, not partition-tolerant.
// Wall-clock allowlist file (ARCHITECTURE.md §6): this layer measures
// real time by design; clippy.toml bans the methods elsewhere.
#![allow(clippy::disallowed_methods)]

use super::faults::{self, FaultInjector};
use super::frame::{decode_step, Decoded, Frame, PayloadKind};
use crate::config::TransportSpec;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Timeout and retry knobs for one endpoint.
#[derive(Debug, Clone)]
pub struct TimeoutCfg {
    /// Longest single blocking read before the poll loop re-checks
    /// its deadline.
    pub io_chunk: Duration,
    /// Base ack-wait before the first retransmission.
    pub ack_base: Duration,
    /// Ceiling for the exponentially backed-off ack wait.
    pub ack_cap: Duration,
    /// Retransmission attempts before a send fails.
    pub max_retries: u32,
    /// Overall deadline for a blocking receive.
    pub recv_deadline: Duration,
    /// Connection attempts before a dial fails.
    pub dial_attempts: u32,
    /// Base delay between dial attempts (exponential, capped).
    pub dial_base: Duration,
    /// Ceiling for the dial backoff.
    pub dial_cap: Duration,
}

impl Default for TimeoutCfg {
    fn default() -> Self {
        TimeoutCfg {
            io_chunk: Duration::from_millis(500),
            ack_base: Duration::from_millis(100),
            ack_cap: Duration::from_secs(2),
            max_retries: 40,
            recv_deadline: Duration::from_secs(60),
            dial_attempts: 10,
            dial_base: Duration::from_millis(25),
            dial_cap: Duration::from_secs(2),
        }
    }
}

/// Bounded exponential backoff: `base * 2^attempt`, saturating at
/// `cap`. Pure so the schedule is unit-testable.
pub fn backoff_delay(attempt: u32, base: Duration, cap: Duration) -> Duration {
    let mult = 1u32.checked_shl(attempt.min(16)).unwrap_or(u32::MAX);
    base.checked_mul(mult).map_or(cap, |d| d.min(cap))
}

/// One established wire connection.
#[derive(Debug)]
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(UnixStream),
}

impl Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read(buf),
        }
    }

    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.write_all(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write_all(buf),
        }
    }

    fn set_read_timeout(&self, dur: Duration) -> std::io::Result<()> {
        // A zero timeout means "block forever" to the socket API;
        // clamp up so the poll loop always regains control.
        let dur = dur.max(Duration::from_millis(1));
        match self {
            Conn::Tcp(s) => s.set_read_timeout(Some(dur)),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_read_timeout(Some(dur)),
        }
    }

    fn set_blocking(&self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(false),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_nonblocking(false),
        }
    }
}

/// Dial an address token (`tcp:HOST:PORT`, `uds:PATH`, or bare
/// `HOST:PORT`) with bounded exponential-backoff retries.
pub fn dial(token: &str, timeouts: &TimeoutCfg) -> anyhow::Result<Conn> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..timeouts.dial_attempts.max(1) {
        if attempt > 0 {
            std::thread::sleep(backoff_delay(attempt - 1, timeouts.dial_base, timeouts.dial_cap));
        }
        match try_connect(token) {
            Ok(conn) => return Ok(conn),
            Err(err) => last = Some(err),
        }
    }
    anyhow::bail!(
        "failed to connect to {token} after {} attempts: {}",
        timeouts.dial_attempts,
        last.map_or_else(|| "no attempt made".into(), |e| e.to_string())
    )
}

fn try_connect(token: &str) -> std::io::Result<Conn> {
    if let Some(path) = token.strip_prefix("uds:") {
        #[cfg(unix)]
        return Ok(Conn::Uds(UnixStream::connect(path)?));
        #[cfg(not(unix))]
        return Err(std::io::Error::new(
            ErrorKind::Unsupported,
            format!("unix sockets unavailable on this platform ({path})"),
        ));
    }
    let addr = token.strip_prefix("tcp:").unwrap_or(token);
    Ok(Conn::Tcp(TcpStream::connect(addr)?))
}

/// A bound accept socket for the coordinator side.
#[derive(Debug)]
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(UnixListener, PathBuf),
}

impl Listener {
    /// Bind per the transport spec: TCP on an ephemeral localhost
    /// port, or a fresh socket path under the system temp dir.
    pub fn bind(spec: TransportSpec) -> anyhow::Result<Self> {
        match spec {
            TransportSpec::Tcp => Ok(Listener::Tcp(TcpListener::bind("127.0.0.1:0")?)),
            TransportSpec::Uds => bind_uds(),
            TransportSpec::Inproc => anyhow::bail!("inproc transport has no listener"),
        }
    }

    /// The `--connect` token workers dial to reach this listener.
    pub fn addr_token(&self) -> anyhow::Result<String> {
        match self {
            Listener::Tcp(l) => Ok(format!("tcp:{}", l.local_addr()?)),
            #[cfg(unix)]
            Listener::Uds(_, path) => Ok(format!("uds:{}", path.display())),
        }
    }

    /// Accept one connection, polling against a deadline so a worker
    /// that never dials fails the run loudly instead of hanging.
    pub fn accept_deadline(&self, deadline: Instant) -> anyhow::Result<Conn> {
        self.set_nonblocking(true)?;
        loop {
            let conn = match self {
                Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
                #[cfg(unix)]
                Listener::Uds(l, _) => l.accept().map(|(s, _)| Conn::Uds(s)),
            };
            match conn {
                Ok(conn) => {
                    conn.set_blocking()?;
                    return Ok(conn);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        anyhow::bail!("timed out waiting for a worker to connect");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Uds(l, _) => l.set_nonblocking(nb),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(unix)]
fn bind_uds() -> anyhow::Result<Listener> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static UDS_COUNTER: AtomicU64 = AtomicU64::new(0);
    // Socket paths must stay short (the sockaddr_un limit), so use the
    // system temp dir with a pid + counter suffix for uniqueness.
    let path = std::env::temp_dir().join(format!(
        "kimad-{}-{}.sock",
        std::process::id(),
        UDS_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_file(&path);
    Ok(Listener::Uds(UnixListener::bind(&path)?, path))
}

#[cfg(not(unix))]
fn bind_uds() -> anyhow::Result<Listener> {
    anyhow::bail!("unix-socket transport unavailable on this platform")
}

/// One reliable frame endpoint over an established connection.
#[derive(Debug)]
pub struct Endpoint {
    conn: Conn,
    faults: FaultInjector,
    timeouts: TimeoutCfg,
    label: String,
    next_send_seq: u64,
    next_recv_seq: u64,
    /// One-slot buffer for a data frame that arrived as an implicit
    /// ack during [`Endpoint::send_reliable`].
    pending: Option<Frame>,
    rx: Vec<u8>,
}

impl Endpoint {
    pub fn new(conn: Conn, faults: FaultInjector, timeouts: TimeoutCfg, label: String) -> Self {
        Endpoint {
            conn,
            faults,
            timeouts,
            label,
            next_send_seq: 0,
            next_recv_seq: 0,
            pending: None,
            rx: Vec::new(),
        }
    }

    /// Swap in a fault injector (the coordinator learns which worker a
    /// connection belongs to — and hence its fault leg — only after
    /// the Probe handshake).
    pub fn set_faults(&mut self, faults: FaultInjector) {
        self.faults = faults;
    }

    /// Rename the endpoint for error messages.
    pub fn set_label(&mut self, label: String) {
        self.label = label;
    }

    /// Send one data frame, retransmitting with exponential backoff
    /// until it is acknowledged (explicitly, or implicitly by the
    /// peer's next in-order data frame).
    pub fn send_reliable(
        &mut self,
        kind: PayloadKind,
        worker: u32,
        round: u64,
        payload: Vec<u8>,
    ) -> anyhow::Result<()> {
        debug_assert!(kind != PayloadKind::Ack, "acks are sent internally");
        let seq = self.next_send_seq;
        self.next_send_seq += 1;
        let bytes = Frame::new(kind, worker, round, seq, payload).encode();
        for attempt in 0..=self.timeouts.max_retries {
            self.transmit(&bytes)?;
            let wait = backoff_delay(attempt, self.timeouts.ack_base, self.timeouts.ack_cap);
            let deadline = Instant::now() + wait;
            while let Some(frame) = self.poll_frame(deadline)? {
                if self.note_frame(frame, seq)? {
                    return Ok(());
                }
            }
        }
        anyhow::bail!(
            "no ack for seq {seq} from {} after {} retransmissions",
            self.label,
            self.timeouts.max_retries
        )
    }

    /// Classify a frame seen while awaiting the ack for `sent_seq`.
    /// Returns true once that send is acknowledged.
    fn note_frame(&mut self, frame: Frame, sent_seq: u64) -> anyhow::Result<bool> {
        match frame.kind {
            // For acks, `round` carries the acknowledged sequence.
            PayloadKind::Ack => Ok(frame.round == sent_seq),
            _ => {
                if frame.seq == self.next_recv_seq {
                    // Implicit ack: the peer only sends data after
                    // receiving ours. Ack it, stash it for the next
                    // recv, and consider our send complete.
                    self.next_recv_seq += 1;
                    self.ack(&frame)?;
                    anyhow::ensure!(
                        self.pending.is_none(),
                        "protocol violation: two unconsumed data frames from {}",
                        self.label
                    );
                    self.pending = Some(frame);
                    Ok(true)
                } else if frame.seq < self.next_recv_seq {
                    // Our earlier ack was lost; quench the retransmit.
                    self.ack(&frame)?;
                    Ok(false)
                } else {
                    anyhow::bail!(
                        "out-of-order frame from {}: seq {} but expected {}",
                        self.label,
                        frame.seq,
                        self.next_recv_seq
                    )
                }
            }
        }
    }

    /// Receive the next in-order data frame, acking it (and re-acking
    /// any duplicates drained along the way).
    pub fn recv_reliable(&mut self) -> anyhow::Result<Frame> {
        if let Some(frame) = self.pending.take() {
            return Ok(frame);
        }
        let deadline = Instant::now() + self.timeouts.recv_deadline;
        loop {
            let Some(frame) = self.poll_frame(deadline)? else {
                anyhow::bail!(
                    "timed out after {:?} waiting for a frame from {}",
                    self.timeouts.recv_deadline,
                    self.label
                )
            };
            match frame.kind {
                // A stale ack for a send that already completed.
                PayloadKind::Ack => continue,
                _ => {
                    if frame.seq == self.next_recv_seq {
                        self.next_recv_seq += 1;
                        self.ack(&frame)?;
                        return Ok(frame);
                    } else if frame.seq < self.next_recv_seq {
                        self.ack(&frame)?;
                    } else {
                        anyhow::bail!(
                            "out-of-order frame from {}: seq {} but expected {}",
                            self.label,
                            frame.seq,
                            self.next_recv_seq
                        )
                    }
                }
            }
        }
    }

    /// Drain and re-ack retransmissions until the peer closes the
    /// connection (or the receive deadline passes). The last ack a
    /// side sends can always be lost, so whoever finishes first must
    /// stay around to quench retransmissions instead of slamming the
    /// socket shut — a worker calls this after `Shutdown`, and the
    /// coordinator's drop of the connection is what releases it.
    pub fn linger(&mut self) {
        let deadline = Instant::now() + self.timeouts.recv_deadline;
        loop {
            match self.poll_frame(deadline) {
                Ok(Some(frame)) => {
                    if frame.kind != PayloadKind::Ack
                        && frame.seq < self.next_recv_seq
                        && self.ack(&frame).is_err()
                    {
                        return;
                    }
                }
                // Deadline, or the peer closed — the normal release.
                Ok(None) | Err(_) => return,
            }
        }
    }

    fn ack(&mut self, frame: &Frame) -> anyhow::Result<()> {
        let ack = Frame::new(PayloadKind::Ack, frame.worker, frame.seq, frame.seq, Vec::new());
        self.transmit(&ack.encode())
    }

    /// Write one encoded frame, routed through the fault injector.
    fn transmit(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let plan = self.faults.next();
        if plan.delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(plan.delay_ms));
        }
        if plan.drop {
            return Ok(());
        }
        if plan.truncate {
            self.conn.write_all(&faults::truncate_frame(bytes))?;
            return Ok(());
        }
        self.conn.write_all(bytes)?;
        if plan.duplicate {
            self.conn.write_all(bytes)?;
        }
        Ok(())
    }

    /// Drain the socket until one whole valid frame decodes or the
    /// deadline passes (`Ok(None)`). Corrupt prefixes are skipped per
    /// [`decode_step`]'s resync rule.
    fn poll_frame(&mut self, deadline: Instant) -> anyhow::Result<Option<Frame>> {
        let mut buf = [0u8; 64 * 1024];
        loop {
            loop {
                match decode_step(&self.rx) {
                    Decoded::Frame(frame, used) => {
                        self.rx.drain(..used);
                        return Ok(Some(frame));
                    }
                    Decoded::Incomplete => break,
                    Decoded::Corrupt { skip, .. } => {
                        let n = skip.min(self.rx.len());
                        self.rx.drain(..n);
                    }
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            self.conn.set_read_timeout((deadline - now).min(self.timeouts.io_chunk))?;
            match self.conn.read(&mut buf) {
                Ok(0) => anyhow::bail!("connection to {} closed by peer", self.label),
                Ok(n) => self.rx.extend_from_slice(&buf[..n]),
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut
                        || e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(anyhow::anyhow!("read from {} failed: {e}", self.label));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::faults::FaultPlan;

    #[test]
    fn backoff_doubles_then_caps() {
        let base = Duration::from_millis(25);
        let cap = Duration::from_secs(2);
        assert_eq!(backoff_delay(0, base, cap), Duration::from_millis(25));
        assert_eq!(backoff_delay(1, base, cap), Duration::from_millis(50));
        assert_eq!(backoff_delay(3, base, cap), Duration::from_millis(200));
        assert_eq!(backoff_delay(10, base, cap), cap);
        assert_eq!(backoff_delay(u32::MAX, base, cap), cap);
    }

    fn pair(spec: TransportSpec, plan: &FaultPlan) -> (Endpoint, Endpoint) {
        let listener = Listener::bind(spec).unwrap();
        let token = listener.addr_token().unwrap();
        let timeouts = TimeoutCfg {
            ack_base: Duration::from_millis(30),
            recv_deadline: Duration::from_secs(20),
            ..TimeoutCfg::default()
        };
        let client = dial(&token, &timeouts).unwrap();
        let server = listener.accept_deadline(Instant::now() + Duration::from_secs(5)).unwrap();
        let coord_faults = FaultInjector::new(plan, 1000);
        let a = Endpoint::new(server, coord_faults, timeouts.clone(), "client".into());
        let b = Endpoint::new(client, FaultInjector::new(plan, 1), timeouts, "server".into());
        (a, b)
    }

    fn ping_pong(mut a: Endpoint, mut b: Endpoint, rounds: u64) {
        let worker = std::thread::spawn(move || {
            for k in 0..rounds {
                let f = b.recv_reliable().unwrap();
                assert_eq!(f.kind, PayloadKind::Broadcast);
                assert_eq!(f.round, k);
                assert_eq!(f.payload, vec![k as u8; 64]);
                b.send_reliable(PayloadKind::Upload, 0, k, vec![!k as u8; 32]).unwrap();
            }
        });
        for k in 0..rounds {
            a.send_reliable(PayloadKind::Broadcast, 0, k, vec![k as u8; 64]).unwrap();
            let f = a.recv_reliable().unwrap();
            assert_eq!(f.kind, PayloadKind::Upload);
            assert_eq!(f.round, k);
            assert_eq!(f.payload, vec![!k as u8; 32]);
        }
        // Our ack of the final upload may have been dropped: keep
        // re-acking retransmissions until the peer's send completes
        // and it closes its end.
        a.linger();
        worker.join().unwrap();
    }

    #[test]
    fn reliable_ping_pong_tcp() {
        let (a, b) = pair(TransportSpec::Tcp, &FaultPlan::none());
        ping_pong(a, b, 8);
    }

    #[cfg(unix)]
    #[test]
    fn reliable_ping_pong_uds() {
        let (a, b) = pair(TransportSpec::Uds, &FaultPlan::none());
        ping_pong(a, b, 8);
    }

    #[test]
    fn reliable_under_faults() {
        // Heavy seeded faults on every transmission (including acks):
        // the stop-and-wait layer must still deliver every frame, in
        // order, with the exact payload bytes.
        let plan =
            FaultPlan::parse("drop=0.2,dup=0.15,trunc=0.15,delay=0.2,delay_ms=2,seed=11").unwrap();
        let (a, b) = pair(TransportSpec::Tcp, &plan);
        ping_pong(a, b, 12);
    }

    #[test]
    fn dial_bad_address_fails_bounded() {
        let timeouts = TimeoutCfg {
            dial_attempts: 2,
            dial_base: Duration::from_millis(1),
            ..TimeoutCfg::default()
        };
        // Port 1 on localhost: nothing listens there in CI.
        assert!(dial("tcp:127.0.0.1:1", &timeouts).is_err());
    }

    #[cfg(unix)]
    #[test]
    fn uds_listener_cleans_up_socket_path() {
        let listener = Listener::bind(TransportSpec::Uds).unwrap();
        let token = listener.addr_token().unwrap();
        let path = PathBuf::from(token.strip_prefix("uds:").unwrap());
        assert!(path.exists());
        drop(listener);
        assert!(!path.exists());
    }
}
