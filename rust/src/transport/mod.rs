//! A real wire for the round engine: multi-process coordinator/worker
//! execution over TCP or Unix-domain sockets.
//!
//! # Why byte-identity is by construction
//!
//! The engine's rounds are bit-deterministic functions of (config,
//! seed): same compressed selections, same EF21 mirror advances, same
//! wire messages on every machine. The wire mode exploits that by
//! running *lockstep replicas* — the coordinator and every worker
//! build the identical [`Simulation`](crate::coordinator::Simulation)
//! from the same config ([`WarmFamily::build_wired`]) and step it in
//! lockstep, so each side can compute the exact bytes the other must
//! send. Every received payload is verified against the local
//! replica's bytes frame by frame at runtime: any divergence —
//! engine nondeterminism, codec bug, corruption the CRC missed —
//! fails the run loudly instead of training on silently wrong bits.
//! Only arrival *timestamps* differ between inproc and wired runs;
//! results ([`ExperimentResult`]) are byte-identical.
//!
//! # Frame format
//!
//! See [`frame`] for the full spec (32-byte little-endian header,
//! CRC-32 trailer, typed decode errors, length clamped before
//! allocation). Kinds: `Broadcast`, `Upload`, `Probe` (handshake),
//! `Ack`, `Shutdown`.
//!
//! # Round protocol (Sync, dense only)
//!
//! 1. Workers dial the coordinator (bounded exponential-backoff
//!    reconnect) and send a `Probe` carrying `(worker id, M)`.
//! 2. Per round: the coordinator steps its replica, sends each worker
//!    a `Broadcast` frame (the round's serialized per-layer broadcast
//!    messages), and waits for each worker's `Upload`. Each worker
//!    gates its replica's round k on `Broadcast` k, verifies the
//!    payload equals its own locally computed broadcast bytes, then
//!    uploads its worker's serialized messages — which the coordinator
//!    verifies in turn. The round barrier is the M upload receipts.
//! 3. After the last round the coordinator sends `Shutdown`s.
//!
//! Delivery is stop-and-wait with acks, duplicate suppression and
//! retransmission ([`endpoint`]); seeded fault injection ([`faults`])
//! can drop/delay/duplicate/truncate any transmission attempt and the
//! run must still produce identical results.
// Wall-clock allowlist file (ARCHITECTURE.md §6): this layer measures
// real time by design; clippy.toml bans the methods elsewhere.
#![allow(clippy::disallowed_methods)]

pub mod endpoint;
pub mod faults;
pub mod frame;
pub mod worker;

use crate::config::ExperimentConfig;
use crate::driver::{ExperimentResult, WarmFamily, WiredCell};
use endpoint::{Endpoint, Listener, TimeoutCfg};
use faults::{FaultInjector, FaultPlan};
use frame::PayloadKind;
use std::time::Instant;

/// Fault-injector leg offset for coordinator-side endpoints (worker
/// side uses `id + 1`), keeping every endpoint's decision stream
/// distinct.
const COORD_LEG_BASE: u64 = 1000;

/// How worker peers are spawned for a wired run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpawnMode {
    /// Processes when a `kimad` binary is identifiable (the running
    /// executable is `kimad`, or `KIMAD_WORKER_BIN` is set), else
    /// threads — so `cargo test` binaries transparently get the
    /// in-process-tree topology.
    Auto,
    /// OS threads in this process, sharing the prepared family.
    Thread,
    /// Separate OS processes running `kimad worker`.
    Process,
}

/// Runtime options for a wired run — env-derived by the driver path,
/// explicit in tests.
#[derive(Debug, Clone)]
pub struct WireOpts {
    pub faults: FaultPlan,
    pub timeouts: TimeoutCfg,
    pub spawn: SpawnMode,
}

impl Default for WireOpts {
    fn default() -> Self {
        WireOpts {
            faults: FaultPlan::none(),
            timeouts: TimeoutCfg::default(),
            spawn: SpawnMode::Auto,
        }
    }
}

impl WireOpts {
    /// Options from the environment: `KIMAD_WIRE_FAULTS` (see
    /// [`FaultPlan::parse`]) and `KIMAD_WIRE_SPAWN` (`thread` |
    /// `process`).
    pub fn from_env() -> anyhow::Result<Self> {
        let spawn = match std::env::var("KIMAD_WIRE_SPAWN").ok().as_deref() {
            Some("thread") => SpawnMode::Thread,
            Some("process") => SpawnMode::Process,
            Some(other) => anyhow::bail!("KIMAD_WIRE_SPAWN='{other}' (want thread or process)"),
            None => SpawnMode::Auto,
        };
        Ok(WireOpts { faults: FaultPlan::from_env()?, timeouts: TimeoutCfg::default(), spawn })
    }
}

/// One coordinator-side wire event, logged by
/// [`run_wired_captured`] for the golden harness: the payload bytes
/// that crossed (or arrived over) the socket, minus transport framing.
#[derive(Debug, Clone, PartialEq)]
pub struct CapturedFrame {
    pub kind: PayloadKind,
    pub worker: u32,
    pub round: u64,
    pub payload: Vec<u8>,
}

/// Run a wired experiment with env-derived options (the
/// [`WarmFamily::run_with_eval`] dispatch target).
pub fn run_wired(
    family: &WarmFamily,
    cfg: &ExperimentConfig,
    eval_batches: usize,
) -> anyhow::Result<ExperimentResult> {
    let opts = WireOpts::from_env()?;
    run_wired_with(family, cfg, &opts, eval_batches, None)
}

/// [`run_wired`] with explicit options, logging every coordinator-side
/// data frame (sent broadcasts, received uploads) for the harness.
pub fn run_wired_captured(
    family: &WarmFamily,
    cfg: &ExperimentConfig,
    opts: &WireOpts,
    eval_batches: usize,
) -> anyhow::Result<(ExperimentResult, Vec<CapturedFrame>)> {
    let mut log = Vec::new();
    let result = run_wired_with(family, cfg, opts, eval_batches, Some(&mut log))?;
    Ok((result, log))
}

fn run_wired_with(
    family: &WarmFamily,
    cfg: &ExperimentConfig,
    opts: &WireOpts,
    eval_batches: usize,
    capture: Option<&mut Vec<CapturedFrame>>,
) -> anyhow::Result<ExperimentResult> {
    anyhow::ensure!(cfg.transport.is_wire(), "config transport is inproc; nothing to wire");
    anyhow::ensure!(cfg.m >= 1, "wired runs need at least one worker");
    let listener = Listener::bind(cfg.transport)?;
    let addr = listener.addr_token()?;
    match resolve_spawn(opts.spawn)? {
        Spawned::Threads => {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..cfg.m)
                    .map(|id| {
                        let addr = addr.clone();
                        s.spawn(move || {
                            worker::serve_with_family(
                                family,
                                cfg,
                                &addr,
                                id,
                                &opts.faults,
                                &opts.timeouts,
                            )
                        })
                    })
                    .collect();
                let result = coordinate(family, cfg, &listener, opts, eval_batches, capture);
                join_results(result, handles.into_iter().map(|h| h.join()).collect())
            })
        }
        Spawned::Processes(bin) => {
            let mut procs = WorkerProcs::spawn(&bin, cfg, &addr, &opts.faults)?;
            let result = coordinate(family, cfg, &listener, opts, eval_batches, capture);
            procs.finish(result.is_ok()).and(result)
        }
    }
}

/// The coordinator side: accept M handshakes, then drive the lockstep
/// rounds, verifying every upload payload against the local replica.
fn coordinate(
    family: &WarmFamily,
    cfg: &ExperimentConfig,
    listener: &Listener,
    opts: &WireOpts,
    eval_batches: usize,
    mut capture: Option<&mut Vec<CapturedFrame>>,
) -> anyhow::Result<ExperimentResult> {
    let t_build = Instant::now();
    let mut cell: WiredCell = family.build_wired(cfg)?;
    let m = cfg.m;
    let accept_by = Instant::now() + opts.timeouts.recv_deadline;
    let mut slots: Vec<Option<Endpoint>> = (0..m).map(|_| None).collect();
    for _ in 0..m {
        let conn = listener.accept_deadline(accept_by)?;
        let mut ep = Endpoint::new(
            conn,
            FaultInjector::inert(),
            opts.timeouts.clone(),
            "unidentified worker".into(),
        );
        let hello = ep.recv_reliable()?;
        anyhow::ensure!(hello.kind == PayloadKind::Probe, "expected a Probe handshake");
        anyhow::ensure!(hello.payload.len() == 8, "malformed Probe payload");
        let id_raw = u32::from_le_bytes(hello.payload[..4].try_into().expect("length checked"));
        let m_raw = u32::from_le_bytes(hello.payload[4..8].try_into().expect("length checked"));
        let id = usize::try_from(id_raw).expect("u32 fits usize");
        let peer_m = usize::try_from(m_raw).expect("u32 fits usize");
        anyhow::ensure!(peer_m == m, "worker {id} believes M = {peer_m}, coordinator has {m}");
        anyhow::ensure!(id < m, "worker id {id} out of range for M = {m}");
        anyhow::ensure!(slots[id].is_none(), "duplicate handshake for worker {id}");
        ep.set_faults(FaultInjector::new(&opts.faults, COORD_LEG_BASE + u64::from(id_raw) + 1));
        ep.set_label(format!("worker {id}"));
        slots[id] = Some(ep);
    }
    let mut eps: Vec<Endpoint> = slots.into_iter().map(|s| s.expect("all slots filled")).collect();
    let build_ms = t_build.elapsed().as_secs_f64() * 1e3;

    let mut records = Vec::with_capacity(usize::try_from(cfg.rounds).unwrap_or(0));
    for _ in 0..cfg.rounds {
        let record = cell.round()?;
        let wire = cell.take_wire()?;
        let bcast_payload = frame::encode_msgs(&wire.broadcast);
        for (id, ep) in eps.iter_mut().enumerate() {
            let wid = u32::try_from(id).expect("worker index fits u32");
            ep.send_reliable(PayloadKind::Broadcast, wid, wire.step, bcast_payload.clone())?;
            if let Some(log) = capture.as_deref_mut() {
                log.push(CapturedFrame {
                    kind: PayloadKind::Broadcast,
                    worker: wid,
                    round: wire.step,
                    payload: bcast_payload.clone(),
                });
            }
        }
        for (id, ep) in eps.iter_mut().enumerate() {
            let wid = u32::try_from(id).expect("worker index fits u32");
            let upload = ep.recv_reliable()?;
            anyhow::ensure!(
                upload.kind == PayloadKind::Upload && upload.worker == wid,
                "expected worker {id}'s Upload, got {:?} from worker {}",
                upload.kind,
                upload.worker
            );
            anyhow::ensure!(
                upload.round == wire.step,
                "worker {id} uploaded round {} during round {}",
                upload.round,
                wire.step
            );
            // The wire-bit-identity contract: the peer's bytes must
            // equal what this replica computed for that worker.
            let expect = frame::encode_msgs(&wire.uploads[id]);
            anyhow::ensure!(
                upload.payload == expect,
                "wire divergence: worker {id} round {} upload is {} bytes vs local {} \
                 (or differing content)",
                wire.step,
                upload.payload.len(),
                expect.len()
            );
            if let Some(log) = capture.as_deref_mut() {
                log.push(CapturedFrame {
                    kind: PayloadKind::Upload,
                    worker: wid,
                    round: wire.step,
                    payload: upload.payload,
                });
            }
        }
        records.push(record);
    }
    for (id, ep) in eps.iter_mut().enumerate() {
        let wid = u32::try_from(id).expect("worker index fits u32");
        ep.send_reliable(PayloadKind::Shutdown, wid, cfg.rounds, Vec::new())?;
    }
    let total_time = cell.clock();
    let eval = if eval_batches > 0 { cell.evaluate(eval_batches)? } else { None };
    Ok(ExperimentResult {
        records,
        layers: cell.layers.clone(),
        n_params: cell.n_params,
        eval,
        total_time,
        build_ms,
    })
}

enum Spawned {
    Threads,
    Processes(std::path::PathBuf),
}

fn resolve_spawn(mode: SpawnMode) -> anyhow::Result<Spawned> {
    let bin_override = std::env::var_os("KIMAD_WORKER_BIN").map(std::path::PathBuf::from);
    let own_kimad = || {
        std::env::current_exe().ok().filter(|exe| {
            exe.file_stem().map(|s| s.to_string_lossy() == "kimad").unwrap_or(false)
        })
    };
    match mode {
        SpawnMode::Thread => Ok(Spawned::Threads),
        SpawnMode::Process => {
            let bin = bin_override.or_else(own_kimad).ok_or_else(|| {
                anyhow::anyhow!(
                    "process spawn needs a kimad binary: set KIMAD_WORKER_BIN or run via kimad"
                )
            })?;
            Ok(Spawned::Processes(bin))
        }
        SpawnMode::Auto => match bin_override.or_else(own_kimad) {
            Some(bin) => Ok(Spawned::Processes(bin)),
            None => Ok(Spawned::Threads),
        },
    }
}

fn join_results(
    result: anyhow::Result<ExperimentResult>,
    joins: Vec<std::thread::Result<anyhow::Result<()>>>,
) -> anyhow::Result<ExperimentResult> {
    let mut errs = Vec::new();
    for (id, join) in joins.into_iter().enumerate() {
        match join {
            Ok(Ok(())) => {}
            Ok(Err(e)) => errs.push(format!("worker {id}: {e}")),
            Err(_) => errs.push(format!("worker {id}: panicked")),
        }
    }
    match result {
        Ok(res) if errs.is_empty() => Ok(res),
        Ok(_) => anyhow::bail!("wired run: {}", errs.join("; ")),
        Err(e) if errs.is_empty() => Err(e),
        Err(e) => anyhow::bail!("wired run: {e}; {}", errs.join("; ")),
    }
}

/// Spawned `kimad worker` children plus their temp config file; both
/// are reaped/cleaned on drop so a failing coordinator never leaks
/// orphan processes.
struct WorkerProcs {
    children: Vec<std::process::Child>,
    cfg_path: std::path::PathBuf,
}

impl WorkerProcs {
    fn spawn(
        bin: &std::path::Path,
        cfg: &ExperimentConfig,
        addr: &str,
        faults: &FaultPlan,
    ) -> anyhow::Result<Self> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static CFG_COUNTER: AtomicU64 = AtomicU64::new(0);
        let cfg_path = std::env::temp_dir().join(format!(
            "kimad-wire-{}-{}.json",
            std::process::id(),
            CFG_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&cfg_path, cfg.to_json_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", cfg_path.display()))?;
        let mut procs = WorkerProcs { children: Vec::with_capacity(cfg.m), cfg_path };
        for id in 0..cfg.m {
            let mut cmd = std::process::Command::new(bin);
            cmd.arg("worker")
                .arg("--connect")
                .arg(addr)
                .arg("--config")
                .arg(&procs.cfg_path)
                .arg("--id")
                .arg(id.to_string());
            if let Some(dir) = std::env::var_os("KIMAD_ARTIFACTS") {
                cmd.arg("--artifacts").arg(dir);
            }
            // The fault plan travels explicitly so spawned processes
            // fault-inject identically to in-process threads.
            if faults.is_active() {
                cmd.env("KIMAD_WIRE_FAULTS", faults.to_token());
            } else {
                cmd.env_remove("KIMAD_WIRE_FAULTS");
            }
            let child = cmd
                .spawn()
                .map_err(|e| anyhow::anyhow!("spawning {} worker: {e}", bin.display()))?;
            procs.children.push(child);
        }
        Ok(procs)
    }

    /// Wait for all children (when the coordinator succeeded) or kill
    /// them (when it failed — they would otherwise block on a dead
    /// socket until their own timeouts).
    fn finish(&mut self, coordinator_ok: bool) -> anyhow::Result<()> {
        let mut errs = Vec::new();
        for (id, mut child) in self.children.drain(..).enumerate() {
            if !coordinator_ok {
                let _ = child.kill();
            }
            match child.wait() {
                Ok(status) if status.success() || !coordinator_ok => {}
                Ok(status) => errs.push(format!("worker {id} exited with {status}")),
                Err(e) => errs.push(format!("worker {id}: {e}")),
            }
        }
        anyhow::ensure!(errs.is_empty(), "{}", errs.join("; "));
        Ok(())
    }
}

impl Drop for WorkerProcs {
    fn drop(&mut self) {
        for child in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
        let _ = std::fs::remove_file(&self.cfg_path);
    }
}
