//! The worker peer of a wired run: a lockstep replica of the round
//! engine gated on the coordinator's `Broadcast` frames.
//!
//! The worker rebuilds the identical deterministic simulation from the
//! same config + seed, so it *knows* the bytes the coordinator must
//! broadcast each round. Receiving `Broadcast` k releases round k:
//! the worker steps its replica, checks the received payload against
//! its locally computed broadcast bytes (the wire-bit-identity
//! contract, enforced from both sides), and answers with its own
//! worker's serialized `Upload` messages. `Shutdown` ends the loop.

use super::endpoint::{self, Endpoint, TimeoutCfg};
use super::faults::{FaultInjector, FaultPlan};
use super::frame::{self, PayloadKind};
use crate::config::ExperimentConfig;
use crate::driver::WarmFamily;

/// Serve one worker id against a prepared family (the in-process-tree
/// topology used by thread spawn and the integration harness).
pub fn serve_with_family(
    family: &WarmFamily,
    cfg: &ExperimentConfig,
    addr: &str,
    id: usize,
    faults: &FaultPlan,
    timeouts: &TimeoutCfg,
) -> anyhow::Result<()> {
    anyhow::ensure!(id < cfg.m, "worker id {id} out of range for M = {}", cfg.m);
    let wid = u32::try_from(id).map_err(|_| anyhow::anyhow!("worker id {id} exceeds u32"))?;
    let m = u32::try_from(cfg.m).map_err(|_| anyhow::anyhow!("M = {} exceeds u32", cfg.m))?;
    let mut cell = family.build_wired(cfg)?;
    let conn = endpoint::dial(addr, timeouts)?;
    let mut ep = Endpoint::new(
        conn,
        FaultInjector::new(faults, u64::from(wid) + 1),
        timeouts.clone(),
        format!("coordinator (from worker {id})"),
    );

    // Handshake: claim the worker id and cross-check M.
    let mut hello = Vec::with_capacity(8);
    hello.extend_from_slice(&wid.to_le_bytes());
    hello.extend_from_slice(&m.to_le_bytes());
    ep.send_reliable(PayloadKind::Probe, wid, 0, hello)?;

    loop {
        let f = ep.recv_reliable()?;
        match f.kind {
            PayloadKind::Shutdown => {
                // Our Shutdown ack may have been lost; quench any
                // retransmissions until the coordinator hangs up.
                ep.linger();
                return Ok(());
            }
            PayloadKind::Broadcast => {
                // Broadcast k releases replica round k.
                cell.round()?;
                let wire = cell.take_wire()?;
                anyhow::ensure!(
                    f.round == wire.step,
                    "worker {id}: coordinator broadcast round {} but replica is at {}",
                    f.round,
                    wire.step
                );
                let expect = frame::encode_msgs(&wire.broadcast);
                anyhow::ensure!(
                    f.payload == expect,
                    "wire divergence: worker {id} round {} broadcast is {} bytes from the \
                     coordinator vs {} computed locally (or differing content)",
                    wire.step,
                    f.payload.len(),
                    expect.len()
                );
                let upload = frame::encode_msgs(&wire.uploads[id]);
                ep.send_reliable(PayloadKind::Upload, wid, wire.step, upload)?;
            }
            other => anyhow::bail!("worker {id}: unexpected {other:?} frame"),
        }
    }
}

/// The `kimad worker` subcommand body: prepare the family from the
/// config file and serve until `Shutdown`. Fault plan from
/// `KIMAD_WIRE_FAULTS` (set by the spawning coordinator).
pub fn run_worker(
    cfg: &ExperimentConfig,
    artifacts: Option<&str>,
    addr: &str,
    id: usize,
) -> anyhow::Result<()> {
    let family = WarmFamily::prepare(cfg, artifacts)?;
    let faults = FaultPlan::from_env()?;
    serve_with_family(&family, cfg, addr, id, &faults, &TimeoutCfg::default())
}
