//! Seeded fault injection on the wire: drop / delay / duplicate /
//! truncate any transmission attempt, deterministically per (seed,
//! leg, attempt). Faults perturb *delivery*, never content — the
//! reliable endpoint layer must recover to the exact same byte stream
//! the fault-free run produces, which is what the golden harness
//! asserts.
//!
//! Plans ride in the `KIMAD_WIRE_FAULTS` environment variable (not
//! the experiment config) so a faulted run's config JSON — and hence
//! its cell ids and index.json — stay byte-identical to the clean run:
//!
//! ```text
//! KIMAD_WIRE_FAULTS="drop=0.2,dup=0.1,trunc=0.1,delay=0.2,delay_ms=5,seed=7"
//! ```

use super::frame::{HEADER_LEN, TRAILER_LEN};
use crate::util::rng::Rng;

/// Fault probabilities for one run; all legs share the plan but every
/// endpoint derives its own RNG stream from `seed`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Probability a transmission attempt is silently dropped.
    pub drop_p: f64,
    /// Probability a frame is written twice back to back.
    pub dup_p: f64,
    /// Probability a frame is truncated (self-consistent shorter
    /// frame with a stale CRC, so the receiver discards it cleanly).
    pub trunc_p: f64,
    /// Probability the attempt is delayed by `delay_ms` first.
    pub delay_p: f64,
    /// Delay applied when the delay fault fires, in milliseconds.
    pub delay_ms: u64,
    /// Base seed for all fault decision streams.
    pub seed: u64,
}

impl FaultPlan {
    /// The no-faults plan (every probability zero).
    pub fn none() -> Self {
        FaultPlan { drop_p: 0.0, dup_p: 0.0, trunc_p: 0.0, delay_p: 0.0, delay_ms: 0, seed: 0 }
    }

    /// Does any fault have nonzero probability?
    pub fn is_active(&self) -> bool {
        self.drop_p > 0.0 || self.dup_p > 0.0 || self.trunc_p > 0.0 || self.delay_p > 0.0
    }

    /// Parse a `key=value,key=value` token as carried by
    /// `KIMAD_WIRE_FAULTS`. Keys: `drop`, `dup`, `trunc`, `delay`
    /// (probabilities in [0,1]), `delay_ms`, `seed`.
    pub fn parse(token: &str) -> anyhow::Result<Self> {
        let mut plan = FaultPlan::none();
        for part in token.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault token '{part}' is not key=value"))?;
            let parse_p = |v: &str| -> anyhow::Result<f64> {
                let p: f64 =
                    v.parse().map_err(|_| anyhow::anyhow!("bad fault probability '{v}'"))?;
                anyhow::ensure!((0.0..=1.0).contains(&p), "fault probability {p} not in [0,1]");
                Ok(p)
            };
            match key.trim() {
                "drop" => plan.drop_p = parse_p(value)?,
                "dup" => plan.dup_p = parse_p(value)?,
                "trunc" => plan.trunc_p = parse_p(value)?,
                "delay" => plan.delay_p = parse_p(value)?,
                "delay_ms" => {
                    plan.delay_ms =
                        value.parse().map_err(|_| anyhow::anyhow!("bad delay_ms '{value}'"))?
                }
                "seed" => {
                    plan.seed =
                        value.parse().map_err(|_| anyhow::anyhow!("bad fault seed '{value}'"))?
                }
                other => anyhow::bail!("unknown fault key '{other}'"),
            }
        }
        Ok(plan)
    }

    /// Read the plan from `KIMAD_WIRE_FAULTS`; absent or empty means
    /// no faults.
    pub fn from_env() -> anyhow::Result<Self> {
        match std::env::var("KIMAD_WIRE_FAULTS") {
            Ok(token) if !token.trim().is_empty() => Self::parse(&token),
            _ => Ok(Self::none()),
        }
    }

    /// Serialize back to the env-token form (inverse of [`parse`]),
    /// used when re-exporting the plan to spawned worker processes.
    ///
    /// [`parse`]: FaultPlan::parse
    pub fn to_token(&self) -> String {
        format!(
            "drop={},dup={},trunc={},delay={},delay_ms={},seed={}",
            self.drop_p, self.dup_p, self.trunc_p, self.delay_p, self.delay_ms, self.seed
        )
    }
}

/// The faults drawn for one transmission attempt.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SendFaults {
    pub delay_ms: u64,
    pub drop: bool,
    pub truncate: bool,
    pub duplicate: bool,
}

/// Per-endpoint fault decision stream: `leg` separates the RNG streams
/// so the coordinator side and each worker side draw independently.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Rng,
    active: bool,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan, leg: u64) -> Self {
        FaultInjector {
            plan: plan.clone(),
            rng: Rng::seed_from_u64(plan.seed).derive(leg),
            active: plan.is_active(),
        }
    }

    /// The inert injector — zero draws, zero branches taken.
    pub fn inert() -> Self {
        Self::new(&FaultPlan::none(), 0)
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Draw the fault decisions for the next transmission attempt.
    /// Always burns the same number of RNG draws per call so decision
    /// streams stay aligned regardless of which faults fire.
    pub fn next(&mut self) -> SendFaults {
        if !self.active {
            return SendFaults::default();
        }
        let delay = self.rng.next_f64() < self.plan.delay_p;
        let drop = self.rng.next_f64() < self.plan.drop_p;
        let truncate = self.rng.next_f64() < self.plan.trunc_p;
        let duplicate = self.rng.next_f64() < self.plan.dup_p;
        SendFaults {
            delay_ms: if delay { self.plan.delay_ms } else { 0 },
            drop,
            truncate,
            duplicate,
        }
    }
}

/// Corrupt an encoded frame the way a cut cable would: keep the
/// framing self-consistent (header `len` halved, payload cut to
/// match) but leave the original CRC trailer, so the receiver parses
/// a complete frame, fails the checksum, discards it, and recovers by
/// retransmission. Zero-payload frames get a flipped CRC bit instead.
pub fn truncate_frame(bytes: &[u8]) -> Vec<u8> {
    debug_assert!(bytes.len() >= HEADER_LEN + TRAILER_LEN);
    let len_field = u32::from_le_bytes(bytes[28..32].try_into().expect("header len field"));
    let len = usize::try_from(len_field).expect("u32 fits usize");
    if len == 0 {
        let mut out = bytes.to_vec();
        let last = out.len() - 1;
        out[last] ^= 0x01;
        return out;
    }
    let new_len = len / 2;
    let mut out = Vec::with_capacity(HEADER_LEN + new_len + TRAILER_LEN);
    out.extend_from_slice(&bytes[..28]);
    out.extend_from_slice(&u32::try_from(new_len).expect("halved len fits u32").to_le_bytes());
    out.extend_from_slice(&bytes[HEADER_LEN..HEADER_LEN + new_len]);
    // Stale CRC: almost surely wrong for the shortened body, and a
    // flipped bit guarantees it differs from the original's.
    let stale = &bytes[HEADER_LEN + len..HEADER_LEN + len + TRAILER_LEN];
    out.extend_from_slice(stale);
    let last = out.len() - 1;
    out[last] ^= 0x80;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{decode_step, Decoded, Frame, PayloadKind};

    #[test]
    fn parse_roundtrip() {
        let plan =
            FaultPlan::parse("drop=0.2, dup=0.1,trunc=0.05,delay=0.3,delay_ms=5,seed=7").unwrap();
        assert_eq!(plan.drop_p, 0.2);
        assert_eq!(plan.dup_p, 0.1);
        assert_eq!(plan.trunc_p, 0.05);
        assert_eq!(plan.delay_p, 0.3);
        assert_eq!(plan.delay_ms, 5);
        assert_eq!(plan.seed, 7);
        assert!(plan.is_active());
        assert_eq!(FaultPlan::parse(&plan.to_token()).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_bad_tokens() {
        assert!(FaultPlan::parse("drop=1.5").is_err());
        assert!(FaultPlan::parse("nope=0.1").is_err());
        assert!(FaultPlan::parse("drop").is_err());
        assert!(!FaultPlan::parse("").unwrap().is_active());
    }

    #[test]
    fn injector_is_deterministic_per_leg() {
        let plan = FaultPlan::parse("drop=0.5,dup=0.5,seed=42").unwrap();
        let draws = |leg| {
            let mut inj = FaultInjector::new(&plan, leg);
            (0..32).map(|_| inj.next()).collect::<Vec<_>>()
        };
        assert_eq!(draws(1), draws(1));
        assert_ne!(draws(1), draws(2));
        assert!(draws(1).iter().any(|f| f.drop));
    }

    #[test]
    fn truncated_frame_is_discarded_not_decoded() {
        let frame = Frame::new(PayloadKind::Upload, 1, 4, 9, vec![7u8; 24]);
        let cut = truncate_frame(&frame.encode());
        match decode_step(&cut) {
            Decoded::Corrupt { skip, .. } => assert_eq!(skip, cut.len()),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // Zero-payload frames degrade to a CRC flip, still discarded.
        let empty = Frame::new(PayloadKind::Ack, 0, 2, 3, vec![]);
        let cut = truncate_frame(&empty.encode());
        assert!(matches!(decode_step(&cut), Decoded::Corrupt { .. }));
    }
}
