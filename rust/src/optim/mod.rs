//! Optimizers and learning-rate schedules.
//!
//! The server-side update of Algorithm 3 line 15 is plain (S)GD over the
//! aggregated EF21 estimators; Theorem 1 additionally allows layer-wise
//! step sizes γ_i^k = γ·w_i, which [`LayerwiseSgd`] implements.

use crate::model::Layer;

/// Learning-rate schedule γ^k.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    Constant(f64),
    /// γ / (1 + decay·k)
    InverseTime { gamma0: f64, decay: f64 },
    /// γ·factor^(k / step)
    StepDecay { gamma0: f64, factor: f64, every: usize },
}

impl Schedule {
    pub fn at(&self, k: usize) -> f64 {
        match *self {
            Schedule::Constant(g) => g,
            Schedule::InverseTime { gamma0, decay } => gamma0 / (1.0 + decay * k as f64),
            Schedule::StepDecay { gamma0, factor, every } => {
                gamma0 * factor.powi((k / every.max(1)) as i32)
            }
        }
    }
}

/// SGD with optional per-layer weights w_i (γ_i^k = γ^k · w_i).
#[derive(Debug, Clone)]
pub struct LayerwiseSgd {
    pub schedule: Schedule,
    /// One weight per layer id; empty = all 1.0.
    pub layer_weights: Vec<f64>,
}

impl LayerwiseSgd {
    pub fn new(schedule: Schedule) -> Self {
        Self { schedule, layer_weights: Vec::new() }
    }

    pub fn with_layer_weights(mut self, w: Vec<f64>) -> Self {
        self.layer_weights = w;
        self
    }

    fn weight(&self, layer_id: usize) -> f64 {
        self.layer_weights.get(layer_id).copied().unwrap_or(1.0)
    }

    /// x ← x − γ_i^k · dir on each layer span.
    pub fn step(&self, k: usize, x: &mut [f32], dir: &[f32], layers: &[Layer]) {
        self.step_scaled(k, 1.0, x, dir, layers);
    }

    /// [`step`](Self::step) with the schedule's γ^k multiplied by
    /// `scale` — the asynchronous engine's staleness damping
    /// (γ_eff = γ^k · damping^staleness). `scale = 1.0` is bit-identical
    /// to the plain step.
    pub fn step_scaled(&self, k: usize, scale: f64, x: &mut [f32], dir: &[f32], layers: &[Layer]) {
        debug_assert_eq!(x.len(), dir.len());
        for l in layers {
            self.step_layer(
                k,
                scale,
                l.id,
                &mut x[l.offset..l.offset + l.size],
                &dir[l.offset..l.offset + l.size],
            );
        }
    }

    /// One layer's slice of [`step_scaled`](Self::step_scaled): update
    /// the layer-local span `x ← x − γ^k·scale·w_i · dir`. This is the
    /// unit the sharded server path fans across threads
    /// ([`crate::coordinator::shard`]); calling it per layer in order
    /// is bit-identical to the whole-model step.
    pub fn step_layer(&self, k: usize, scale: f64, layer_id: usize, x: &mut [f32], dir: &[f32]) {
        debug_assert_eq!(x.len(), dir.len());
        let g = (self.schedule.at(k) * scale * self.weight(layer_id)) as f32;
        for (xi, &di) in x.iter_mut().zip(dir) {
            *xi -= g * di;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelLayout;

    #[test]
    fn schedules() {
        assert_eq!(Schedule::Constant(0.1).at(99), 0.1);
        let it = Schedule::InverseTime { gamma0: 1.0, decay: 1.0 };
        assert!((it.at(0) - 1.0).abs() < 1e-12);
        assert!((it.at(1) - 0.5).abs() < 1e-12);
        let sd = Schedule::StepDecay { gamma0: 1.0, factor: 0.5, every: 10 };
        assert!((sd.at(9) - 1.0).abs() < 1e-12);
        assert!((sd.at(10) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn step_moves_against_direction() {
        let layout = ModelLayout::synthetic(&[2, 2]);
        let layers = layout.layers();
        let sgd = LayerwiseSgd::new(Schedule::Constant(0.5));
        let mut x = vec![1.0f32; 4];
        sgd.step(0, &mut x, &[2.0, 2.0, 2.0, 2.0], &layers);
        assert_eq!(x, vec![0.0; 4]);
    }

    #[test]
    fn layer_weights_apply_per_span() {
        let layout = ModelLayout::synthetic(&[2, 2]);
        let layers = layout.layers();
        let sgd = LayerwiseSgd::new(Schedule::Constant(1.0)).with_layer_weights(vec![1.0, 0.0]);
        let mut x = vec![1.0f32; 4];
        sgd.step(0, &mut x, &[1.0, 1.0, 1.0, 1.0], &layers);
        assert_eq!(x, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn step_scaled_damps_and_unit_scale_matches() {
        let layout = ModelLayout::synthetic(&[2, 2]);
        let layers = layout.layers();
        let sgd = LayerwiseSgd::new(Schedule::Constant(0.5));
        let dir = [2.0f32, 2.0, 2.0, 2.0];
        let mut a = vec![1.0f32; 4];
        let mut b = vec![1.0f32; 4];
        sgd.step(3, &mut a, &dir, &layers);
        sgd.step_scaled(3, 1.0, &mut b, &dir, &layers);
        assert_eq!(a, b, "scale=1.0 must be bit-identical to step");
        let mut c = vec![1.0f32; 4];
        sgd.step_scaled(3, 0.5, &mut c, &dir, &layers);
        assert_eq!(c, vec![0.5; 4]);
    }

    #[test]
    fn step_layer_composes_to_step_scaled() {
        let layout = ModelLayout::synthetic(&[3, 5]);
        let layers = layout.layers();
        let sgd = LayerwiseSgd::new(Schedule::InverseTime { gamma0: 0.4, decay: 0.1 })
            .with_layer_weights(vec![1.0, 0.25]);
        let dir: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let mut whole = vec![2.0f32; 8];
        sgd.step_scaled(5, 0.9, &mut whole, &dir, &layers);
        let mut by_layer = vec![2.0f32; 8];
        for l in &layers {
            sgd.step_layer(
                5,
                0.9,
                l.id,
                &mut by_layer[l.offset..l.offset + l.size],
                &dir[l.offset..l.offset + l.size],
            );
        }
        assert_eq!(whole, by_layer, "per-layer steps must compose bit-identically");
    }

    #[test]
    fn quadratic_descent() {
        // f(x) = 0.5 x^2 per coordinate: GD with γ=0.5 halves x.
        let layout = ModelLayout::synthetic(&[3]);
        let layers = layout.layers();
        let sgd = LayerwiseSgd::new(Schedule::Constant(0.5));
        let mut x = vec![8.0f32, -4.0, 2.0];
        for _ in 0..3 {
            let g = x.clone();
            sgd.step(0, &mut x, &g, &layers);
        }
        assert_eq!(x, vec![1.0, -0.5, 0.25]);
    }
}
