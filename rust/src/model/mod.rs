//! Model metadata: parameter layout, layer grouping, flat-vector views.
//!
//! The coordinator treats a model as one flat `f32` vector partitioned
//! into *layers* (Kimad+ allocates its budget across these). For the
//! deep model the layout is loaded from `artifacts/layout-<preset>.json`
//! written by `python/compile/aot.py` (or by `kimad gen-artifacts` via
//! [`native`]); synthetic workloads build layouts programmatically.

use std::path::Path;

use crate::util::json::Value;

pub mod native;

pub use native::{NativeConfig, NativeModelSource};

/// One parameter tensor slot (mirrors python ParamMeta).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSlot {
    pub name: String,
    pub shape: Vec<usize>,
    /// Kimad+ layer group id (embed=0, block i=i+1, head=last).
    pub group: usize,
    /// Element offset into the flat vector.
    pub offset: usize,
    pub size: usize,
}

/// Full model layout: slots in wire order + derived group spans.
#[derive(Debug, Clone)]
pub struct ModelLayout {
    pub preset: String,
    pub batch: usize,
    pub seq: usize,
    pub d_in: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub d_ff: usize,
    pub n_classes: usize,
    pub n_params: usize,
    pub n_groups: usize,
    pub params: Vec<ParamSlot>,
}

/// A contiguous "layer" for compression purposes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub id: usize,
    pub name: String,
    pub offset: usize,
    pub size: usize,
}

impl ModelLayout {
    pub fn from_json_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let layout = Self::from_json(&Value::parse(&text)?)?;
        layout.validate()?;
        Ok(layout)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let us = |k: &str| -> usize {
            v.opt(k).and_then(|x| x.as_usize().ok()).unwrap_or(0)
        };
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSlot {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|s| s.as_usize())
                        .collect::<anyhow::Result<Vec<_>>>()?,
                    group: p.get("group")?.as_usize()?,
                    offset: p.get("offset")?.as_usize()?,
                    size: p.get("size")?.as_usize()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self {
            preset: v
                .opt("preset")
                .and_then(|x| x.as_str().ok())
                .unwrap_or("")
                .to_string(),
            batch: us("batch"),
            seq: us("seq"),
            d_in: us("d_in"),
            d_model: us("d_model"),
            n_heads: us("n_heads"),
            n_blocks: us("n_blocks"),
            d_ff: us("d_ff"),
            n_classes: us("n_classes"),
            n_params: v.get("n_params")?.as_usize()?,
            n_groups: us("n_groups"),
            params,
        })
    }

    /// A synthetic layout: `sizes[i]` elements in layer i (used by the
    /// quadratic workload and unit tests).
    pub fn synthetic(sizes: &[usize]) -> Self {
        let mut params = Vec::with_capacity(sizes.len());
        let mut off = 0;
        for (i, &s) in sizes.iter().enumerate() {
            params.push(ParamSlot {
                name: format!("layer{i}"),
                shape: vec![s],
                group: i,
                offset: off,
                size: s,
            });
            off += s;
        }
        Self {
            preset: "synthetic".into(),
            batch: 0,
            seq: 0,
            d_in: 0,
            d_model: 0,
            n_heads: 0,
            n_blocks: 0,
            d_ff: 0,
            n_classes: 0,
            n_params: off,
            n_groups: sizes.len(),
            params,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let mut off = 0;
        for p in &self.params {
            if p.offset != off {
                anyhow::bail!("slot {} offset {} != expected {off}", p.name, p.offset);
            }
            let numel: usize = p.shape.iter().product::<usize>().max(1);
            if numel != p.size && !p.shape.is_empty() {
                anyhow::bail!("slot {} size {} != shape prod {numel}", p.name, p.size);
            }
            off += p.size;
        }
        if off != self.n_params {
            anyhow::bail!("sum of slot sizes {off} != n_params {}", self.n_params);
        }
        Ok(())
    }

    /// Compression layers = group spans (contiguous by construction).
    pub fn layers(&self) -> Vec<Layer> {
        let mut layers: Vec<Layer> = Vec::new();
        for p in &self.params {
            match layers.last_mut() {
                Some(l) if l.id == p.group => {
                    debug_assert_eq!(l.offset + l.size, p.offset, "groups must be contiguous");
                    l.size += p.size;
                }
                _ => layers.push(Layer {
                    id: p.group,
                    name: group_name(&p.name),
                    offset: p.offset,
                    size: p.size,
                }),
            }
        }
        layers
    }

    /// Treat the whole model as a single layer (plain Kimad / EF21).
    pub fn single_layer(&self) -> Vec<Layer> {
        vec![Layer { id: 0, name: "model".into(), offset: 0, size: self.n_params }]
    }

    /// Total uncompressed wire size in bits.
    pub fn wire_bits(&self) -> u64 {
        self.n_params as u64 * 32
    }

    /// Serialize in the `layout-<preset>.json` shape [`Self::from_json`]
    /// reads (and `python/compile/aot.py` writes) — what lets
    /// `kimad gen-artifacts` emit an artifact set without JAX.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("preset", Value::str(self.preset.clone())),
            ("batch", Value::num(self.batch as f64)),
            ("seq", Value::num(self.seq as f64)),
            ("d_in", Value::num(self.d_in as f64)),
            ("d_model", Value::num(self.d_model as f64)),
            ("n_heads", Value::num(self.n_heads as f64)),
            ("n_blocks", Value::num(self.n_blocks as f64)),
            ("d_ff", Value::num(self.d_ff as f64)),
            ("n_classes", Value::num(self.n_classes as f64)),
            ("n_params", Value::num(self.n_params as f64)),
            ("n_groups", Value::num(self.n_groups as f64)),
            (
                "params",
                Value::Arr(
                    self.params
                        .iter()
                        .map(|p| {
                            Value::obj(vec![
                                ("name", Value::str(p.name.clone())),
                                (
                                    "shape",
                                    Value::Arr(
                                        p.shape.iter().map(|&s| Value::num(s as f64)).collect(),
                                    ),
                                ),
                                ("group", Value::num(p.group as f64)),
                                ("offset", Value::num(p.offset as f64)),
                                ("size", Value::num(p.size as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn group_name(param_name: &str) -> String {
    param_name
        .split('/')
        .next()
        .unwrap_or(param_name)
        .to_string()
}

/// Split a flat vector according to layers, yielding (layer, slice).
pub fn layer_slices<'a>(
    flat: &'a [f32],
    layers: &'a [Layer],
) -> impl Iterator<Item = (&'a Layer, &'a [f32])> {
    layers.iter().map(move |l| (l, &flat[l.offset..l.offset + l.size]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_layout_valid() {
        let l = ModelLayout::synthetic(&[10, 20, 5]);
        assert_eq!(l.n_params, 35);
        l.validate().unwrap();
        let layers = l.layers();
        assert_eq!(layers.len(), 3);
        assert_eq!(layers[1].offset, 10);
        assert_eq!(layers[2].size, 5);
    }

    #[test]
    fn groups_merge_contiguous_slots() {
        let mut l = ModelLayout::synthetic(&[4, 4]);
        // Rewrite as two slots in the same group.
        l.params[1].group = 0;
        let layers = l.layers();
        assert_eq!(layers.len(), 1);
        assert_eq!(layers[0].size, 8);
    }

    #[test]
    fn validate_rejects_gap() {
        let mut l = ModelLayout::synthetic(&[4, 4]);
        l.params[1].offset = 5;
        assert!(l.validate().is_err());
    }

    #[test]
    fn single_layer_spans_model() {
        let l = ModelLayout::synthetic(&[3, 3, 3]);
        let s = l.single_layer();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].size, 9);
        assert_eq!(l.wire_bits(), 9 * 32);
    }

    #[test]
    fn layout_json_roundtrip() {
        let l = NativeConfig::preset("tiny").unwrap().layout_named("tiny");
        let v = Value::parse(&l.to_json().to_string()).unwrap();
        let back = ModelLayout::from_json(&v).unwrap();
        back.validate().unwrap();
        assert_eq!(back.preset, l.preset);
        assert_eq!(back.n_params, l.n_params);
        assert_eq!(back.params, l.params);
        assert_eq!(
            (back.batch, back.seq, back.d_in, back.d_model),
            (l.batch, l.seq, l.d_in, l.d_model)
        );
        assert_eq!(
            (back.n_heads, back.n_blocks, back.d_ff, back.n_classes, back.n_groups),
            (l.n_heads, l.n_blocks, l.d_ff, l.n_classes, l.n_groups)
        );
    }

    #[test]
    fn layer_slices_iterate() {
        let l = ModelLayout::synthetic(&[2, 3]);
        let flat = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let layers = l.layers();
        let got: Vec<_> = layer_slices(&flat, &layers)
            .map(|(_, s)| s.to_vec())
            .collect();
        assert_eq!(got, vec![vec![1.0, 2.0], vec![3.0, 4.0, 5.0]]);
    }
}
