//! Native (pure-rust) execution of the deep-model workload.
//!
//! The PJRT runtime executes the AOT-lowered JAX transformer when the
//! `xla` bindings are vendored in; offline builds ship only the stub
//! (`runtime::backend`), which used to leave every deep-model code path
//! dead. This module is the fallback that lights them up: the **same
//! transformer** (`python/compile/model.py` — pre-norm encoder over
//! patch tokens, mean-pool + linear head) implemented forward *and*
//! backward in plain rust, driven by the same [`ModelLayout`] /
//! [`SyntheticDataset`] pair the PJRT source uses.
//!
//! Two deliberate properties:
//!
//! * **Determinism** — all math runs in `f64` with serial, fixed-order
//!   reductions, so a run is bit-reproducible across machines, thread
//!   counts and scenario-matrix pool sizes (the engine contract).
//! * **Backend-local numerics** — the native source is *not* expected
//!   to match PJRT bit for bit (different backends round differently);
//!   what matters is that warm and cold runs on the *same* backend are
//!   identical, which they are because execution is a pure function of
//!   (layout, params, batch).
//!
//! [`NativeConfig`] mirrors `ModelConfig`/`PRESETS` from
//! `python/compile/model.py`, so `kimad gen-artifacts` can emit a
//! layout + initial-params artifact set without JAX (see
//! `runtime::artifact::write_native_artifacts`).
//!
//! [`SyntheticDataset`]: crate::data::SyntheticDataset

use crate::coordinator::GradientSource;
use crate::data::SyntheticDataset;
use crate::model::{ModelLayout, ParamSlot};
use crate::runtime::EvalMetrics;
use crate::util::rng::Rng;

/// `sqrt(2/π)` — the tanh-GELU constant (the JAX default approximate
/// GELU the python model lowers).
const GELU_C: f64 = 0.797_884_560_802_865_4;
const LN_EPS: f64 = 1e-5;

/// Transformer preset shapes — the rust mirror of
/// `python/compile/model.py::PRESETS` (kept in lockstep by the layout
/// tests below).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NativeConfig {
    pub batch: usize,
    pub seq: usize,
    pub d_in: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub d_ff: usize,
    pub n_classes: usize,
}

/// Preset names accepted by [`NativeConfig::preset`], smallest first.
pub const PRESETS: [&str; 4] = ["tiny", "small", "e2e", "big"];

impl NativeConfig {
    /// The named preset (`tiny | small | e2e | big`), matching the
    /// python `PRESETS` table shape for shape.
    pub fn preset(name: &str) -> anyhow::Result<Self> {
        let c = |batch, seq, d_in, d_model, n_heads, n_blocks, d_ff| Self {
            batch,
            seq,
            d_in,
            d_model,
            n_heads,
            n_blocks,
            d_ff,
            n_classes: 10,
        };
        Ok(match name {
            "tiny" => c(8, 4, 8, 16, 2, 1, 32),
            "small" => c(32, 8, 16, 32, 4, 2, 64),
            "e2e" => c(64, 16, 32, 128, 4, 4, 512),
            "big" => c(8, 32, 64, 1024, 16, 8, 4096),
            other => anyhow::bail!("unknown preset '{other}' (tiny|small|e2e|big)"),
        })
    }

    /// Recover the config from a layout (artifact-loaded layouts carry
    /// every shape field). Validates that the layout's slot table is
    /// exactly the canonical one, so a stale or hand-edited
    /// `layout-<preset>.json` fails loudly instead of mis-indexing.
    pub fn from_layout(layout: &ModelLayout) -> anyhow::Result<Self> {
        let cfg = Self {
            batch: layout.batch,
            seq: layout.seq,
            d_in: layout.d_in,
            d_model: layout.d_model,
            n_heads: layout.n_heads,
            n_blocks: layout.n_blocks,
            d_ff: layout.d_ff,
            n_classes: layout.n_classes,
        };
        anyhow::ensure!(
            cfg.d_model > 0 && cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0,
            "layout '{}' is not a transformer layout (d_model {} / n_heads {})",
            layout.preset,
            cfg.d_model,
            cfg.n_heads
        );
        let canon = cfg.layout_named(&layout.preset);
        anyhow::ensure!(
            canon.params == layout.params && canon.n_params == layout.n_params,
            "layout '{}' does not match the canonical transformer slot table",
            layout.preset
        );
        Ok(cfg)
    }

    /// (name, shape, group) for every parameter slot, in wire order —
    /// the rust mirror of `model.py::param_specs`.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>, usize)> {
        let d = self.d_model;
        let mut specs: Vec<(String, Vec<usize>, usize)> = vec![
            ("embed/w".into(), vec![self.d_in, d], 0),
            ("embed/b".into(), vec![d], 0),
            ("embed/pos".into(), vec![self.seq, d], 0),
        ];
        for i in 0..self.n_blocks {
            let g = i + 1;
            let p = format!("block{i}");
            specs.push((format!("{p}/ln1/g"), vec![d], g));
            specs.push((format!("{p}/ln1/b"), vec![d], g));
            specs.push((format!("{p}/attn/wqkv"), vec![d, 3 * d], g));
            specs.push((format!("{p}/attn/bqkv"), vec![3 * d], g));
            specs.push((format!("{p}/attn/wo"), vec![d, d], g));
            specs.push((format!("{p}/attn/bo"), vec![d], g));
            specs.push((format!("{p}/ln2/g"), vec![d], g));
            specs.push((format!("{p}/ln2/b"), vec![d], g));
            specs.push((format!("{p}/ffn/w1"), vec![d, self.d_ff], g));
            specs.push((format!("{p}/ffn/b1"), vec![self.d_ff], g));
            specs.push((format!("{p}/ffn/w2"), vec![self.d_ff, d], g));
            specs.push((format!("{p}/ffn/b2"), vec![d], g));
        }
        let gh = self.n_blocks + 1;
        specs.push(("final_ln/g".into(), vec![d], gh));
        specs.push(("final_ln/b".into(), vec![d], gh));
        specs.push(("head/w".into(), vec![d, self.n_classes], gh));
        specs.push(("head/b".into(), vec![self.n_classes], gh));
        specs
    }

    /// The canonical [`ModelLayout`] for this config.
    pub fn layout_named(&self, preset: &str) -> ModelLayout {
        let mut params = Vec::new();
        let mut off = 0;
        for (name, shape, group) in self.param_specs() {
            let size: usize = shape.iter().product();
            params.push(ParamSlot { name, shape, group, offset: off, size });
            off += size;
        }
        ModelLayout {
            preset: preset.to_string(),
            batch: self.batch,
            seq: self.seq,
            d_in: self.d_in,
            d_model: self.d_model,
            n_heads: self.n_heads,
            n_blocks: self.n_blocks,
            d_ff: self.d_ff,
            n_classes: self.n_classes,
            n_params: off,
            n_groups: self.n_blocks + 2,
            params,
        }
    }

    pub fn n_params(&self) -> usize {
        self.param_specs().iter().map(|(_, shape, _)| shape.iter().product::<usize>()).sum()
    }

    /// Seeded initial parameters, `model.py::init_params`'s scheme:
    /// LeCun-normal weights, zero biases, unit LN gains, 0.02-scale
    /// positional table. One deterministic stream in wire order (the
    /// *scheme* matches python; the draws need not — initialization is
    /// backend-local, like the rest of the numerics).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut out: Vec<f32> = Vec::with_capacity(self.n_params());
        for (name, shape, _) in self.param_specs() {
            let size: usize = shape.iter().product();
            let leaf = name.rsplit('/').next().unwrap_or(&name);
            match leaf {
                "b" | "bqkv" | "bo" | "b1" | "b2" => out.resize(out.len() + size, 0.0),
                "g" => out.resize(out.len() + size, 1.0),
                "pos" => out.extend((0..size).map(|_| (0.02 * rng.normal()) as f32)),
                _ => {
                    let scale = 1.0 / (shape[0] as f64).sqrt();
                    out.extend((0..size).map(|_| (scale * rng.normal()) as f32));
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// Parameter offsets
// ---------------------------------------------------------------------

/// Element offsets of each block's slots inside the flat vector.
struct BlockOffs {
    ln1_g: usize,
    ln1_b: usize,
    wqkv: usize,
    bqkv: usize,
    wo: usize,
    bo: usize,
    ln2_g: usize,
    ln2_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
}

struct Offsets {
    embed_w: usize,
    embed_b: usize,
    pos: usize,
    blocks: Vec<BlockOffs>,
    final_g: usize,
    final_b: usize,
    head_w: usize,
    head_b: usize,
}

impl Offsets {
    fn new(cfg: &NativeConfig) -> Self {
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let mut off = 0;
        let mut take = |n: usize| {
            let o = off;
            off += n;
            o
        };
        let embed_w = take(cfg.d_in * d);
        let embed_b = take(d);
        let pos = take(cfg.seq * d);
        // Struct-literal fields evaluate left to right, so each `take`
        // advances through the wire order exactly like `param_specs`.
        let blocks = (0..cfg.n_blocks)
            .map(|_| BlockOffs {
                ln1_g: take(d),
                ln1_b: take(d),
                wqkv: take(d * 3 * d),
                bqkv: take(3 * d),
                wo: take(d * d),
                bo: take(d),
                ln2_g: take(d),
                ln2_b: take(d),
                w1: take(d * f),
                b1: take(f),
                w2: take(f * d),
                b2: take(d),
            })
            .collect();
        Self {
            embed_w,
            embed_b,
            pos,
            blocks,
            final_g: take(d),
            final_b: take(d),
            head_w: take(d * cfg.n_classes),
            head_b: take(cfg.n_classes),
        }
    }
}

// ---------------------------------------------------------------------
// Kernels (f64, serial, fixed reduction order)
// ---------------------------------------------------------------------

/// y[r, :dout] = x[r, :din] · w + b, for `rows` rows.
fn linear_fwd(
    x: &[f64],
    w: &[f64],
    b: &[f64],
    rows: usize,
    din: usize,
    dout: usize,
    y: &mut [f64],
) {
    for r in 0..rows {
        let yr = &mut y[r * dout..(r + 1) * dout];
        yr.copy_from_slice(b);
        let xr = &x[r * din..(r + 1) * din];
        for (i, &xv) in xr.iter().enumerate() {
            let wrow = &w[i * dout..(i + 1) * dout];
            for (yv, &wv) in yr.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    }
}

/// Backward of [`linear_fwd`]: accumulates `dw`/`db` and (when `dx` is
/// given) **adds** `dy · wᵀ` into it.
#[allow(clippy::too_many_arguments)] // flat-slice kernel: dims travel unpacked
fn linear_bwd(
    x: &[f64],
    w: &[f64],
    dy: &[f64],
    rows: usize,
    din: usize,
    dout: usize,
    dw: &mut [f64],
    db: &mut [f64],
    dx: Option<&mut [f64]>,
) {
    for r in 0..rows {
        let dyr = &dy[r * dout..(r + 1) * dout];
        for (dbv, &dyv) in db.iter_mut().zip(dyr) {
            *dbv += dyv;
        }
        let xr = &x[r * din..(r + 1) * din];
        for (i, &xv) in xr.iter().enumerate() {
            let dwrow = &mut dw[i * dout..(i + 1) * dout];
            for (dwv, &dyv) in dwrow.iter_mut().zip(dyr) {
                *dwv += xv * dyv;
            }
        }
    }
    if let Some(dx) = dx {
        for r in 0..rows {
            let dyr = &dy[r * dout..(r + 1) * dout];
            let dxr = &mut dx[r * din..(r + 1) * din];
            for (i, dxv) in dxr.iter_mut().enumerate() {
                let wrow = &w[i * dout..(i + 1) * dout];
                let mut acc = 0.0;
                for (&wv, &dyv) in wrow.iter().zip(dyr) {
                    acc += wv * dyv;
                }
                *dxv += acc;
            }
        }
    }
}

/// Row-wise layernorm: saves `xhat` and `rstd` for the backward pass.
#[allow(clippy::too_many_arguments)] // flat-slice kernel: dims travel unpacked
fn layernorm_fwd(
    x: &[f64],
    g: &[f64],
    b: &[f64],
    rows: usize,
    d: usize,
    xhat: &mut [f64],
    rstd: &mut [f64],
    y: &mut [f64],
) {
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f64>() / d as f64;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            let h = (xr[j] - mu) * rs;
            xh[j] = h;
            yr[j] = h * g[j] + b[j];
        }
    }
}

/// Backward of [`layernorm_fwd`]: accumulates `dg`/`db` and **adds**
/// the input gradient into `dx` (callers merge residual branches).
#[allow(clippy::too_many_arguments)] // flat-slice kernel: dims travel unpacked
fn layernorm_bwd(
    dy: &[f64],
    xhat: &[f64],
    rstd: &[f64],
    g: &[f64],
    rows: usize,
    d: usize,
    dg: &mut [f64],
    db: &mut [f64],
    dx: &mut [f64],
) {
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &xhat[r * d..(r + 1) * d];
        for j in 0..d {
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            m1 += dxh;
            m2 += dxh * xh[j];
        }
        m1 /= d as f64;
        m2 /= d as f64;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dxr[j] += rstd[r] * (dxh - m1 - xh[j] * m2);
        }
    }
}

fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + (GELU_C * (x + 0.044715 * x * x * x)).tanh())
}

fn gelu_grad(x: f64) -> f64 {
    let t = (GELU_C * (x + 0.044715 * x * x * x)).tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * 0.044715 * x * x)
}

// ---------------------------------------------------------------------
// Saved activations
// ---------------------------------------------------------------------

/// Per-block activations the backward pass re-reads.
struct BlockActs {
    xhat1: Vec<f64>,
    rstd1: Vec<f64>,
    a: Vec<f64>,
    qkv: Vec<f64>,
    attn: Vec<f64>,
    ao: Vec<f64>,
    xhat2: Vec<f64>,
    rstd2: Vec<f64>,
    fx: Vec<f64>,
    u1: Vec<f64>,
    gact: Vec<f64>,
}

struct Acts {
    blocks: Vec<BlockActs>,
    xhatf: Vec<f64>,
    rstdf: Vec<f64>,
    pooled: Vec<f64>,
    logits: Vec<f64>,
}

// ---------------------------------------------------------------------
// The gradient source
// ---------------------------------------------------------------------

/// Deep-model [`GradientSource`] running the transformer natively —
/// the offline stand-in for `runtime::PjrtModelSource` with the same
/// constructor inputs and the same dataset/sharding semantics.
pub struct NativeModelSource {
    pub layout: ModelLayout,
    pub dataset: SyntheticDataset,
    cfg: NativeConfig,
    offs: Offsets,
    t_comp: f64,
    n_exec: u64,
}

impl NativeModelSource {
    /// Build from an (artifact-loaded) layout. `seed` feeds the
    /// synthetic dataset — pass the artifact manifest's seed, exactly
    /// like `PjrtModelSource::load` does.
    pub fn new(layout: &ModelLayout, sigma: f32, seed: u64, t_comp: f64) -> anyhow::Result<Self> {
        let cfg = NativeConfig::from_layout(layout)?;
        let offs = Offsets::new(&cfg);
        let dataset = SyntheticDataset::new(cfg.seq, cfg.d_in, cfg.n_classes, sigma, seed);
        Ok(Self { layout: layout.clone(), dataset, cfg, offs, t_comp, n_exec: 0 })
    }

    /// Number of train/eval executions so far (perf accounting).
    pub fn executions(&self) -> u64 {
        self.n_exec
    }

    /// Forward pass, saving activations for [`Self::backward`].
    fn forward(&self, p: &[f64], x: &[f64]) -> Acts {
        let NativeConfig { batch: bsz, seq, d_in, d_model: d, n_heads, d_ff, n_classes, .. } =
            self.cfg;
        let rows = bsz * seq;
        let hd = d / n_heads;
        let inv = 1.0 / (hd as f64).sqrt();
        let o = &self.offs;

        // Embedding + positional table; `h` carries the running stream.
        let mut h = vec![0.0; rows * d];
        linear_fwd(x, &p[o.embed_w..], &p[o.embed_b..o.embed_b + d], rows, d_in, d, &mut h);
        for b in 0..bsz {
            for s in 0..seq {
                let hr = &mut h[(b * seq + s) * d..(b * seq + s + 1) * d];
                let pr = &p[o.pos + s * d..o.pos + (s + 1) * d];
                for (hv, &pv) in hr.iter_mut().zip(pr) {
                    *hv += pv;
                }
            }
        }

        let mut blocks = Vec::with_capacity(self.cfg.n_blocks);
        for bo in &o.blocks {
            // ln1 over the block input.
            let mut xhat1 = vec![0.0; rows * d];
            let mut rstd1 = vec![0.0; rows];
            let mut a = vec![0.0; rows * d];
            let (g1, b1) = (&p[bo.ln1_g..bo.ln1_g + d], &p[bo.ln1_b..bo.ln1_b + d]);
            layernorm_fwd(&h, g1, b1, rows, d, &mut xhat1, &mut rstd1, &mut a);
            // qkv projection.
            let mut qkv = vec![0.0; rows * 3 * d];
            linear_fwd(&a, &p[bo.wqkv..], &p[bo.bqkv..bo.bqkv + 3 * d], rows, d, 3 * d, &mut qkv);
            // Scaled-dot attention per (batch, head).
            let mut attn = vec![0.0; bsz * n_heads * seq * seq];
            let mut ao = vec![0.0; rows * d];
            for b in 0..bsz {
                for hh in 0..n_heads {
                    let q_of = |s: usize| (b * seq + s) * 3 * d + hh * hd;
                    let k_of = |s: usize| (b * seq + s) * 3 * d + d + hh * hd;
                    let v_of = |s: usize| (b * seq + s) * 3 * d + 2 * d + hh * hd;
                    let at_base = (b * n_heads + hh) * seq * seq;
                    for s in 0..seq {
                        // Scores with a max-shifted (stable) softmax.
                        let mut row = vec![0.0; seq];
                        let mut mx = f64::NEG_INFINITY;
                        for (t, rv) in row.iter_mut().enumerate() {
                            let mut acc = 0.0;
                            for e in 0..hd {
                                acc += qkv[q_of(s) + e] * qkv[k_of(t) + e];
                            }
                            *rv = acc * inv;
                            mx = mx.max(*rv);
                        }
                        let mut z = 0.0;
                        for rv in row.iter_mut() {
                            *rv = (*rv - mx).exp();
                            z += *rv;
                        }
                        let at_row = &mut attn[at_base + s * seq..at_base + (s + 1) * seq];
                        for (av, &rv) in at_row.iter_mut().zip(&row) {
                            *av = rv / z;
                        }
                        // out_h[s] = Σ_t attn[s,t] · v[t].
                        let o_of = (b * seq + s) * d + hh * hd;
                        for (t, &av) in at_row.iter().enumerate() {
                            for e in 0..hd {
                                ao[o_of + e] += av * qkv[v_of(t) + e];
                            }
                        }
                    }
                }
            }
            // Output projection; residual folds into `h` in place.
            let mut proj = vec![0.0; rows * d];
            linear_fwd(&ao, &p[bo.wo..], &p[bo.bo..bo.bo + d], rows, d, d, &mut proj);
            for (hv, &pv) in h.iter_mut().zip(&proj) {
                *hv += pv;
            }
            // ln2 -> FFN (GELU) -> residual.
            let mut xhat2 = vec![0.0; rows * d];
            let mut rstd2 = vec![0.0; rows];
            let mut fx = vec![0.0; rows * d];
            let (g2, b2) = (&p[bo.ln2_g..bo.ln2_g + d], &p[bo.ln2_b..bo.ln2_b + d]);
            layernorm_fwd(&h, g2, b2, rows, d, &mut xhat2, &mut rstd2, &mut fx);
            let mut u1 = vec![0.0; rows * d_ff];
            linear_fwd(&fx, &p[bo.w1..], &p[bo.b1..bo.b1 + d_ff], rows, d, d_ff, &mut u1);
            let gact: Vec<f64> = u1.iter().map(|&v| gelu(v)).collect();
            let mut ff = vec![0.0; rows * d];
            linear_fwd(&gact, &p[bo.w2..], &p[bo.b2..bo.b2 + d], rows, d_ff, d, &mut ff);
            for (hv, &fv) in h.iter_mut().zip(&ff) {
                *hv += fv;
            }
            blocks.push(BlockActs { xhat1, rstd1, a, qkv, attn, ao, xhat2, rstd2, fx, u1, gact });
        }

        // Final LN -> mean pool -> head.
        let mut xhatf = vec![0.0; rows * d];
        let mut rstdf = vec![0.0; rows];
        let mut hf = vec![0.0; rows * d];
        let (gf, bf) = (&p[o.final_g..o.final_g + d], &p[o.final_b..o.final_b + d]);
        layernorm_fwd(&h, gf, bf, rows, d, &mut xhatf, &mut rstdf, &mut hf);
        let mut pooled = vec![0.0; bsz * d];
        for b in 0..bsz {
            for s in 0..seq {
                let hr = &hf[(b * seq + s) * d..(b * seq + s + 1) * d];
                let pr = &mut pooled[b * d..(b + 1) * d];
                for (pv, &hv) in pr.iter_mut().zip(hr) {
                    *pv += hv / seq as f64;
                }
            }
        }
        let mut logits = vec![0.0; bsz * n_classes];
        let (wh, bh) = (&p[o.head_w..], &p[o.head_b..o.head_b + n_classes]);
        linear_fwd(&pooled, wh, bh, bsz, d, n_classes, &mut logits);
        Acts { blocks, xhatf, rstdf, pooled, logits }
    }

    /// Mean softmax cross-entropy and its logits gradient.
    fn loss_and_dlogits(&self, logits: &[f64], y: &[i32]) -> (f64, Vec<f64>) {
        let (bsz, c) = (self.cfg.batch, self.cfg.n_classes);
        let mut loss = 0.0;
        let mut dlogits = vec![0.0; bsz * c];
        for b in 0..bsz {
            let lr = &logits[b * c..(b + 1) * c];
            let mx = lr.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
            let z: f64 = lr.iter().map(|&v| (v - mx).exp()).sum();
            let lse = mx + z.ln();
            let yi = y[b] as usize;
            loss += lse - lr[yi];
            let dr = &mut dlogits[b * c..(b + 1) * c];
            for (j, dv) in dr.iter_mut().enumerate() {
                let soft = (lr[j] - mx).exp() / z;
                *dv = (soft - if j == yi { 1.0 } else { 0.0 }) / bsz as f64;
            }
        }
        (loss / bsz as f64, dlogits)
    }

    /// Reverse pass: fills the flat `grads` (same wire layout as `p`).
    fn backward(&self, p: &[f64], x: &[f64], acts: &Acts, dlogits: &[f64], grads: &mut [f64]) {
        let NativeConfig { batch: bsz, seq, d_in, d_model: d, n_heads, d_ff, n_classes, .. } =
            self.cfg;
        let rows = bsz * seq;
        let hd = d / n_heads;
        let inv = 1.0 / (hd as f64).sqrt();
        let o = &self.offs;

        // Head: logits = pooled · Wh + bh. (Every `split_at_mut` below
        // leans on the wire order putting each bias right after its
        // weight slot — guaranteed by `param_specs`.)
        let mut dpooled = vec![0.0; bsz * d];
        {
            let (dw, rest) = grads[o.head_w..].split_at_mut(d * n_classes);
            let db = &mut rest[..n_classes];
            let dx = Some(&mut dpooled[..]);
            linear_bwd(&acts.pooled, &p[o.head_w..], dlogits, bsz, d, n_classes, dw, db, dx);
        }
        // Mean pool: dhf[b,s,:] = dpooled[b,:] / S.
        let mut dhf = vec![0.0; rows * d];
        for b in 0..bsz {
            let pr = &dpooled[b * d..(b + 1) * d];
            for s in 0..seq {
                let dr = &mut dhf[(b * seq + s) * d..(b * seq + s + 1) * d];
                for (dv, &pv) in dr.iter_mut().zip(pr) {
                    *dv = pv / seq as f64;
                }
            }
        }
        // Final LN; `dh` carries the running stream gradient backwards.
        let mut dh = vec![0.0; rows * d];
        {
            let (dg, db) = grads[o.final_g..o.final_g + 2 * d].split_at_mut(d);
            let gf = &p[o.final_g..o.final_g + d];
            layernorm_bwd(&dhf, &acts.xhatf, &acts.rstdf, gf, rows, d, dg, db, &mut dh);
        }

        // Blocks, reversed. Entering each block, `dh` is the gradient
        // w.r.t. the block output `hout = h1 + ff`.
        for (bo, ba) in o.blocks.iter().zip(&acts.blocks).rev() {
            // FFN: ff = gelu(fx·W1 + b1)·W2 + b2.
            let mut dgact = vec![0.0; rows * d_ff];
            {
                let (dw2, rest) = grads[bo.w2..].split_at_mut(d_ff * d);
                let db2 = &mut rest[..d];
                let dx = Some(&mut dgact[..]);
                linear_bwd(&ba.gact, &p[bo.w2..], &dh, rows, d_ff, d, dw2, db2, dx);
            }
            let du1: Vec<f64> =
                dgact.iter().zip(&ba.u1).map(|(&dv, &uv)| dv * gelu_grad(uv)).collect();
            let mut dfx = vec![0.0; rows * d];
            {
                let (dw1, rest) = grads[bo.w1..].split_at_mut(d * d_ff);
                let db1 = &mut rest[..d_ff];
                linear_bwd(&ba.fx, &p[bo.w1..], &du1, rows, d, d_ff, dw1, db1, Some(&mut dfx[..]));
            }
            // ln2 adds into the residual path: dh1 = dh + LNbwd(dfx).
            let mut dh1 = dh;
            {
                let (dg, db) = grads[bo.ln2_g..bo.ln2_g + 2 * d].split_at_mut(d);
                let g2 = &p[bo.ln2_g..bo.ln2_g + d];
                layernorm_bwd(&dfx, &ba.xhat2, &ba.rstd2, g2, rows, d, dg, db, &mut dh1);
            }
            // h1 = hin + ao·Wo + bo.
            let mut dao = vec![0.0; rows * d];
            {
                let (dwo, rest) = grads[bo.wo..].split_at_mut(d * d);
                let dbo = &mut rest[..d];
                linear_bwd(&ba.ao, &p[bo.wo..], &dh1, rows, d, d, dwo, dbo, Some(&mut dao[..]));
            }
            // Attention backward per (batch, head).
            let mut dqkv = vec![0.0; rows * 3 * d];
            for b in 0..bsz {
                for hh in 0..n_heads {
                    let q_of = |s: usize| (b * seq + s) * 3 * d + hh * hd;
                    let k_of = |s: usize| (b * seq + s) * 3 * d + d + hh * hd;
                    let v_of = |s: usize| (b * seq + s) * 3 * d + 2 * d + hh * hd;
                    let o_of = |s: usize| (b * seq + s) * d + hh * hd;
                    let at_base = (b * n_heads + hh) * seq * seq;
                    for s in 0..seq {
                        let at_row = &ba.attn[at_base + s * seq..at_base + (s + 1) * seq];
                        // dattn[s,t] = dao_h[s]·v[t]; dv[t] += attn[s,t]·dao_h[s].
                        let mut dattn = vec![0.0; seq];
                        for (t, dat) in dattn.iter_mut().enumerate() {
                            let mut acc = 0.0;
                            for e in 0..hd {
                                acc += dao[o_of(s) + e] * ba.qkv[v_of(t) + e];
                            }
                            *dat = acc;
                            for e in 0..hd {
                                dqkv[v_of(t) + e] += at_row[t] * dao[o_of(s) + e];
                            }
                        }
                        // Softmax backward, then the 1/sqrt(hd) scale.
                        let dot: f64 = dattn.iter().zip(at_row).map(|(&da, &av)| da * av).sum();
                        for t in 0..seq {
                            let ds = at_row[t] * (dattn[t] - dot) * inv;
                            for e in 0..hd {
                                dqkv[q_of(s) + e] += ds * ba.qkv[k_of(t) + e];
                                dqkv[k_of(t) + e] += ds * ba.qkv[q_of(s) + e];
                            }
                        }
                    }
                }
            }
            // qkv = a·Wqkv + bqkv.
            let mut da = vec![0.0; rows * d];
            {
                let (dwq, rest) = grads[bo.wqkv..].split_at_mut(d * 3 * d);
                let dbq = &mut rest[..3 * d];
                let dx = Some(&mut da[..]);
                linear_bwd(&ba.a, &p[bo.wqkv..], &dqkv, rows, d, 3 * d, dwq, dbq, dx);
            }
            // ln1 adds into the residual path: dhin = dh1 + LNbwd(da).
            let mut dhin = dh1;
            {
                let (dg, db) = grads[bo.ln1_g..bo.ln1_g + 2 * d].split_at_mut(d);
                let g1 = &p[bo.ln1_g..bo.ln1_g + d];
                layernorm_bwd(&da, &ba.xhat1, &ba.rstd1, g1, rows, d, dg, db, &mut dhin);
            }
            dh = dhin;
        }

        // Embedding: h0 = x·We + be + pos.
        for b in 0..bsz {
            for s in 0..seq {
                let dr = &dh[(b * seq + s) * d..(b * seq + s + 1) * d];
                let pr = &mut grads[o.pos + s * d..o.pos + (s + 1) * d];
                for (pv, &dv) in pr.iter_mut().zip(dr) {
                    *pv += dv;
                }
            }
        }
        let (dwe, rest) = grads[o.embed_w..].split_at_mut(d_in * d);
        linear_bwd(x, &p[o.embed_w..], &dh, rows, d_in, d, dwe, &mut rest[..d], None);
    }

    /// One full train step at `params` on one batch: loss + flat grads.
    fn train_step(&self, params: &[f64], x: &[f64], y: &[i32]) -> (f64, Vec<f64>) {
        let acts = self.forward(params, x);
        let (loss, dlogits) = self.loss_and_dlogits(&acts.logits, y);
        let mut grads = vec![0.0; params.len()];
        self.backward(params, x, &acts, &dlogits, &mut grads);
        (loss, grads)
    }

    /// Evaluate `params` on `n_batches` held-out batches — the native
    /// twin of `PjrtModelSource::evaluate` (same dataset, same rank
    /// counting for Top-5).
    pub fn evaluate(&mut self, params: &[f32], n_batches: usize) -> anyhow::Result<EvalMetrics> {
        anyhow::ensure!(params.len() == self.layout.n_params, "flat params dim mismatch");
        anyhow::ensure!(n_batches > 0, "evaluate needs n_batches >= 1");
        let p: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        let (bsz, c) = (self.cfg.batch, self.cfg.n_classes);
        let mut loss = 0.0;
        let mut top1 = 0.0;
        let mut top5 = 0.0;
        let k = 5usize.min(c);
        for batch in self.dataset.eval_batches(bsz, n_batches) {
            let x: Vec<f64> = batch.x.iter().map(|&v| v as f64).collect();
            let acts = self.forward(&p, &x);
            let (l, _) = self.loss_and_dlogits(&acts.logits, &batch.y);
            self.n_exec += 1;
            loss += l;
            for b in 0..bsz {
                let lr = &acts.logits[b * c..(b + 1) * c];
                let yi = batch.y[b] as usize;
                // Rank counting, like the exported eval_step: the true
                // class is in the top k iff < k logits strictly beat it.
                let rank = lr.iter().filter(|&&v| v > lr[yi]).count();
                if rank == 0 {
                    top1 += 1.0;
                }
                if rank < k {
                    top5 += 1.0;
                }
            }
        }
        let n = n_batches * bsz;
        Ok(EvalMetrics {
            loss: loss / n_batches.max(1) as f64,
            top1: top1 / n as f64,
            top5: top5 / n as f64,
            n,
        })
    }
}

impl GradientSource for NativeModelSource {
    fn dim(&self) -> usize {
        self.layout.n_params
    }

    fn update(
        &mut self,
        worker: usize,
        step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(x_hat.len() == self.layout.n_params, "flat params dim mismatch");
        anyhow::ensure!(out.len() == self.layout.n_params, "gradient buffer dim mismatch");
        let batch = self.dataset.batch(self.cfg.batch, worker, step);
        let p: Vec<f64> = x_hat.iter().map(|&v| v as f64).collect();
        let x: Vec<f64> = batch.x.iter().map(|&v| v as f64).collect();
        let (loss, grads) = self.train_step(&p, &x, &batch.y);
        self.n_exec += 1;
        for (ov, &gv) in out.iter_mut().zip(&grads) {
            *ov = gv as f32;
        }
        Ok(loss)
    }

    fn t_comp(&self) -> f64 {
        self.t_comp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeConfig {
        NativeConfig::preset("tiny").unwrap()
    }

    fn source(cfg: &NativeConfig) -> NativeModelSource {
        let layout = cfg.layout_named("tiny");
        NativeModelSource::new(&layout, 0.3, 21, 1.0).unwrap()
    }

    #[test]
    fn presets_match_python_param_counts() {
        // n_params counted the way model.py counts them; tiny's table:
        // embed 208 + block 2224 + head 202 = 2634.
        assert_eq!(tiny().n_params(), 2634);
        for name in PRESETS {
            let cfg = NativeConfig::preset(name).unwrap();
            let l = cfg.layout_named(name);
            l.validate().unwrap();
            assert_eq!(l.n_params, cfg.n_params());
            assert_eq!(l.n_groups, cfg.n_blocks + 2);
            assert_eq!(l.layers().len(), cfg.n_blocks + 2, "{name}");
        }
        assert!(NativeConfig::preset("nope").is_err());
    }

    #[test]
    fn init_params_scheme() {
        let cfg = tiny();
        let p = cfg.init_params(21);
        assert_eq!(p.len(), cfg.n_params());
        let layout = cfg.layout_named("tiny");
        for slot in &layout.params {
            let vals = &p[slot.offset..slot.offset + slot.size];
            let leaf = slot.name.rsplit('/').next().unwrap();
            match leaf {
                "b" | "bqkv" | "bo" | "b1" | "b2" => assert!(vals.iter().all(|&v| v == 0.0)),
                "g" => assert!(vals.iter().all(|&v| v == 1.0)),
                _ => assert!(vals.iter().any(|&v| v != 0.0), "{}", slot.name),
            }
        }
        // Deterministic in the seed.
        assert_eq!(p, cfg.init_params(21));
        assert_ne!(p, cfg.init_params(22));
    }

    #[test]
    fn from_layout_validates_slot_table() {
        let cfg = tiny();
        let layout = cfg.layout_named("tiny");
        assert_eq!(NativeConfig::from_layout(&layout).unwrap(), cfg);
        let mut bad = layout.clone();
        bad.params[3].name = "renamed".into();
        assert!(NativeConfig::from_layout(&bad).is_err());
        // A synthetic (non-transformer) layout is rejected up front.
        assert!(NativeConfig::from_layout(&ModelLayout::synthetic(&[4, 4])).is_err());
    }

    #[test]
    fn loss_near_ln10_at_init_and_deterministic() {
        let cfg = tiny();
        let mut src = source(&cfg);
        let params = cfg.init_params(21);
        let mut g1 = vec![0.0f32; cfg.n_params()];
        let l1 = src.update(0, 0, &params, &mut g1).unwrap();
        // Cross-entropy at a random init sits near ln(10).
        assert!((l1 - (10f64).ln()).abs() < 1.5, "loss={l1}");
        let norm: f64 = g1.iter().map(|&g| (g as f64) * (g as f64)).sum();
        assert!(norm > 0.0 && norm.is_finite());
        let mut g2 = vec![0.0f32; cfg.n_params()];
        let l2 = src.update(0, 0, &params, &mut g2).unwrap();
        assert_eq!(l1, l2, "same (worker, step) must be bit-identical");
        assert_eq!(g1, g2);
        assert_eq!(src.executions(), 2);
    }

    #[test]
    fn gradients_match_finite_differences() {
        // The safety net for the hand-written backward pass: central
        // finite differences over coordinates touching every slot kind
        // (embed, LN gains/biases, attention, FFN, head). The forward
        // runs in f64, so tight tolerances hold.
        let cfg = tiny();
        let src = source(&cfg);
        let layout = cfg.layout_named("tiny");
        let batch = src.dataset.batch(cfg.batch, 0, 0);
        let p0: Vec<f64> = cfg.init_params(21).iter().map(|&v| v as f64).collect();
        let x: Vec<f64> = batch.x.iter().map(|&v| v as f64).collect();
        let (_, grads) = src.train_step(&p0, &x, &batch.y);
        let eps = 1e-5;
        for slot in &layout.params {
            // First, middle and last coordinate of every slot.
            for idx in [slot.offset, slot.offset + slot.size / 2, slot.offset + slot.size - 1] {
                let mut pp = p0.clone();
                pp[idx] += eps;
                let (lp, _) = src.train_step(&pp, &x, &batch.y);
                pp[idx] = p0[idx] - eps;
                let (lm, _) = src.train_step(&pp, &x, &batch.y);
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[idx];
                assert!(
                    (fd - an).abs() <= 1e-6 + 1e-4 * an.abs().max(fd.abs()),
                    "{}[{}]: analytic {an} vs fd {fd}",
                    slot.name,
                    idx - slot.offset
                );
            }
        }
    }

    #[test]
    fn sgd_reduces_loss() {
        let cfg = tiny();
        let mut src = source(&cfg);
        let mut params = cfg.init_params(21);
        let mut grads = vec![0.0f32; cfg.n_params()];
        let first = src.update(0, 0, &params, &mut grads).unwrap();
        let mut last = first;
        for step in 0..40 {
            last = src.update(0, step, &params, &mut grads).unwrap();
            for (p, &g) in params.iter_mut().zip(&grads) {
                *p -= 0.05 * g;
            }
        }
        assert!(last < first - 0.15, "loss did not drop: {first:.4} -> {last:.4}");
    }

    #[test]
    fn evaluate_counts_consistent() {
        let cfg = tiny();
        let mut src = source(&cfg);
        let params = cfg.init_params(21);
        let e = src.evaluate(&params, 2).unwrap();
        assert!(e.loss.is_finite());
        assert!((0.0..=1.0).contains(&e.top1));
        assert!(e.top5 >= e.top1 && e.top5 <= 1.0);
        assert_eq!(e.n, 2 * cfg.batch);
        let e2 = src.evaluate(&params, 2).unwrap();
        assert_eq!(e.loss, e2.loss);
        assert_eq!(e.top1, e2.top1);
        // Zero batches is a loud error, not NaN accuracies.
        assert!(src.evaluate(&params, 0).is_err());
    }
}
