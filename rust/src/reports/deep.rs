//! Deep-model reports: Fig. 7 (communication adaptivity), Fig. 8 (loss
//! curve), Fig. 9 (compression error), Table 1 (step time), Table 2
//! (Top-5 accuracy vs M). All run the AOT transformer through PJRT.

use crate::config::{ExperimentConfig, OptimizerSpec, WorkloadSpec};
use crate::driver::{paper_bandwidth_spec, run_experiment, ExperimentResult};
use crate::kimad::{BudgetParams, CompressPolicy};
use crate::metrics::{Series, SeriesSet, Table};

use super::ReportCtx;

/// ResNet18's wire size (11.69M params x 32 bit) — what the paper's
/// 30–330 Mbps band was calibrated against.
const RESNET18_BITS: f64 = 11_689_512.0 * 32.0;

/// Scale the paper's bandwidth band to OUR model so the fit ratio
/// B·t / model_bits — the quantity that decides how much compression
/// the budget forces — matches the paper's setting (DESIGN.md §3).
fn bandwidth_scale(ctx: &ReportCtx) -> f64 {
    let n_params = match ctx.preset() {
        "small" => 18_282.0,
        "e2e" => 800_906.0,
        _ => 800_906.0,
    };
    (n_params * 32.0) / RESNET18_BITS
}

/// The §4.2 base experiment: M=4, sin² 30–330 Mbps (scaled to the
/// model, see bandwidth_scale) with per-worker noise, T_comm = 1 s,
/// γ = 0.01, TopK family, warm start.
pub fn base_config(
    ctx: &ReportCtx,
    policy: CompressPolicy,
    t_comm: f64,
    m: usize,
) -> ExperimentConfig {
    let s = bandwidth_scale(ctx);
    let scaled = |seed: u64| match paper_bandwidth_spec(seed) {
        crate::bandwidth::TraceSpec::NoisySinSquared {
            eta, theta, delta, phase, noise_sigma, seed, horizon,
        } => crate::bandwidth::TraceSpec::NoisySinSquared {
            eta: eta * s,
            theta,
            delta: delta * s,
            phase,
            noise_sigma,
            seed,
            horizon,
        },
        other => other,
    };
    ExperimentConfig {
        name: "deep".into(),
        m,
        participation: 1.0,
        cohorts: 0,
        workload: WorkloadSpec::DeepModel {
            preset: ctx.preset().into(),
            sigma: 0.3,
            t_comp: 0.0, // §4.2: ModelSize / AverageBandwidth
        },
        budget: BudgetParams::PerDirection { t_comm },
        up_policy: policy.clone(),
        down_policy: policy,
        optimizer: OptimizerSpec { gamma: 0.01, layer_weights: vec![] },
        uplink: scaled(21),
        downlink: scaled(1021),
        alpha: 1.0,
        rounds: if ctx.fast { 30 } else { 200 },
        prior_bps: 0.0,
        warm_start: true,
        single_layer: false,
        // Conservative budget: the trailing-window estimate overruns
        // the deadline on falling bandwidth without margin (DC2-style).
        budget_safety: 0.8,
        threads: 0,
        shards: 0,
        thread_cap: 0,
        mode: crate::config::ExecModeSpec::Sync,
        compute: crate::coordinator::ComputeModel::Constant,
        transport: crate::config::TransportSpec::Inproc,
        seed: 21,
    }
}

fn run(
    ctx: &ReportCtx,
    cfg: &ExperimentConfig,
    eval_batches: usize,
) -> anyhow::Result<ExperimentResult> {
    run_experiment(cfg, Some(&ctx.artifacts), eval_batches)
}

/// Mean uplink bits/round/worker — used to hand EF21 the *same* total
/// communication as Kimad (the §4.2 baseline construction).
fn mean_up_bits(res: &ExperimentResult) -> f64 {
    let mut total = 0u64;
    let mut n = 0u64;
    for r in &res.records {
        for w in &r.workers {
            total += w.up_bits;
            n += 1;
        }
    }
    total as f64 / n.max(1) as f64
}

fn matched_ef21_ratio(res: &ExperimentResult, n_params: usize) -> f64 {
    (mean_up_bits(res) / (n_params as f64 * 64.0)).clamp(0.0, 1.0)
}

// ---------------------------------------------------------------------
// Fig. 7 — communication size over time, per T_comm.
// ---------------------------------------------------------------------

pub fn fig7(ctx: &ReportCtx) -> anyhow::Result<String> {
    let t_comms = [1.0, 0.5, 0.2, 0.1];
    let mut set = SeriesSet::default();
    let mut md = String::from("## fig7 (communication adaptivity, M=4)\n\n");
    md.push_str(
        "| T_comm | mean up Mbit/round | max (uncompressed) Mbit | rounds at cap |\n\
         |---|---|---|---|\n",
    );
    #[allow(unused_assignments)]
    let mut max_bits = 0.0f64;
    for &t_comm in &t_comms {
        let cfg = base_config(ctx, CompressPolicy::KimadUniform, t_comm, 4);
        let res = run(ctx, &cfg, 0)?;
        max_bits = res.n_params as f64 * 32.0;
        // Worker 0's sent bits against virtual time (the paper plots one
        // worker); plus the ground-truth bandwidth for the dashed curve.
        let mut s = Series::new(format!("kimad_t{t_comm}"));
        let mut bw = Series::new(format!("bandwidth_t{t_comm}"));
        let mut at_cap = 0usize;
        for r in &res.records {
            let w = &r.workers[0];
            s.push(r.t_start, w.up_bits as f64);
            bw.push(r.t_start, w.true_up_bps);
            if (w.up_bits as f64) >= max_bits {
                at_cap += 1;
            }
        }
        md.push_str(&format!(
            "| {t_comm}s | {:.2} | {:.2} | {}/{} |\n",
            mean_up_bits(&res) / 1e6,
            max_bits / 1e6,
            at_cap,
            res.records.len()
        ));
        set.push(s);
        set.push(bw);
    }
    let csv = ctx.csv_path("fig7_comm.csv");
    set.write_csv(&csv, "time_s", "bits_or_bps")?;
    md.push_str(&format!(
        "\nPlateau check: larger T_comm ⇒ more rounds at the uncompressed cap.\nCSV: {}\n",
        csv.display()
    ));
    Ok(md)
}

// ---------------------------------------------------------------------
// Fig. 8 — loss vs time, Kimad vs comm-matched EF21.
// ---------------------------------------------------------------------

pub fn fig8(ctx: &ReportCtx) -> anyhow::Result<String> {
    let kimad_cfg = base_config(ctx, CompressPolicy::KimadUniform, 1.0, 4);
    let kimad = run(ctx, &kimad_cfg, 0)?;
    let ratio = matched_ef21_ratio(&kimad, kimad.n_params);
    let mut ef_cfg = base_config(ctx, CompressPolicy::FixedRatio { ratio }, 1.0, 4);
    ef_cfg.rounds = kimad_cfg.rounds;
    let ef = run(ctx, &ef_cfg, 0)?;

    let mut set = SeriesSet::default();
    for (name, res) in [("Kimad", &kimad), ("EF21", &ef)] {
        let mut s = Series::new(name);
        for r in &res.records {
            s.push(r.t_end(), r.loss);
        }
        set.push(s);
    }
    let csv = ctx.csv_path("fig8_loss.csv");
    set.write_csv(&csv, "time_s", "loss")?;

    let k_end = kimad.total_time;
    let e_end = ef.total_time;
    let mut md = String::from("## fig8 (loss curve, M=4, T_comm=1.0s)\n\n");
    md.push_str(&format!(
        "| method | rounds | total time | final loss |\n|---|---|---|---|\n\
         | Kimad | {} | {k_end:.1}s | {:.4} |\n\
         | EF21 (ratio {ratio:.3}) | {} | {e_end:.1}s | {:.4} |\n",
        kimad.records.len(),
        kimad.records.last().map(|r| r.loss).unwrap_or(f64::NAN),
        ef.records.len(),
        ef.records.last().map(|r| r.loss).unwrap_or(f64::NAN),
    ));
    md.push_str(&format!(
        "\nShape: same rounds & comm volume, Kimad finishes in {:.0}% of EF21's time.\nCSV: {}\n",
        100.0 * k_end / e_end,
        csv.display()
    ));
    Ok(md)
}

// ---------------------------------------------------------------------
// Fig. 9 — compression error: Kimad vs Kimad+ vs optimal.
// ---------------------------------------------------------------------

pub fn fig9(ctx: &ReportCtx) -> anyhow::Result<String> {
    let policies = [
        ("Kimad", CompressPolicy::KimadUniform),
        (
            "Kimad+",
            CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![] },
        ),
        ("Optimal", CompressPolicy::WholeModelTopK),
    ];
    let mut set = SeriesSet::default();
    let mut means = Vec::new();
    for (name, policy) in policies {
        let cfg = base_config(ctx, policy, 1.0, 4);
        let res = run(ctx, &cfg, 0)?;
        let mut s = Series::new(name);
        for r in &res.records {
            s.push(r.t_start, r.workers[0].compression_error);
        }
        means.push((name, s.mean_y().unwrap_or(f64::NAN)));
        set.push(s);
    }
    let csv = ctx.csv_path("fig9_error.csv");
    set.write_csv(&csv, "time_s", "compression_error")?;

    let mut md = String::from("## fig9 (compression error at worker 0, T_comm=1.0s)\n\n");
    md.push_str("| policy | mean ||u − û||² |\n|---|---|\n");
    for (name, m) in &means {
        md.push_str(&format!("| {name} | {m:.4e} |\n"));
    }
    md.push_str(&format!(
        "\nExpected order: Optimal <= Kimad+ <= Kimad.\nCSV: {}\n",
        csv.display()
    ));
    Ok(md)
}

// ---------------------------------------------------------------------
// Table 1 — average step time across T_comm, Kimad vs matched EF21.
// ---------------------------------------------------------------------

pub fn table1(ctx: &ReportCtx) -> anyhow::Result<String> {
    let t_comms = [1.0, 0.5, 0.2, 0.1];
    let mut ef_row = Vec::new();
    let mut kimad_row = Vec::new();
    for &t_comm in &t_comms {
        let kcfg = base_config(ctx, CompressPolicy::KimadUniform, t_comm, 4);
        let kres = run(ctx, &kcfg, 0)?;
        let ratio = matched_ef21_ratio(&kres, kres.n_params);
        let ecfg = base_config(ctx, CompressPolicy::FixedRatio { ratio }, t_comm, 4);
        let eres = run(ctx, &ecfg, 0)?;
        kimad_row.push(kres.mean_step_time());
        ef_row.push(eres.mean_step_time());
    }
    let mut table = Table::new(
        "table1 (average step time, M=4)",
        &["1.0s", "0.5s", "0.2s", "0.1s"],
    );
    table.push_row("EF21", ef_row.clone());
    table.push_row("Kimad", kimad_row.clone());
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.csv_path("table1_steptime.csv"), table.to_csv())?;

    let mut md = table.render("s", 3);
    let saving: f64 = ef_row
        .iter()
        .zip(&kimad_row)
        .map(|(e, k)| 1.0 - k / e)
        .sum::<f64>()
        / ef_row.len() as f64;
    md.push_str(&format!(
        "\nMean saving: {:.1}% (paper reports ≈20%).\n",
        100.0 * saving
    ));
    Ok(md)
}

// ---------------------------------------------------------------------
// Table 2 — Top-5 accuracy across M.
// ---------------------------------------------------------------------

pub fn table2(ctx: &ReportCtx) -> anyhow::Result<String> {
    let ms = [2usize, 4, 8, 16];
    let eval_batches = if ctx.fast { 2 } else { 8 };
    let mut ef_row = Vec::new();
    let mut kimad_row = Vec::new();
    for &m in &ms {
        let kcfg = base_config(ctx, CompressPolicy::KimadUniform, 1.0, m);
        let kres = run(ctx, &kcfg, eval_batches)?;
        let ratio = matched_ef21_ratio(&kres, kres.n_params);
        let ecfg = base_config(ctx, CompressPolicy::FixedRatio { ratio }, 1.0, m);
        let eres = run(ctx, &ecfg, eval_batches)?;
        kimad_row.push(kres.eval.map(|e| e.top5 * 100.0).unwrap_or(f64::NAN));
        ef_row.push(eres.eval.map(|e| e.top5 * 100.0).unwrap_or(f64::NAN));
    }
    let mut table = Table::new(
        "table2 (Top-5 accuracy %, T_comm=1s)",
        &["2", "4", "8", "16"],
    );
    table.push_row("EF21", ef_row);
    table.push_row("Kimad", kimad_row);
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.csv_path("table2_scaling.csv"), table.to_csv())?;

    let mut md = table.render("%", 2);
    md.push_str("\nShape: comparable accuracy across M for both methods.\n");
    Ok(md)
}
