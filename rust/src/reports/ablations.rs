//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. Bandwidth monitor: EWMA weight / sliding-window vs deadline
//!    adherence (the §2.4 estimator choice).
//! 2. Budget safety factor (DC2-style conservatism) vs step time and
//!    communicated volume.
//! 3. Kimad+ discretization factor D: allocation quality vs DP cost
//!    (the paper's O(N·K·D) knob, §3.2).

use std::sync::Arc;
use std::time::Instant;

use crate::bandwidth::{BandwidthTrace, SinSquaredTrace};
use crate::coordinator::{QuadraticSource, SimConfig, Simulation};
use crate::kimad::knapsack::{allocate, topk_options, KnapsackParams};
use crate::kimad::{BudgetParams, CompressPolicy, ErrorCurve};
use crate::metrics::Table;
use crate::netsim::{Link, NetSim};
use crate::optim::{LayerwiseSgd, Schedule};
use crate::quadratic::Quadratic;
use crate::util::rng::Rng;

use super::ReportCtx;

fn sim_with(budget_safety: f64, monitor_alpha: f64) -> Simulation<QuadraticSource> {
    let q = Quadratic::paper_instance(200);
    let layers = q.layout(4).layers();
    let src = QuadraticSource::new(q, 0.2);
    let wave = |phase: f64| SinSquaredTrace::new(6400.0, 0.05, 320.0).with_phase(phase);
    let net = NetSim::new(
        (0..2)
            .map(|i| {
                Link::new(
                    Arc::new(wave(0.3 * i as f64)),
                    Arc::new(wave(1.0 + 0.3 * i as f64)),
                )
            })
            .collect(),
    );
    let cfg = SimConfig {
        m: 2,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: 0.9 },
        up_policy: CompressPolicy::KimadUniform,
        down_policy: CompressPolicy::KimadUniform,
        optimizer: LayerwiseSgd::new(Schedule::Constant(0.02)),
        layers,
        warm_start: true,
        prior_bps: 3520.0,
        round_deadline: Some(2.0),
        budget_safety,
        threads: 1,
        mode: crate::coordinator::ExecMode::Sync,
        compute: crate::coordinator::ComputeModel::Constant,
    };
    let mut sim = Simulation::new(cfg, net, src, vec![1.0f32; 200]);
    // Swap the monitors for the requested EWMA weight.
    for w in &mut sim.workers {
        w.monitor = Box::new(crate::bandwidth::EwmaMonitor::new(monitor_alpha));
    }
    sim
}

/// Ablation 1+2: (monitor alpha x safety) -> overrun fraction, volume.
pub fn monitor_and_safety(ctx: &ReportCtx) -> anyhow::Result<String> {
    let rounds = if ctx.fast { 80 } else { 400 };
    let mut table = Table::new(
        "ablation: monitor EWMA weight x budget safety (quadratic, M=2)",
        &["overrun %", "mean step s", "Mbit/round"],
    );
    for &(alpha, safety) in &[
        (0.3, 1.0),
        (0.7, 1.0),
        (1.0, 1.0),
        (0.7, 0.8),
        (0.7, 0.6),
    ] {
        let mut sim = sim_with(safety, alpha);
        let recs = sim.run(rounds)?;
        let overruns = recs.iter().filter(|r| r.duration > 2.0 + 1e-9).count();
        let mean_step = recs.iter().map(|r| r.duration).sum::<f64>() / recs.len() as f64;
        let vol = recs
            .iter()
            .map(|r| r.total_up_bits() as f64)
            .sum::<f64>()
            / recs.len() as f64
            / 1e6;
        table.push_row(
            format!("a={alpha} s={safety}"),
            vec![100.0 * overruns as f64 / recs.len() as f64, mean_step, vol],
        );
    }
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.csv_path("ablation_monitor_safety.csv"), table.to_csv())?;
    let mut md = table.render("", 3);
    md.push_str(
        "\nTradeoff: fresher estimates (higher a) and margin (lower s) cut deadline\n\
         overruns at the cost of communicated volume.\n",
    );
    Ok(md)
}

/// Ablation 3: Kimad+ discretization D -> allocation error + DP time.
pub fn discretization(ctx: &ReportCtx) -> anyhow::Result<String> {
    let mut rng = Rng::seed_from_u64(21);
    // Transformer-like heterogeneous layers.
    let sizes = [4096usize, 49152, 16384, 65536, 1280];
    let grads: Vec<Vec<f32>> = sizes
        .iter()
        .map(|&d| {
            (0..d)
                .map(|i| (-(i as f32) / (d as f32 / 6.0)).exp() * rng.range_f32(-2.0, 2.0))
                .collect()
        })
        .collect();
    let curves: Vec<ErrorCurve> = grads.iter().map(|g| ErrorCurve::build(g)).collect();
    let grid = crate::kimad::knapsack::paper_ratio_grid();
    let options = topk_options(&curves, &grid, 64);
    let total_bits: u64 = sizes.iter().map(|&d| d as u64 * 64).sum();
    let budget = total_bits / 10;

    let mut table = Table::new(
        "ablation: Kimad+ discretization D (5 transformer-scale layers, 10% budget)",
        &["total error", "DP µs"],
    );
    let reps = if ctx.fast { 3 } else { 20 };
    let mut base_err = None;
    for &d in &[50usize, 200, 1000, 5000, 20000] {
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now(); // tidy:allow(wall-clock) -- DP timing table, not results
        let mut alloc = None;
        for _ in 0..reps {
            alloc = Some(allocate(
                &options,
                KnapsackParams { budget_bits: budget, discretization: d },
            ));
        }
        let us = t0.elapsed().as_micros() as f64 / reps as f64;
        let a = alloc.unwrap();
        assert!(a.total_bits <= budget);
        base_err.get_or_insert(a.total_error);
        table.push_row(format!("D={d}"), vec![a.total_error, us]);
    }
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(ctx.csv_path("ablation_discretization.csv"), table.to_csv())?;
    let mut md = table.render("", 1);
    md.push_str(
        "\nD=1000 (the paper's setting) already sits at the error plateau; cost grows\n\
         linearly in D (O(N*K*D)).\n",
    );
    Ok(md)
}

pub fn generate(ctx: &ReportCtx) -> anyhow::Result<String> {
    let mut out = monitor_and_safety(ctx)?;
    out.push('\n');
    out.push_str(&discretization(ctx)?);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_generate() {
        let dir = std::env::temp_dir().join(format!("kimad-abl-{}", std::process::id()));
        let ctx =
            ReportCtx { artifacts: "artifacts".into(), out_dir: dir.clone(), fast: true };
        let md = generate(&ctx).unwrap();
        assert!(md.contains("ablation: monitor"));
        assert!(md.contains("D=1000"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn finer_discretization_not_worse() {
        let mut rng = Rng::seed_from_u64(3);
        let g: Vec<f32> = (0..4000).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let curves = vec![ErrorCurve::build(&g[..1000]), ErrorCurve::build(&g[1000..])];
        let options = topk_options(&curves, &crate::kimad::knapsack::paper_ratio_grid(), 64);
        let budget = 4000 * 64 / 8;
        let coarse = allocate(&options, KnapsackParams { budget_bits: budget, discretization: 50 });
        let fine =
            allocate(&options, KnapsackParams { budget_bits: budget, discretization: 20000 });
        assert!(fine.total_error <= coarse.total_error + 1e-9);
    }
}
