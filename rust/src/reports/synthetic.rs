//! Figs. 3–6: the §4.1 synthetic quadratic under four bandwidth
//! regimes. GD vs best-tuned EF21(TopK) vs Kimad, f(x) against virtual
//! time; uplink only (the paper neglects the downlink here).

use std::sync::Arc;

use crate::bandwidth::{ConstantTrace, SinSquaredTrace};
use crate::coordinator::{GradientSource, QuadraticSource, SimConfig, Simulation};
use crate::kimad::{BudgetParams, CompressPolicy};
use crate::metrics::{Series, SeriesSet};
use crate::netsim::{Link, NetSim};
use crate::optim::{LayerwiseSgd, Schedule};
use crate::quadratic::Quadratic;

use super::ReportCtx;

pub const D: usize = 30;
/// Bits for one sparse coordinate (index + value).
const CB: f64 = 64.0;
/// Per-round computation time T_comp (§3.1): every method pays it, and
/// it is what makes straggler rounds expensive relative to the budget.
pub const T_COMP: f64 = 0.2;
/// Kimad's time-budget grid: the paper tunes t per task ("we focus on
/// optimizing the time budget parameter t").
pub const T_GRID: &[f64] = &[0.4, 0.6, 1.0, 2.0];

/// The four bandwidth regimes of Figs. 3–6 (units: bits/s, scaled so a
/// "coordinate" is 64 bits and the time budget is 1 s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Fig. 3 — extremely small: B_max ≪ d (≈ 1..6 coords/round).
    XSmall,
    /// Fig. 4 — small: B_max < d (≈ 1..21 coords/round).
    Small,
    /// Fig. 5 — oscillation between small and high (≈ 2..60).
    Oscillation,
    /// Fig. 6 — high with small oscillation (≈ 100..120; no gain).
    High,
}

impl Scenario {
    pub fn id(&self) -> &'static str {
        match self {
            Scenario::XSmall => "fig3_xsmall",
            Scenario::Small => "fig4_small",
            Scenario::Oscillation => "fig5_oscillation",
            Scenario::High => "fig6_high",
        }
    }

    /// (eta, theta, delta) of the sin² trace, in coords/s × CB bits.
    /// Troughs approach zero bandwidth in Figs. 3–5 (the paper's
    /// sinusoid rides near the axis): that is where fixed-K baselines
    /// stall — a k-coordinate round takes k·CB/B seconds — while Kimad
    /// shrinks its message and keeps the 1 s round cadence.
    pub fn trace_params(&self) -> (f64, f64, f64) {
        match self {
            Scenario::XSmall => (6.0 * CB, 0.1, 0.1 * CB),
            Scenario::Small => (24.0 * CB, 0.1, 0.1 * CB),
            Scenario::Oscillation => (60.0 * CB, 0.1, 0.5 * CB),
            Scenario::High => (20.0 * CB, 0.1, 100.0 * CB),
        }
    }

    pub fn horizon(&self) -> f64 {
        match self {
            Scenario::XSmall => 400.0,
            Scenario::Small => 250.0,
            Scenario::Oscillation => 150.0,
            Scenario::High => 60.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Gd,
    Ef21Fixed { k: usize },
    /// Kimad with round time budget `t` (tuned like the paper does).
    Kimad { t: f64 },
}

impl Method {
    fn name(&self) -> String {
        match self {
            Method::Gd => "GD".into(),
            Method::Ef21Fixed { k } => format!("EF21-top{k}"),
            Method::Kimad { t } => format!("Kimad(t={t})"),
        }
    }

    fn policy(&self) -> CompressPolicy {
        match self {
            Method::Gd => CompressPolicy::FixedRatio { ratio: 1.0 },
            Method::Ef21Fixed { k } => {
                CompressPolicy::FixedRatio { ratio: *k as f64 / D as f64 }
            }
            Method::Kimad { .. } => CompressPolicy::KimadUniform,
        }
    }

}

/// Run one (scenario, method, gamma) to `horizon` virtual seconds at
/// system round cadence `t_sys` and return the f(x)-vs-time series.
///
/// The harness is a *deadline-scheduled* PS (the setting Kimad is
/// designed for): rounds are scheduled every `t_sys` seconds; a round
/// whose transfer overruns delays the schedule (straggler). Kimad fills
/// the window via Eq. (2); fixed-ratio baselines send their fixed
/// payload, overrunning troughs and under-filling peaks.
pub fn run(scn: Scenario, method: Method, gamma: f64, horizon: f64) -> Series {
    let t = if let Method::Kimad { t } = method { t } else { 1.0 };
    run_at(scn, method, gamma, t, horizon)
}

pub fn run_at(scn: Scenario, method: Method, gamma: f64, t_sys: f64, horizon: f64) -> Series {
    let (eta, theta, delta) = scn.trace_params();
    let q = Quadratic::paper_instance(D);
    let layout = q.layout(1); // single layer: plain Kimad granularity
    let layers = layout.layers();
    let src = QuadraticSource::new(q, T_COMP);
    // Uplink = the scenario trace; downlink neglected (≈infinite).
    let net = NetSim::new(vec![Link::new(
        Arc::new(SinSquaredTrace::new(eta, theta, delta)),
        Arc::new(ConstantTrace::new(1e15)),
    )]);
    let cfg = SimConfig {
        m: 1,
        weights: vec![],
        budget: BudgetParams::PerDirection { t_comm: (t_sys - T_COMP).max(0.05) },
        round_deadline: Some(t_sys),
        up_policy: method.policy(),
        down_policy: CompressPolicy::FixedRatio { ratio: 1.0 },
        optimizer: LayerwiseSgd::new(Schedule::Constant(gamma)),
        layers,
        warm_start: true,
        prior_bps: delta + 0.5 * eta,
        budget_safety: 1.0,
        threads: 1,
        mode: crate::coordinator::ExecMode::Sync,
        compute: crate::coordinator::ComputeModel::Constant,
    };
    let mut sim = Simulation::new(cfg, net, src, vec![1.0f32; D]);
    let mut series = Series::new(method.name());
    series.push(0.0, sim.source.objective(&sim.server.x).unwrap());
    let recs = sim.run_until(horizon, 100_000).unwrap();
    for r in &recs {
        series.push(r.t_end(), r.f_x);
    }
    series
}

/// Grid-tune all hyperparameters exactly as the paper does ("it's
/// crucial to fine-tune all hyperparameters for each method"): Kimad
/// tunes its time budget t and gamma; the system then runs at that
/// cadence, and the baselines tune their own K and gamma at the same
/// cadence (the schedule is a system property, the compressor is the
/// method's). Returns the best series per method by final f(x).
pub fn tuned_comparison(scn: Scenario, fast: bool) -> SeriesSet {
    let horizon = if fast { scn.horizon() / 4.0 } else { scn.horizon() };
    let gammas: &[f64] = if fast {
        &[0.02, 0.05, 0.1]
    } else {
        &[0.01, 0.02, 0.05, 0.1, 0.15, 0.18]
    };
    let ks: &[usize] = if fast { &[1, 3, 10] } else { &[1, 2, 3, 5, 10, 15, 25, 30] };
    let t_grid: &[f64] = if fast { &[0.6, 1.0] } else { T_GRID };

    // Kimad: best over (t, gamma); fixes the system cadence.
    let mut best_kimad: Option<(Series, f64)> = None;
    for &t in t_grid {
        let s = best_over_gammas(scn, Method::Kimad { t }, gammas, t, horizon);
        if better(&s, best_kimad.as_ref().map(|(s, _)| s)) {
            best_kimad = Some((s, t));
        }
    }
    let (mut km, t_sys) = best_kimad.unwrap();
    km.name = format!("Kimad-best ({})", km.name);

    let mut set = SeriesSet::default();
    // GD baseline at the system cadence.
    set.push(best_over_gammas(scn, Method::Gd, gammas, t_sys, horizon));
    // EF21: best over (K, gamma) at the system cadence.
    let mut best_ef: Option<Series> = None;
    for &k in ks {
        let s = best_over_gammas(scn, Method::Ef21Fixed { k }, gammas, t_sys, horizon);
        if better(&s, best_ef.as_ref()) {
            best_ef = Some(s);
        }
    }
    let mut ef = best_ef.unwrap();
    ef.name = format!("EF21-best ({})", ef.name);
    set.push(ef);
    set.push(km);
    set
}

fn best_over_gammas(scn: Scenario, m: Method, gammas: &[f64], t_sys: f64, horizon: f64) -> Series {
    let mut best: Option<Series> = None;
    for &g in gammas {
        let s = run_at(scn, m, g, t_sys, horizon);
        if better(&s, best.as_ref()) {
            best = Some(s);
        }
    }
    best.unwrap()
}

fn better(s: &Series, cur: Option<&Series>) -> bool {
    let last = s.last_y().unwrap_or(f64::INFINITY);
    let last = if last.is_finite() { last } else { f64::INFINITY };
    match cur {
        None => true,
        Some(c) => last < c.last_y().unwrap_or(f64::INFINITY),
    }
}

pub fn generate_one(ctx: &ReportCtx, scn: Scenario) -> anyhow::Result<String> {
    let mut set = tuned_comparison(scn, ctx.fast);
    // Robustness rows: individual fixed-K baselines at the same cadence
    // and a mid-grid gamma — the practical cost of *not* adapting when
    // K is mistuned for the bandwidth regime.
    let horizon = if ctx.fast { scn.horizon() / 4.0 } else { scn.horizon() };
    for k in [1usize, 5, 15] {
        set.push(run_at(scn, Method::Ef21Fixed { k }, 0.05, 1.0, horizon));
    }
    let csv = ctx.csv_path(&format!("{}.csv", scn.id()));
    set.write_csv(&csv, "time_s", "f_x")?;

    let mut md = format!("## {} (quadratic d={D})\n\n", scn.id());
    md.push_str("| method | final f(x) | time to f<=1e-3 |\n|---|---|---|\n");
    for s in &set.series {
        let t = s
            .first_x_below(1e-3)
            .map(|t| format!("{t:.1}s"))
            .unwrap_or_else(|| "-".into());
        md.push_str(&format!(
            "| {} | {:.3e} | {} |\n",
            s.name,
            s.last_y().unwrap_or(f64::NAN),
            t
        ));
    }
    md.push_str(&format!("\nCSV: {}\n", csv.display()));
    Ok(md)
}

pub fn generate_all(ctx: &ReportCtx) -> anyhow::Result<String> {
    let mut out = String::new();
    for scn in [
        Scenario::XSmall,
        Scenario::Small,
        Scenario::Oscillation,
        Scenario::High,
    ] {
        out.push_str(&generate_one(ctx, scn)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kimad_competitive_when_bandwidth_scarce() {
        // Fig. 3's shape, in miniature (see EXPERIMENTS.md §fig3-6 for
        // the honest accounting): Kimad — with NO oracle knowledge of
        // the best K — must beat GD outright and sit in the same
        // convergence regime as the post-hoc best-tuned EF21, while
        // mistuned fixed K (k=1 here) is catastrophically worse.
        let set = tuned_comparison(Scenario::XSmall, true);
        let last = |name: &str| {
            set.series
                .iter()
                .find(|s| s.name.starts_with(name))
                .unwrap()
                .last_y()
                .unwrap()
        };
        let kimad = last("Kimad");
        let ef = last("EF21-best");
        let gd = last("GD");
        assert!(kimad < gd, "kimad {kimad} vs gd {gd}");
        // Same convergence regime as the oracle-tuned baseline: within
        // a bounded log-distance over a >15-order dynamic range.
        assert!(
            kimad.log10() <= ef.log10() + 9.0,
            "kimad {kimad} vs best-ef {ef}"
        );
        // And the mistuned baseline is far worse than Kimad.
        let ef_k1 = run_at(Scenario::XSmall, Method::Ef21Fixed { k: 1 }, 0.05, 1.0, 50.0)
            .last_y()
            .unwrap();
        assert!(kimad < ef_k1, "kimad {kimad} vs ef-k1 {ef_k1}");
    }

    #[test]
    fn no_gain_when_bandwidth_plentiful() {
        // Fig. 6's claim: Kimad ≈ GD when the link is never a bottleneck.
        let kimad = run(Scenario::High, Method::Kimad { t: 1.0 }, 0.1, 30.0);
        let gd = run(Scenario::High, Method::Gd, 0.1, 30.0);
        let k = kimad.last_y().unwrap();
        let g = gd.last_y().unwrap();
        assert!((k - g).abs() <= 0.3 * g.max(1e-12) + 1e-9, "k={k} g={g}");
    }

    #[test]
    fn series_monotone_time() {
        let s = run(Scenario::Small, Method::Kimad { t: 1.0 }, 0.1, 50.0);
        for w in s.points.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
        assert!(s.points.len() > 10);
    }
}
