//! Fig. 1: EC2-like bandwidth discrepancy across 4 workers.
//!
//! The paper measured iperf3 from 4 EC2 workers to a Frankfurt TCP
//! server. We substitute the closest synthetic equivalent (DESIGN.md
//! §3): per-worker Ornstein–Uhlenbeck jitter around worker-specific
//! means modulated by a slow diurnal swing — the same qualitative
//! shape (persistent per-worker discrepancy + transient dips).

use crate::bandwidth::{mbps, BandwidthTrace, CompositeTrace, OuNoiseTrace, SinSquaredTrace};
use crate::metrics::{Series, SeriesSet};

use super::ReportCtx;

/// Build the 4 worker traces (bits/s), 120 s horizon.
pub fn ec2_like_traces(seed: u64) -> Vec<Box<dyn BandwidthTrace>> {
    let means = [mbps(840.0), mbps(620.0), mbps(410.0), mbps(290.0)];
    means
        .iter()
        .enumerate()
        .map(|(i, &mu)| {
            Box::new(CompositeTrace::new(
                Box::new(OuNoiseTrace::new(
                    mu,
                    0.8,
                    mu * 0.25,
                    seed + i as u64 * 7919,
                    200.0,
                )),
                // Slow congestion swing (shared shape, shifted phase).
                Box::new(SinSquaredTrace::new(0.35, 0.03, 0.65).with_phase(0.9 * i as f64)),
            )) as Box<dyn BandwidthTrace>
        })
        .collect()
}

pub fn generate(ctx: &ReportCtx) -> anyhow::Result<String> {
    let traces = ec2_like_traces(21);
    let horizon = if ctx.fast { 30.0 } else { 120.0 };
    let mut set = SeriesSet::default();
    for (i, tr) in traces.iter().enumerate() {
        let mut s = Series::new(format!("worker{}", i + 1));
        let mut t = 0.0;
        while t <= horizon {
            s.push(t, tr.at(t) / 1e6); // Mbps for the plot
            t += 0.5;
        }
        set.push(s);
    }
    let csv = ctx.csv_path("fig1_bandwidth.csv");
    set.write_csv(&csv, "time_s", "mbps")?;

    let mut md = String::from("## fig1 (EC2-like bandwidth, 4 workers)\n\n");
    md.push_str("| worker | mean Mbps | min | max |\n|---|---|---|---|\n");
    for s in &set.series {
        let ys: Vec<f64> = s.points.iter().map(|p| p.1).collect();
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let min = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = ys.iter().cloned().fold(0.0, f64::max);
        md.push_str(&format!(
            "| {} | {mean:.0} | {min:.0} | {max:.0} |\n",
            s.name
        ));
    }
    md.push_str(&format!("\nCSV: {}\n", csv.display()));
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_distinct_workers() {
        let traces = ec2_like_traces(1);
        assert_eq!(traces.len(), 4);
        // Persistent discrepancy: time-averaged bandwidths differ.
        let means: Vec<f64> = traces
            .iter()
            .map(|t| t.integrate(0.0, 60.0) / 60.0)
            .collect();
        for i in 0..3 {
            assert!(means[i] > means[i + 1] * 1.05, "{means:?}");
        }
    }

    #[test]
    fn bandwidth_positive_and_variable() {
        for tr in ec2_like_traces(2) {
            let samples: Vec<f64> = (0..100).map(|i| tr.at(i as f64)).collect();
            assert!(samples.iter().all(|&v| v > 0.0));
            let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = samples.iter().cloned().fold(0.0, f64::max);
            assert!(max > min * 1.3, "trace should fluctuate");
        }
    }
}
