//! Report generators: one function per paper figure/table.
//!
//! Each regenerates the corresponding evaluation artifact (same rows /
//! series the paper shows) from the simulator, writes CSVs under the
//! output directory, and returns a markdown summary. The criterion
//! benches and the `kimad report` CLI both call these (DESIGN.md §5).

pub mod ablations;
pub mod deep;
pub mod fig1;
pub mod synthetic;

use std::path::PathBuf;

/// Shared context for report generation.
#[derive(Debug, Clone)]
pub struct ReportCtx {
    /// artifacts/ directory (deep-model workloads).
    pub artifacts: String,
    /// Where CSVs land.
    pub out_dir: PathBuf,
    /// Fast mode: fewer rounds / smaller presets (used by benches and
    /// CI); full mode reproduces the paper-scale runs.
    pub fast: bool,
}

impl Default for ReportCtx {
    fn default() -> Self {
        Self { artifacts: "artifacts".into(), out_dir: "reports".into(), fast: false }
    }
}

impl ReportCtx {
    pub fn fast() -> Self {
        Self { fast: true, ..Default::default() }
    }

    /// Deep-model preset: the benches use `small`, full runs `e2e`.
    pub fn preset(&self) -> &'static str {
        if self.fast {
            "small"
        } else {
            "e2e"
        }
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(name)
    }
}

/// Dispatch by report id ("fig1", "fig3".."fig9", "table1", "table2").
pub fn generate(id: &str, ctx: &ReportCtx) -> anyhow::Result<String> {
    match id {
        "fig1" => fig1::generate(ctx),
        "fig3" => synthetic::generate_one(ctx, synthetic::Scenario::XSmall),
        "fig4" => synthetic::generate_one(ctx, synthetic::Scenario::Small),
        "fig5" => synthetic::generate_one(ctx, synthetic::Scenario::Oscillation),
        "fig6" => synthetic::generate_one(ctx, synthetic::Scenario::High),
        "fig3to6" => synthetic::generate_all(ctx),
        "fig7" => deep::fig7(ctx),
        "fig8" => deep::fig8(ctx),
        "fig9" => deep::fig9(ctx),
        "table1" => deep::table1(ctx),
        "table2" => deep::table2(ctx),
        "ablations" => ablations::generate(ctx),
        other => anyhow::bail!(
            "unknown report '{other}' (try fig1, fig3..fig9, fig3to6, table1, table2, ablations)"
        ),
    }
}

pub const ALL_REPORTS: &[&str] = &[
    "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table1", "table2",
];
