//! Synthetic classification data: the CIFAR10 stand-in (DESIGN.md §3).
//!
//! A 10-class "prototype + noise" generator over patch tokens: class c
//! has a fixed random prototype P_c ∈ R^{seq×d_in}; a sample is
//! `x = P_y + σ·ε`. Learnable signal, seeded, shardable per worker —
//! exactly the structure the data-parallel PS loop needs, with Python
//! nowhere in sight at runtime.

use crate::util::rng::Rng;

/// One batch in the layout the HLO executable expects.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    /// Row-major `[batch, seq, d_in]`.
    pub x: Vec<f32>,
    /// `[batch]` class labels.
    pub y: Vec<i32>,
}

/// Seeded synthetic dataset; workers get disjoint shards by stream id.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    pub seq: usize,
    pub d_in: usize,
    pub n_classes: usize,
    /// Noise scale σ: higher = harder task.
    pub sigma: f32,
    prototypes: Vec<f32>, // [n_classes, seq, d_in]
}

impl SyntheticDataset {
    pub fn new(seq: usize, d_in: usize, n_classes: usize, sigma: f32, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let prototypes = (0..n_classes * seq * d_in)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect();
        Self { seq, d_in, n_classes, sigma, prototypes }
    }

    /// Per-worker, per-step deterministic batch: worker `m`'s shard is
    /// the stream seeded by (m, step), disjoint from every other worker.
    pub fn batch(&self, batch: usize, worker: usize, step: u64) -> Batch {
        let seed = (worker as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(step)
            .wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let mut rng = Rng::seed_from_u64(seed);
        let tok = self.seq * self.d_in;
        let mut x = Vec::with_capacity(batch * tok);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = rng.range_usize(0, self.n_classes);
            y.push(c as i32);
            let proto = &self.prototypes[c * tok..(c + 1) * tok];
            for &p in proto {
                x.push(p + self.sigma * rng.range_f32(-1.0, 1.0));
            }
        }
        Batch { x, y }
    }

    /// A fixed evaluation set (same for every worker): worker id
    /// `usize::MAX - 1` so it never collides with training shards.
    pub fn eval_batches(&self, batch: usize, n_batches: usize) -> Vec<Batch> {
        (0..n_batches)
            .map(|i| self.batch(batch, usize::MAX - 1, u64::MAX - i as u64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticDataset {
        SyntheticDataset::new(4, 8, 10, 0.3, 21)
    }

    #[test]
    fn shapes_and_label_range() {
        let b = ds().batch(16, 0, 0);
        assert_eq!(b.x.len(), 16 * 4 * 8);
        assert_eq!(b.y.len(), 16);
        assert!(b.y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn deterministic_per_worker_step() {
        let d = ds();
        assert_eq!(d.batch(8, 1, 5), d.batch(8, 1, 5));
        assert_ne!(d.batch(8, 1, 5), d.batch(8, 2, 5));
        assert_ne!(d.batch(8, 1, 5), d.batch(8, 1, 6));
    }

    #[test]
    fn signal_above_noise() {
        // Same-class samples must be closer than cross-class on average.
        let d = ds();
        let b = d.batch(64, 0, 0);
        let tok = d.seq * d.d_in;
        let mut same = (0.0f64, 0usize);
        let mut diff = (0.0f64, 0usize);
        for i in 0..32 {
            for j in 32..64 {
                let dist: f64 = (0..tok)
                    .map(|t| {
                        let a = b.x[i * tok + t] - b.x[j * tok + t];
                        (a as f64) * (a as f64)
                    })
                    .sum();
                if b.y[i] == b.y[j] {
                    same = (same.0 + dist, same.1 + 1);
                } else {
                    diff = (diff.0 + dist, diff.1 + 1);
                }
            }
        }
        if same.1 > 0 && diff.1 > 0 {
            assert!(same.0 / same.1 as f64 + 1e-9 < diff.0 / diff.1 as f64);
        }
    }

    #[test]
    fn eval_batches_fixed() {
        let d = ds();
        let a = d.eval_batches(8, 2);
        let b = d.eval_batches(8, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
    }
}
