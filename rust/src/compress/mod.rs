//! Gradient compressors (§2.2): sparsification, quantization, low-rank.
//!
//! A [`Compressor`] maps a dense update vector to a [`Compressed`]
//! message with an exact *wire size* in bits — the quantity Kimad's
//! budget constrains — plus a contraction factor `alpha` used by the
//! EF21 theory (Theorem 1: `C in C^d(alpha)` means
//! `E||C(u) - u||^2 <= (1 - alpha) ||u||^2`).
//!
//! Wire-size accounting (per message):
//!   sparse:  k * (32-bit index + 32-bit value)
//!   dense-quantized: d * bits_per_value + 32-bit scale
//!   low-rank: rank * (rows + cols) * 32
//! Header/framing overhead is a constant per message and configurable
//! at the netsim layer; compressors report payload bits.
//!
//! # Example: a TopK round trip
//!
//! The default compressor (§4): keep the k largest-|u| coordinates,
//! pay `k · (index + value)` bits on the wire, decompress by adding
//! into a zeroed vector:
//!
//! ```
//! use kimad::compress::{Compressor, TopK};
//!
//! let u = [5.0f32, -0.1, 4.0, 0.2, -3.0];
//! let msg = TopK::new(2).compress(&u);
//! assert_eq!(msg.wire_bits(), 2 * (32 + 32));
//! assert_eq!(msg.to_dense(u.len()), vec![5.0, 0.0, 4.0, 0.0, 0.0]);
//! ```

pub mod identity;
pub mod lowrank;
pub mod quantize;
pub mod randk;
pub mod topk;

pub use identity::Identity;
pub use lowrank::LowRank;
pub use quantize::{OneBitSign, QuantizeBits};
pub use randk::RandK;
pub use topk::TopK;

/// The compressor panel identity, folded into content-addressed result
/// caches (`scenarios::cache`) via [`crate::driver::engine_fingerprint`]:
/// a coarse stamp for the set of compressor families a policy may
/// select from. Extend it when a new family lands (sketches, AdaComp,
/// DGC — see ROADMAP) so summaries cached before the panel grew are
/// treated as stale rather than silently reused.
pub const PANEL: &str = "identity,topk,randk,quantize,lowrank";

/// Bits for one f32 on the wire.
pub const F32_BITS: u64 = 32;
/// Bits for one coordinate index on the wire.
pub const IDX_BITS: u64 = 32;

/// A compressed update message, as it would travel on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Compressed {
    /// Selected coordinates (sparsification).
    Sparse { dim: usize, idx: Vec<u32>, val: Vec<f32> },
    /// Dense quantized payload, already dequantized for simulation
    /// (values carry the quantization error), with its true wire bits.
    Dense { val: Vec<f32>, bits_per_val: u64 },
    /// Rank-r factors of the matrix view (rows x cols) of the vector.
    Factors { rows: usize, cols: usize, u: Vec<f32>, v: Vec<f32> },
}

impl Default for Compressed {
    /// An empty sparse message — the natural seed for a reusable
    /// [`Compressor::compress_into`] buffer.
    fn default() -> Self {
        Compressed::Sparse { dim: 0, idx: Vec::new(), val: Vec::new() }
    }
}

/// Make `out` a `Sparse` message for dimension `dim`, reusing its index
/// and value buffers when the variant already matches (the hot path:
/// zero allocations once capacity is warm).
pub(crate) fn sparse_parts(out: &mut Compressed, dim: usize) -> (&mut Vec<u32>, &mut Vec<f32>) {
    if !matches!(out, Compressed::Sparse { .. }) {
        *out = Compressed::default();
    }
    match out {
        Compressed::Sparse { dim: d, idx, val } => {
            *d = dim;
            idx.clear();
            val.clear();
            (idx, val)
        }
        _ => unreachable!("sparse_parts just normalized the variant"),
    }
}

/// Make `out` a `Dense` message at `bits_per_val`, reusing its value
/// buffer when the variant already matches.
pub(crate) fn dense_parts(out: &mut Compressed, bits_per_val: u64) -> &mut Vec<f32> {
    if !matches!(out, Compressed::Dense { .. }) {
        *out = Compressed::Dense { val: Vec::new(), bits_per_val };
    }
    match out {
        Compressed::Dense { val, bits_per_val: b } => {
            *b = bits_per_val;
            val.clear();
            val
        }
        _ => unreachable!("dense_parts just normalized the variant"),
    }
}

impl Compressed {
    /// Exact payload size in bits.
    pub fn wire_bits(&self) -> u64 {
        match self {
            Compressed::Sparse { idx, val, .. } => {
                idx.len() as u64 * IDX_BITS + val.len() as u64 * F32_BITS
            }
            Compressed::Dense { val, bits_per_val } => {
                val.len() as u64 * bits_per_val + F32_BITS // + scale
            }
            Compressed::Factors { u, v, .. } => (u.len() + v.len()) as u64 * F32_BITS,
        }
    }

    /// Decompress into a dense vector of dimension `dim`.
    pub fn to_dense(&self, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        self.add_into(&mut out);
        out
    }

    /// Add the decompressed content into `out` (EF21's `x̂ += C(...)`).
    pub fn add_into(&self, out: &mut [f32]) {
        match self {
            Compressed::Sparse { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] += v;
                }
            }
            Compressed::Dense { val, .. } => {
                for (o, &v) in out.iter_mut().zip(val) {
                    *o += v;
                }
            }
            Compressed::Factors { rows, cols, u, v } => {
                // A ≈ u v^T laid out row-major into the flat vector.
                let r = u.len() / rows;
                for i in 0..*rows {
                    for j in 0..*cols {
                        let mut acc = 0.0f32;
                        for k in 0..r {
                            acc += u[i * r + k] * v[j * r + k];
                        }
                        let p = i * cols + j;
                        if p < out.len() {
                            out[p] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// A gradient compressor `C: R^d -> R^d` with wire-size accounting.
pub trait Compressor: Send + Sync {
    /// Compress `u`; the result decompresses to an approximation of `u`.
    fn compress(&self, u: &[f32]) -> Compressed;

    /// Compress `u` into a caller-owned message buffer, reusing its
    /// allocations when the variant matches. Semantically identical to
    /// [`compress`](Self::compress); the sparsifiers and quantizers
    /// override this to keep the round loop allocation-free
    /// (EXPERIMENTS.md §Perf, `benches/hotpath.rs`).
    fn compress_into(&self, u: &[f32], out: &mut Compressed) {
        *out = self.compress(u);
    }

    /// Contraction factor `alpha in (0, 1]` (1 = lossless) for dimension
    /// `d` — worst-case over inputs, as used by Theorem 1.
    fn alpha(&self, d: usize) -> f64;

    /// Wire bits this compressor produces for dimension `d`
    /// (before seeing data — used by budget planning).
    fn planned_bits(&self, d: usize) -> u64;

    /// Human-readable name for logs/CSV.
    fn name(&self) -> String;
}

/// Squared L2 compression error `||u - C(u)||^2` measured explicitly —
/// the oracle used by tests and the Fig. 9 error series.
pub fn compression_error(c: &dyn Compressor, u: &[f32]) -> f64 {
    let msg = c.compress(u);
    let dec = msg.to_dense(u.len());
    u.iter()
        .zip(&dec)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        // tidy:allow(float-reduce) -- serial fold in coordinate order, deterministic
        .sum()
}

/// Declarative compressor family `Omega`.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressorSpec {
    Identity,
    TopK { k: usize },
    RandK { k: usize, seed: u64 },
    QuantizeBits { bits: u64 },
    OneBit,
    LowRank { rows: usize, cols: usize, rank: usize },
}

impl CompressorSpec {
    pub fn build(&self) -> Box<dyn Compressor> {
        match *self {
            CompressorSpec::Identity => Box::new(Identity),
            CompressorSpec::TopK { k } => Box::new(TopK::new(k)),
            CompressorSpec::RandK { k, seed } => Box::new(RandK::new(k, seed)),
            CompressorSpec::QuantizeBits { bits } => Box::new(QuantizeBits::new(bits)),
            CompressorSpec::OneBit => Box::new(OneBitSign),
            CompressorSpec::LowRank { rows, cols, rank } => {
                Box::new(LowRank::new(rows, cols, rank))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_wire_bits() {
        let m = Compressed::Sparse { dim: 10, idx: vec![1, 3], val: vec![1.0, 2.0] };
        assert_eq!(m.wire_bits(), 2 * 32 + 2 * 32);
    }

    #[test]
    fn add_into_accumulates() {
        let m = Compressed::Sparse { dim: 4, idx: vec![0, 2], val: vec![1.0, -1.0] };
        let mut out = vec![1.0f32; 4];
        m.add_into(&mut out);
        assert_eq!(out, vec![2.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn compress_into_matches_compress_and_reuses_buffers() {
        // RandK is excluded: its internal round counter makes each call
        // a fresh sample by design (covered in randk.rs).
        let u: Vec<f32> = (0..64).map(|i| ((i * 31 % 17) as f32) - 8.0).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(9)),
            Box::new(QuantizeBits::new(6)),
            Box::new(OneBitSign),
            Box::new(Identity),
            Box::new(LowRank::new(8, 8, 2)),
        ];
        for c in &comps {
            let mut msg = Compressed::default();
            c.compress_into(&u, &mut msg);
            assert_eq!(msg, c.compress(&u), "{}", c.name());
            // Second call into the warm buffer: identical result.
            c.compress_into(&u, &mut msg);
            assert_eq!(msg, c.compress(&u), "{} (reused)", c.name());
        }
    }

    #[test]
    fn spec_builds_all() {
        let specs = [
            CompressorSpec::Identity,
            CompressorSpec::TopK { k: 3 },
            CompressorSpec::RandK { k: 3, seed: 1 },
            CompressorSpec::QuantizeBits { bits: 8 },
            CompressorSpec::OneBit,
            CompressorSpec::LowRank { rows: 4, cols: 4, rank: 1 },
        ];
        let u: Vec<f32> = (0..16).map(|i| i as f32 - 8.0).collect();
        for s in specs {
            let c = s.build();
            let err = compression_error(c.as_ref(), &u);
            let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum();
            // Contraction property: error <= (1 - alpha) ||u||^2 + eps.
            assert!(
                err <= (1.0 - c.alpha(u.len())) * norm + 1e-3,
                "{}: err={err} bound={}",
                c.name(),
                (1.0 - c.alpha(u.len())) * norm
            );
        }
    }
}
