//! RandK sparsification: keep K uniformly random coordinates.
//!
//! Unbiased when scaled by d/k; we ship the *unscaled* variant (as in
//! EF21-style contractive analysis) plus an optional scaling for the
//! unbiased-compressor baselines.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::Rng;

use super::{sparse_parts, Compressed, Compressor};

#[derive(Debug)]
pub struct RandK {
    pub k: usize,
    pub seed: u64,
    /// If true, scale kept values by d/k (unbiased estimator).
    pub scale: bool,
    round: AtomicU64,
}

impl RandK {
    pub fn new(k: usize, seed: u64) -> Self {
        Self { k, seed, scale: false, round: AtomicU64::new(0) }
    }

    pub fn unbiased(mut self) -> Self {
        self.scale = true;
        self
    }
}

impl Clone for RandK {
    fn clone(&self) -> Self {
        Self {
            k: self.k,
            seed: self.seed,
            scale: self.scale,
            round: AtomicU64::new(self.round.load(Ordering::Relaxed)),
        }
    }
}

impl Compressor for RandK {
    fn compress(&self, u: &[f32]) -> Compressed {
        let mut out = Compressed::default();
        self.compress_into(u, &mut out);
        out
    }

    fn compress_into(&self, u: &[f32], out: &mut Compressed) {
        let d = u.len();
        let k = self.k.min(d);
        // Fresh randomness each call, but deterministic per (seed, call#).
        let call = self.round.fetch_add(1, Ordering::Relaxed);
        let mut rng = Rng::seed_from_u64(self.seed).derive(call);
        let (idx, val) = sparse_parts(out, d);
        rng.sample_indices_into(d, k, idx);
        let factor = if self.scale && k > 0 { d as f32 / k as f32 } else { 1.0 };
        val.extend(idx.iter().map(|&i| u[i as usize] * factor));
    }

    fn alpha(&self, d: usize) -> f64 {
        if d == 0 {
            return 1.0;
        }
        // E||C(u)-u||^2 = (1 - k/d)||u||^2 for the unscaled variant.
        (self.k.min(d) as f64 / d as f64).clamp(0.0, 1.0)
    }

    fn planned_bits(&self, d: usize) -> u64 {
        (self.k.min(d) as u64) * (super::IDX_BITS + super::F32_BITS)
    }

    fn name(&self) -> String {
        format!("rand{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_exactly_k() {
        let u = vec![1.0f32; 100];
        if let Compressed::Sparse { idx, val, .. } = RandK::new(7, 1).compress(&u) {
            assert_eq!(idx.len(), 7);
            assert_eq!(val, vec![1.0f32; 7]);
            let mut s = idx.clone();
            s.sort();
            s.dedup();
            assert_eq!(s.len(), 7, "indices must be distinct");
        } else {
            panic!()
        }
    }

    #[test]
    fn different_calls_different_support() {
        let u = vec![1.0f32; 50];
        let c = RandK::new(5, 3);
        let a = c.compress(&u);
        let b = c.compress(&u);
        assert_ne!(a, b, "successive rounds should resample");
    }

    #[test]
    fn compress_into_matches_fresh_compress() {
        // Same seed, same call counter: the reuse path must replay the
        // exact sampling stream of the allocating path.
        let u: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let a = RandK::new(7, 5);
        let b = RandK::new(7, 5);
        let mut msg = Compressed::default();
        a.compress_into(&u, &mut msg);
        assert_eq!(msg, b.compress(&u));
        a.compress_into(&u, &mut msg);
        assert_eq!(msg, b.compress(&u));
    }

    #[test]
    fn unbiased_scales() {
        let u = vec![2.0f32; 10];
        if let Compressed::Sparse { val, .. } = RandK::new(5, 0).unbiased().compress(&u) {
            for v in val {
                assert!((v - 4.0).abs() < 1e-6);
            }
        } else {
            panic!()
        }
    }

    #[test]
    fn expected_contraction_statistically() {
        let mut rng = Rng::seed_from_u64(9);
        let d = 200;
        let u: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum();
        let c = RandK::new(50, 11);
        let trials = 200;
        let mean_err: f64 = (0..trials)
            .map(|_| crate::compress::compression_error(&c, &u))
            .sum::<f64>()
            / trials as f64;
        let expect = (1.0 - 0.25) * norm;
        assert!(
            (mean_err - expect).abs() / expect < 0.15,
            "mean_err={mean_err} expect={expect}"
        );
    }
}
