//! Quantization compressors (§2.2): b-bit uniform and 1-bit sign.

use super::{dense_parts, Compressed, Compressor};

/// Uniform symmetric quantization to `bits` per value with a per-message
/// max-abs scale; simulated by round-tripping values through the grid so
/// the decompressed vector carries the true quantization error.
#[derive(Debug, Clone, Copy)]
pub struct QuantizeBits {
    pub bits: u64,
}

impl QuantizeBits {
    pub fn new(bits: u64) -> Self {
        assert!((1..=32).contains(&bits), "bits must be in 1..=32");
        Self { bits }
    }

    fn levels(&self) -> f32 {
        // Symmetric signed grid: 2^(bits-1) - 1 positive steps.
        ((1u64 << (self.bits - 1)) - 1).max(1) as f32
    }
}

impl Compressor for QuantizeBits {
    fn compress(&self, u: &[f32]) -> Compressed {
        let mut out = Compressed::default();
        self.compress_into(u, &mut out);
        out
    }

    // tidy:alloc-free(quantize)
    fn compress_into(&self, u: &[f32], out: &mut Compressed) {
        let val = dense_parts(out, self.bits);
        // Chunked max-abs scan (f32 max is associative, so the result
        // is bit-identical to the serial fold — util::chunk docs).
        let scale = crate::util::chunk::max_abs(u);
        if scale == 0.0 || self.bits >= 32 {
            val.extend_from_slice(u);
        } else {
            let l = self.levels();
            val.extend(u.iter().map(|&v| (v / scale * l).round() / l * scale));
        }
    }

    fn alpha(&self, d: usize) -> f64 {
        // Worst case for max-abs-scaled uniform quantization: d-1
        // coordinates sit just below half a grid step s/(2L) (each is
        // rounded to zero, losing its full energy) while one coordinate
        // at s pins the scale and is exact. The error/energy ratio is
        // then d (s/2L)^2 / (d (s/2L)^2 + s^2) = d / (d + 4L^2), so
        //   alpha = 4 L^2 / (d + 4 L^2),
        // which -> 1 for generous bit widths and is appropriately tiny
        // for 1-2 bit grids.
        let l = self.levels() as f64;
        (4.0 * l * l) / (d as f64 + 4.0 * l * l)
    }

    fn planned_bits(&self, d: usize) -> u64 {
        d as u64 * self.bits + super::F32_BITS
    }

    fn name(&self) -> String {
        format!("q{}bit", self.bits)
    }
}

/// 1-bit SGD style sign compression with per-message mean-|u| magnitude
/// (Seide et al. 2014).
#[derive(Debug, Clone, Copy)]
pub struct OneBitSign;

impl Compressor for OneBitSign {
    fn compress(&self, u: &[f32]) -> Compressed {
        let mut out = Compressed::default();
        self.compress_into(u, &mut out);
        out
    }

    fn compress_into(&self, u: &[f32], out: &mut Compressed) {
        let val = dense_parts(out, 1);
        let d = u.len();
        let mag = if d == 0 {
            0.0
        } else {
            // tidy:allow(float-reduce) -- serial fold in coordinate order, deterministic
            u.iter().map(|v| v.abs()).sum::<f32>() / d as f32
        };
        val.extend(u.iter().map(|&v| mag * v.signum()));
    }

    fn alpha(&self, d: usize) -> f64 {
        // ||u||_1^2 / (d ||u||_2^2) >= 1/d; worst-case alpha = 1/d.
        if d == 0 {
            1.0
        } else {
            1.0 / d as f64
        }
    }

    fn planned_bits(&self, d: usize) -> u64 {
        d as u64 + super::F32_BITS
    }

    fn name(&self) -> String {
        "sign1bit".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::compression_error;

    #[test]
    fn full_precision_lossless() {
        let u = [0.3f32, -1.7, 2.4];
        let msg = QuantizeBits::new(32).compress(&u);
        assert_eq!(msg.to_dense(3), u.to_vec());
    }

    #[test]
    fn wire_bits_scale_with_bits() {
        let u = vec![1.0f32; 100];
        assert_eq!(QuantizeBits::new(8).compress(&u).wire_bits(), 100 * 8 + 32);
        assert_eq!(OneBitSign.compress(&u).wire_bits(), 100 + 32);
    }

    #[test]
    fn quant_error_decreases_with_bits() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(3);
        let u: Vec<f32> = (0..500).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let e4 = compression_error(&QuantizeBits::new(4), &u);
        let e8 = compression_error(&QuantizeBits::new(8), &u);
        let e16 = compression_error(&QuantizeBits::new(16), &u);
        assert!(e4 > e8 && e8 > e16);
    }

    #[test]
    fn zero_vector_exact() {
        let u = vec![0.0f32; 16];
        assert_eq!(compression_error(&QuantizeBits::new(4), &u), 0.0);
        assert_eq!(compression_error(&OneBitSign, &u), 0.0);
    }

    #[test]
    fn sign_preserves_signs() {
        let u = [3.0f32, -1.0, 0.5];
        let d = OneBitSign.compress(&u).to_dense(3);
        assert!(d[0] > 0.0 && d[1] < 0.0 && d[2] > 0.0);
        assert!((d[0].abs() - 1.5).abs() < 1e-6); // mean |u| = 1.5
    }
}
