//! TopK sparsification — the paper's default compressor (§4: "We use
//! TopK with fixed K as the default compression method").
//!
//! Selection is O(d) via `select_nth_unstable` on |u| (a full sort would
//! be O(d log d) and dominates the coordinator hot path at d ~ 10^7 —
//! see EXPERIMENTS.md §Perf).

use std::cell::RefCell;

use super::{sparse_parts, Compressed, Compressor};

thread_local! {
    /// Packed-key scratch for the quickselect: one warm buffer per
    /// thread keeps [`TopK::select_indices_into`] allocation-free on
    /// the round loop's hot path (and safe under the parallel worker
    /// phase — each worker thread owns its own copy).
    static PACKED: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// Keep the K coordinates of largest absolute value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        Self { k }
    }

    /// Indices of the `k` largest |u| entries (unordered), O(d).
    pub fn select_indices(u: &[f32], k: usize) -> Vec<u32> {
        let mut out = Vec::new();
        Self::select_indices_into(u, k, &mut out);
        out
    }

    /// [`select_indices`](Self::select_indices) into a reused buffer.
    ///
    /// Keys are packed as `(abs_bits << 32) | index` u64s so the
    /// quickselect compares plain integers instead of chasing f32s
    /// through an index indirection: |f32| bit patterns order exactly
    /// like their values for finite floats (sign bit cleared), and NaN
    /// payloads sort above everything, matching total_cmp. ~2-3x
    /// faster at d = 10^7 (EXPERIMENTS.md §Perf).
    pub fn select_indices_into(u: &[f32], k: usize, out: &mut Vec<u32>) {
        PACKED.with(|cell| Self::select_indices_with(u, k, out, &mut cell.borrow_mut()));
    }

    /// [`select_indices_into`](Self::select_indices_into) with the
    /// packed-key scratch passed explicitly — for callers that carry
    /// their own per-instance scratch (e.g. the selector's
    /// `SelectScratch`, reused across rounds) instead of the
    /// thread-local above. The thread-local path delegates here, so
    /// both forms share one implementation.
    // tidy:alloc-free(topk_select)
    pub fn select_indices_with(u: &[f32], k: usize, out: &mut Vec<u32>, packed: &mut Vec<u64>) {
        out.clear();
        let d = u.len();
        let k = k.min(d);
        if k == 0 {
            return;
        }
        if k == d {
            out.extend(0..d as u32);
            return;
        }
        packed.clear();
        packed.extend(u.iter().enumerate().map(|(i, &v)| {
            let abs_bits = (v.to_bits() & 0x7FFF_FFFF) as u64;
            (abs_bits << 32) | i as u64
        }));
        // k-th largest == (d-k)-th smallest.
        packed.select_nth_unstable(d - k);
        out.extend(packed[d - k..].iter().map(|&p| p as u32));
    }
}

impl Compressor for TopK {
    fn compress(&self, u: &[f32]) -> Compressed {
        let mut out = Compressed::default();
        self.compress_into(u, &mut out);
        out
    }

    fn compress_into(&self, u: &[f32], out: &mut Compressed) {
        let (idx, val) = sparse_parts(out, u.len());
        Self::select_indices_into(u, self.k, idx);
        val.extend(idx.iter().map(|&i| u[i as usize]));
    }

    fn alpha(&self, d: usize) -> f64 {
        if d == 0 {
            return 1.0;
        }
        (self.k.min(d) as f64 / d as f64).clamp(0.0, 1.0)
    }

    fn planned_bits(&self, d: usize) -> u64 {
        (self.k.min(d) as u64) * (super::IDX_BITS + super::F32_BITS)
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::compression_error;

    #[test]
    fn selects_largest_magnitudes() {
        let u = [0.1, -5.0, 3.0, 0.0, -0.2];
        let msg = TopK::new(2).compress(&u);
        if let Compressed::Sparse { mut idx, .. } = msg {
            idx.sort();
            assert_eq!(idx, vec![1, 2]);
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn k_zero_and_k_full() {
        let u = [1.0f32, 2.0, 3.0];
        assert_eq!(TopK::new(0).compress(&u).wire_bits(), 0);
        let full = TopK::new(3).compress(&u).to_dense(3);
        assert_eq!(full, u.to_vec());
        let over = TopK::new(10).compress(&u).to_dense(3);
        assert_eq!(over, u.to_vec());
    }

    #[test]
    fn error_equals_dropped_tail() {
        let u = [4.0f32, -3.0, 2.0, 1.0];
        let err = compression_error(&TopK::new(2), &u);
        assert!((err - (4.0 + 1.0)).abs() < 1e-6); // 2^2 + 1^2
    }

    #[test]
    fn alpha_is_k_over_d() {
        assert!((TopK::new(25).alpha(100) - 0.25).abs() < 1e-12);
        assert_eq!(TopK::new(200).alpha(100), 1.0);
    }

    #[test]
    fn explicit_scratch_matches_thread_local() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(19);
        let mut packed = Vec::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..20 {
            let d = rng.range_usize(1, 300);
            let k = rng.range_usize(0, d + 1);
            let u: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            TopK::select_indices_into(&u, k, &mut a);
            TopK::select_indices_with(&u, k, &mut b, &mut packed);
            assert_eq!(a, b, "d={d} k={k}");
        }
    }

    #[test]
    fn contraction_property_random() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(7);
        for _ in 0..20 {
            let d = rng.range_usize(1, 300);
            let k = rng.range_usize(0, d + 1);
            let u: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let c = TopK::new(k);
            let err = compression_error(&c, &u);
            let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum();
            assert!(err <= (1.0 - c.alpha(d)) * norm + 1e-6);
        }
    }
}
