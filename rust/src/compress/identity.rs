//! Identity (no compression) — the GD/no-compression baseline and the
//! compressor Kimad falls back to when the budget exceeds the model.

use super::{dense_parts, Compressed, Compressor};

#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn compress(&self, u: &[f32]) -> Compressed {
        Compressed::Dense { val: u.to_vec(), bits_per_val: super::F32_BITS }
    }

    fn compress_into(&self, u: &[f32], out: &mut Compressed) {
        dense_parts(out, super::F32_BITS).extend_from_slice(u);
    }

    fn alpha(&self, _d: usize) -> f64 {
        1.0
    }

    fn planned_bits(&self, d: usize) -> u64 {
        d as u64 * super::F32_BITS + super::F32_BITS
    }

    fn name(&self) -> String {
        "identity".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::compression_error;

    #[test]
    fn lossless() {
        let u = [1.5f32, -2.0, 0.0];
        assert_eq!(compression_error(&Identity, &u), 0.0);
        assert_eq!(Identity.alpha(3), 1.0);
    }
}
