//! Low-rank decomposition compressor (PowerSGD-style, §2.2):
//! `A ≈ U V^T` with U: rows x r, V: cols x r via subspace iteration.

use super::{Compressed, Compressor};

#[derive(Debug, Clone, Copy)]
pub struct LowRank {
    pub rows: usize,
    pub cols: usize,
    pub rank: usize,
    pub iters: usize,
}

impl LowRank {
    pub fn new(rows: usize, cols: usize, rank: usize) -> Self {
        assert!(rows > 0 && cols > 0 && rank > 0);
        Self { rows, cols, rank: rank.min(rows.min(cols)), iters: 2 }
    }
}

/// a (m x k, row-major)^T * b (m x n) -> k x n
fn at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            for j in 0..n {
                out[p * n + j] += aip * b[i * n + j];
            }
        }
    }
    out
}

/// a (m x k) * b (k x n) -> m x n
fn a_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += aip * b[p * n + j];
            }
        }
    }
    out
}

/// Gram-Schmidt orthonormalize columns of a (m x k, row-major), in place.
fn orthonormalize(a: &mut [f32], m: usize, k: usize) {
    for j in 0..k {
        for p in 0..j {
            let mut dot = 0.0f32;
            for i in 0..m {
                dot += a[i * k + j] * a[i * k + p];
            }
            for i in 0..m {
                a[i * k + j] -= dot * a[i * k + p];
            }
        }
        let mut norm = 0.0f32;
        for i in 0..m {
            norm += a[i * k + j] * a[i * k + j];
        }
        let norm = norm.sqrt();
        if norm > 1e-12 {
            for i in 0..m {
                a[i * k + j] /= norm;
            }
        }
    }
}

impl Compressor for LowRank {
    fn compress(&self, u: &[f32]) -> Compressed {
        let (m, n, r) = (self.rows, self.cols, self.rank);
        // Pad/truncate the flat vector into the matrix view.
        let mut a = vec![0.0f32; m * n];
        let take = u.len().min(m * n);
        a[..take].copy_from_slice(&u[..take]);

        // Deterministic init for V (m*n can be big; pseudo-random but
        // reproducible without carrying a RNG).
        let mut v: Vec<f32> = (0..n * r)
            .map(|i| {
                let h = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 33) as f32 / (1u64 << 31) as f32) - 0.5
            })
            .collect();
        orthonormalize(&mut v, n, r);

        let mut uu = vec![0.0f32; m * r];
        for _ in 0..self.iters {
            // U = A V ; orthonormalize; V = A^T U
            uu = a_b(&a, &v_t_to_colmajor(&v, n, r), m, n, r);
            orthonormalize(&mut uu, m, r);
            let vt = at_b(&uu, &a, m, r, n); // r x n
            v = colmajor_to_v(&vt, r, n);
        }
        Compressed::Factors { rows: m, cols: n, u: uu, v }
    }

    fn alpha(&self, _d: usize) -> f64 {
        // Rank-r truncation keeps at least the top-r singular mass; the
        // worst case over matrices keeps r/min(m,n) of the energy.
        (self.rank as f64 / self.rows.min(self.cols) as f64).clamp(0.0, 1.0)
    }

    fn planned_bits(&self, _d: usize) -> u64 {
        ((self.rows + self.cols) * self.rank) as u64 * super::F32_BITS
    }

    fn name(&self) -> String {
        format!("lowrank{}", self.rank)
    }
}

/// v is stored rows=cols(nxr, row-major) as in Compressed::Factors where
/// decompression reads v[j*r + k]. Convert to (n x r row-major) -> the
/// k x n multiplication layout.
fn v_t_to_colmajor(v: &[f32], n: usize, r: usize) -> Vec<f32> {
    // produce (n*r) laid out as n rows of r -> we need (n x r) as B in
    // a_b(A: m x n, B: n x r): B[p*n? ] — a_b expects b as k x n with
    // k=n, n=r: b[p * r + j] = v[p * r + j]; identical layout.
    let _ = n;
    let _ = r;
    v.to_vec()
}

fn colmajor_to_v(vt: &[f32], r: usize, n: usize) -> Vec<f32> {
    // vt is r x n row-major; Factors::v wants v[j*r + k].
    let mut v = vec![0.0f32; n * r];
    for k in 0..r {
        for j in 0..n {
            v[j * r + k] = vt[k * n + j];
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::compression_error;

    #[test]
    fn rank1_exact_on_rank1_matrix() {
        // A = x y^T is exactly rank 1.
        let x = [1.0f32, 2.0, -1.0];
        let y = [0.5f32, 1.5];
        let mut a = vec![0.0f32; 6];
        for i in 0..3 {
            for j in 0..2 {
                a[i * 2 + j] = x[i] * y[j];
            }
        }
        let c = LowRank::new(3, 2, 1);
        let err = compression_error(&c, &a);
        let norm: f64 = a.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(err / norm < 1e-6, "err={err} norm={norm}");
    }

    #[test]
    fn full_rank_near_lossless() {
        let a: Vec<f32> = (0..16).map(|i| (i * 7 % 5) as f32 - 2.0).collect();
        let mut c = LowRank::new(4, 4, 4);
        c.iters = 10;
        let err = compression_error(&c, &a);
        let norm: f64 = a.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(err / norm < 1e-3, "err={err} norm={norm}");
    }

    #[test]
    fn wire_bits_formula() {
        let c = LowRank::new(100, 50, 4);
        assert_eq!(c.planned_bits(5000), (150 * 4) as u64 * 32);
        let u = vec![1.0f32; 5000];
        assert_eq!(c.compress(&u).wire_bits(), c.planned_bits(5000));
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(5);
        let a: Vec<f32> = (0..400).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let e1 = compression_error(&LowRank::new(20, 20, 1), &a);
        let e4 = compression_error(&LowRank::new(20, 20, 4), &a);
        let e16 = compression_error(&LowRank::new(20, 20, 16), &a);
        assert!(e1 > e4 && e4 > e16, "{e1} {e4} {e16}");
    }
}
