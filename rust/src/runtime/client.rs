//! Thin, typed wrappers over the PJRT client ([`super::backend`]).
//!
//! In an offline build the backend is a stub whose constructors error;
//! callers that can skip (tests, benches, the deep-model reports) check
//! [`Runtime::available`]/artifact presence first, and everything else
//! surfaces the backend's descriptive error through `anyhow`.

use std::path::Path;

use crate::model::ModelLayout;

use super::backend as xla;

/// One PJRT client per process (CPU plugin).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Self { client })
    }

    /// Whether this build carries a real PJRT backend at all.
    pub fn available() -> bool {
        xla::AVAILABLE
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled executable returning a single tuple (return_tuple=True).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; unwrap the tuple output.
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).map_err(wrap)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap)?;
        lit.to_tuple().map_err(wrap)
    }
}

/// Build a rank-N f32 literal from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(numel == data.len(), "literal shape/product mismatch");
    let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(wrap)
}

/// Build a rank-1 i32 literal.
pub fn literal_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Marshal a flat parameter vector into per-slot literals in wire order.
pub fn params_to_literals(flat: &[f32], layout: &ModelLayout) -> anyhow::Result<Vec<xla::Literal>> {
    anyhow::ensure!(flat.len() == layout.n_params, "flat params dim mismatch");
    layout
        .params
        .iter()
        .map(|p| literal_f32(&flat[p.offset..p.offset + p.size], &p.shape))
        .collect()
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_checked() {
        // The shape/product check fires before the backend is touched,
        // so it holds in stub and real builds alike.
        assert!(literal_f32(&[1.0; 3], &[2, 2]).is_err());
    }

    #[test]
    fn params_dim_checked() {
        let layout = ModelLayout::synthetic(&[2, 3]);
        let err = params_to_literals(&[1.0f32; 4], &layout).unwrap_err();
        assert!(err.to_string().contains("dim mismatch"));
    }

    #[test]
    fn stub_build_fails_gracefully() {
        if Runtime::available() {
            return; // real backend: nothing to assert here
        }
        let err = Runtime::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT backend"), "{err}");
    }

    #[test]
    fn literal_roundtrip_when_available() {
        if !Runtime::available() {
            return;
        }
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }
}
