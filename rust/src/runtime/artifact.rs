//! Artifact store: the manifest written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::ModelLayout;
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub train_hlo: String,
    pub eval_hlo: String,
    pub layout: String,
    pub n_params: usize,
    pub params: Option<String>,
}

#[derive(Debug, Clone)]
pub struct KernelArtifact {
    pub hlo: String,
    pub d: usize,
}

#[derive(Debug, Clone)]
struct Manifest {
    seed: u64,
    models: BTreeMap<String, ModelArtifact>,
    kernels: BTreeMap<String, KernelArtifact>,
}

impl Manifest {
    fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models")?.as_obj()? {
            models.insert(
                name.clone(),
                ModelArtifact {
                    train_hlo: m.get("train_hlo")?.as_str()?.to_string(),
                    eval_hlo: m.get("eval_hlo")?.as_str()?.to_string(),
                    layout: m.get("layout")?.as_str()?.to_string(),
                    n_params: m.get("n_params")?.as_usize()?,
                    params: m
                        .opt("params")
                        .and_then(|p| p.as_str().ok())
                        .map(|s| s.to_string()),
                },
            );
        }
        let mut kernels = BTreeMap::new();
        for (name, k) in v.get("kernels")?.as_obj()? {
            kernels.insert(
                name.clone(),
                KernelArtifact {
                    hlo: k.get("hlo")?.as_str()?.to_string(),
                    d: k.get("d")?.as_usize()?,
                },
            );
        }
        Ok(Self { seed: v.get("seed")?.as_u64()?, models, kernels })
    }
}

/// The artifacts/ directory, parsed.
#[derive(Debug)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    manifest: Manifest,
    /// Initial-params cache (preset → shared flat vector): a store
    /// shared across warm families (`Arc<ArtifactStore>`) reads each
    /// `params-<preset>.bin` from disk once, however many families
    /// hold it.
    params_cache: std::sync::Mutex<BTreeMap<String, std::sync::Arc<Vec<f32>>>>,
}

impl ArtifactStore {
    pub fn open(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let dir = dir.into();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {} failed ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        let manifest = Manifest::from_json(&Value::parse(&text)?)?;
        Ok(Self { dir, manifest, params_cache: Default::default() })
    }

    /// Default location: ./artifacts or $KIMAD_ARTIFACTS.
    pub fn open_default() -> anyhow::Result<Self> {
        let dir = std::env::var("KIMAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn seed(&self) -> u64 {
        self.manifest.seed
    }

    pub fn model(&self, preset: &str) -> anyhow::Result<&ModelArtifact> {
        self.manifest
            .models
            .get(preset)
            .ok_or_else(|| anyhow::anyhow!("preset '{preset}' not in manifest"))
    }

    pub fn model_presets(&self) -> Vec<&str> {
        self.manifest.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn kernel(&self, name: &str) -> anyhow::Result<&KernelArtifact> {
        self.manifest
            .kernels
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("kernel '{name}' not in manifest"))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    pub fn layout(&self, preset: &str) -> anyhow::Result<ModelLayout> {
        let m = self.model(preset)?;
        ModelLayout::from_json_file(&self.path(&m.layout))
    }

    /// The seeded initial parameters (f32 LE), when exported.
    pub fn initial_params(&self, preset: &str) -> anyhow::Result<Vec<f32>> {
        Ok((*self.initial_params_shared(preset)?).clone())
    }

    /// Whether the preset's exported train HLO is real lowered text —
    /// as opposed to the `gen-artifacts` placeholder, which only the
    /// native backend can execute. The driver keys its PJRT-vs-native
    /// backend choice on this, so a native-generated artifact set
    /// keeps working on a build that carries the real PJRT bindings.
    /// A missing/unreadable HLO file is an **error** (the manifest
    /// lists it, so the set is broken), never a silent backend switch.
    /// Only a fixed-size prefix is read — real HLO modules run to MB.
    pub fn has_real_hlo(&self, preset: &str) -> anyhow::Result<bool> {
        use std::io::Read;
        let m = self.model(preset)?;
        let path = self.path(&m.train_hlo);
        let file = std::fs::File::open(&path).map_err(|e| {
            anyhow::anyhow!("reading {} (broken artifact set?): {e}", path.display())
        })?;
        let mut head = Vec::new();
        file.take(64).read_to_end(&mut head)?;
        Ok(!head.starts_with(NATIVE_HLO_PLACEHOLDER.as_bytes()))
    }

    /// [`Self::initial_params`] behind a shared handle, read from disk
    /// once per store — what `driver::WarmDeep` holds so several warm
    /// families over one preset keep one resident copy.
    pub fn initial_params_shared(
        &self,
        preset: &str,
    ) -> anyhow::Result<std::sync::Arc<Vec<f32>>> {
        let mut cache = self.params_cache.lock().expect("params cache poisoned");
        if let Some(p) = cache.get(preset) {
            return Ok(p.clone());
        }
        let m = self.model(preset)?;
        let rel = m
            .params
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("preset '{preset}' has no params.bin"))?;
        let params = std::sync::Arc::new(read_f32_le(&self.path(rel))?);
        cache.insert(preset.to_string(), params.clone());
        Ok(params)
    }
}

/// First line of the placeholder HLO files `write_native_artifacts`
/// emits — the marker [`ArtifactStore::has_real_hlo`] keys on.
pub const NATIVE_HLO_PLACEHOLDER: &str = "// native artifact set";

/// Write a **native** artifact set — layout + seeded initial params +
/// manifest — for the given transformer presets, without JAX: the rust
/// mirror of `python/compile/aot.py` minus the HLO lowering. The HLO
/// entries point at placeholder text files (the native backend never
/// reads them). Regenerating cannot clobber a full `make artifacts`
/// set: an existing manifest is *merged into* (other presets and the
/// Pallas kernel entries survive), a preset whose HLO is real lowered
/// text is **refused** outright (its JAX-drawn params/layout stay
/// authoritative — pick a fresh `--out-dir`), and a seed mismatch
/// against an existing manifest is an error (params and dataset must
/// agree on one seed). This is what `kimad gen-artifacts` runs, and
/// what lets CI smoke the deep-model scenario grid offline.
pub fn write_native_artifacts(
    dir: &Path,
    presets: &[String],
    seed: u64,
) -> anyhow::Result<ArtifactStore> {
    use crate::model::NativeConfig;
    std::fs::create_dir_all(dir)?;
    // Merge with an existing manifest instead of clobbering it.
    let manifest_path = dir.join("manifest.json");
    let mut manifest = match std::fs::read_to_string(&manifest_path) {
        Ok(text) => {
            let v = Value::parse(&text)?;
            let existing = v.get("seed")?.as_u64()?;
            anyhow::ensure!(
                existing == seed,
                "artifacts at {} were built with seed {existing}, not {seed}; pick a \
                 fresh --out-dir or pass the matching --seed",
                dir.display()
            );
            v
        }
        Err(_) => Value::obj(vec![
            ("seed", Value::num(seed as f64)),
            ("models", Value::Obj(Default::default())),
            ("kernels", Value::Obj(Default::default())),
        ]),
    };
    for preset in presets {
        let cfg = NativeConfig::preset(preset)?;
        let layout = cfg.layout_named(preset);
        // A preset `make artifacts` exported for real (lowered HLO on
        // disk) keeps its JAX-drawn params/layout: silently replacing
        // params-<preset>.bin with native draws would change every
        // subsequent PJRT run's starting point.
        let train_hlo = dir.join(format!("model-{preset}.hlo.txt"));
        if let Ok(existing) = std::fs::read_to_string(&train_hlo) {
            anyhow::ensure!(
                existing.starts_with(NATIVE_HLO_PLACEHOLDER),
                "preset '{preset}' in {} carries real lowered HLO (from `make artifacts`); \
                 refusing to overwrite its params/layout — use a fresh --out-dir",
                dir.display()
            );
        }
        std::fs::write(dir.join(format!("layout-{preset}.json")), layout.to_json().to_string())?;
        let params = cfg.init_params(seed);
        let bytes: Vec<u8> = params.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join(format!("params-{preset}.bin")), bytes)?;
        let placeholder = format!(
            "{NATIVE_HLO_PLACEHOLDER} (kimad gen-artifacts): no HLO exported for '{preset}'.\n\
             // Run `make artifacts` (python -m compile.aot) to lower the real modules.\n"
        );
        std::fs::write(&train_hlo, &placeholder)?;
        std::fs::write(dir.join(format!("eval-{preset}.hlo.txt")), &placeholder)?;
        let entry = Value::obj(vec![
            ("train_hlo", Value::str(format!("model-{preset}.hlo.txt"))),
            ("eval_hlo", Value::str(format!("eval-{preset}.hlo.txt"))),
            ("layout", Value::str(format!("layout-{preset}.json"))),
            ("n_params", Value::num(layout.n_params as f64)),
            ("params", Value::str(format!("params-{preset}.bin"))),
        ]);
        let Value::Obj(fields) = &mut manifest else {
            anyhow::bail!("manifest is not an object");
        };
        match fields.get_mut("models") {
            Some(Value::Obj(models)) => models.insert(preset.clone(), entry),
            _ => anyhow::bail!("manifest 'models' is not an object"),
        };
    }
    std::fs::write(&manifest_path, manifest.to_string())?;
    ArtifactStore::open(dir)
}

/// Read a little-endian f32 binary file.
pub fn read_f32_le(path: &Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "file size not a multiple of 4");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("kimad-artifact-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let dir = tmpdir("parse");
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"seed": 21, "models": {"tiny": {"train_hlo": "a", "eval_hlo": "b",
                "layout": "c", "n_params": 10, "params": "d"}},
               "kernels": {"k": {"hlo": "e", "d": 4096}}}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.seed(), 21);
        assert_eq!(store.model("tiny").unwrap().n_params, 10);
        assert_eq!(store.kernel("k").unwrap().d, 4096);
        assert!(store.model("nope").is_err());
        assert_eq!(store.model_presets(), vec!["tiny"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_hints_make() {
        let dir = tmpdir("missing");
        let err = ArtifactStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn native_artifacts_roundtrip_through_the_store() {
        let dir = tmpdir("native");
        let store =
            write_native_artifacts(&dir, &["tiny".to_string(), "small".to_string()], 21).unwrap();
        assert_eq!(store.seed(), 21);
        assert_eq!(store.model_presets(), vec!["small", "tiny"]);
        let layout = store.layout("tiny").unwrap();
        layout.validate().unwrap();
        let cfg = crate::model::NativeConfig::preset("tiny").unwrap();
        assert_eq!(layout.n_params, cfg.n_params());
        // The params round-trip bit-for-bit through the f32-LE file,
        // and the shared handle is cached (one disk read per store).
        assert_eq!(store.initial_params("tiny").unwrap(), cfg.init_params(21));
        let a = store.initial_params_shared("tiny").unwrap();
        let b = store.initial_params_shared("tiny").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        // And the layout is exactly the canonical transformer table, so
        // the native source accepts it.
        crate::model::NativeConfig::from_layout(&layout).unwrap();
        // Unknown presets still fail loudly.
        assert!(write_native_artifacts(&dir, &["nope".to_string()], 21).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn native_artifacts_merge_into_existing_sets_without_clobbering() {
        let dir = tmpdir("merge");
        // Simulate a full `make artifacts` set: a manifest carrying
        // another preset and a Pallas kernel, plus real lowered HLO
        // for the 'small' preset.
        std::fs::write(dir.join("model-small.hlo.txt"), "HloModule real").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"seed": 21, "models": {"small": {"train_hlo": "model-small.hlo.txt",
                "eval_hlo": "b", "layout": "c", "n_params": 10}},
               "kernels": {"k": {"hlo": "e", "d": 4096}}}"#,
        )
        .unwrap();
        let store = write_native_artifacts(&dir, &["tiny".to_string()], 21).unwrap();
        // The JAX preset and the kernel entries survive the merge.
        assert_eq!(store.model_presets(), vec!["small", "tiny"]);
        assert!(store.kernel("k").is_ok());
        // The backend chooser can tell the two presets apart.
        assert!(store.has_real_hlo("small").unwrap());
        assert!(!store.has_real_hlo("tiny").unwrap());
        // Regenerating a native preset is fine; a JAX-exported preset
        // is refused (its params/layout stay authoritative).
        write_native_artifacts(&dir, &["tiny".to_string()], 21).unwrap();
        let err = write_native_artifacts(&dir, &["small".to_string()], 21).unwrap_err();
        assert!(err.to_string().contains("real lowered HLO"), "{err}");
        assert_eq!(
            std::fs::read_to_string(dir.join("model-small.hlo.txt")).unwrap(),
            "HloModule real"
        );
        // A seed mismatch against the existing set is refused.
        let err = write_native_artifacts(&dir, &["tiny".to_string()], 22).unwrap_err();
        assert!(err.to_string().contains("seed"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn f32_le_roundtrip() {
        let dir = tmpdir("f32");
        let p = dir.join("x.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_le(&p).unwrap(), vals.to_vec());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
