//! Artifact store: the manifest written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::ModelLayout;
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub train_hlo: String,
    pub eval_hlo: String,
    pub layout: String,
    pub n_params: usize,
    pub params: Option<String>,
}

#[derive(Debug, Clone)]
pub struct KernelArtifact {
    pub hlo: String,
    pub d: usize,
}

#[derive(Debug, Clone)]
struct Manifest {
    seed: u64,
    models: BTreeMap<String, ModelArtifact>,
    kernels: BTreeMap<String, KernelArtifact>,
}

impl Manifest {
    fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models")?.as_obj()? {
            models.insert(
                name.clone(),
                ModelArtifact {
                    train_hlo: m.get("train_hlo")?.as_str()?.to_string(),
                    eval_hlo: m.get("eval_hlo")?.as_str()?.to_string(),
                    layout: m.get("layout")?.as_str()?.to_string(),
                    n_params: m.get("n_params")?.as_usize()?,
                    params: m
                        .opt("params")
                        .and_then(|p| p.as_str().ok())
                        .map(|s| s.to_string()),
                },
            );
        }
        let mut kernels = BTreeMap::new();
        for (name, k) in v.get("kernels")?.as_obj()? {
            kernels.insert(
                name.clone(),
                KernelArtifact {
                    hlo: k.get("hlo")?.as_str()?.to_string(),
                    d: k.get("d")?.as_usize()?,
                },
            );
        }
        Ok(Self { seed: v.get("seed")?.as_u64()?, models, kernels })
    }
}

/// The artifacts/ directory, parsed.
#[derive(Debug)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    manifest: Manifest,
}

impl ArtifactStore {
    pub fn open(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let dir = dir.into();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {} failed ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        let manifest = Manifest::from_json(&Value::parse(&text)?)?;
        Ok(Self { dir, manifest })
    }

    /// Default location: ./artifacts or $KIMAD_ARTIFACTS.
    pub fn open_default() -> anyhow::Result<Self> {
        let dir = std::env::var("KIMAD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn seed(&self) -> u64 {
        self.manifest.seed
    }

    pub fn model(&self, preset: &str) -> anyhow::Result<&ModelArtifact> {
        self.manifest
            .models
            .get(preset)
            .ok_or_else(|| anyhow::anyhow!("preset '{preset}' not in manifest"))
    }

    pub fn model_presets(&self) -> Vec<&str> {
        self.manifest.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn kernel(&self, name: &str) -> anyhow::Result<&KernelArtifact> {
        self.manifest
            .kernels
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("kernel '{name}' not in manifest"))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    pub fn layout(&self, preset: &str) -> anyhow::Result<ModelLayout> {
        let m = self.model(preset)?;
        ModelLayout::from_json_file(&self.path(&m.layout))
    }

    /// The seeded initial parameters (f32 LE), when exported.
    pub fn initial_params(&self, preset: &str) -> anyhow::Result<Vec<f32>> {
        let m = self.model(preset)?;
        let rel = m
            .params
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("preset '{preset}' has no params.bin"))?;
        read_f32_le(&self.path(rel))
    }
}

/// Read a little-endian f32 binary file.
pub fn read_f32_le(path: &Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "file size not a multiple of 4");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("kimad-artifact-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let dir = tmpdir("parse");
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"seed": 21, "models": {"tiny": {"train_hlo": "a", "eval_hlo": "b",
                "layout": "c", "n_params": 10, "params": "d"}},
               "kernels": {"k": {"hlo": "e", "d": 4096}}}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.seed(), 21);
        assert_eq!(store.model("tiny").unwrap().n_params, 10);
        assert_eq!(store.kernel("k").unwrap().d, 4096);
        assert!(store.model("nope").is_err());
        assert_eq!(store.model_presets(), vec!["tiny"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_hints_make() {
        let dir = tmpdir("missing");
        let err = ArtifactStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn f32_le_roundtrip() {
        let dir = tmpdir("f32");
        let p = dir.join("x.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_le(&p).unwrap(), vals.to_vec());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
