//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! L3 hot path — rust-only at runtime, Python only at build time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).
//!
//!   artifact.rs   — manifest/layout/params loading
//!   client.rs     — PJRT client + executable wrappers
//!   model_exec.rs — the deep-model GradientSource over the runtime

pub mod artifact;
pub mod client;
pub mod model_exec;

pub use artifact::{ArtifactStore, KernelArtifact, ModelArtifact};
pub use client::{Executable, Runtime};
pub use model_exec::{EvalMetrics, PjrtModelSource};
