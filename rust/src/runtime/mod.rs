//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! L3 hot path — rust-only at runtime, Python only at build time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).
//!
//!   artifact.rs   — manifest/layout/params loading
//!   backend.rs    — PJRT bindings (stubbed in offline builds)
//!   client.rs     — PJRT client + executable wrappers
//!   model_exec.rs — the deep-model GradientSource over the runtime

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the real xla PJRT bindings vendored under \
     vendor/xla and wired into runtime::backend; this offline build \
     ships only the stub"
);

pub mod artifact;
pub mod backend;
pub mod client;
pub mod model_exec;

pub use artifact::{write_native_artifacts, ArtifactStore, KernelArtifact, ModelArtifact};
pub use client::{Executable, Runtime};
pub use model_exec::{EvalMetrics, PjrtModelSource};
