//! Build-gated PJRT backend.
//!
//! The real runtime binds the `xla` PJRT crate (CPU plugin) to execute
//! the AOT-lowered HLO artifacts. Those bindings cannot be fetched in
//! the offline build, so this module provides an API-identical stub:
//! every entry point that would touch PJRT returns a descriptive error,
//! and [`AVAILABLE`] lets tests and benches skip gracefully. The rest
//! of the crate (`runtime::client`, `runtime::model_exec`) compiles
//! unchanged against either implementation.

use std::path::Path;

/// Whether a real PJRT plugin backs this build.
pub const AVAILABLE: bool = false;

fn unavailable() -> Error {
    Error(
        "PJRT backend not compiled in (offline build); vendor the xla \
         bindings and enable the `pjrt` feature"
            .into(),
    )
}

/// Backend error (mirrors `xla::Error` as used by `runtime::client`).
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (dense array) handle.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// One PJRT client per process.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation ready to compile.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!AVAILABLE);
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend not compiled in"));
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo")).is_err());
        assert_eq!(Literal::vec1(&[1.0f32]).element_count(), 0);
    }
}
