//! The deep-model gradient source: `train_step`/`eval_step` HLO
//! executables driven from the coordinator (Python never runs here).

use std::sync::Arc;

use crate::coordinator::GradientSource;
use crate::data::SyntheticDataset;
use crate::model::ModelLayout;

use super::artifact::ArtifactStore;
use super::client::{literal_f32, literal_i32, params_to_literals, Executable, Runtime};

/// Evaluation metrics over a held-out set (Table 2's Top-5 accuracy).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalMetrics {
    pub loss: f64,
    pub top1: f64,
    pub top5: f64,
    pub n: usize,
}

/// GradientSource backed by the AOT-compiled JAX model.
///
/// The executables are held by shared handle: compiling HLO is the
/// most expensive setup step, so a warm cell family compiles the two
/// modules once ([`Self::compile`]) and builds one source per member
/// cell from the shared handles ([`Self::from_parts`]).
pub struct PjrtModelSource {
    pub layout: ModelLayout,
    pub dataset: SyntheticDataset,
    train: Arc<Executable>,
    eval: Arc<Executable>,
    /// Virtual computation time per round (§4.2 sets
    /// `T_comp = ModelSize / AverageBandwidth`).
    pub t_comp: f64,
    /// Scratch for the incoming grads.
    n_exec: u64,
}

impl PjrtModelSource {
    /// Load a preset from the artifact store onto a PJRT runtime.
    pub fn load(
        rt: &Runtime,
        store: &ArtifactStore,
        preset: &str,
        sigma: f32,
        t_comp: f64,
    ) -> anyhow::Result<Self> {
        let (train, eval) = Self::compile(rt, store, preset)?;
        let layout = store.layout(preset)?;
        Ok(Self::from_parts(layout, train, eval, sigma, store.seed(), t_comp))
    }

    /// Compile the preset's train/eval HLO modules once, behind shared
    /// handles a family can hand to every member cell's source.
    pub fn compile(
        rt: &Runtime,
        store: &ArtifactStore,
        preset: &str,
    ) -> anyhow::Result<(Arc<Executable>, Arc<Executable>)> {
        let art = store.model(preset)?;
        let train = Arc::new(rt.load_hlo_text(&store.path(&art.train_hlo))?);
        let eval = Arc::new(rt.load_hlo_text(&store.path(&art.eval_hlo))?);
        Ok((train, eval))
    }

    /// Assemble a source from pre-compiled executables and a parsed
    /// layout — the warm-family path ([`Self::load`] is compile +
    /// this).
    pub fn from_parts(
        layout: ModelLayout,
        train: Arc<Executable>,
        eval: Arc<Executable>,
        sigma: f32,
        seed: u64,
        t_comp: f64,
    ) -> Self {
        let dataset =
            SyntheticDataset::new(layout.seq, layout.d_in, layout.n_classes, sigma, seed);
        Self { layout, dataset, train, eval, t_comp, n_exec: 0 }
    }

    /// Number of train/eval executions so far (perf accounting).
    pub fn executions(&self) -> u64 {
        self.n_exec
    }

    /// Evaluate `params` on `n_batches` held-out batches.
    pub fn evaluate(&mut self, params: &[f32], n_batches: usize) -> anyhow::Result<EvalMetrics> {
        let b = self.layout.batch;
        let mut loss = 0.0;
        let mut top1 = 0.0;
        let mut top5 = 0.0;
        for batch in self.dataset.eval_batches(b, n_batches) {
            let mut inputs = params_to_literals(params, &self.layout)?;
            inputs.push(literal_f32(
                &batch.x,
                &[b, self.layout.seq, self.layout.d_in],
            )?);
            inputs.push(literal_i32(&batch.y));
            let out = self.eval.run(&inputs)?;
            anyhow::ensure!(out.len() == 3, "eval_step must return 3 outputs");
            self.n_exec += 1;
            loss += out[0].to_vec::<f32>()?[0] as f64;
            top1 += out[1].to_vec::<f32>()?[0] as f64;
            top5 += out[2].to_vec::<f32>()?[0] as f64;
        }
        let n = n_batches * b;
        Ok(EvalMetrics {
            loss: loss / n_batches.max(1) as f64,
            top1: top1 / n as f64,
            top5: top5 / n as f64,
            n,
        })
    }
}

impl GradientSource for PjrtModelSource {
    fn dim(&self) -> usize {
        self.layout.n_params
    }

    fn update(
        &mut self,
        worker: usize,
        step: u64,
        x_hat: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<f64> {
        let b = self.layout.batch;
        let batch = self.dataset.batch(b, worker, step);
        let mut inputs = params_to_literals(x_hat, &self.layout)?;
        inputs.push(literal_f32(
            &batch.x,
            &[b, self.layout.seq, self.layout.d_in],
        )?);
        inputs.push(literal_i32(&batch.y));
        let outputs = self.train.run(&inputs)?;
        anyhow::ensure!(
            outputs.len() == 1 + self.layout.params.len(),
            "train_step returned {} outputs, expected {}",
            outputs.len(),
            1 + self.layout.params.len()
        );
        self.n_exec += 1;
        let loss = outputs[0].to_vec::<f32>()?[0] as f64;
        for (slot, lit) in self.layout.params.iter().zip(&outputs[1..]) {
            let g = lit.to_vec::<f32>()?;
            anyhow::ensure!(g.len() == slot.size, "grad slot {} size mismatch", slot.name);
            out[slot.offset..slot.offset + slot.size].copy_from_slice(&g);
        }
        Ok(loss)
    }

    fn t_comp(&self) -> f64 {
        self.t_comp
    }
}
