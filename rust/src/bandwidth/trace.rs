//! Synthetic bandwidth traces (deterministic, seeded).
//!
//! Built traces are immutable (`at`/`integrate` take `&self`), so
//! [`TraceSpec::build`] hands out `Arc<dyn BandwidthTrace>` handles: a
//! scenario-matrix *cell family* builds each trace once and every
//! member cell's [`Link`](crate::netsim::Link) clones the handle —
//! bit-identical to rebuilding from the spec, since construction is a
//! deterministic function of the spec alone.

use std::sync::Arc;

use crate::util::json::Value;
use crate::util::rng::Rng;

use super::BandwidthTrace;

/// Floor below which no trace is allowed to fall: keeps transfer times
/// finite and mirrors reality (links do not drop to exactly zero).
pub const MIN_BPS: f64 = 1.0;

/// Constant bandwidth.
#[derive(Debug, Clone)]
pub struct ConstantTrace {
    bps: f64,
}

impl ConstantTrace {
    pub fn new(bps: f64) -> Self {
        Self { bps: bps.max(MIN_BPS) }
    }
}

impl BandwidthTrace for ConstantTrace {
    fn at(&self, _t: f64) -> f64 {
        self.bps
    }
    fn integrate(&self, t0: f64, t1: f64) -> f64 {
        self.bps * (t1 - t0).max(0.0)
    }
    fn transfer_time(&self, _t0: f64, bits: f64) -> f64 {
        bits.max(0.0) / self.bps
    }
}

/// The paper's §4.2 family: `eta * sin(theta * t)^2 + delta`.
///
/// `eta` is the oscillation amplitude, `theta` the angular frequency and
/// `delta` the floor; the paper's deep-model runs use 30–330 Mbps.
#[derive(Debug, Clone)]
pub struct SinSquaredTrace {
    pub eta: f64,
    pub theta: f64,
    pub delta: f64,
    pub phase: f64,
}

impl SinSquaredTrace {
    pub fn new(eta: f64, theta: f64, delta: f64) -> Self {
        Self { eta, theta, delta, phase: 0.0 }
    }

    pub fn with_phase(mut self, phase: f64) -> Self {
        self.phase = phase;
        self
    }
}

impl BandwidthTrace for SinSquaredTrace {
    fn at(&self, t: f64) -> f64 {
        let s = (self.theta * t + self.phase).sin();
        (self.eta * s * s + self.delta).max(MIN_BPS)
    }

    /// Closed form: ∫ η sin²(θt+φ) + δ dt
    ///            = (η/2 + δ) t − η sin(2(θt+φ)) / (4θ),
    /// valid whenever the MIN_BPS clamp is inactive (δ ≥ MIN_BPS and
    /// η ≥ 0 keep the integrand above the floor); O(1) instead of the
    /// millisecond-lattice trapezoid (EXPERIMENTS.md §Perf).
    fn integrate(&self, t0: f64, t1: f64) -> f64 {
        if self.delta < MIN_BPS || self.eta < 0.0 || self.theta.abs() < 1e-12 {
            // Fall back to the generic trapezoid via a local copy of
            // the default implementation semantics.
            return generic_integrate(self, t0, t1);
        }
        let anti = |t: f64| {
            (0.5 * self.eta + self.delta) * t
                - self.eta * (2.0 * (self.theta * t + self.phase)).sin() / (4.0 * self.theta)
        };
        (anti(t1) - anti(t0)).max(0.0)
    }
}

/// The trait's generic trapezoid integration, callable from overrides.
fn generic_integrate<T: BandwidthTrace + ?Sized>(tr: &T, t0: f64, t1: f64) -> f64 {
    let span = t1 - t0;
    if span <= 0.0 {
        return 0.0;
    }
    let steps = ((span / 1e-3).ceil() as usize).clamp(1, 200_000);
    let h = span / steps as f64;
    let mut acc = 0.0;
    let mut prev = tr.at(t0);
    for i in 1..=steps {
        let cur = tr.at(t0 + h * i as f64);
        acc += 0.5 * (prev + cur) * h;
        prev = cur;
    }
    acc
}

/// Square wave oscillating between `low` and `high` with the given
/// period (seconds); used for the Fig. 5 small/high oscillation regime.
#[derive(Debug, Clone)]
pub struct SquareWaveTrace {
    pub low: f64,
    pub high: f64,
    pub period: f64,
    pub duty: f64,
}

impl SquareWaveTrace {
    pub fn new(low: f64, high: f64, period: f64) -> Self {
        Self { low: low.max(MIN_BPS), high: high.max(MIN_BPS), period, duty: 0.5 }
    }
}

impl BandwidthTrace for SquareWaveTrace {
    fn at(&self, t: f64) -> f64 {
        let frac = (t / self.period).rem_euclid(1.0);
        if frac < self.duty {
            self.high
        } else {
            self.low
        }
    }
}

/// Mean-reverting Ornstein–Uhlenbeck noise on a 10 ms lattice — the
/// EC2-like jitter of Fig. 1. Deterministic in (seed, t).
///
///   X_{n+1} = X_n + kappa (mu - X_n) dt + sigma sqrt(dt) N(0,1)
///
/// The whole lattice is materialized up front (reproducible, queryable
/// in O(1) with linear interpolation).
#[derive(Debug, Clone)]
pub struct OuNoiseTrace {
    lattice: Vec<f64>,
    dt: f64,
    mu: f64,
}

impl OuNoiseTrace {
    /// `horizon`: max simulation time covered (queries beyond clamp).
    pub fn new(mu: f64, kappa: f64, sigma: f64, seed: u64, horizon: f64) -> Self {
        let dt = 0.01;
        let n = (horizon / dt).ceil() as usize + 2;
        let mut rng = Rng::seed_from_u64(seed);
        let mut x = mu;
        let mut lattice = Vec::with_capacity(n);
        for _ in 0..n {
            lattice.push(x.max(MIN_BPS));
            let z = rng.normal();
            x += kappa * (mu - x) * dt + sigma * dt.sqrt() * z;
        }
        Self { lattice, dt, mu }
    }

    pub fn mean(&self) -> f64 {
        self.mu
    }
}

impl BandwidthTrace for OuNoiseTrace {
    fn at(&self, t: f64) -> f64 {
        let idx = (t / self.dt).floor();
        let i = (idx.max(0.0) as usize).min(self.lattice.len() - 2);
        let frac = (t / self.dt - i as f64).clamp(0.0, 1.0);
        self.lattice[i] * (1.0 - frac) + self.lattice[i + 1] * frac
    }
}

/// Replay a recorded `(time, bps)` step function (e.g. a real iperf CSV).
#[derive(Debug, Clone)]
pub struct ReplayTrace {
    points: Vec<(f64, f64)>,
}

impl ReplayTrace {
    /// `points` must be sorted by time; values before the first point
    /// use the first value, after the last the last value.
    pub fn new(mut points: Vec<(f64, f64)>) -> Self {
        points.sort_by(|a, b| a.0.total_cmp(&b.0));
        for p in &mut points {
            p.1 = p.1.max(MIN_BPS);
        }
        assert!(!points.is_empty(), "replay trace needs >= 1 point");
        Self { points }
    }

    /// Parse simple `time_s,bps` CSV (no header; `#` comments allowed).
    pub fn from_csv(text: &str) -> anyhow::Result<Self> {
        let mut pts = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split(',');
            let t: f64 = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {ln}: missing time"))?
                .trim()
                .parse()?;
            let b: f64 = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {ln}: missing bps"))?
                .trim()
                .parse()?;
            pts.push((t, b));
        }
        anyhow::ensure!(!pts.is_empty(), "empty trace CSV");
        Ok(Self::new(pts))
    }
}

impl BandwidthTrace for ReplayTrace {
    fn at(&self, t: f64) -> f64 {
        match self.points.binary_search_by(|p| p.0.total_cmp(&t)) {
            Ok(i) => self.points[i].1,
            Err(0) => self.points[0].1,
            Err(i) => self.points[i - 1].1,
        }
    }
}

/// Multiplicative composition: `base(t) * modulator(t)` (modulator is a
/// unitless factor, e.g. OU noise with mu=1.0). Used to give each worker
/// "the same pattern with different noise" (§4.2).
pub struct CompositeTrace {
    pub base: Box<dyn BandwidthTrace>,
    pub modulator: Box<dyn BandwidthTrace>,
}

impl CompositeTrace {
    pub fn new(base: Box<dyn BandwidthTrace>, modulator: Box<dyn BandwidthTrace>) -> Self {
        Self { base, modulator }
    }
}

impl BandwidthTrace for CompositeTrace {
    fn at(&self, t: f64) -> f64 {
        (self.base.at(t) * self.modulator.at(t)).max(MIN_BPS)
    }
}

/// Declarative trace description (config-file friendly; JSON-codable).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceSpec {
    Constant { bps: f64 },
    /// `eta sin(theta t + phase)^2 + delta`
    SinSquared { eta: f64, theta: f64, delta: f64, phase: f64 },
    SquareWave { low: f64, high: f64, period: f64 },
    OuNoise { mu: f64, kappa: f64, sigma: f64, seed: u64, horizon: f64 },
    /// sin^2 base modulated by OU noise around 1.0 — the §4.2 deep-model
    /// setting ("same patterns with different noise").
    NoisySinSquared {
        eta: f64,
        theta: f64,
        delta: f64,
        phase: f64,
        noise_sigma: f64,
        seed: u64,
        horizon: f64,
    },
}

impl TraceSpec {
    /// Build the trace behind a shared, immutable handle. Cloning the
    /// `Arc` is how a cell family shares one built trace across member
    /// cells; a fresh `build` of the same spec is bit-identical.
    pub fn build(&self) -> Arc<dyn BandwidthTrace> {
        match self.clone() {
            TraceSpec::Constant { bps } => Arc::new(ConstantTrace::new(bps)),
            TraceSpec::SinSquared { eta, theta, delta, phase } => {
                Arc::new(SinSquaredTrace::new(eta, theta, delta).with_phase(phase))
            }
            TraceSpec::SquareWave { low, high, period } => {
                Arc::new(SquareWaveTrace::new(low, high, period))
            }
            TraceSpec::OuNoise { mu, kappa, sigma, seed, horizon } => {
                Arc::new(OuNoiseTrace::new(mu, kappa, sigma, seed, horizon))
            }
            TraceSpec::NoisySinSquared {
                eta,
                theta,
                delta,
                phase,
                noise_sigma,
                seed,
                horizon,
            } => Arc::new(CompositeTrace::new(
                Box::new(SinSquaredTrace::new(eta, theta, delta).with_phase(phase)),
                Box::new(OuNoiseTrace::new(1.0, 2.0, noise_sigma, seed, horizon)),
            )),
        }
    }

    /// The spec worker `m` runs: same pattern, different seed/phase
    /// (§4.2). Exposed separately from [`per_worker`](Self::per_worker)
    /// so the seed-derivation rule itself is unit-testable.
    pub fn per_worker_spec(&self, m: usize) -> TraceSpec {
        let mut spec = self.clone();
        match &mut spec {
            TraceSpec::OuNoise { seed, .. } => *seed = seed.wrapping_add(m as u64 * 7919),
            TraceSpec::NoisySinSquared { seed, .. } => {
                *seed = seed.wrapping_add(m as u64 * 7919)
            }
            TraceSpec::SinSquared { phase, .. } => *phase += 0.13 * m as f64,
            _ => {}
        }
        spec
    }

    /// Per-worker variants: same pattern, different seed/phase (§4.2).
    pub fn per_worker(&self, m: usize) -> Arc<dyn BandwidthTrace> {
        self.per_worker_spec(m).build()
    }

    // -- JSON codec (config files) --------------------------------------

    pub fn to_json(&self) -> Value {
        match self {
            TraceSpec::Constant { bps } => Value::obj(vec![
                ("kind", Value::str("constant")),
                ("bps", Value::num(*bps)),
            ]),
            TraceSpec::SinSquared { eta, theta, delta, phase } => Value::obj(vec![
                ("kind", Value::str("sin_squared")),
                ("eta", Value::num(*eta)),
                ("theta", Value::num(*theta)),
                ("delta", Value::num(*delta)),
                ("phase", Value::num(*phase)),
            ]),
            TraceSpec::SquareWave { low, high, period } => Value::obj(vec![
                ("kind", Value::str("square_wave")),
                ("low", Value::num(*low)),
                ("high", Value::num(*high)),
                ("period", Value::num(*period)),
            ]),
            TraceSpec::OuNoise { mu, kappa, sigma, seed, horizon } => Value::obj(vec![
                ("kind", Value::str("ou_noise")),
                ("mu", Value::num(*mu)),
                ("kappa", Value::num(*kappa)),
                ("sigma", Value::num(*sigma)),
                ("seed", Value::num(*seed as f64)),
                ("horizon", Value::num(*horizon)),
            ]),
            TraceSpec::NoisySinSquared { eta, theta, delta, phase, noise_sigma, seed, horizon } => {
                Value::obj(vec![
                    ("kind", Value::str("noisy_sin_squared")),
                    ("eta", Value::num(*eta)),
                    ("theta", Value::num(*theta)),
                    ("delta", Value::num(*delta)),
                    ("phase", Value::num(*phase)),
                    ("noise_sigma", Value::num(*noise_sigma)),
                    ("seed", Value::num(*seed as f64)),
                    ("horizon", Value::num(*horizon)),
                ])
            }
        }
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let kind = v.get("kind")?.as_str()?;
        let f = |k: &str| -> anyhow::Result<f64> { v.get(k)?.as_f64() };
        let fo = |k: &str, d: f64| -> f64 {
            v.opt(k).and_then(|x| x.as_f64().ok()).unwrap_or(d)
        };
        Ok(match kind {
            "constant" => TraceSpec::Constant { bps: f("bps")? },
            "sin_squared" => TraceSpec::SinSquared {
                eta: f("eta")?,
                theta: f("theta")?,
                delta: f("delta")?,
                phase: fo("phase", 0.0),
            },
            "square_wave" => TraceSpec::SquareWave {
                low: f("low")?,
                high: f("high")?,
                period: f("period")?,
            },
            "ou_noise" => TraceSpec::OuNoise {
                mu: f("mu")?,
                kappa: f("kappa")?,
                sigma: f("sigma")?,
                seed: v.get("seed")?.as_u64()?,
                horizon: f("horizon")?,
            },
            "noisy_sin_squared" => TraceSpec::NoisySinSquared {
                eta: f("eta")?,
                theta: f("theta")?,
                delta: f("delta")?,
                phase: fo("phase", 0.0),
                noise_sigma: f("noise_sigma")?,
                seed: v.get("seed")?.as_u64()?,
                horizon: f("horizon")?,
            },
            other => anyhow::bail!("unknown trace kind '{other}'"),
        })
    }
}

/// Convenience: build the M per-worker (uplink, downlink) trace pairs.
///
/// The handles are `Arc`-shared: a cell family builds them once and
/// every member cell's netsim clones them (see `driver::WarmFamily`).
pub struct PerWorkerTraces;

impl PerWorkerTraces {
    pub fn build(
        up: &TraceSpec,
        down: &TraceSpec,
        m: usize,
    ) -> Vec<(Arc<dyn BandwidthTrace>, Arc<dyn BandwidthTrace>)> {
        (0..m)
            .map(|i| (up.per_worker(i), down.per_worker(i + 104_729)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sin_squared_bounds() {
        let tr = SinSquaredTrace::new(300.0, 0.7, 30.0);
        for i in 0..1000 {
            let v = tr.at(i as f64 * 0.05);
            assert!((30.0..=330.0 + 1e-9).contains(&v));
        }
    }

    #[test]
    fn square_wave_levels() {
        let tr = SquareWaveTrace::new(10.0, 100.0, 2.0);
        assert_eq!(tr.at(0.1), 100.0);
        assert_eq!(tr.at(1.1), 10.0);
        assert_eq!(tr.at(2.1), 100.0);
    }

    #[test]
    fn ou_noise_deterministic_and_positive() {
        let a = OuNoiseTrace::new(50.0, 0.5, 10.0, 42, 10.0);
        let b = OuNoiseTrace::new(50.0, 0.5, 10.0, 42, 10.0);
        for i in 0..100 {
            let t = i as f64 * 0.1;
            assert_eq!(a.at(t), b.at(t));
            assert!(a.at(t) >= MIN_BPS);
        }
        let c = OuNoiseTrace::new(50.0, 0.5, 10.0, 43, 10.0);
        assert!((0..100).any(|i| a.at(i as f64 * 0.1) != c.at(i as f64 * 0.1)));
        assert_eq!(a.mean(), 50.0);
    }

    #[test]
    fn ou_mean_reversion() {
        let tr = OuNoiseTrace::new(100.0, 2.0, 5.0, 7, 50.0);
        let mean = tr.integrate(0.0, 50.0) / 50.0;
        assert!((mean - 100.0).abs() < 10.0, "mean={mean}");
    }

    #[test]
    fn replay_step_function() {
        let tr = ReplayTrace::new(vec![(0.0, 10.0), (1.0, 20.0), (2.0, 5.0)]);
        assert_eq!(tr.at(-1.0), 10.0);
        assert_eq!(tr.at(0.5), 10.0);
        assert_eq!(tr.at(1.0), 20.0);
        assert_eq!(tr.at(1.99), 20.0);
        assert_eq!(tr.at(5.0), 5.0);
    }

    #[test]
    fn replay_from_csv() {
        let tr = ReplayTrace::from_csv("# header\n0.0, 10\n1.0, 20\n").unwrap();
        assert_eq!(tr.at(0.5), 10.0);
        assert_eq!(tr.at(1.5), 20.0);
        assert!(ReplayTrace::from_csv("# nothing\n").is_err());
    }

    #[test]
    fn spec_json_roundtrip() {
        let specs = [
            TraceSpec::Constant { bps: 100.0 },
            TraceSpec::SinSquared { eta: 3e8, theta: 0.7, delta: 3e7, phase: 0.1 },
            TraceSpec::SquareWave { low: 1.0, high: 2.0, period: 3.0 },
            TraceSpec::OuNoise { mu: 1.0, kappa: 2.0, sigma: 0.1, seed: 9, horizon: 10.0 },
            TraceSpec::NoisySinSquared {
                eta: 3e8,
                theta: 0.7,
                delta: 3e7,
                phase: 0.0,
                noise_sigma: 0.15,
                seed: 21,
                horizon: 100.0,
            },
        ];
        for s in specs {
            let v = Value::parse(&s.to_json().to_string()).unwrap();
            assert_eq!(TraceSpec::from_json(&v).unwrap(), s);
        }
    }

    #[test]
    fn per_worker_variants_differ() {
        let spec = TraceSpec::NoisySinSquared {
            eta: 300e6,
            theta: 0.7,
            delta: 30e6,
            phase: 0.0,
            noise_sigma: 0.1,
            seed: 1,
            horizon: 100.0,
        };
        let t = spec.build();
        assert!(t.at(3.0) > 0.0);
        let w0 = spec.per_worker(0);
        let w1 = spec.per_worker(1);
        assert!((0..50).any(|i| w0.at(i as f64 * 0.3) != w1.at(i as f64 * 0.3)));
    }

    #[test]
    fn per_worker_seed_derivation_deterministic_and_distinct() {
        // The §4.2 "same pattern, different noise" rule must be a pure
        // function of (spec, worker): building worker m twice gives the
        // same spec (and therefore a bit-identical trace), while
        // distinct workers get distinct seeds/phases.
        let specs = [
            TraceSpec::OuNoise { mu: 50.0, kappa: 0.5, sigma: 10.0, seed: 9, horizon: 20.0 },
            TraceSpec::NoisySinSquared {
                eta: 300e6,
                theta: 0.7,
                delta: 30e6,
                phase: 0.0,
                noise_sigma: 0.1,
                seed: 1,
                horizon: 100.0,
            },
            TraceSpec::SinSquared { eta: 10.0, theta: 0.3, delta: 5.0, phase: 0.2 },
        ];
        for spec in specs {
            for m in [0usize, 1, 7, 104_729] {
                assert_eq!(spec.per_worker_spec(m), spec.per_worker_spec(m));
                let a = spec.per_worker(m);
                let b = spec.per_worker(m);
                for i in 0..40 {
                    let t = i as f64 * 0.25;
                    assert_eq!(a.at(t), b.at(t), "worker {m} not deterministic");
                }
            }
            let mut variants: Vec<TraceSpec> =
                (0..4).map(|m| spec.per_worker_spec(m)).collect();
            let n = variants.len();
            variants.dedup();
            assert_eq!(variants.len(), n, "worker variants must be distinct");
        }
        // Constant traces have no per-worker noise: all workers equal.
        let c = TraceSpec::Constant { bps: 100.0 };
        assert_eq!(c.per_worker_spec(0), c.per_worker_spec(3));
    }

    #[test]
    fn shared_arc_handle_is_bit_identical_to_fresh_build() {
        // The Arc-sharing contract: one built trace queried through two
        // clones of the handle agrees with an independent rebuild from
        // the same spec, sample for sample.
        let spec = TraceSpec::OuNoise { mu: 80.0, kappa: 1.0, sigma: 8.0, seed: 4, horizon: 30.0 };
        let shared = spec.build();
        let clone = Arc::clone(&shared);
        let fresh = spec.build();
        assert!(Arc::ptr_eq(&shared, &clone));
        for i in 0..100 {
            let t = i as f64 * 0.21;
            assert_eq!(shared.at(t), fresh.at(t));
            assert_eq!(clone.integrate(0.0, t), fresh.integrate(0.0, t));
        }
    }
}
