//! Runtime bandwidth estimation from observed transfers.
//!
//! Kimad's endpoints never see the ground-truth trace: they observe
//! `(bytes, seconds)` for each completed transfer and must *estimate*
//! `B_m^k` for the next round (Algorithm 3 lines 3/10). The paper calls
//! the simulated monitor "trivial"; we still implement the interface a
//! real NIC-level monitor (DC2-style shim) would satisfy, with two
//! estimators: EWMA and sliding-window median.

/// Online estimator of current link bandwidth (bits/second).
pub trait BandwidthMonitor: Send {
    /// Record one completed transfer of `bits` that took `seconds`.
    fn observe(&mut self, bits: f64, seconds: f64);

    /// Current estimate in bits/second; `None` until warm.
    fn estimate_bps(&self) -> Option<f64>;

    /// Estimate with a fallback prior for the cold-start rounds.
    fn estimate_or(&self, prior: f64) -> f64 {
        self.estimate_bps().unwrap_or(prior)
    }

    fn reset(&mut self);
}

/// Exponentially-weighted moving average over observed rates.
#[derive(Debug, Clone)]
pub struct EwmaMonitor {
    alpha: f64,
    est: Option<f64>,
}

impl EwmaMonitor {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1]");
        Self { alpha, est: None }
    }
}

impl Default for EwmaMonitor {
    fn default() -> Self {
        Self::new(0.5)
    }
}

impl BandwidthMonitor for EwmaMonitor {
    fn observe(&mut self, bits: f64, seconds: f64) {
        if seconds <= 0.0 || bits <= 0.0 {
            return;
        }
        let rate = bits / seconds;
        self.est = Some(match self.est {
            None => rate,
            Some(prev) => self.alpha * rate + (1.0 - self.alpha) * prev,
        });
    }

    fn estimate_bps(&self) -> Option<f64> {
        self.est
    }

    fn reset(&mut self) {
        self.est = None;
    }
}

/// Median over the last `window` observed rates — robust to the
/// transient congestion spikes of Fig. 1.
#[derive(Debug, Clone)]
pub struct SlidingWindowMonitor {
    window: usize,
    rates: Vec<f64>,
}

impl SlidingWindowMonitor {
    pub fn new(window: usize) -> Self {
        assert!(window >= 1);
        Self { window, rates: Vec::new() }
    }
}

impl BandwidthMonitor for SlidingWindowMonitor {
    fn observe(&mut self, bits: f64, seconds: f64) {
        if seconds <= 0.0 || bits <= 0.0 {
            return;
        }
        if self.rates.len() == self.window {
            self.rates.remove(0);
        }
        self.rates.push(bits / seconds);
    }

    fn estimate_bps(&self) -> Option<f64> {
        if self.rates.is_empty() {
            return None;
        }
        let mut sorted = self.rates.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mid = sorted.len() / 2;
        Some(if sorted.len() % 2 == 1 {
            sorted[mid]
        } else {
            0.5 * (sorted[mid - 1] + sorted[mid])
        })
    }

    fn reset(&mut self) {
        self.rates.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_cold_start_then_converges() {
        let mut m = EwmaMonitor::new(0.5);
        assert!(m.estimate_bps().is_none());
        assert_eq!(m.estimate_or(123.0), 123.0);
        for _ in 0..20 {
            m.observe(100.0, 1.0);
        }
        assert!((m.estimate_bps().unwrap() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_tracks_changes() {
        let mut m = EwmaMonitor::new(0.5);
        m.observe(100.0, 1.0);
        m.observe(200.0, 1.0);
        let e = m.estimate_bps().unwrap();
        assert!(e > 100.0 && e < 200.0);
    }

    #[test]
    fn ewma_ignores_degenerate() {
        let mut m = EwmaMonitor::default();
        m.observe(0.0, 1.0);
        m.observe(10.0, 0.0);
        assert!(m.estimate_bps().is_none());
    }

    #[test]
    fn window_median_robust_to_spike() {
        let mut m = SlidingWindowMonitor::new(5);
        for _ in 0..4 {
            m.observe(100.0, 1.0);
        }
        m.observe(10_000.0, 1.0); // spike
        assert_eq!(m.estimate_bps().unwrap(), 100.0);
    }

    #[test]
    fn window_evicts_oldest() {
        let mut m = SlidingWindowMonitor::new(2);
        m.observe(10.0, 1.0);
        m.observe(100.0, 1.0);
        m.observe(100.0, 1.0);
        assert_eq!(m.estimate_bps().unwrap(), 100.0);
    }

    #[test]
    fn reset_clears() {
        let mut m = EwmaMonitor::default();
        m.observe(5.0, 1.0);
        m.reset();
        assert!(m.estimate_bps().is_none());
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn bad_alpha_panics() {
        EwmaMonitor::new(0.0);
    }
}
