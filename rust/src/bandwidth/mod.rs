//! Bandwidth substrate: synthetic traces + runtime monitoring.
//!
//! The paper's deep-model evaluation drives everything off the family
//! `Bandwidth(time) = eta * sin(theta * time)^2 + delta` (§4.2) plus
//! per-worker noise; Fig. 1 motivates it with measured EC2 traces. We
//! implement that family, a square wave, an Ornstein–Uhlenbeck noise
//! process (the EC2-like trace used for our Fig. 1 reproduction), CSV
//! replay, and composition — all behind one [`BandwidthTrace`] trait so
//! the netsim and the monitor never care which one is running.
//!
//! [`monitor`] implements the continuous bandwidth monitoring of §2.4
//! and §3: NIC-counter-style observations feed an estimator (EWMA or
//! sliding window) whose read at "the time communication is triggered"
//! (§3.1) is what the Eq. (2) budget multiplies.

pub mod monitor;
pub mod trace;

pub use monitor::{BandwidthMonitor, EwmaMonitor, SlidingWindowMonitor};
pub use trace::{
    CompositeTrace, ConstantTrace, OuNoiseTrace, PerWorkerTraces, ReplayTrace,
    SinSquaredTrace, SquareWaveTrace, TraceSpec,
};

/// A (possibly time-varying) link bandwidth in **bits per second**.
///
/// Implementations must be deterministic functions of `t` (seeded noise
/// included) so simulations are exactly reproducible.
pub trait BandwidthTrace: Send + Sync {
    /// Instantaneous bandwidth at absolute simulation time `t` (seconds).
    fn at(&self, t: f64) -> f64;

    /// Integrate bandwidth over `[t0, t1]` -> bits transferable.
    ///
    /// Default: adaptive trapezoid at millisecond resolution, which is
    /// exact for piecewise-smooth traces at the timescales we simulate.
    fn integrate(&self, t0: f64, t1: f64) -> f64 {
        debug_assert!(t1 >= t0);
        let span = t1 - t0;
        if span <= 0.0 {
            return 0.0;
        }
        let steps = ((span / 1e-3).ceil() as usize).clamp(1, 200_000);
        let h = span / steps as f64;
        let mut acc = 0.0;
        let mut prev = self.at(t0);
        for i in 1..=steps {
            let cur = self.at(t0 + h * i as f64);
            acc += 0.5 * (prev + cur) * h;
            prev = cur;
        }
        acc
    }

    /// Time needed to move `bits` starting at `t0` (inverse of
    /// [`integrate`](Self::integrate)): smallest `dt` with
    /// `integrate(t0, t0+dt) >= bits`.
    ///
    /// Default: single forward trapezoid march (accumulate until the
    /// bits are consumed, interpolate within the final step) — one pass
    /// over the trace instead of the bracketing+bisection that
    /// re-integrates O(60) times (EXPERIMENTS.md §Perf).
    fn transfer_time(&self, t0: f64, bits: f64) -> f64 {
        if bits <= 0.0 {
            return 0.0;
        }
        // Step size adapted to the expected span at the current rate.
        let b0 = self.at(t0).max(1e-9);
        let expected = bits / b0;
        // ~0.5% of the expected span per step: trapezoid + final-step
        // interpolation keeps relative error ~1e-4 on smooth traces.
        let h = (expected / 200.0).clamp(1e-4, 0.1);
        let mut acc = 0.0;
        let mut prev = b0;
        let mut t = t0;
        for _ in 0..20_000_000u64 {
            let cur = self.at(t + h);
            let inc = 0.5 * (prev + cur) * h;
            if acc + inc >= bits {
                // Linear interpolation inside the final trapezoid.
                let frac = (bits - acc) / inc.max(1e-300);
                return t - t0 + h * frac;
            }
            acc += inc;
            prev = cur;
            t += h;
        }
        f64::INFINITY
    }
}

impl<T: BandwidthTrace + ?Sized> BandwidthTrace for Box<T> {
    fn at(&self, t: f64) -> f64 {
        (**self).at(t)
    }
}

/// Shared-handle form: traces are immutable, so an `Arc` clone is
/// indistinguishable from the original (what lets a scenario cell
/// family build each trace once — see `driver::WarmFamily`).
///
/// Deliberately forwards **only `at`**, exactly like the `Box` impl
/// above: a handle held in a [`netsim::Link`](crate::netsim::Link) must
/// keep running the generic `integrate`/`transfer_time` defaults, as
/// the former `Box`-typed links did, so swapping `Box` for `Arc` is
/// bit-identical by construction (a forwarded `integrate` would switch
/// e.g. [`SinSquaredTrace`] links from the trapezoid to its closed
/// form — a numeric, if tiny, behavior change).
impl<T: BandwidthTrace + ?Sized> BandwidthTrace for std::sync::Arc<T> {
    fn at(&self, t: f64) -> f64 {
        (**self).at(t)
    }
}

/// Convert megabits/s to bits/s (the paper quotes Mbps).
pub const fn mbps(v: f64) -> f64 {
    v * 1_000_000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_integrate_exact() {
        let tr = ConstantTrace::new(100.0);
        assert!((tr.integrate(0.0, 2.0) - 200.0).abs() < 1e-6);
    }

    #[test]
    fn transfer_time_inverts_integrate() {
        let tr = SinSquaredTrace::new(mbps(300.0), 0.3, mbps(30.0));
        for &bits in &[1e3, 1e6, 5e7] {
            let dt = tr.transfer_time(1.7, bits);
            let got = tr.integrate(1.7, 1.7 + dt);
            assert!(
                (got - bits).abs() / bits < 1e-3,
                "bits={bits} dt={dt} got={got}"
            );
        }
    }

    #[test]
    fn zero_bits_zero_time() {
        let tr = ConstantTrace::new(1.0);
        assert_eq!(tr.transfer_time(0.0, 0.0), 0.0);
    }
}
