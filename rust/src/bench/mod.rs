//! The perf subsystem behind `kimad bench`: one shared timing core
//! (also used by every rust/benches/ harness), a counting allocator,
//! the hot-path kernel suite, the end-to-end grid runner, and the
//! `BENCH_*.json` report schema the CI regression gate compares.
//!
//! See docs/ARCHITECTURE.md §7 for the timing core and the
//! fixed-reduction-order rule that keeps hot-path optimizations
//! bit-reproducible.

// The crate denies `unsafe_code`; the counting allocator is the one
// audited exception (GlobalAlloc is an unsafe trait).
#[allow(unsafe_code)]
pub mod alloc;
pub mod e2e;
pub mod kernels;
pub mod report;
pub mod timing;

pub use alloc::{allocs, CountingAlloc, ALLOCS};
pub use report::{current_commit, host_tag, BenchConfig, BenchReport, E2eRecord, KernelRecord};
pub use timing::{bench, black_box, fmt_ns, time_once, BenchResult};

/// Kernel problem sizes every run measures (identical in quick and
/// full mode, so a quick CI run always has matching baseline rows).
pub const KERNEL_SIZES: [usize; 2] = [1 << 16, 1 << 20];

/// Run the whole suite: kernels at [`KERNEL_SIZES`], then the
/// end-to-end grid(s) — the reduced `quick-r20` grid always (cold,
/// plus a `quick-r20-resume` pass over a populated cell cache: the
/// warm-path number that keeps `--resume` honest), the default 48-cell
/// grid when `quick` is false, and the three workers-scaling
/// population grids (M = 10²..10⁶ at a fixed ~10-client quorum) in
/// both modes — each is a single sampled cell, so they cost seconds
/// even at a million clients.
pub fn run(quick: bool) -> anyhow::Result<BenchReport> {
    let sizes = KERNEL_SIZES.to_vec();
    let samples = if quick { 3 } else { 10 };
    let kernels = kernels::run_kernels(&sizes, samples);
    let mut e2e_records = vec![e2e::run_grid(&e2e::quick_grid())?];
    e2e_records.push(e2e::run_grid_resumed(&e2e::quick_grid())?);
    if !quick {
        e2e_records.push(e2e::run_grid(&e2e::default_grid())?);
    }
    for grid in e2e::workers_scaling_grids() {
        e2e_records.push(e2e::run_grid(&grid)?);
    }
    Ok(BenchReport {
        commit: current_commit(),
        config: BenchConfig {
            host: host_tag(),
            quick,
            samples,
            sizes,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        },
        kernels,
        e2e: e2e_records,
    })
}
