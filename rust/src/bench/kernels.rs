//! The hot-path kernel suite `kimad bench` runs: every per-round
//! kernel the simulator's inner loop executes, measured standalone on
//! parameterized sizes — median ns/iter from the timing core, plus a
//! heap-allocation count per iteration from the counting allocator
//! (real only when the calling binary installs
//! [`CountingAlloc`](crate::bench::CountingAlloc); otherwise the delta
//! reads 0, which is also what the warm reuse paths must report).

use crate::bench::alloc::allocs;
use crate::bench::report::KernelRecord;
use crate::bench::timing::{bench, black_box};
use crate::compress::{Compressed, Compressor, QuantizeBits, RandK, TopK};
use crate::coordinator::shard::{self, BroadcastScratch, ShardPlan};
use crate::ef21::Estimator;
use crate::kimad::select::SPARSE_COORD_BITS;
use crate::kimad::{CompressPolicy, Selector};
use crate::model::ModelLayout;
use crate::util::chunk;
use crate::util::rng::Rng;

/// Reps for the allocation count (separate from the timed samples so
/// calibration noise never leaks into the alloc delta).
const ALLOC_REPS: u64 = 32;

/// Mirrors for the aggregate/broadcast kernels.
const M: usize = 4;
/// Layers for the layered kernels (aggregate/broadcast/EF21 spans).
const N_LAYERS: usize = 8;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

/// Time + count one kernel: `bench` for the median, then a fixed rep
/// loop for the alloc delta (averaged, rounding up so a single cold
/// allocation inside the loop still registers).
fn measure<F: FnMut()>(
    name: &str,
    n: usize,
    bytes_per_iter: u64,
    samples: usize,
    mut f: F,
) -> KernelRecord {
    f(); // warm buffers + thread-local scratch before anything counts
    let r = bench(&format!("{name} n={n}"), samples, &mut f);
    let before = allocs();
    for _ in 0..ALLOC_REPS {
        f();
    }
    let delta = allocs() - before;
    KernelRecord {
        name: name.to_string(),
        n,
        ns_per_iter: r.median_ns(),
        bytes_per_iter,
        allocs: delta.div_ceil(ALLOC_REPS),
    }
}

/// Run the whole kernel suite at each size in `sizes` with `samples`
/// timed samples per kernel. Deterministic inputs (seeded RNG), so two
/// runs report identical `allocs` columns.
pub fn run_kernels(sizes: &[usize], samples: usize) -> Vec<KernelRecord> {
    let mut out = Vec::new();
    for &n in sizes {
        let n = n.max(N_LAYERS); // layered kernels need a coordinate per layer
        let k = (n / 100).max(1);
        let a = grad(n, 1);
        let b = grad(n, 2);

        // diff: the EF21 `u − û` fill (upload leg + broadcast phase 1).
        let mut d = vec![0.0f32; n];
        out.push(measure("diff", n, 12 * n as u64, samples, || {
            chunk::diff_into(black_box(&mut d), black_box(&a), black_box(&b));
        }));

        // topk_select: the quickselect behind every TopK compressor.
        let mut idx = Vec::new();
        let mut packed = Vec::new();
        out.push(measure("topk_select", n, 4 * n as u64, samples, || {
            TopK::select_indices_with(black_box(&a), k, &mut idx, &mut packed);
            black_box(&idx);
        }));

        // randk_select: the RandK baseline's sampling + gather.
        let randk = RandK::new(k, 7);
        let mut msg = Compressed::default();
        out.push(measure("randk_select", n, 4 * n as u64, samples, || {
            randk.compress_into(black_box(&a), &mut msg);
            black_box(&msg);
        }));

        // quantize: 8-bit uniform with the chunked max-abs scale scan.
        let q8 = QuantizeBits::new(8);
        let mut qmsg = Compressed::default();
        out.push(measure("quantize", n, 8 * n as u64, samples, || {
            q8.compress_into(black_box(&a), &mut qmsg);
            black_box(&qmsg);
        }));

        // ef21_advance: compress-advance of one layer-sized estimator.
        let layer = crate::model::Layer { id: 0, name: "l".into(), offset: 0, size: n };
        let mut est = Estimator::zeros(n);
        let mut scratch = Vec::with_capacity(n);
        let mut emsg = Compressed::default();
        let topk = TopK::new(k);
        out.push(measure("ef21_advance", n, 16 * n as u64, samples, || {
            est.compress_advance_into(&topk, black_box(&a), &layer, &mut scratch, &mut emsg);
            black_box(&emsg);
        }));

        // aggregate: Σ w_m û_m over M mirrors (serialized shard kernel).
        let layers = ModelLayout::synthetic(&[n / N_LAYERS; N_LAYERS]).layers();
        let dim = layers.iter().map(|l| l.size).sum::<usize>();
        let plan = ShardPlan::build(&layers, 1);
        let u_hats: Vec<Estimator> = (0..M)
            .map(|w| {
                let mut e = Estimator::zeros(dim);
                e.value.copy_from_slice(&grad(dim, 10 + w as u64));
                e
            })
            .collect();
        let weights = vec![1.0 / M as f64; M];
        let mut agg = vec![0.0f32; dim];
        out.push(measure(
            "aggregate",
            dim,
            (M as u64 + 1) * 4 * dim as u64,
            samples,
            || {
                black_box(shard::aggregate(&plan, &weights, &u_hats, &mut agg, false));
            },
        ));

        // broadcast: the full downlink phase (diff + A^compress +
        // per-layer EF21) through the serialized shard kernel.
        let sel = Selector::new(CompressPolicy::KimadUniform);
        let c_down = (dim as u64 / 100).max(1) * SPARSE_COORD_BITS;
        let xb = &a[..dim];
        let mut hat = Estimator::zeros(dim);
        let mut diff_b = vec![0.0f32; dim];
        let mut scr = BroadcastScratch::default();
        out.push(measure("broadcast", dim, 16 * dim as u64, samples, || {
            black_box(shard::broadcast(
                &plan,
                &sel,
                &layers,
                c_down,
                black_box(xb),
                &mut hat,
                &mut diff_b,
                &mut scr,
                false,
            ));
        }));
    }
    out
}

/// The kernels whose warm paths must report exactly zero allocations
/// per iteration (the buffer-reuse contract the benches assert).
pub fn alloc_free_kernels() -> &'static [&'static str] {
    &["diff", "topk_select", "quantize", "ef21_advance", "aggregate", "broadcast"]
}
