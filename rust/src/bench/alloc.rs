//! Counting allocator shared by the `kimad bench` subcommand, the
//! rust/benches/ harnesses, and the bench-harness integration test.
//!
//! A `#[global_allocator]` can only be installed by the final binary,
//! so the library exposes the type and the counter here and each
//! entry point (src/main.rs, benches/hotpath.rs,
//! tests/bench_harness.rs) declares:
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: kimad::bench::CountingAlloc = kimad::bench::CountingAlloc;
//! ```
//!
//! When it is *not* installed, [`allocs`] just reads a counter nothing
//! increments — callers report deltas, which are then zero, so the
//! library stays usable either way.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Total allocation events (alloc / realloc / alloc_zeroed; frees are
/// not counted) since process start, when [`CountingAlloc`] is the
/// global allocator.
pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Current value of the allocation counter. Take a delta around the
/// region of interest; absolute values include harness overhead.
#[inline]
pub fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Counts heap allocations so benches can *prove* the buffer-reuse
/// paths perform zero per-call allocations once warm.
pub struct CountingAlloc;

// SAFETY: a pure pass-through to `System`; the only extra work is a
// relaxed atomic counter bump, which cannot violate GlobalAlloc's
// contract (no allocation, no panic, no reentrancy into the allocator).
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards the caller's layout to `System.alloc` unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwards ptr/layout pairs that `alloc` produced.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: forwards the caller's ptr/layout/new_size unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: forwards the caller's layout to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}
