//! Micro-benchmark timing core (criterion is unavailable offline):
//! warmup + timed iterations, median/mean/p95 over samples, throughput
//! helper. Shared by the `kimad bench` subcommand and every file under
//! rust/benches/ (which import it through the `util::bench` shim).
// Wall-clock allowlist file (ARCHITECTURE.md §6): this layer measures
// real time by design; clippy.toml bans the methods elsewhere.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn median_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        s[s.len() / 2]
    }

    pub fn p95_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        s[((s.len() as f64 * 0.95) as usize).min(s.len() - 1)]
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>12}  mean {:>12}  p95 {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            self.samples_ns.len(),
            self.iters_per_sample
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-calibrating iteration count to ~20 ms per
/// sample; prints a criterion-style line and returns the stats.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // Warmup + calibration: find iters such that one sample ~20 ms.
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt > Duration::from_millis(20) || iters > 1 << 30 {
            break;
        }
        iters = (iters * 2).max(1);
    }

    let mut samples_ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    let res = BenchResult { name: name.to_string(), samples_ns, iters_per_sample: iters };
    println!("{}", res.report());
    res
}

/// Time one invocation of `f` (for end-to-end report generation).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("{name}: {}", fmt_ns(t0.elapsed().as_nanos() as f64));
    out
}

/// Black-box to stop the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_stats() {
        let mut acc = 0u64;
        let r = bench("noop-ish", 5, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.samples_ns.len(), 5);
        assert!(r.mean_ns() > 0.0);
        assert!(r.median_ns() > 0.0);
        assert!(r.p95_ns() >= r.median_ns() * 0.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
