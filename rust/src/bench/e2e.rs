//! End-to-end throughput: run a scenario grid through the real matrix
//! runner ([`run_matrix`]) and report cells/sec. This is the number the
//! committed baseline pins — kernel wins that do not move it are not
//! wins on the path that matters.

use std::time::Instant;

use crate::bench::report::E2eRecord;
use crate::scenarios::{run_matrix, ScenarioGrid};

/// The default 48-cell reference grid (`kimad scenarios` with no file).
pub fn default_grid() -> ScenarioGrid {
    ScenarioGrid::default_grid()
}

/// The reduced grid `--quick` runs (and full runs include, so CI's
/// quick reports always have a matching baseline entry): the same 48
/// cells at a third of the rounds.
pub fn quick_grid() -> ScenarioGrid {
    let mut g = ScenarioGrid::default_grid();
    g.name = "quick-r20".into();
    g.base.rounds = 20;
    g
}

/// Execute `grid` once on the full worker pool and summarize. Wall
/// time covers the whole matrix run (family prep included — that is
/// the end-to-end number); the summed per-cell `build_ms` is reported
/// alongside so regressions can be attributed.
pub fn run_grid(grid: &ScenarioGrid) -> anyhow::Result<E2eRecord> {
    let t0 = Instant::now();
    let summaries = run_matrix(grid, 0)?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cells = summaries.len();
    let build_ms: f64 = summaries.iter().map(|s| s.build_ms).sum();
    Ok(E2eRecord {
        grid: grid.name.clone(),
        cells,
        wall_ms,
        build_ms,
        cells_per_sec: if wall_ms > 0.0 { cells as f64 / (wall_ms / 1e3) } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_is_the_default_grid_at_fewer_rounds() {
        let q = quick_grid();
        let d = default_grid();
        assert_eq!(q.n_cells(), d.n_cells());
        assert_eq!(q.n_cells(), 48);
        assert!(q.base.rounds < d.base.rounds);
        assert_ne!(q.name, d.name, "distinct baseline keys");
    }
}
