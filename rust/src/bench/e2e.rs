//! End-to-end throughput: run a scenario grid through the real matrix
//! runner ([`run_matrix`]) and report cells/sec. This is the number the
//! committed baseline pins — kernel wins that do not move it are not
//! wins on the path that matters.
// Wall-clock allowlist file (ARCHITECTURE.md §6): this layer measures
// real time by design; clippy.toml bans the methods elsewhere.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use crate::bench::report::E2eRecord;
use crate::scenarios::{run_matrix, run_matrix_cached, CacheMode, ScenarioGrid};

/// The default 48-cell reference grid (`kimad scenarios` with no file).
pub fn default_grid() -> ScenarioGrid {
    ScenarioGrid::default_grid()
}

/// The reduced grid `--quick` runs (and full runs include, so CI's
/// quick reports always have a matching baseline entry): the same 48
/// cells at a third of the rounds.
pub fn quick_grid() -> ScenarioGrid {
    let mut g = ScenarioGrid::default_grid();
    g.name = "quick-r20".into();
    g.base.rounds = 20;
    g
}

/// The workers-scaling suite: one single-cell population grid per
/// decade of M (10² → 10⁶), each sampling a fixed ~10-client quorum so
/// wall time measures how cell cost scales with the *population* size
/// while per-round work stays constant. A flat engine (cells/sec
/// roughly equal across the three) demonstrates the O(quorum + cohorts)
/// contract; a dense engine would scale linearly in M and the m1m grid
/// would not finish.
pub fn workers_scaling_grids() -> Vec<ScenarioGrid> {
    // (tag, population, participation): each pair keeps
    // quorum = ceil(p·M) = 10.
    [("m100", 100, 0.1), ("m10k", 10_000, 1e-3), ("m1m", 1_000_000, 1e-5)]
        .into_iter()
        .map(|(tag, m, p)| {
            let mut g = ScenarioGrid::default_grid();
            g.name = format!("workers-scaling-{tag}");
            g.base.rounds = 10;
            g.workloads.truncate(1); // quad
            g.traces.truncate(1); // flat
            g.policies.retain(|pol| pol.name == "kimad");
            g.modes.truncate(1); // sync (population cells are Sync-only)
            g.worker_counts = vec![m];
            g.participations = vec![p];
            g
        })
        .collect()
}

/// Execute `grid` once on the full worker pool and summarize. Wall
/// time covers the whole matrix run (family prep included — that is
/// the end-to-end number); the summed per-cell `build_ms` is reported
/// alongside so regressions can be attributed.
pub fn run_grid(grid: &ScenarioGrid) -> anyhow::Result<E2eRecord> {
    let t0 = Instant::now();
    let summaries = run_matrix(grid, 0)?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cells = summaries.len();
    let build_ms: f64 = summaries.iter().map(|s| s.build_ms).sum();
    Ok(E2eRecord {
        grid: grid.name.clone(),
        cells,
        wall_ms,
        build_ms,
        cells_per_sec: if wall_ms > 0.0 { cells as f64 / (wall_ms / 1e3) } else { 0.0 },
    })
}

/// Execute `grid` twice over a scratch cache directory — a cold pass
/// to populate it, then a timed `--resume` pass that must hit on every
/// cell — and summarize the *resumed* pass as `<name>-resume`. This is
/// the number that keeps the content-addressed cache honest in the
/// perf baseline: warm cells/sec should sit orders of magnitude above
/// the cold row, and a probe regression (hash, parse, verify) shows up
/// here before anyone notices `--resume` got slow.
pub fn run_grid_resumed(grid: &ScenarioGrid) -> anyhow::Result<E2eRecord> {
    let dir = std::env::temp_dir()
        .join(format!("kimad-bench-resume-{}-{}", grid.name, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    run_matrix_cached(grid, 0, 0, Some(&dir), CacheMode::Fresh)?;
    let t0 = Instant::now();
    let run = run_matrix_cached(grid, 0, 0, Some(&dir), CacheMode::Resume)?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let _ = std::fs::remove_dir_all(&dir);
    anyhow::ensure!(
        run.n_executed == 0,
        "resumed grid '{}' re-executed {} of {} cells",
        grid.name,
        run.n_executed,
        run.summaries.len()
    );
    let cells = run.summaries.len();
    Ok(E2eRecord {
        grid: format!("{}-resume", grid.name),
        cells,
        wall_ms,
        // Nothing is built on a full-hit pass; the stored summaries
        // still carry the cold run's build_ms, which would misattribute.
        build_ms: 0.0,
        cells_per_sec: if wall_ms > 0.0 { cells as f64 / (wall_ms / 1e3) } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_is_the_default_grid_at_fewer_rounds() {
        let q = quick_grid();
        let d = default_grid();
        assert_eq!(q.n_cells(), d.n_cells());
        assert_eq!(q.n_cells(), 48);
        assert!(q.base.rounds < d.base.rounds);
        assert_ne!(q.name, d.name, "distinct baseline keys");
    }

    #[test]
    fn workers_scaling_grids_pin_a_fixed_quorum() {
        let grids = workers_scaling_grids();
        assert_eq!(grids.len(), 3);
        for g in &grids {
            assert_eq!(g.n_cells(), 1, "{}: one cell per grid", g.name);
            g.validate().unwrap();
            let cells = g.expand();
            let cell = &cells[0];
            assert!(cell.cfg.is_population(), "{}: must use the sampled engine", g.name);
            assert_eq!(cell.cfg.quorum(), 10, "{}: fixed 10-client quorum", g.name);
        }
        assert_eq!(grids[2].worker_counts, vec![1_000_000]);
    }

    #[test]
    fn resumed_grid_hits_every_cell() {
        let mut g = quick_grid();
        g.name = "resume-test".into();
        g.base.rounds = 4;
        g.policies.truncate(1);
        g.modes.truncate(1);
        g.worker_counts.truncate(1);
        let rec = run_grid_resumed(&g).unwrap();
        assert_eq!(rec.grid, "resume-test-resume");
        assert_eq!(rec.cells, g.n_cells());
        assert_eq!(rec.build_ms, 0.0, "a full-hit pass builds nothing");
        assert!(rec.cells_per_sec > 0.0);
    }

    #[test]
    fn million_worker_grid_runs_in_quorum_sized_time() {
        // The headline satellite check: the M = 10⁶ cell completes like
        // a small one because per-round state is O(quorum + cohorts).
        let grids = workers_scaling_grids();
        let rec = run_grid(&grids[2]).unwrap();
        assert_eq!(rec.cells, 1);
        assert!(rec.cells_per_sec > 0.0);
    }
}
