//! The `BENCH_*.json` schema: what `kimad bench` emits, what
//! `scripts/bench_check` compares, and what `BENCH_baseline.json`
//! commits. One report per run:
//!
//! ```json
//! {
//!   "commit": "…", "config": {…},
//!   "kernels": [{"name", "n", "ns_per_iter", "bytes_per_iter", "allocs"}],
//!   "e2e":     [{"grid", "cells", "wall_ms", "build_ms", "cells_per_sec"}]
//! }
//! ```
//!
//! `allocs` is the heap-allocation delta per iteration from the
//! counting allocator (exactly 0 on the buffer-reuse paths); `build_ms`
//! is per-cell construction time, excluded from `wall_ms` so
//! `cells_per_sec` is comparable warm vs cold.

use crate::util::json::Value;

/// One hot-path kernel measurement at one problem size.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRecord {
    pub name: String,
    /// Problem size (coordinates processed per iteration).
    pub n: usize,
    /// Median wall time per iteration.
    pub ns_per_iter: f64,
    /// Bytes the kernel touches per iteration (for MB/s derivation).
    pub bytes_per_iter: u64,
    /// Heap allocations per iteration (counting-allocator delta,
    /// averaged over a fixed rep loop; 0 on the reuse paths).
    pub allocs: u64,
}

/// One end-to-end scenario-grid measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct E2eRecord {
    pub grid: String,
    pub cells: usize,
    /// Steady-state wall time over the whole grid (construction
    /// excluded — see `build_ms`).
    pub wall_ms: f64,
    /// Per-cell construction/warm-up time summed over the grid.
    pub build_ms: f64,
    pub cells_per_sec: f64,
}

/// Run settings, recorded so baselines are only compared like-for-like.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchConfig {
    pub host: String,
    pub quick: bool,
    pub samples: usize,
    pub sizes: Vec<usize>,
    pub threads: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub commit: String,
    pub config: BenchConfig,
    pub kernels: Vec<KernelRecord>,
    pub e2e: Vec<E2eRecord>,
}

impl KernelRecord {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::str(&self.name)),
            ("n", Value::num(self.n as f64)),
            ("ns_per_iter", Value::num(self.ns_per_iter)),
            ("bytes_per_iter", Value::num(self.bytes_per_iter as f64)),
            ("allocs", Value::num(self.allocs as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            n: v.get("n")?.as_usize()?,
            ns_per_iter: v.get("ns_per_iter")?.as_f64()?,
            bytes_per_iter: v.get("bytes_per_iter")?.as_u64()?,
            allocs: v.get("allocs")?.as_u64()?,
        })
    }
}

impl E2eRecord {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("grid", Value::str(&self.grid)),
            ("cells", Value::num(self.cells as f64)),
            ("wall_ms", Value::num(self.wall_ms)),
            ("build_ms", Value::num(self.build_ms)),
            ("cells_per_sec", Value::num(self.cells_per_sec)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            grid: v.get("grid")?.as_str()?.to_string(),
            cells: v.get("cells")?.as_usize()?,
            wall_ms: v.get("wall_ms")?.as_f64()?,
            // Older reports may predate the build_ms split.
            build_ms: v.opt("build_ms").map_or(Ok(0.0), Value::as_f64)?,
            cells_per_sec: v.get("cells_per_sec")?.as_f64()?,
        })
    }
}

impl BenchConfig {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("host", Value::str(&self.host)),
            ("quick", Value::Bool(self.quick)),
            ("samples", Value::num(self.samples as f64)),
            (
                "sizes",
                Value::Arr(self.sizes.iter().map(|&n| Value::num(n as f64)).collect()),
            ),
            ("threads", Value::num(self.threads as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            host: v.get("host")?.as_str()?.to_string(),
            quick: v.get("quick")?.as_bool()?,
            samples: v.get("samples")?.as_usize()?,
            sizes: v
                .get("sizes")?
                .as_arr()?
                .iter()
                .map(Value::as_usize)
                .collect::<anyhow::Result<_>>()?,
            threads: v.get("threads")?.as_usize()?,
        })
    }
}

impl BenchReport {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("commit", Value::str(&self.commit)),
            ("config", self.config.to_json()),
            (
                "kernels",
                Value::Arr(self.kernels.iter().map(KernelRecord::to_json).collect()),
            ),
            (
                "e2e",
                Value::Arr(self.e2e.iter().map(E2eRecord::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            commit: v.get("commit")?.as_str()?.to_string(),
            config: BenchConfig::from_json(v.get("config")?)?,
            kernels: v
                .get("kernels")?
                .as_arr()?
                .iter()
                .map(KernelRecord::from_json)
                .collect::<anyhow::Result<_>>()?,
            e2e: v
                .get("e2e")?
                .as_arr()?
                .iter()
                .map(E2eRecord::from_json)
                .collect::<anyhow::Result<_>>()?,
        })
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        Self::from_json(&Value::parse(text)?)
    }
}

/// Short commit id of HEAD, read straight from `.git` (git may not be
/// on PATH where the bench runs); `"unknown"` outside a checkout.
pub fn current_commit() -> String {
    fn read(p: &std::path::Path) -> Option<String> {
        std::fs::read_to_string(p).ok().map(|s| s.trim().to_string())
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let git = dir.join(".git");
        if git.is_dir() {
            if let Some(head) = read(&git.join("HEAD")) {
                let sha = match head.strip_prefix("ref: ") {
                    Some(r) => read(&git.join(r.trim())).unwrap_or(head),
                    None => head,
                };
                let mut sha = sha;
                sha.truncate(12);
                if !sha.is_empty() {
                    return sha;
                }
            }
            break;
        }
        if !dir.pop() {
            break;
        }
    }
    "unknown".to_string()
}

/// Host tag for the output filename: `$KIMAD_HOST_TAG`, else the
/// kernel hostname, else `"local"`. Sanitized to `[A-Za-z0-9._-]`.
pub fn host_tag() -> String {
    let raw = std::env::var("KIMAD_HOST_TAG")
        .ok()
        .filter(|s| !s.trim().is_empty())
        .or_else(|| std::fs::read_to_string("/proc/sys/kernel/hostname").ok())
        .unwrap_or_default();
    let tag: String = raw
        .trim()
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                c
            } else {
                '-'
            }
        })
        .collect();
    if tag.is_empty() {
        "local".to_string()
    } else {
        tag
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        BenchReport {
            commit: "abc123def456".into(),
            config: BenchConfig {
                host: "ci".into(),
                quick: true,
                samples: 5,
                sizes: vec![10_000, 100_000],
                threads: 4,
            },
            kernels: vec![KernelRecord {
                name: "diff".into(),
                n: 100_000,
                ns_per_iter: 12_345.6,
                bytes_per_iter: 1_200_000,
                allocs: 0,
            }],
            e2e: vec![E2eRecord {
                grid: "quick".into(),
                cells: 48,
                wall_ms: 9_876.5,
                build_ms: 123.4,
                cells_per_sec: 4.86,
            }],
        }
    }

    #[test]
    fn report_roundtrips_through_json_text() {
        let r = sample();
        let text = r.to_json().to_string();
        let back = BenchReport::parse(&text).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn schema_has_required_keys() {
        let v = sample().to_json();
        for key in ["commit", "config", "kernels", "e2e"] {
            assert!(v.get(key).is_ok(), "missing top-level '{key}'");
        }
        let k = &v.get("kernels").unwrap().as_arr().unwrap()[0];
        for key in ["name", "n", "ns_per_iter", "bytes_per_iter", "allocs"] {
            assert!(k.get(key).is_ok(), "missing kernel '{key}'");
        }
        let e = &v.get("e2e").unwrap().as_arr().unwrap()[0];
        for key in ["grid", "cells", "wall_ms", "build_ms", "cells_per_sec"] {
            assert!(e.get(key).is_ok(), "missing e2e '{key}'");
        }
    }

    #[test]
    fn e2e_build_ms_defaults_for_old_reports() {
        let text = r#"{"grid":"quick","cells":48,"wall_ms":100.0,"cells_per_sec":480}"#;
        let e = E2eRecord::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(e.build_ms, 0.0);
    }

    #[test]
    fn host_tag_is_filename_safe() {
        let tag = host_tag();
        assert!(!tag.is_empty());
        assert!(tag
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')));
    }
}
