//! Deterministic event queue over virtual time.
//!
//! The event-driven engine schedules per-worker pipeline milestones —
//! [`EventKind::BroadcastDone`], [`EventKind::ComputeDone`],
//! [`EventKind::UploadDone`] — on a binary heap keyed by virtual
//! timestamp. This is what lets the coordinator express semi-sync and
//! fully asynchronous rounds (stragglers, partial participation) with
//! the same vocabulary as the lockstep loop.
//!
//! # Determinism guarantees
//!
//! Simulations must be bit-reproducible, so the pop order is a *total*
//! order, independent of insertion order:
//!
//! 1. earlier `time` first (`f64::total_cmp`, so the order is total
//!    even though times are floats; the engine never schedules NaN);
//! 2. ties by event kind, in pipeline order (`BroadcastDone` <
//!    `ComputeDone` < `UploadDone`);
//! 3. remaining ties by **worker index** (lowest first);
//! 4. finally by originating round (lowest first).
//!
//! Two identical runs therefore drain identical event sequences, and a
//! run's results never depend on how the heap happened to be filled.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// A per-worker pipeline milestone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The server's broadcast finished arriving at the worker.
    BroadcastDone,
    /// The worker's gradient computation finished.
    ComputeDone,
    /// The worker's compressed upload finished arriving at the server.
    UploadDone,
}

impl EventKind {
    /// Pipeline rank used for tie-breaking (see module docs).
    fn rank(self) -> u8 {
        match self {
            EventKind::BroadcastDone => 0,
            EventKind::ComputeDone => 1,
            EventKind::UploadDone => 2,
        }
    }
}

/// One scheduled milestone on the virtual timeline.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Absolute virtual time the milestone completes.
    pub time: f64,
    /// Worker the milestone belongs to.
    pub worker: usize,
    pub kind: EventKind,
    /// Server round whose broadcast started this worker's chain (late
    /// uploads keep the round they were computed for).
    pub round: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then_with(|| self.kind.rank().cmp(&other.kind.rank()))
            .then_with(|| self.worker.cmp(&other.worker))
            .then_with(|| self.round.cmp(&other.round))
    }
}

/// Min-heap of [`Event`]s over virtual time with the module-level
/// deterministic total order.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, ev: Event) {
        debug_assert!(ev.time.is_finite(), "event time must be finite");
        self.heap.push(Reverse(ev));
    }

    /// Pop the earliest event (ties per the documented total order).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(ev)| ev)
    }

    /// The earliest event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|Reverse(ev)| ev)
    }

    /// Pop the earliest event **plus every queued event sharing its
    /// timestamp and kind** into `out` (cleared first), returning the
    /// batch size (0 when the queue is empty).
    ///
    /// Because the pop order is total, the batch comes out in ascending
    /// worker order — the same sequence `pop` would produce — so batch
    /// handling is a pure regrouping of the serialized drain. This is
    /// what lets the coordinator hand a whole timestamp's upload
    /// arrivals to the sharded server path in one fan-out.
    pub fn pop_batch_into(&mut self, out: &mut Vec<Event>) -> usize {
        out.clear();
        let Some(first) = self.pop() else {
            return 0;
        };
        out.push(first);
        while let Some(next) = self.peek() {
            if next.time.total_cmp(&first.time) != Ordering::Equal || next.kind != first.kind {
                break;
            }
            let ev = self.pop().expect("peeked event must pop");
            out.push(ev);
        }
        out.len()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, worker: usize, kind: EventKind) -> Event {
        Event { time, worker, kind, round: 0 }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ev(3.0, 0, EventKind::UploadDone));
        q.push(ev(1.0, 1, EventKind::BroadcastDone));
        q.push(ev(2.0, 2, EventKind::ComputeDone));
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().time, 1.0);
        assert_eq!(q.pop().unwrap().time, 2.0);
        assert_eq!(q.pop().unwrap().time, 3.0);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_by_kind_then_worker() {
        let mut q = EventQueue::new();
        q.push(ev(1.0, 2, EventKind::BroadcastDone));
        q.push(ev(1.0, 0, EventKind::UploadDone));
        q.push(ev(1.0, 1, EventKind::BroadcastDone));
        q.push(ev(1.0, 0, EventKind::ComputeDone));
        let order: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|e| (e.kind, e.worker))
            .collect();
        assert_eq!(
            order,
            vec![
                (EventKind::BroadcastDone, 1),
                (EventKind::BroadcastDone, 2),
                (EventKind::ComputeDone, 0),
                (EventKind::UploadDone, 0),
            ]
        );
    }

    #[test]
    fn order_is_insertion_independent() {
        let mut events = vec![
            ev(2.0, 1, EventKind::ComputeDone),
            ev(1.0, 3, EventKind::UploadDone),
            ev(1.0, 0, EventKind::UploadDone),
            ev(0.5, 2, EventKind::BroadcastDone),
            ev(2.0, 1, EventKind::UploadDone),
        ];
        let mut a = EventQueue::new();
        for &e in &events {
            a.push(e);
        }
        events.reverse();
        let mut b = EventQueue::new();
        for &e in &events {
            b.push(e);
        }
        while let Some(x) = a.pop() {
            assert_eq!(x, b.pop().unwrap());
        }
        assert!(b.is_empty());
    }

    #[test]
    fn pop_batch_groups_same_time_and_kind() {
        let mut q = EventQueue::new();
        q.push(ev(1.0, 2, EventKind::UploadDone));
        q.push(ev(1.0, 0, EventKind::UploadDone));
        q.push(ev(1.0, 1, EventKind::ComputeDone));
        q.push(ev(2.0, 0, EventKind::UploadDone));
        let mut batch = Vec::new();
        // Same time, earlier kind first: the ComputeDone is its own
        // batch of one.
        assert_eq!(q.pop_batch_into(&mut batch), 1);
        assert_eq!(batch[0].kind, EventKind::ComputeDone);
        // Then both t=1 uploads, worker-ascending.
        assert_eq!(q.pop_batch_into(&mut batch), 2);
        assert_eq!(
            batch.iter().map(|e| e.worker).collect::<Vec<_>>(),
            vec![0, 2],
            "batches come out in worker order"
        );
        assert!(batch.iter().all(|e| e.kind == EventKind::UploadDone && e.time == 1.0));
        // The t=2 upload is not merged across timestamps.
        assert_eq!(q.pop_batch_into(&mut batch), 1);
        assert_eq!(batch[0].time, 2.0);
        assert_eq!(q.pop_batch_into(&mut batch), 0);
        assert!(batch.is_empty());
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(ev(5.0, 0, EventKind::ComputeDone));
        q.push(ev(4.0, 1, EventKind::BroadcastDone));
        assert_eq!(q.peek().unwrap().time, 4.0);
        assert_eq!(q.pop().unwrap().worker, 1);
        q.clear();
        assert!(q.peek().is_none());
    }
}
