//! Virtual-time Parameter-Server network simulator.
//!
//! The paper's evaluation is "simulation-based, running as a Parameter
//! Server architecture with dynamic asymmetric bandwidth" (§4). This
//! module is that substrate: each worker has an independent asymmetric
//! link (uplink + downlink traces), transfers advance a *virtual clock*
//! (deterministic — no wall-clock noise), and the broadcast congestion
//! coefficient `alpha` of §3.1 scales the downlink.
//!
//! A synchronous PS round is:
//!   server broadcast (downlink, per worker) -> worker compute
//!   -> worker upload (uplink) -> round time = max over workers.
//!
//! [`events`] adds the deterministic virtual-time event queue the
//! coordinator's semi-sync and asynchronous execution modes schedule
//! per-worker `BroadcastDone` / `ComputeDone` / `UploadDone` milestones
//! on.

pub mod events;

pub use events::{Event, EventKind, EventQueue};

use std::sync::Arc;

use crate::bandwidth::BandwidthTrace;

/// Direction of a transfer on a worker link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server -> worker (broadcast).
    Down,
    /// Worker -> server (upload).
    Up,
}

/// One worker's asymmetric link.
///
/// The traces are held by shared handle: traces are immutable, so a
/// scenario cell family can build each per-worker trace once and
/// assemble every member cell's `NetSim` from `Arc` clones of the same
/// allocation (`driver::WarmFamily`) — bit-identical to building fresh
/// traces from the spec, since construction is deterministic.
pub struct Link {
    pub up: Arc<dyn BandwidthTrace>,
    pub down: Arc<dyn BandwidthTrace>,
}

impl Link {
    pub fn new(up: Arc<dyn BandwidthTrace>, down: Arc<dyn BandwidthTrace>) -> Self {
        Self { up, down }
    }
}

/// Result of simulating one transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub bits: f64,
    pub start: f64,
    pub seconds: f64,
    /// The link's instantaneous (nominal) rate at `start` — the rate a
    /// zero-duration transfer is attributed to.
    pub nominal_bps: f64,
}

impl Transfer {
    pub fn end(&self) -> f64 {
        self.start + self.seconds
    }

    /// Rate this transfer achieved. Zero-duration transfers (e.g. a
    /// zero-bit message) report the link's nominal rate instead of
    /// `inf`/`NaN`, which would otherwise poison any EWMA bandwidth
    /// monitor fed from observed transfers.
    pub fn observed_bps(&self) -> f64 {
        if self.seconds > 0.0 {
            self.bits / self.seconds
        } else {
            self.nominal_bps
        }
    }
}

/// The simulated network: M asymmetric links + broadcast congestion.
///
/// # The α asymmetry
///
/// `alpha` scales the **downlink only** — deliberately, and in every
/// bandwidth view this module exposes ([`true_bps`](Self::true_bps),
/// [`window_bps`](Self::window_bps), [`transfer`](Self::transfer)
/// agree, so a monitor fed from any of them sees one consistent
/// world). §3.1 defines α as the *broadcast congestion* coefficient:
/// the server fans one model message out to all M workers at once, so
/// each downlink sees a 1/α share of its nominal rate. Uploads are
/// independent unicast flows from M distinct endpoints — there is no
/// shared broadcast bottleneck on the way up, so `Direction::Up` is
/// never divided by α.
pub struct NetSim {
    links: Vec<Link>,
    /// Broadcast congestion coefficient `alpha` (§3.1): downlink time is
    /// `alpha * bits / B_down`. The paper sets alpha = 1 (§4.2).
    pub alpha: f64,
}

impl NetSim {
    pub fn new(links: Vec<Link>) -> Self {
        Self { links, alpha: 1.0 }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        assert!(alpha > 0.0);
        self.alpha = alpha;
        self
    }

    pub fn n_workers(&self) -> usize {
        self.links.len()
    }

    /// Worker `m`'s link (read-only: lets tests assert that a
    /// family-assembled netsim really shares its trace handles via
    /// `Arc::ptr_eq`).
    pub fn link(&self, worker: usize) -> &Link {
        &self.links[worker]
    }

    /// Ground-truth instantaneous bandwidth (for plots / oracles only —
    /// the coordinator must go through a `BandwidthMonitor`).
    pub fn true_bps(&self, worker: usize, dir: Direction, t: f64) -> f64 {
        let link = &self.links[worker];
        match dir {
            Direction::Up => link.up.at(t),
            Direction::Down => link.down.at(t) / self.alpha,
        }
    }

    /// Trailing-window average bandwidth ending at `t` — what a
    /// NIC-counter monitor actually reports (feeds the monitors). Like
    /// [`true_bps`](Self::true_bps) and [`transfer`](Self::transfer),
    /// the broadcast congestion α divides the downlink only (see the
    /// type docs for why the asymmetry is correct).
    pub fn window_bps(&self, worker: usize, dir: Direction, t: f64, window: f64) -> f64 {
        let t0 = (t - window).max(0.0);
        let span = (t - t0).max(1e-9);
        let link = &self.links[worker];
        match dir {
            Direction::Up => link.up.integrate(t0, t) / span,
            Direction::Down => link.down.integrate(t0, t) / span / self.alpha,
        }
    }

    /// Simulate transferring `bits` on `worker`'s link starting at
    /// virtual time `start`; returns the completed transfer record.
    pub fn transfer(&self, worker: usize, dir: Direction, start: f64, bits: f64) -> Transfer {
        let link = &self.links[worker];
        let seconds = match dir {
            Direction::Up => link.up.transfer_time(start, bits),
            // alpha scales *time*, equivalent to dividing bandwidth.
            Direction::Down => self.alpha * link.down.transfer_time(start, bits),
        };
        Transfer { bits, start, seconds, nominal_bps: self.true_bps(worker, dir, start) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandwidth::{ConstantTrace, SinSquaredTrace};

    fn sim2() -> NetSim {
        NetSim::new(vec![
            Link::new(
                Arc::new(ConstantTrace::new(100.0)),
                Arc::new(ConstantTrace::new(200.0)),
            ),
            Link::new(
                Arc::new(SinSquaredTrace::new(50.0, 1.0, 10.0)),
                Arc::new(ConstantTrace::new(50.0)),
            ),
        ])
    }

    #[test]
    fn constant_transfer_time() {
        let sim = sim2();
        let tr = sim.transfer(0, Direction::Up, 0.0, 1000.0);
        assert!((tr.seconds - 10.0).abs() < 1e-9);
        assert!((tr.end() - 10.0).abs() < 1e-9);
        assert!((tr.observed_bps() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn asymmetric_links_differ() {
        let sim = sim2();
        let up = sim.transfer(0, Direction::Up, 0.0, 1000.0);
        let down = sim.transfer(0, Direction::Down, 0.0, 1000.0);
        assert!(down.seconds < up.seconds);
    }

    #[test]
    fn alpha_scales_downlink_only() {
        let sim = sim2().with_alpha(2.0);
        let down = sim.transfer(0, Direction::Down, 0.0, 1000.0);
        assert!((down.seconds - 10.0).abs() < 1e-9); // 2 * 1000/200
        let up = sim.transfer(0, Direction::Up, 0.0, 1000.0);
        assert!((up.seconds - 10.0).abs() < 1e-9); // unchanged
        assert!((sim.true_bps(0, Direction::Down, 0.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn window_bps_divides_downlink_by_alpha_only() {
        // α is broadcast congestion (§3.1): the shared fan-out divides
        // every downlink's share, while uploads are independent unicast
        // flows — so the windowed monitor view must scale Down and
        // leave Up untouched, consistently with true_bps and transfer.
        let sim = sim2().with_alpha(2.0);
        // Constant 100 bps uplink: trailing mean unaffected by α.
        assert!((sim.window_bps(0, Direction::Up, 10.0, 5.0) - 100.0).abs() < 1e-9);
        assert!((sim.true_bps(0, Direction::Up, 10.0) - 100.0).abs() < 1e-9);
        // Constant 200 bps downlink: both views report 200 / α = 100.
        assert!((sim.window_bps(0, Direction::Down, 10.0, 5.0) - 100.0).abs() < 1e-9);
        assert!((sim.true_bps(0, Direction::Down, 10.0) - 100.0).abs() < 1e-9);
        // α = 1 (the paper's §4.2 setting) is the identity on both.
        let plain = sim2();
        assert!((plain.window_bps(0, Direction::Up, 10.0, 5.0) - 100.0).abs() < 1e-9);
        assert!((plain.window_bps(0, Direction::Down, 10.0, 5.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_transfer_reports_nominal_rate() {
        // Regression: a zero-bit (zero-duration) transfer used to
        // report observed_bps = inf, which poisoned EWMA monitors fed
        // from observed transfers.
        let sim = sim2();
        let tr = sim.transfer(0, Direction::Up, 0.0, 0.0);
        assert_eq!(tr.seconds, 0.0);
        assert!(tr.observed_bps().is_finite());
        assert!((tr.observed_bps() - 100.0).abs() < 1e-9);
        // The downlink nominal rate folds in the congestion alpha.
        let sim = sim2().with_alpha(2.0);
        let tr = sim.transfer(0, Direction::Down, 0.0, 0.0);
        assert!((tr.observed_bps() - 100.0).abs() < 1e-9);
        // Feeding the clamped observation into a monitor keeps it sane.
        use crate::bandwidth::{BandwidthMonitor, EwmaMonitor};
        let mut m = EwmaMonitor::new(0.5);
        m.observe(1.0, 1.0 / tr.observed_bps());
        assert!(m.estimate_bps().unwrap().is_finite());
    }

    #[test]
    fn varying_trace_transfer_consistent() {
        let sim = sim2();
        let tr = sim.transfer(1, Direction::Up, 2.0, 500.0);
        // Inverse relation: integrating the trace over the transfer
        // window must recover the bits.
        let got = sim.links[1].up.integrate(2.0, 2.0 + tr.seconds);
        assert!((got - 500.0).abs() / 500.0 < 1e-3);
    }
}
