//! Shim: the micro-bench timing core moved to [`crate::bench::timing`]
//! so the `kimad bench` subcommand and rust/benches/ share one
//! implementation. Re-exported here to keep existing
//! `kimad::util::bench::{bench, black_box, fmt_ns, ...}` imports
//! compiling.

pub use crate::bench::timing::*;
