//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Bit-stable across platforms and rust versions (documented update
//! functions, no FP in the core), which is what makes every simulation
//! in this repo exactly reproducible from its seed.

/// SplitMix64 — used for seeding and cheap hash-like streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** (Blackman & Vigna) — the workhorse generator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream (e.g. per worker / per round).
    pub fn derive(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [lo, hi) — rejection-free Lemire reduction.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range_usize(0, i + 1);
            v.swap(i, j);
        }
    }

    /// `k` distinct indices from 0..n (partial Fisher–Yates), O(n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.sample_indices_into(n, k, &mut out);
        out
    }

    /// [`sample_indices`](Self::sample_indices) into a reused buffer —
    /// the single implementation both paths share, so the sampling
    /// stream can never diverge between them.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        let k = k.min(n);
        out.clear();
        out.extend(0..n as u32);
        for i in 0..k {
            let j = self.range_usize(i, n);
            out.swap(i, j);
        }
        out.truncate(k);
    }

    /// `k` distinct indices from `0..n`, ascending, via Floyd's
    /// algorithm — O(k) draws and O(k) memory, no O(n) scratch, which
    /// is what lets a million-client population sample a thousand-client
    /// quorum per round without ever materializing `0..n`.
    ///
    /// Exactly `k.min(n)` values are drawn from the stream, so the
    /// result is a pure function of (rng state, n, k) — independent of
    /// thread or shard counts by construction.
    pub fn sample_distinct_sorted_into(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        debug_assert!(n <= u32::MAX as usize, "population exceeds u32 index space");
        let k = k.min(n);
        out.clear();
        out.reserve(k);
        for j in n - k..n {
            let t = self.range_usize(0, j + 1) as u32;
            match out.binary_search(&t) {
                // Collision: take j itself. Every element already in
                // `out` came from an earlier (smaller) j, so j is new
                // and larger than all of them — push keeps order.
                Ok(_) => out.push(j as u32),
                Err(pos) => out.insert(pos, t),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_usize_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.range_usize(3, 13);
            assert!((3..13).contains(&v));
            seen[v - 3] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(13);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 100));
        // k > n clamps
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }

    #[test]
    fn sample_indices_into_matches_allocating() {
        let mut a = Rng::seed_from_u64(3);
        let mut b = Rng::seed_from_u64(3);
        let mut buf = Vec::new();
        for &(n, k) in &[(10usize, 3usize), (50, 50), (7, 0), (100, 99)] {
            b.sample_indices_into(n, k, &mut buf);
            assert_eq!(a.sample_indices(n, k), buf, "n={n} k={k}");
        }
    }

    #[test]
    fn sample_distinct_sorted_is_sorted_distinct_in_range() {
        let mut r = Rng::seed_from_u64(17);
        let mut out = Vec::new();
        for &(n, k) in &[(100usize, 20usize), (1, 1), (5, 5), (1_000_000, 37), (8, 0)] {
            r.sample_distinct_sorted_into(n, k, &mut out);
            assert_eq!(out.len(), k.min(n), "n={n} k={k}");
            assert!(out.windows(2).all(|w| w[0] < w[1]), "n={n} k={k}: {out:?}");
            assert!(out.iter().all(|&i| (i as usize) < n));
        }
        // k > n clamps to a full (sorted) enumeration.
        r.sample_distinct_sorted_into(4, 10, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn sample_distinct_sorted_deterministic_and_covers() {
        let mut a = Rng::seed_from_u64(23);
        let mut b = Rng::seed_from_u64(23);
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        for _ in 0..50 {
            a.sample_distinct_sorted_into(1000, 13, &mut oa);
            b.sample_distinct_sorted_into(1000, 13, &mut ob);
            assert_eq!(oa, ob);
        }
        // Over many rounds every residue class should get hit: the
        // sampler is not stuck in a corner of the index space.
        let mut r = Rng::seed_from_u64(29);
        let mut seen = [false; 10];
        let mut out = Vec::new();
        for _ in 0..200 {
            r.sample_distinct_sorted_into(10, 3, &mut out);
            for &i in &out {
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(15);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn derive_streams_independent() {
        let base = Rng::seed_from_u64(1);
        let mut a = base.derive(0);
        let mut b = base.derive(1);
        assert_ne!(a.next_u64(), b.next_u64());
        let mut a2 = base.derive(0);
        let mut a3 = base.derive(0);
        assert_eq!(a2.next_u64(), a3.next_u64());
    }
}
