//! Tiny CLI argument parser: `--flag`, `--key value`, positionals.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse, treating names in `flag_names` as boolean flags (no value).
    pub fn parse(argv: &[String], flag_names: &[&str]) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?;
                    out.options.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v}: {e}")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_parsing() {
        let a = Args::parse(
            &sv(&["report", "--out", "dir", "--fast", "--k=3", "fig8"]),
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["report", "fig8"]);
        assert_eq!(a.opt("out"), Some("dir"));
        assert_eq!(a.opt("k"), Some("3"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn typed_options() {
        let a = Args::parse(&sv(&["--x", "2.5", "--n", "7"]), &[]).unwrap();
        assert_eq!(a.opt_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.opt_usize("n", 0).unwrap(), 7);
        assert_eq!(a.opt_f64("missing", 1.5).unwrap(), 1.5);
        assert!(Args::parse(&sv(&["--x", "abc"]), &[])
            .unwrap()
            .opt_f64("x", 0.0)
            .is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--out"]), &[]).is_err());
    }
}
