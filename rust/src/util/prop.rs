//! Property-testing loop (proptest is unavailable offline): run a
//! closure over N seeded random cases; on failure report the seed so
//! the case replays exactly.

use super::rng::Rng;

/// Run `f(case_rng)` for `cases` deterministic random cases derived
/// from `seed`. Panics with the failing case index + derived seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, seed: u64, cases: usize, mut f: F) {
    let base = Rng::seed_from_u64(seed);
    for case in 0..cases {
        let mut rng = base.derive(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: seed={seed}, derive({case})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 1, 50, |rng| {
            let a = rng.range_f64(-10.0, 10.0);
            let b = rng.range_f64(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 2, 10, |_| panic!("boom"));
    }
}
