//! Minimal JSON: a recursive-descent parser + serializer over a
//! [`Value`] enum. Covers the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bool, null); used for
//! artifacts/manifest.json, layout JSON, and experiment configs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> anyhow::Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> anyhow::Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key '{key}'")),
            _ => anyhow::bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => anyhow::bail!("not a number"),
        }
    }

    pub fn as_u64(&self) -> anyhow::Result<u64> {
        let f = self.as_f64()?;
        anyhow::ensure!(f >= 0.0 && f.fract() == 0.0, "not a u64: {f}");
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => anyhow::bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => anyhow::bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => anyhow::bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => anyhow::bail!("not an object"),
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    // -- serialization (via Display: `value.to_string()`) --------------

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek()? == c,
            "expected '{}' at byte {}, found '{}'",
            c as char,
            self.i,
            self.b[self.i] as char
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> anyhow::Result<Value> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        anyhow::ensure!(start + len <= self.b.len(), "bad utf8");
                        s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap());
        assert_eq!(v.get("b").unwrap().get("d").unwrap(), &Value::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Value::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse(r#""héllo → ∞""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2,3],"num":1.5,"s":"x\ny","t":true}"#;
        let v = Value::parse(src).unwrap();
        let back = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("123abc").is_err());
        assert!(Value::parse(r#"{"a":1} extra"#).is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Value::parse(r#"{"a": 1.5}"#).unwrap();
        assert!(v.get("b").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(v.get("a").unwrap().as_u64().is_err()); // fractional
        assert!(v.as_f64().is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(Default::default()));
        assert_eq!(Value::parse("[]").unwrap().to_string(), "[]");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Value::Num(21.0).to_string(), "21");
        assert_eq!(Value::Num(2.5).to_string(), "2.5");
    }
}
