//! Chunked elementwise kernels for the hot paths (the EF21 diff fills,
//! the quantizer's max-abs scale scan).
//!
//! # The fixed-reduction-order rule
//!
//! Every optimization here must keep simulations bit-identical to the
//! frozen serial loops (`Simulation::round_reference` is the golden),
//! so only two shapes of loop may be chunked:
//!
//! * **elementwise maps** (`out[i] = a[i] - b[i]`): each output depends
//!   on exactly one input index, so any block structure visits the same
//!   operations in the same per-element order — identical bits for
//!   every chunk size;
//! * **associative reductions over f32 `max`** (the quantizer's max-abs
//!   scale): `f32::max` is associative and commutative over the
//!   non-negative absolute values it sees here, so regrouping per chunk
//!   cannot change the result.
//!
//! Non-associative accumulations — every f32/f64 **sum** on the hot
//! path (aggregate norms, compression errors, `OneBitSign`'s mean) —
//! stay strictly serial in their original order and must never route
//! through this module. Tests assert bit-identity against the naive
//! serial forms across chunk sizes on randomized inputs.
//!
//! The fixed [`CHUNK`] width gives the optimizer short inner loops with
//! a known trip count (unroll + vectorize) while the `_chunked` forms
//! keep the width testable.

/// Block width of the production entry points. 64 f32s = one 256-byte
/// block — enough for full vector unrolling, small enough to stay in
/// registers/L1.
pub const CHUNK: usize = 64;

/// `out[i] = a[i] − b[i]` over the common prefix of the three slices
/// (like the `zip` loops it replaces, extra tail elements are left
/// untouched). Bit-identical to the serial loop for every chunk width.
#[inline]
pub fn diff_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    diff_into_chunked(out, a, b, CHUNK);
}

/// [`diff_into`] with an explicit block width (test hook).
// tidy:alloc-free(diff)
pub fn diff_into_chunked(out: &mut [f32], a: &[f32], b: &[f32], chunk: usize) {
    let chunk = chunk.max(1);
    for ((oc, ac), bc) in out
        .chunks_mut(chunk)
        .zip(a.chunks(chunk))
        .zip(b.chunks(chunk))
    {
        for ((o, &x), &y) in oc.iter_mut().zip(ac).zip(bc) {
            *o = x - y;
        }
    }
}

/// The per-message quantization scale `max_i |u_i|`, chunked.
/// Bit-identical to the serial fold: `max` over the non-negative
/// `|u_i|` is associative, so per-chunk partials regroup freely.
#[inline]
pub fn max_abs(u: &[f32]) -> f32 {
    max_abs_chunked(u, CHUNK)
}

/// [`max_abs`] with an explicit block width (test hook).
pub fn max_abs_chunked(u: &[f32], chunk: usize) -> f32 {
    let chunk = chunk.max(1);
    let mut m = 0.0f32;
    for c in u.chunks(chunk) {
        let mut cm = 0.0f32;
        for &v in c {
            cm = cm.max(v.abs());
        }
        m = m.max(cm);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The chunked kernels vs the frozen serial forms, across chunk
    /// widths (including widths that do and do not divide the length)
    /// on randomized inputs — the bit-identity contract.
    #[test]
    fn chunked_kernels_match_serial_bitwise() {
        let mut rng = Rng::seed_from_u64(17);
        for len in [0usize, 1, 7, 63, 64, 65, 200, 1023] {
            let a: Vec<f32> = (0..len).map(|_| rng.range_f32(-10.0, 10.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.range_f32(-10.0, 10.0)).collect();
            let mut want = vec![0.0f32; len];
            for (d, (&x, &y)) in want.iter_mut().zip(a.iter().zip(&b)) {
                *d = x - y;
            }
            let want_max = b.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for chunk in [1usize, 2, 3, 7, 16, 64, 101, 4096] {
                let mut got = vec![f32::NAN; len];
                diff_into_chunked(&mut got, &a, &b, chunk);
                let same = got
                    .iter()
                    .zip(&want)
                    .all(|(g, w)| g.to_bits() == w.to_bits());
                assert!(same, "diff len={len} chunk={chunk}");
                let gm = max_abs_chunked(&b, chunk);
                assert_eq!(gm.to_bits(), want_max.to_bits(), "max len={len} chunk={chunk}");
            }
            // The production entry points are the CHUNK-width forms.
            let mut got = vec![0.0f32; len];
            diff_into(&mut got, &a, &b);
            assert_eq!(got, want);
            assert_eq!(max_abs(&b).to_bits(), want_max.to_bits());
        }
    }

    #[test]
    fn diff_stops_at_shortest_like_zip() {
        let a = [5.0f32, 6.0, 7.0];
        let b = [1.0f32, 1.0];
        let mut out = [f32::NAN; 4];
        diff_into(&mut out, &a, &b);
        assert_eq!(&out[..2], &[4.0, 5.0]);
        assert!(out[2].is_nan() && out[3].is_nan(), "tail untouched");
    }

    #[test]
    fn max_abs_edge_cases() {
        assert_eq!(max_abs(&[]), 0.0);
        assert_eq!(max_abs(&[-0.0]), 0.0);
        assert_eq!(max_abs(&[-3.5, 2.0]), 3.5);
    }
}
