//! In-repo substrates (the build is fully offline, so these replace the
//! usual crates): deterministic RNG, JSON, CLI parsing, a micro-bench
//! harness, and a property-testing loop.

pub mod bench;
pub mod chunk;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
