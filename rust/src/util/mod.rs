//! In-repo substrates (the build is fully offline, so these replace the
//! usual crates): deterministic RNG, JSON, CLI parsing, a micro-bench
//! harness, a property-testing loop, SHA-256 content addressing, and
//! atomic file publication.

pub mod atomicfile;
pub mod bench;
pub mod chunk;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
