//! Atomic artifact writes: stage to a hidden temp file in the target
//! directory, then `rename` over the destination. A crash or kill at
//! any instant leaves either the previous file or the new one — never
//! a truncated JSON — which is what makes the scenario matrix's
//! incremental `index.json` and per-cell summaries safe to resume
//! from (docs/ARCHITECTURE.md §11).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-process sequence so concurrent writers to *different* paths in
/// the same directory never collide on a temp name.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` atomically (tmp file + rename), creating
/// parent directories as needed. The rename is atomic on the same
/// filesystem, which the same-directory temp file guarantees.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    std::fs::create_dir_all(&parent)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", parent.display()))?;
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow::anyhow!("write_atomic: no file name in {}", path.display()))?;
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = parent.join(format!(".{name}.{}.{seq}.tmp", std::process::id()));
    std::fs::write(&tmp, bytes).map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        // Leave no droppings behind a failed publish.
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("renaming {} -> {}: {e}", tmp.display(), path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_create_dirs_and_replace_existing() {
        let dir = std::env::temp_dir().join(format!("kimad-atomic-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/out.json");
        write_atomic(&path, b"{\"v\":1}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"v\":1}");
        // Overwrite is atomic replace, not append.
        write_atomic(&path, b"{\"v\":2}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"v\":2}");
        // No temp droppings remain next to the target.
        let leftovers: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_to_distinct_files_never_collide() {
        let dir = std::env::temp_dir().join(format!("kimad-atomic-par-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::thread::scope(|s| {
            for t in 0..4 {
                let dir = dir.clone();
                s.spawn(move || {
                    for i in 0..16 {
                        let p = dir.join(format!("f{t}-{i}.json"));
                        write_atomic(&p, format!("{{\"t\":{t},\"i\":{i}}}").as_bytes()).unwrap();
                    }
                });
            }
        });
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(n, 64, "every file published, no temp leftovers");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
